"""Fused single-launch construction pipeline: parity + launch accounting.

The contract under test (ISSUE 4 acceptance criteria):

* the fused build is bit-identical to the ``build_hierarchy`` oracle —
  values, leftmost-tie positions, and padding — across ragged geometries
  (``n % c != 0``, ``capacity > n``, single-level plans, positions
  on/off, f32/f64);
* every index implementation (``RMQ``, ``StreamingRMQ``,
  ``HybridRMQ.from_hierarchy``, ``DistributedRMQ``) builds through the
  one shared pipeline and answers identically regardless of the
  construction backend;
* the fused path issues exactly ONE kernel launch per build (the
  per-level path issues one per upper level), asserted via the
  trace-time launch counter.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import RMQ, build_hierarchy, build_many, make_plan
from repro.core.distributed import DistributedRMQ
from repro.core.hybrid import HybridRMQ
from repro.core.protocol import resolve_backend, runtime_backend
from repro.kernels.hierarchy_build.ops import build_hierarchy_pallas
from repro.kernels.hierarchy_fused.ops import build_hierarchy_fused
from repro.kernels.hierarchy_fused.ref import fused_build_ref
from repro.kernels.profiling import count_launches
from repro.streaming import StreamingRMQ

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st


def _tied_input(rng, n, dtype=np.float32):
    """Random values with deliberate ties so leftmost-position breaks
    are actually exercised."""
    x = rng.random(n).astype(dtype)
    x[rng.integers(0, n, max(n // 8, 1))] = 0.5
    return x


def _assert_hierarchies_identical(h_ref, h_got):
    np.testing.assert_array_equal(
        np.asarray(h_ref.base), np.asarray(h_got.base)
    )
    np.testing.assert_array_equal(
        np.asarray(h_ref.upper), np.asarray(h_got.upper)
    )
    assert h_ref.with_positions == h_got.with_positions
    if h_ref.with_positions:
        assert h_ref.upper_pos.dtype == h_got.upper_pos.dtype
        np.testing.assert_array_equal(
            np.asarray(h_ref.upper_pos), np.asarray(h_got.upper_pos)
        )


# geometries: ragged tails, reserved capacity, single-level, deep plans
GEOMETRIES = [
    (1000, 8, 2, None),     # n % c != 0
    (4096, 8, 2, 8192),     # capacity > n (aligned)
    (999, 2, 1, 1500),      # ragged + ragged capacity, 10 upper levels
    (12_345, 16, 4, None),  # ragged, mid-depth
    (700, 128, 64, None),   # single-level plan (n <= c*t): no launch
    (300, 16, 2, 1000),     # capacity-derived levels from a tiny n
]


class TestFusedBuildParity:
    @pytest.mark.parametrize("n,c,t,cap", GEOMETRIES)
    @pytest.mark.parametrize("with_pos", [False, True])
    def test_matches_oracle_and_per_level(self, n, c, t, cap, with_pos):
        rng = np.random.default_rng(n + c)
        x = jnp.asarray(_tied_input(rng, n))
        plan = make_plan(n, c=c, t=t, capacity=cap)
        h_ref = build_hierarchy(x, plan, with_positions=with_pos)
        h_fused = build_hierarchy_fused(
            x, plan, with_positions=with_pos, interpret=True
        )
        h_level = build_hierarchy_pallas(
            x, plan, with_positions=with_pos, interpret=True
        )
        _assert_hierarchies_identical(h_ref, h_fused)
        _assert_hierarchies_identical(h_ref, h_level)
        # the package's pure-jnp ref oracle agrees too
        u, p = fused_build_ref(h_ref.base, plan, with_positions=with_pos)
        np.testing.assert_array_equal(
            np.asarray(h_ref.upper), np.asarray(u)
        )
        if with_pos:
            np.testing.assert_array_equal(
                np.asarray(h_ref.upper_pos), np.asarray(p)
            )

    @pytest.mark.parametrize("n,c,t,cap", [(777, 4, 2, 1024)])
    def test_f64_parity(self, n, c, t, cap):
        """x64 mode: f64 values with int64 positions, all backends."""
        with jax.experimental.enable_x64():
            rng = np.random.default_rng(7)
            x = jnp.asarray(_tied_input(rng, n, np.float64))
            assert x.dtype == jnp.float64
            plan = make_plan(n, c=c, t=t, capacity=cap)
            h_ref = build_hierarchy(x, plan, with_positions=True)
            assert h_ref.upper.dtype == jnp.float64
            h_fused = build_hierarchy_fused(
                x, plan, with_positions=True, interpret=True
            )
            h_level = build_hierarchy_pallas(
                x, plan, with_positions=True, interpret=True
            )
            _assert_hierarchies_identical(h_ref, h_fused)
            _assert_hierarchies_identical(h_ref, h_level)

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis")
    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=3000),
        log_c=st.integers(min_value=1, max_value=6),
        t=st.integers(min_value=1, max_value=8),
        headroom=st.integers(min_value=0, max_value=500),
        with_pos=st.booleans(),
    )
    def test_property_random_geometry(self, n, log_c, t, headroom,
                                      with_pos):
        c = 2 ** log_c
        rng = np.random.default_rng(n * 31 + c)
        x = jnp.asarray(_tied_input(rng, n))
        plan = make_plan(n, c=c, t=t, capacity=n + headroom)
        h_ref = build_hierarchy(x, plan, with_positions=with_pos)
        h_fused = build_hierarchy_fused(
            x, plan, with_positions=with_pos, interpret=True
        )
        _assert_hierarchies_identical(h_ref, h_fused)


class TestLaunchAccounting:
    def test_fused_is_one_launch_per_level_is_many(self):
        # a geometry no other test builds, so tracing is fresh here
        n, c, t = 4999, 8, 4
        plan = make_plan(n, c=c, t=t)
        assert plan.num_levels == 4  # 3 upper levels
        x = jnp.asarray(np.random.default_rng(0).random(n, np.float32))
        with count_launches() as fused:
            build_hierarchy_fused(x, plan, interpret=True)
        assert fused == {"hierarchy_fused": 1}
        with count_launches() as per_level:
            build_hierarchy_pallas(x, plan, interpret=True)
        assert per_level == {"hierarchy_build": plan.num_levels - 1}

    def test_single_level_plan_launches_nothing(self):
        plan = make_plan(701, c=128, t=64)
        assert plan.num_levels == 1
        x = jnp.asarray(np.random.default_rng(1).random(701, np.float32))
        with count_launches() as counts:
            h = build_hierarchy_fused(x, plan, interpret=True)
        assert counts == {}
        assert h.upper.shape == (0,)


class TestBackendRouting:
    def test_resolve_and_runtime(self):
        from repro.core.protocol import mutation_backend

        assert resolve_backend("fused") == "fused"
        # since the fused QUERY kernel landed, 'fused' is a runtime
        # backend (one launch per batch); only mutations degrade
        assert runtime_backend("fused") == "fused"
        assert runtime_backend("jax") == "jax"
        assert runtime_backend("pallas") == "pallas"
        assert mutation_backend("fused") in ("jax", "pallas")
        assert mutation_backend("jax") == "jax"
        assert mutation_backend("pallas") == "pallas"
        with pytest.raises(ValueError):
            resolve_backend("cuda")

    def test_all_four_indexes_build_fused(self):
        """RMQ / StreamingRMQ / HybridRMQ.from_hierarchy / DistributedRMQ
        all construct through the fused pipeline and answer bit-identically
        to the jax-built oracle (values AND leftmost-tie positions).

        2-level plan (t=16): the first compile of a 3-level *distributed*
        walk is minutes on CPU XLA (see test_distributed_rmq.py)."""
        n, c, t, cap = 3000, 16, 16, 4000
        rng = np.random.default_rng(42)
        x = _tied_input(rng, n)
        xj = jnp.asarray(x)
        ls = rng.integers(0, n, 96)
        rs = np.minimum(ls + rng.integers(0, n, 96), n - 1)
        ls, rs = np.minimum(ls, rs), np.maximum(ls, rs)
        want = np.array([x[l : r + 1].min() for l, r in zip(ls, rs)])
        wantp = np.array(
            [l + np.argmin(x[l : r + 1]) for l, r in zip(ls, rs)]
        )

        r_f = RMQ.build(
            xj, c=c, t=t, with_positions=True, backend="fused",
            capacity=cap,
        )
        assert r_f.backend == "fused"
        np.testing.assert_array_equal(np.asarray(r_f.query(ls, rs)), want)
        np.testing.assert_array_equal(
            np.asarray(r_f.query_index(ls, rs)), wantp
        )

        s_f = StreamingRMQ.from_array(
            xj, c=c, t=t, with_positions=True, backend="fused",
            capacity=cap,
        )
        np.testing.assert_array_equal(np.asarray(s_f.query(ls, rs)), want)
        np.testing.assert_array_equal(
            np.asarray(s_f.query_index(ls, rs)), wantp
        )

        hyb = HybridRMQ.from_hierarchy(r_f.hierarchy)
        np.testing.assert_array_equal(np.asarray(hyb.query(ls, rs)), want)
        np.testing.assert_array_equal(
            np.asarray(hyb.query_index(ls, rs)), wantp
        )

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        d_f = DistributedRMQ.build(
            x, mesh, c=c, t=t, with_positions=True, backend="fused"
        )
        np.testing.assert_array_equal(np.asarray(d_f.query(ls, rs)), want)
        np.testing.assert_array_equal(
            np.asarray(d_f.query_index(ls, rs)), wantp
        )

        # the engine routes a fused-built index like any other
        eng = r_f.engine(cache_size=64)
        np.testing.assert_array_equal(np.asarray(eng.query(ls, rs)), want)
        np.testing.assert_array_equal(
            np.asarray(eng.query_index(ls, rs)), wantp
        )

    def test_fused_built_index_mutates_like_oracle(self):
        """update/append on a fused-built index dispatch through the
        runtime backend and stay bit-identical to a fresh build."""
        n, cap = 1200, 2000
        rng = np.random.default_rng(3)
        x = _tied_input(rng, n)
        r = RMQ.build(
            jnp.asarray(x), c=8, t=2, with_positions=True,
            backend="fused", capacity=cap,
        )
        idxs = rng.integers(0, n, 40)
        vals = rng.random(40).astype(np.float32)
        tail = rng.random(64).astype(np.float32)
        r2 = r.update(idxs, vals).append(tail)
        x2 = x.copy()
        x2[idxs] = vals  # numpy setitem is last-wins, like the contract
        x2 = np.concatenate([x2, tail])
        ref = RMQ.build(
            jnp.asarray(x2), c=8, t=2, with_positions=True,
            plan=make_plan(len(x2), c=8, t=2, capacity=cap),
        )
        _assert_hierarchies_identical(ref.hierarchy, r2.hierarchy)


class TestBatchedBuild:
    def test_build_many_rows_match_solo_builds(self):
        rng = np.random.default_rng(11)
        xs = np.stack([_tied_input(rng, 5000) for _ in range(4)])
        plan = make_plan(5000, c=16, t=4, capacity=6000)
        batched = build_many(
            jnp.asarray(xs), plan, with_positions=True
        )
        for i in range(4):
            solo = build_hierarchy(
                jnp.asarray(xs[i]), plan, with_positions=True
            )
            np.testing.assert_array_equal(
                np.asarray(batched.base[i]), np.asarray(solo.base)
            )
            np.testing.assert_array_equal(
                np.asarray(batched.upper[i]), np.asarray(solo.upper)
            )
            np.testing.assert_array_equal(
                np.asarray(batched.upper_pos[i]),
                np.asarray(solo.upper_pos),
            )

    def test_build_many_rejects_bad_rank(self):
        plan = make_plan(64, c=8, t=2)
        with pytest.raises(ValueError, match="rank-2"):
            build_many(jnp.zeros((64,)), plan)

    def test_service_register_many(self):
        from repro.qe import QueryService

        rng = np.random.default_rng(5)
        n = 2000
        arrays = {f"idx{i}": _tied_input(rng, n) for i in range(3)}
        svc = QueryService()
        engines = svc.register_many(
            arrays, c=16, t=4, with_positions=True
        )
        assert set(engines) == set(arrays)
        ls = rng.integers(0, n, 32)
        rs = np.minimum(ls + rng.integers(0, n, 32), n - 1)
        ls, rs = np.minimum(ls, rs), np.maximum(ls, rs)
        for name, x in arrays.items():
            want = np.array(
                [x[l : r + 1].min() for l, r in zip(ls, rs)]
            )
            wantp = np.array(
                [l + np.argmin(x[l : r + 1]) for l, r in zip(ls, rs)]
            )
            np.testing.assert_array_equal(
                np.asarray(svc.query(name, ls, rs)), want
            )
            np.testing.assert_array_equal(
                np.asarray(svc.query_index(name, ls, rs)), wantp
            )

    def test_service_register_many_rejects_ragged(self):
        from repro.qe import QueryService

        svc = QueryService()
        with pytest.raises(ValueError, match="equal lengths"):
            svc.register_many(
                {"a": np.zeros(10, np.float32),
                 "b": np.zeros(11, np.float32)}
            )

    def test_service_register_many_all_or_nothing_on_pending(self):
        """A pending ticket for ANY requested name fails the whole call
        before any engine is replaced."""
        from repro.qe import QueryService

        rng = np.random.default_rng(9)
        x = _tied_input(rng, 512)
        svc = QueryService()
        svc.register_many({"a": x, "b": x}, c=16, t=4)
        old_engine = svc.engine("a")
        svc.submit("b", [0], [10])
        with pytest.raises(ValueError, match="pending"):
            svc.register_many({"a": x, "b": x}, c=16, t=4)
        assert svc.engine("a") is old_engine  # 'a' was not re-registered
        svc.flush()
