"""Hybrid (sparse-table top) RMQ — paper §4.5 as a selectable backend."""

import numpy as np
import pytest
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core.api import RMQ
from repro.core.hybrid import HybridRMQ


@pytest.mark.parametrize("n,c,t", [
    (4097, 16, 8),
    (100_000, 128, 1024),
    (1 << 18, 128, 4096),
    (513, 4, 2),
])
def test_hybrid_matches_naive(n, c, t):
    rng = np.random.default_rng(n)
    x = rng.random(n).astype(np.float32)
    h = HybridRMQ.build(x, c=c, t=t)
    ls = rng.integers(0, n, 256)
    rs = np.minimum(ls + rng.integers(0, n, 256), n - 1)
    ls, rs = np.minimum(ls, rs), np.maximum(ls, rs)
    got = np.asarray(h.query(ls, rs))
    want = np.array([x[l : r + 1].min() for l, r in zip(ls, rs)])
    np.testing.assert_allclose(got, want)


def test_hybrid_enables_larger_t_with_fewer_levels():
    """Paper §4.5 implication (1): the O(1) top makes large t free, which
    removes hierarchy levels."""
    n = 1 << 20
    rng = np.random.default_rng(0)
    x = rng.random(n).astype(np.float32)
    scan_version = RMQ.build(x, c=128, t=8, backend="jax")
    hybrid = HybridRMQ.build(x, c=128, t=4096)
    assert hybrid.plan.num_levels < scan_version.plan.num_levels
    # and still answers correctly
    assert float(hybrid.query(np.array([0]), np.array([n - 1]))[0]) == \
        x.min()


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=1500),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hybrid_property(n, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-5, 5, n).astype(np.float32)
    h = HybridRMQ.build(x, c=8, t=4)
    l = int(rng.integers(0, n))
    r = int(rng.integers(l, n))
    got = float(h.query(np.array([l]), np.array([r]))[0])
    assert got == x[l : r + 1].min()


@pytest.mark.parametrize("n,c,t", [
    (50_000, 128, 2),
    (4096, 8, 4),
    (999, 8, 2),
    (600, 1024, 64),   # single-level plan: table directly over the input
])
def test_hybrid_index_tracking_matches_naive(n, c, t):
    """Index-tracking hybrid: leftmost-tie positions, incl. tie storms."""
    rng = np.random.default_rng(n + 7)
    x = rng.random(n).astype(np.float32)
    x[rng.integers(0, n, n // 8)] = 0.25   # force ties
    h = HybridRMQ.build(x, c=c, t=t, with_positions=True)
    assert h.with_positions
    ls = rng.integers(0, n, 256)
    rs = np.minimum(ls + rng.integers(0, n, 256), n - 1)
    ls, rs = np.minimum(ls, rs), np.maximum(ls, rs)
    got = np.asarray(h.query_index(ls, rs))
    want = np.array([l + np.argmin(x[l : r + 1]) for l, r in zip(ls, rs)])
    np.testing.assert_array_equal(got, want)


def test_hybrid_from_hierarchy_reuses_levels():
    """from_hierarchy wraps an existing build — no hierarchy rebuild."""
    from repro.core.hierarchy import build_hierarchy
    from repro.core.plan import make_plan

    rng = np.random.default_rng(3)
    n = 30_000
    x = rng.random(n).astype(np.float32)
    h = build_hierarchy(jnp.asarray(x), make_plan(n, c=64, t=4),
                        with_positions=True)
    hyb = HybridRMQ.from_hierarchy(h)
    assert hyb.hierarchy is h
    assert hyb.with_positions
    ls = rng.integers(0, n, 128)
    rs = np.minimum(ls + rng.integers(0, n, 128), n - 1)
    ls, rs = np.minimum(ls, rs), np.maximum(ls, rs)
    want = np.array([x[l : r + 1].min() for l, r in zip(ls, rs)])
    np.testing.assert_array_equal(np.asarray(hyb.query(ls, rs)), want)


def test_hybrid_value_only_query_index_raises():
    x = np.random.default_rng(0).random(5000).astype(np.float32)
    h = HybridRMQ.build(x, c=16, t=8)
    with pytest.raises(ValueError, match="value-only"):
        h.query_index(np.array([0]), np.array([10]))
