"""Unit + property tests for the core GPU-RMQ hierarchy (paper §4.1–§4.4)."""

import math

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core import (
    RMQ,
    build_hierarchy,
    make_plan,
    rmq_index_batch,
    rmq_value_batch,
)
from repro.core import theory
from repro.core.baselines import FullScan, SparseTable, TwoLevelBlocks


def _random_queries(rng, n, m):
    ls = rng.integers(0, n, m)
    rs = np.minimum(ls + rng.integers(0, n, m), n - 1)
    return (
        np.minimum(ls, rs).astype(np.int32),
        np.maximum(ls, rs).astype(np.int32),
    )


def _naive(x, ls, rs):
    return np.array([x[l : r + 1].min() for l, r in zip(ls, rs)])


def _naive_idx(x, ls, rs):
    return np.array([l + np.argmin(x[l : r + 1]) for l, r in zip(ls, rs)])


# ---------------------------------------------------------------------------
# Plan geometry
# ---------------------------------------------------------------------------
class TestPlan:
    def test_cutoff_respected(self):
        for n in [10, 1000, 1 << 20]:
            for c in [2, 8, 128]:
                for t in [1, 4, 64]:
                    plan = make_plan(n, c=c, t=t)
                    assert plan.top_len <= c * t
                    # every non-top level violates the cutoff (else the
                    # build would have stopped earlier)
                    for ln in plan.level_lens[:-1]:
                        assert ln > c * t or plan.num_levels == 1

    def test_level_lens_are_ceil_chain(self):
        plan = make_plan(100_000, c=8, t=4)
        for a, b in zip(plan.level_lens, plan.level_lens[1:]):
            assert b == -(-a // 8)

    def test_invalid_params_rejected(self):
        with pytest.raises(ValueError):
            make_plan(0)
        with pytest.raises(ValueError):
            make_plan(100, c=3)  # not a power of two
        with pytest.raises(ValueError):
            make_plan(100, c=128, t=0)

    def test_memory_bound_paper_4_1(self):
        """Auxiliary entries <= n/(c-1) + num_levels (ceil-corrected)."""
        for n in [17, 1000, 123_457, 1 << 22]:
            for c in [2, 4, 32, 128]:
                plan = make_plan(n, c=c, t=2)
                logical_aux = sum(plan.level_lens[1:])
                assert logical_aux <= theory.aux_entries_bound_ceil(
                    n, c, plan.num_levels
                )

    def test_scan_bound_paper_4_1(self):
        plan = make_plan(1 << 24, c=32, t=16)
        assert plan.max_scanned_entries() == 32 * 16 + 2 * 32 * (
            plan.num_levels - 1
        )
        # O(log n): far below n
        assert plan.max_scanned_entries() < 4096


# ---------------------------------------------------------------------------
# Hierarchy construction
# ---------------------------------------------------------------------------
class TestBuild:
    def test_upper_levels_are_chunk_minima(self):
        rng = np.random.default_rng(0)
        n, c = 1000, 8
        x = rng.random(n).astype(np.float32)
        plan = make_plan(n, c=c, t=2)
        h = build_hierarchy(jnp.asarray(x), plan)
        off, padded = plan.level_slice(1)
        lvl1 = np.asarray(h.upper[off : off + padded])
        for i in range(plan.level_lens[1]):
            chunk = x[i * c : (i + 1) * c]
            assert lvl1[i] == chunk.min()
        # padding is +inf
        assert np.all(np.isinf(lvl1[plan.level_lens[1] :]))

    def test_positions_point_at_leftmost_minimum(self):
        x = np.array([5, 3, 3, 7, 3, 9, 1, 1], dtype=np.float32)
        plan = make_plan(8, c=2, t=1)
        h = build_hierarchy(jnp.asarray(x), plan, with_positions=True)
        off, _ = plan.level_slice(1)
        # level 1 = min of pairs: [3, 3, 3, 1]; leftmost positions 1, 2, 4, 6
        assert np.asarray(h.upper_pos[off : off + 4]).tolist() == [1, 2, 4, 6]

    def test_memory_accounting(self):
        n = 1 << 20
        plan = make_plan(n, c=128, t=64)
        h = build_hierarchy(jnp.ones(n, jnp.float32), plan)
        assert h.auxiliary_bytes() == h.upper.size * 4
        # paper Fig. 15: aux memory a small fraction of the input for c=128
        assert h.auxiliary_bytes() < 0.02 * n * 4


# ---------------------------------------------------------------------------
# Query correctness (fixed cases + property-based)
# ---------------------------------------------------------------------------
class TestQuery:
    @pytest.mark.parametrize("n,c,t", [
        (17, 2, 1),      # paper's running example size
        (1, 2, 1),       # single element
        (2, 2, 1),
        (1000, 4, 2),
        (4096, 8, 4),    # power-of-c
        (100_003, 128, 64),  # prime n, production params
    ])
    def test_matches_naive(self, n, c, t):
        rng = np.random.default_rng(n)
        x = rng.random(n).astype(np.float32)
        h = build_hierarchy(jnp.asarray(x), make_plan(n, c=c, t=t),
                            with_positions=True)
        ls, rs = _random_queries(rng, n, 256)
        got = np.asarray(rmq_value_batch(h, jnp.asarray(ls), jnp.asarray(rs)))
        np.testing.assert_allclose(got, _naive(x, ls, rs))
        gotp = np.asarray(rmq_index_batch(h, jnp.asarray(ls), jnp.asarray(rs)))
        np.testing.assert_array_equal(gotp, _naive_idx(x, ls, rs))

    def test_paper_figure2_example(self):
        """The paper's Fig. 2: RMQ(3, 14) on a 17-element array -> 8 at idx 5."""
        x = np.array(
            [4, 20, 18, 18, 23, 8, 35, 43, 43, 36, 68, 63, 22, 51, 81, 75, 9],
            dtype=np.float32,
        )
        for c, t in [(2, 1), (2, 4), (4, 1)]:
            h = build_hierarchy(jnp.asarray(x), make_plan(17, c=c, t=t),
                                with_positions=True)
            assert float(rmq_value_batch(h, jnp.array([3]), jnp.array([14]))[0]) == 8.0
            assert int(rmq_index_batch(h, jnp.array([3]), jnp.array([14]))[0]) == 5

    def test_full_range_and_point_queries(self):
        rng = np.random.default_rng(7)
        n = 999
        x = rng.random(n).astype(np.float32)
        h = build_hierarchy(jnp.asarray(x), make_plan(n, c=8, t=2),
                            with_positions=True)
        # full range
        assert float(rmq_value_batch(h, jnp.array([0]), jnp.array([n - 1]))[0]) == x.min()
        # every point query returns the element itself (sampled)
        pts = rng.integers(0, n, 64).astype(np.int32)
        got = np.asarray(rmq_value_batch(h, jnp.asarray(pts), jnp.asarray(pts)))
        np.testing.assert_allclose(got, x[pts])

    def test_ties_return_leftmost(self):
        x = np.zeros(100, dtype=np.float32)  # all ties
        h = build_hierarchy(jnp.asarray(x), make_plan(100, c=4, t=1),
                            with_positions=True)
        ls = np.array([0, 10, 55], dtype=np.int32)
        rs = np.array([99, 88, 56], dtype=np.int32)
        got = np.asarray(rmq_index_batch(h, jnp.asarray(ls), jnp.asarray(rs)))
        np.testing.assert_array_equal(got, ls)

    @settings(max_examples=60, deadline=None)
    @given(
        data=st.data(),
        n=st.integers(min_value=1, max_value=2000),
        c_exp=st.integers(min_value=1, max_value=5),
        t=st.integers(min_value=1, max_value=8),
    )
    def test_property_hierarchical_equals_naive(self, data, n, c_exp, t):
        """∀ arrays, ∀ (l, r): hierarchy answer == naive scan answer."""
        c = 1 << c_exp
        vals = data.draw(
            st.lists(
                st.floats(
                    min_value=-1e6, max_value=1e6,
                    allow_nan=False, width=32,
                ),
                min_size=n, max_size=n,
            )
        )
        x = np.asarray(vals, dtype=np.float32)
        l = data.draw(st.integers(min_value=0, max_value=n - 1))
        r = data.draw(st.integers(min_value=l, max_value=n - 1))
        h = build_hierarchy(jnp.asarray(x), make_plan(n, c=c, t=t),
                            with_positions=True)
        got = float(rmq_value_batch(h, jnp.array([l]), jnp.array([r]))[0])
        assert got == x[l : r + 1].min()
        gotp = int(rmq_index_batch(h, jnp.array([l]), jnp.array([r]))[0])
        assert gotp == l + int(np.argmin(x[l : r + 1]))

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(min_value=2, max_value=500),
        c_exp=st.integers(min_value=1, max_value=4),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_property_duplicates_and_negatives(self, n, c_exp, seed):
        """Arrays with heavy duplication / negative values."""
        rng = np.random.default_rng(seed)
        x = rng.integers(-3, 3, n).astype(np.float32)
        h = build_hierarchy(jnp.asarray(x), make_plan(n, c=1 << c_exp, t=1),
                            with_positions=True)
        ls, rs = _random_queries(rng, n, 32)
        got = np.asarray(rmq_value_batch(h, jnp.asarray(ls), jnp.asarray(rs)))
        np.testing.assert_allclose(got, _naive(x, ls, rs))
        gotp = np.asarray(rmq_index_batch(h, jnp.asarray(ls), jnp.asarray(rs)))
        np.testing.assert_array_equal(gotp, _naive_idx(x, ls, rs))


# ---------------------------------------------------------------------------
# Facade + baselines
# ---------------------------------------------------------------------------
class TestFacadeAndBaselines:
    def test_rmq_facade_roundtrip(self):
        rng = np.random.default_rng(11)
        x = rng.random(3000).astype(np.float32)
        r = RMQ.build(x, c=16, t=8, with_positions=True, backend="jax")
        ls, rs = _random_queries(rng, 3000, 64)
        np.testing.assert_allclose(
            np.asarray(r.query(ls, rs)), _naive(x, ls, rs)
        )
        np.testing.assert_array_equal(
            np.asarray(r.query_index(ls, rs)), _naive_idx(x, ls, rs)
        )
        assert r.auxiliary_bytes() > 0
        assert r.memory_bytes() >= 3000 * 4

    @pytest.mark.parametrize("method", ["full_scan", "sparse_table", "two_level"])
    def test_baselines_match_naive(self, method):
        rng = np.random.default_rng(13)
        n = 4097
        x = rng.random(n).astype(np.float32)
        b = {
            "full_scan": lambda: FullScan.build(jnp.asarray(x)),
            "sparse_table": lambda: SparseTable.build(jnp.asarray(x)),
            "two_level": lambda: TwoLevelBlocks.build(jnp.asarray(x), c=64),
        }[method]()
        ls, rs = _random_queries(rng, n, 128)
        got = np.asarray(b.query_batch(jnp.asarray(ls), jnp.asarray(rs)))
        np.testing.assert_allclose(got, _naive(x, ls, rs))

    def test_memory_profiles_match_paper_fig15_ordering(self):
        """full scan < GPU-RMQ << sparse table (the LCA/RTXRMQ profile)."""
        n = 1 << 16
        x = jnp.asarray(np.random.default_rng(0).random(n), jnp.float32)
        full = FullScan.build(x)
        ours = RMQ.build(x, c=128, t=64, backend="jax")
        sparse = SparseTable.build(x)
        assert full.auxiliary_bytes() == 0
        assert ours.auxiliary_bytes() < 0.02 * n * 4
        assert sparse.auxiliary_bytes() > 10 * n * 4
        # paper: GPU-RMQ needs at most ~30% more memory than full scan
        assert ours.memory_bytes() < 1.3 * full.memory_bytes()


class TestQueryValidation:
    """RMQ.query/query_index input checking (0 <= l <= r < n)."""

    def _rmq(self, n=500):
        rng = np.random.default_rng(2)
        x = rng.random(n).astype(np.float32)
        return x, RMQ.build(x, c=8, t=2, with_positions=True, backend="jax")

    def test_non_integer_bounds_rejected(self):
        _, r = self._rmq()
        with pytest.raises(TypeError, match="integer"):
            r.query(jnp.zeros(3), jnp.zeros(3, jnp.int32))
        with pytest.raises(TypeError, match="integer"):
            r.query_index(jnp.zeros(3, jnp.int32), jnp.zeros(3))

    def test_shape_mismatch_rejected(self):
        _, r = self._rmq()
        with pytest.raises(ValueError, match="shape"):
            r.query(jnp.zeros(3, jnp.int32), jnp.zeros(4, jnp.int32))

    def test_out_of_range_rejected_in_debug_mode(self, monkeypatch):
        monkeypatch.setenv("REPRO_RMQ_DEBUG", "1")
        n = 500
        _, r = self._rmq(n)
        cases = [
            ([-1], [3]),        # negative l
            ([5], [4]),         # l > r
            ([0], [n]),         # r out of range
        ]
        for ls, rs in cases:
            with pytest.raises(ValueError, match="violates"):
                r.query(np.asarray(ls, np.int32), np.asarray(rs, np.int32))
            with pytest.raises(ValueError, match="violates"):
                r.query_index(np.asarray(ls, np.int32),
                              np.asarray(rs, np.int32))

    def test_degenerate_point_queries_pass_validation(self, monkeypatch):
        """l == r is valid (window of one) and returns the element."""
        monkeypatch.setenv("REPRO_RMQ_DEBUG", "1")
        x, r = self._rmq()
        pts = np.array([0, 7, 499], np.int32)
        np.testing.assert_allclose(np.asarray(r.query(pts, pts)), x[pts])
        np.testing.assert_array_equal(
            np.asarray(r.query_index(pts, pts)), pts
        )

    def test_full_range_passes_validation(self, monkeypatch):
        monkeypatch.setenv("REPRO_RMQ_DEBUG", "1")
        x, r = self._rmq()
        ls = np.array([0], np.int32)
        rs = np.array([499], np.int32)
        assert float(r.query(ls, rs)[0]) == x.min()
        assert int(r.query_index(ls, rs)[0]) == int(np.argmin(x))


class TestBf16Values:
    """Beyond-paper: bf16 input values halve index memory on TPU.

    The paper is f32-only (§5.1); the hierarchy/query algebra only needs
    a totally-ordered dtype with an +inf identity, which bf16 has.
    """

    def test_bf16_hierarchy_and_query(self):
        rng = np.random.default_rng(0)
        n = 20_000
        x32 = rng.random(n).astype(np.float32)
        x16 = jnp.asarray(x32, jnp.bfloat16)
        h = build_hierarchy(x16, make_plan(n, c=64, t=8),
                            with_positions=True)
        assert h.upper.dtype == jnp.bfloat16
        ls, rs = _random_queries(rng, n, 128)
        got = rmq_value_batch(h, jnp.asarray(ls), jnp.asarray(rs))
        want = np.array([
            np.asarray(x16, np.float32)[l : r + 1].min()
            for l, r in zip(ls, rs)
        ])
        np.testing.assert_array_equal(
            np.asarray(got, np.float32), want
        )
        # index variant: leftmost argmin in bf16-rounded space
        gotp = np.asarray(
            rmq_index_batch(h, jnp.asarray(ls), jnp.asarray(rs))
        )
        x16np = np.asarray(x16, np.float32)
        wantp = np.array([
            l + int(np.argmin(x16np[l : r + 1])) for l, r in zip(ls, rs)
        ])
        np.testing.assert_array_equal(gotp, wantp)

    def test_bf16_pallas_kernels(self):
        from repro.kernels.hierarchy_build.ops import build_hierarchy_pallas
        from repro.kernels.rmq_scan.ops import rmq_value_batch_pallas

        rng = np.random.default_rng(1)
        n = 50_000
        x = jnp.asarray(rng.random(n), jnp.bfloat16)
        plan = make_plan(n, c=128, t=2)
        h = build_hierarchy_pallas(x, plan, interpret=True)
        ls, rs = _random_queries(rng, n, 64)
        got = rmq_value_batch_pallas(
            h, jnp.asarray(ls), jnp.asarray(rs), qb=16, interpret=True
        )
        want = np.array([
            np.asarray(x, np.float32)[l : r + 1].min()
            for l, r in zip(ls, rs)
        ])
        np.testing.assert_array_equal(np.asarray(got, np.float32), want)
