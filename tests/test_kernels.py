"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.hierarchy import build_hierarchy
from repro.core.plan import make_plan
from repro.core.query import rmq_index_batch, rmq_value_batch


def _queries(rng, n, m):
    ls = rng.integers(0, n, m)
    rs = np.minimum(ls + rng.integers(0, n, m), n - 1)
    return (
        np.minimum(ls, rs).astype(np.int32),
        np.maximum(ls, rs).astype(np.int32),
    )


# ---------------------------------------------------------------------------
# hierarchy_build
# ---------------------------------------------------------------------------
class TestHierarchyBuildKernel:
    @pytest.mark.parametrize("n,c,t", [
        (100_000, 128, 64),
        (4096, 8, 2),
        (999, 2, 1),
        (1 << 18, 256, 16),
        (12_345, 16, 4),
    ])
    @pytest.mark.parametrize("with_pos", [False, True])
    def test_matches_oracle(self, n, c, t, with_pos):
        from repro.kernels.hierarchy_build.ops import build_hierarchy_pallas

        rng = np.random.default_rng(n + c)
        x = jnp.asarray(rng.random(n).astype(np.float32))
        plan = make_plan(n, c=c, t=t)
        h_ref = build_hierarchy(x, plan, with_positions=with_pos)
        h_pal = build_hierarchy_pallas(
            x, plan, with_positions=with_pos, interpret=True
        )
        u1, u2 = np.asarray(h_ref.upper), np.asarray(h_pal.upper)
        finite = np.isfinite(u1)
        np.testing.assert_array_equal(finite, np.isfinite(u2))
        np.testing.assert_array_equal(u1[finite], u2[finite])
        if with_pos:
            np.testing.assert_array_equal(
                np.asarray(h_ref.upper_pos), np.asarray(h_pal.upper_pos)
            )

    def test_level_kernel_direct(self):
        from repro.kernels.hierarchy_build.kernel import build_level
        from repro.kernels.hierarchy_build.ref import build_level_ref

        rng = np.random.default_rng(0)
        for c, tile in [(128, 8), (256, 4), (8, 64)]:
            x = jnp.asarray(rng.random(c * tile * 4).astype(np.float32))
            got = build_level(x, c=c, tile_out=tile, interpret=True)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(build_level_ref(x, c))
            )


# ---------------------------------------------------------------------------
# rmq_scan
# ---------------------------------------------------------------------------
class TestRmqScanKernel:
    @pytest.mark.parametrize("n,c,t,qb", [
        (100_000, 128, 4, 64),
        (65_536, 256, 2, 32),
        (5_000, 128, 1, 16),
        (300_000, 128, 2, 64),   # 4 levels
    ])
    def test_matches_naive(self, n, c, t, qb):
        from repro.kernels.rmq_scan.ops import (
            rmq_index_batch_pallas,
            rmq_value_batch_pallas,
        )

        rng = np.random.default_rng(n)
        x = rng.random(n).astype(np.float32)
        plan = make_plan(n, c=c, t=t)
        h = build_hierarchy(jnp.asarray(x), plan, with_positions=True)
        ls, rs = _queries(rng, n, 128)
        want = np.array([x[l : r + 1].min() for l, r in zip(ls, rs)])
        wantp = np.array([l + np.argmin(x[l : r + 1]) for l, r in zip(ls, rs)])
        got = np.asarray(
            rmq_value_batch_pallas(
                h, jnp.asarray(ls), jnp.asarray(rs), qb=qb, interpret=True
            )
        )
        np.testing.assert_allclose(got, want)
        gotp = np.asarray(
            rmq_index_batch_pallas(
                h, jnp.asarray(ls), jnp.asarray(rs), qb=qb, interpret=True
            )
        )
        np.testing.assert_array_equal(gotp, wantp)

    def test_branchfree_oracle_equals_core(self):
        """Algorithm cross-check: branch-free walk == Listing-2 walk."""
        from repro.kernels.rmq_scan.ref import rmq_branchfree_batch

        rng = np.random.default_rng(33)
        n = 50_000
        x = jnp.asarray(rng.random(n).astype(np.float32))
        plan = make_plan(n, c=128, t=2)
        h = build_hierarchy(x, plan, with_positions=True)
        ls, rs = _queries(rng, n, 512)
        v1 = rmq_value_batch(h, jnp.asarray(ls), jnp.asarray(rs))
        p1 = rmq_index_batch(h, jnp.asarray(ls), jnp.asarray(rs))
        v2, p2 = rmq_branchfree_batch(
            plan, h.base, h.upper, h.upper_pos,
            jnp.asarray(ls), jnp.asarray(rs), track_pos=True,
        )
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))

    def test_query_batch_padding(self):
        """Batch sizes not divisible by qb are padded and sliced correctly."""
        from repro.kernels.rmq_scan.ops import rmq_value_batch_pallas

        rng = np.random.default_rng(5)
        n = 10_000
        x = rng.random(n).astype(np.float32)
        h = build_hierarchy(jnp.asarray(x), make_plan(n, c=128, t=1))
        ls, rs = _queries(rng, n, 37)  # prime batch size
        got = np.asarray(
            rmq_value_batch_pallas(
                h, jnp.asarray(ls), jnp.asarray(rs), qb=16, interpret=True
            )
        )
        want = np.array([x[l : r + 1].min() for l, r in zip(ls, rs)])
        np.testing.assert_allclose(got, want)


# ---------------------------------------------------------------------------
# rmq_fused (whole mixed batch, one launch, both output planes)
# ---------------------------------------------------------------------------
class TestRmqFusedKernel:
    @pytest.mark.parametrize("n,c,t,qb", [
        (100_000, 128, 4, 64),
        (65_536, 256, 2, 32),
        (300_000, 128, 2, 64),   # 4 levels
        (5_000, 16, 4, 16),      # 3 levels, small chunks
    ])
    def test_both_planes_match_naive(self, n, c, t, qb):
        """One interpret-mode launch returns values AND leftmost-tie
        positions matching the naive oracle (the production off-TPU
        lowering is the jnp program — covered by test_differential;
        this pins the pallas kernel itself)."""
        from repro.kernels.rmq_fused.ops import rmq_fused_batch

        rng = np.random.default_rng(n)
        x = rng.random(n).astype(np.float32)
        x[rng.integers(0, n, n // 8)] = 0.5  # ties
        plan = make_plan(n, c=c, t=t)
        h = build_hierarchy(jnp.asarray(x), plan, with_positions=True)
        ls, rs = _queries(rng, n, 128)
        want = np.array([x[l : r + 1].min() for l, r in zip(ls, rs)])
        wantp = np.array(
            [l + np.argmin(x[l : r + 1]) for l, r in zip(ls, rs)]
        )
        vals, pos = rmq_fused_batch(
            h, jnp.asarray(ls), jnp.asarray(rs), track_pos=True, qb=qb,
            interpret=True,
        )
        np.testing.assert_array_equal(np.asarray(vals), want)
        np.testing.assert_array_equal(np.asarray(pos), wantp)

    def test_kernel_equals_package_ref_and_jnp_lowering(self):
        """Kernel vs the package's pure-jnp oracle vs the one-dispatch
        jnp production lowering: all three bit-identical."""
        from repro.kernels.rmq_fused.ops import _fused_jnp, rmq_fused_batch
        from repro.kernels.rmq_fused.ref import rmq_fused_batch_ref

        rng = np.random.default_rng(77)
        n, cap = 20_000, 26_000   # reserved +inf tail in play
        x = rng.integers(-4, 4, n).astype(np.float32)
        plan = make_plan(n, c=64, t=2, capacity=cap)
        h = build_hierarchy(jnp.asarray(x), plan, with_positions=True)
        ls, rs = _queries(rng, n, 96)
        lsj, rsj = jnp.asarray(ls), jnp.asarray(rs)
        kv, kp = rmq_fused_batch(h, lsj, rsj, track_pos=True, qb=32,
                                 interpret=True)
        rv, rp = rmq_fused_batch_ref(plan, h.base, h.upper, h.upper_pos,
                                     lsj, rsj, track_pos=True)
        jv, jp = _fused_jnp(h.base, h.upper, h.upper_pos,
                            lsj.astype(jnp.int32), rsj.astype(jnp.int32),
                            plan, True)
        for got in ((kv, kp), (jv, jp)):
            np.testing.assert_array_equal(np.asarray(got[0]),
                                          np.asarray(rv))
            np.testing.assert_array_equal(np.asarray(got[1]),
                                          np.asarray(rp))

    def test_value_only_and_padding(self):
        """Value-only launches and batch sizes not divisible by qb."""
        from repro.kernels.rmq_fused.ops import rmq_fused_value_batch

        rng = np.random.default_rng(6)
        n = 10_000
        x = rng.random(n).astype(np.float32)
        h = build_hierarchy(jnp.asarray(x), make_plan(n, c=128, t=1))
        ls, rs = _queries(rng, n, 37)  # prime batch size
        got = np.asarray(
            rmq_fused_value_batch(
                h, jnp.asarray(ls), jnp.asarray(rs), qb=16, interpret=True
            )
        )
        want = np.array([x[l : r + 1].min() for l, r in zip(ls, rs)])
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# rmq_short (two-chunk short-span scan)
# ---------------------------------------------------------------------------
class TestRmqShortKernel:
    @staticmethod
    def _short_queries(rng, n, c, m):
        """Random queries satisfying the SHORT predicate (<= 2 chunks)."""
        ls = rng.integers(0, n, m)
        rs = np.minimum(ls + rng.integers(1, 2 * c + 1, m) - 1, n - 1)
        keep = (rs // c) - (ls // c) <= 1
        return ls[keep].astype(np.int32), rs[keep].astype(np.int32)

    @pytest.mark.parametrize("n,c,qb", [
        (100_000, 128, 64),
        (4096, 8, 16),
        (777, 128, 32),     # capacity > 2c but unaligned tail
        (100, 64, 16),      # capacity < 2c -> ref fallback
    ])
    def test_matches_naive_and_walk(self, n, c, qb):
        from repro.kernels.rmq_short.ops import (
            rmq_short_index_batch_pallas,
            rmq_short_value_batch_pallas,
        )

        rng = np.random.default_rng(n + c)
        x = rng.random(n).astype(np.float32)
        x[rng.integers(0, n, n // 8)] = 0.5   # ties: leftmost must win
        h = build_hierarchy(jnp.asarray(x), make_plan(n, c=c, t=4),
                            with_positions=True)
        ls, rs = self._short_queries(rng, n, c, 300)
        want = np.array([x[l : r + 1].min() for l, r in zip(ls, rs)])
        wantp = np.array(
            [l + np.argmin(x[l : r + 1]) for l, r in zip(ls, rs)]
        )
        got = np.asarray(rmq_short_value_batch_pallas(
            h, jnp.asarray(ls), jnp.asarray(rs), qb=qb, interpret=True
        ))
        np.testing.assert_array_equal(got, want)
        gotp = np.asarray(rmq_short_index_batch_pallas(
            h, jnp.asarray(ls), jnp.asarray(rs), qb=qb, interpret=True
        ))
        np.testing.assert_array_equal(gotp, wantp)
        # and bit-identical to the full-walk oracle (engine parity contract)
        np.testing.assert_array_equal(
            got, np.asarray(rmq_value_batch(h, jnp.asarray(ls),
                                            jnp.asarray(rs)))
        )
        np.testing.assert_array_equal(
            gotp, np.asarray(rmq_index_batch(h, jnp.asarray(ls),
                                             jnp.asarray(rs)))
        )

    def test_index_without_positions(self):
        """Level-0 positions are indices: works on value-only builds."""
        from repro.kernels.rmq_short.ops import rmq_short_index_batch_pallas

        rng = np.random.default_rng(9)
        n, c = 20_000, 128
        x = rng.random(n).astype(np.float32)
        h = build_hierarchy(jnp.asarray(x), make_plan(n, c=c, t=2))
        assert not h.with_positions
        ls, rs = self._short_queries(rng, n, c, 100)
        gotp = np.asarray(rmq_short_index_batch_pallas(
            h, jnp.asarray(ls), jnp.asarray(rs), qb=16, interpret=True
        ))
        wantp = np.array(
            [l + np.argmin(x[l : r + 1]) for l, r in zip(ls, rs)]
        )
        np.testing.assert_array_equal(gotp, wantp)

    def test_query_batch_padding(self):
        from repro.kernels.rmq_short.ops import rmq_short_value_batch_pallas

        rng = np.random.default_rng(5)
        n, c = 10_000, 128
        x = rng.random(n).astype(np.float32)
        h = build_hierarchy(jnp.asarray(x), make_plan(n, c=c, t=1))
        ls, rs = self._short_queries(rng, n, c, 41)  # not qb-aligned
        got = np.asarray(rmq_short_value_batch_pallas(
            h, jnp.asarray(ls), jnp.asarray(rs), qb=16, interpret=True
        ))
        want = np.array([x[l : r + 1].min() for l, r in zip(ls, rs)])
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------
class TestFlashAttentionKernel:
    @pytest.mark.parametrize("batch,hq,hkv,s,d", [
        (2, 4, 2, 256, 64),
        (1, 8, 8, 128, 128),   # MHA
        (1, 8, 1, 256, 64),    # MQA
        (2, 2, 2, 512, 32),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, batch, hq, hkv, s, d, dtype):
        from repro.kernels.flash_attention.kernel import flash_attention
        from repro.kernels.flash_attention.ref import attention_ref

        rng = np.random.default_rng(hq * s)
        q = jnp.asarray(rng.standard_normal((batch, hq, s, d)), dtype)
        k = jnp.asarray(rng.standard_normal((batch, hkv, s, d)), dtype)
        v = jnp.asarray(rng.standard_normal((batch, hkv, s, d)), dtype)
        got = flash_attention(q, k, v, interpret=True)
        want = attention_ref(q, k, v)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=tol, rtol=tol,
        )

    @pytest.mark.parametrize("window", [128, 256, 1024])
    def test_sliding_window(self, window):
        from repro.kernels.flash_attention.kernel import flash_attention
        from repro.kernels.flash_attention.ref import attention_ref

        rng = np.random.default_rng(window)
        q = jnp.asarray(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
        got = flash_attention(q, k, v, window=window, interpret=True)
        want = attention_ref(q, k, v, window=window)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5
        )

    def test_first_token_attends_only_to_itself(self):
        from repro.kernels.flash_attention.kernel import flash_attention

        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.standard_normal((1, 1, 128, 64)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 1, 128, 64)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 1, 128, 64)), jnp.float32)
        out = flash_attention(q, k, v, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out[0, 0, 0]), np.asarray(v[0, 0, 0]), rtol=1e-5
        )


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------
class TestSsdScanKernel:
    @pytest.mark.parametrize("batch,l,h,p,n", [
        (2, 256, 4, 64, 128),   # mamba2 geometry
        (1, 128, 2, 64, 16),    # hymba geometry
        (1, 512, 1, 32, 64),
    ])
    def test_chunked_and_pallas_match_naive(self, batch, l, h, p, n):
        from repro.kernels.ssd_scan.kernel import ssd_scan
        from repro.kernels.ssd_scan.ref import ssd_chunked_ref, ssd_ref

        rng = np.random.default_rng(l * h)
        dtx = jnp.asarray(rng.standard_normal((batch, l, h, p)) * 0.1,
                          jnp.float32)
        la = jnp.asarray(-np.abs(rng.standard_normal((batch, l, h))) * 0.1,
                         jnp.float32)
        Bm = jnp.asarray(rng.standard_normal((batch, l, n)) * 0.3, jnp.float32)
        Cm = jnp.asarray(rng.standard_normal((batch, l, n)) * 0.3, jnp.float32)
        y0, s0 = ssd_ref(dtx, la, Bm, Cm)
        y1, s1 = ssd_chunked_ref(dtx, la, Bm, Cm, chunk=128)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                                   atol=1e-4, rtol=1e-4)
        y2 = ssd_scan(dtx, la, Bm, Cm, chunk=128, interpret=True)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y2),
                                   atol=1e-4, rtol=1e-4)

    def test_state_continuity_across_calls(self):
        """Chunked ref with init_state == one long naive scan."""
        from repro.kernels.ssd_scan.ref import ssd_chunked_ref, ssd_ref

        rng = np.random.default_rng(7)
        B, L, H, P, N = 1, 256, 2, 32, 64
        dtx = jnp.asarray(rng.standard_normal((B, L, H, P)) * 0.1, jnp.float32)
        la = jnp.asarray(-np.abs(rng.standard_normal((B, L, H))) * 0.1,
                         jnp.float32)
        Bm = jnp.asarray(rng.standard_normal((B, L, N)) * 0.3, jnp.float32)
        Cm = jnp.asarray(rng.standard_normal((B, L, N)) * 0.3, jnp.float32)
        y_full, s_full = ssd_ref(dtx, la, Bm, Cm)
        half = L // 2
        y_a, s_a = ssd_chunked_ref(
            dtx[:, :half], la[:, :half], Bm[:, :half], Cm[:, :half], chunk=64
        )
        y_b, s_b = ssd_chunked_ref(
            dtx[:, half:], la[:, half:], Bm[:, half:], Cm[:, half:],
            chunk=64, init_state=s_a,
        )
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate([y_a, y_b], axis=1)),
            np.asarray(y_full), atol=1e-4, rtol=1e-4,
        )
        np.testing.assert_allclose(np.asarray(s_b), np.asarray(s_full),
                                   atol=1e-4, rtol=1e-4)
