"""Serving tests: engine generation, RMQ-backed eviction, MoE invariants."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ServeConfig, get_smoke_config
from repro.models import init_params
from repro.models.moe import moe_apply, _capacity
from repro.serve.engine import ServeEngine
from repro.serve.eviction import RMQEvictionManager


class TestEvictionManager:
    def test_keeps_high_scores_evicts_low(self):
        mgr = RMQEvictionManager(budget=40, protected_window=8, c=8, t=4)
        rng = np.random.default_rng(0)
        scores = rng.random(50).astype(np.float32)
        # plant obviously-precious tokens
        scores[[3, 17, 29]] = 10.0
        victims = np.asarray(mgr.plan_evictions(jnp.asarray(scores), 50))
        assert len(victims) == 10
        assert not set(victims.tolist()) & {3, 17, 29}
        # never evicts inside the protected recent window
        assert victims.max() < 50 - 8

    def test_windowed_argmin_spreads_evictions(self):
        """Windowed RMQ eviction never clusters (vs global top-k)."""
        mgr = RMQEvictionManager(budget=92, protected_window=4, c=8, t=4)
        scores = np.ones(100, dtype=np.float32)
        scores[:20] = 0.01  # a low-score cluster
        victims = np.asarray(mgr.plan_evictions(jnp.asarray(scores), 100))
        assert len(victims) == 8
        # victims are one-per-window -> spread across [0, 96)
        assert victims.max() > 50

    def test_apply_evictions_compacts(self):
        mgr = RMQEvictionManager(budget=6, protected_window=2)
        scores = jnp.asarray(np.arange(8, dtype=np.float32))
        cache = jnp.arange(8 * 3).reshape(8, 3)
        victims = jnp.asarray([0, 1], jnp.int32)
        new_scores, (new_cache,), live = mgr.apply_evictions(
            victims, scores, 8, cache
        )
        assert live == 6
        np.testing.assert_array_equal(np.asarray(new_scores),
                                      np.arange(2, 8, dtype=np.float32))
        np.testing.assert_array_equal(np.asarray(new_cache[0]),
                                      np.asarray(cache[2]))

    def test_no_eviction_below_budget(self):
        mgr = RMQEvictionManager(budget=100, protected_window=4)
        assert not mgr.needs_eviction(50)
        v = mgr.plan_evictions(jnp.zeros(50), 50)
        assert v.shape[0] == 0

    def test_tiny_non_pow2_evictable_region(self):
        """Protected window nearly covering the context: the fitted
        chunk size must stay a power of two (regression: evictable=5
        used to feed c=5 into make_plan and crash)."""
        mgr = RMQEvictionManager(budget=43, protected_window=40, c=8, t=4)
        scores = np.ones(45, dtype=np.float32)
        scores[2] = 0.0
        victims = np.asarray(mgr.plan_evictions(jnp.asarray(scores), 45))
        assert len(victims) == 2
        assert 2 in victims.tolist()
        assert victims.max() < 5   # evictable region is [0, 5)


class TestStreamingEviction:
    def test_streaming_path_matches_one_shot_planner(self):
        """Same scores => same victims from both planners (bit-exact)."""
        mgr = RMQEvictionManager(budget=40, protected_window=8, c=8, t=4)
        rng = np.random.default_rng(7)
        for live in (46, 50):
            scores = rng.random(live).astype(np.float32)
            want = np.asarray(mgr.plan_evictions(jnp.asarray(scores), live))
            cap = 64
            index = mgr.make_index(cap)
            slot_scores = jnp.where(
                jnp.arange(cap) < live,
                jnp.pad(jnp.asarray(scores), (0, cap - live)),
                jnp.inf,
            )
            index, got = mgr.plan_evictions_streaming(
                index, slot_scores, live
            )
            np.testing.assert_array_equal(np.asarray(got), want)

    def test_streaming_index_reuses_across_rounds(self):
        """Consecutive rounds mutate the same index — no rebuilds."""
        mgr = RMQEvictionManager(budget=30, protected_window=4, c=8, t=4)
        cap = 64
        index = mgr.make_index(cap)
        rng = np.random.default_rng(1)
        plan0 = index.plan
        for live in (34, 38, 33):
            scores = jnp.where(
                jnp.arange(cap) < live,
                jnp.asarray(rng.random(cap).astype(np.float32)),
                jnp.inf,
            )
            index, victims = mgr.plan_evictions_streaming(
                index, scores, live
            )
            assert victims.shape[0] == live - 30
            assert index.plan is plan0  # geometry never re-planned

    def test_engine_eviction_never_rebuilds_per_round(self, monkeypatch):
        """The hard acceptance bar: one index build per generation, zero
        per-round hierarchy rebuilds (the old path rebuilt every round)."""
        # every implementation builds through the protocol module's shared
        # dispatch, so counting there covers StreamingRMQ.from_array
        import repro.core.protocol as protocol_mod
        from repro.core.api import RMQ as RMQClass

        builds = {"n": 0}
        orig_build = protocol_mod.build_hierarchy

        def counting_build(*args, **kwargs):
            builds["n"] += 1
            return orig_build(*args, **kwargs)

        monkeypatch.setattr(
            protocol_mod, "build_hierarchy", counting_build
        )

        def forbid_rebuild(*args, **kwargs):
            raise AssertionError(
                "eviction round called RMQ.build — rebuild path is dead"
            )

        monkeypatch.setattr(RMQClass, "build", staticmethod(forbid_rebuild))

        cfg = get_smoke_config("llama3.2-3b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        sc = ServeConfig(
            seq_len=96, batch=2, kv_cache_dtype="float32",
            eviction_enabled=True, eviction_budget=48,
            eviction_window=16, rmq_chunk=16, rmq_threshold=4,
        )
        eng = ServeEngine(cfg, params, sc)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                     cfg.vocab_size)
        out = eng.generate(prompts, 48)
        assert out["evicted"] > 0          # eviction actually ran
        assert out["final_pos"] <= 48 + 1  # budget still enforced
        assert builds["n"] == 1            # exactly the one index build


class TestServeEngine:
    def test_greedy_generation_deterministic(self):
        cfg = get_smoke_config("qwen1.5-0.5b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        sc = ServeConfig(seq_len=64, batch=2, kv_cache_dtype="float32")
        eng = ServeEngine(cfg, params, sc)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                     cfg.vocab_size)
        out1 = eng.generate(prompts, 8)
        out2 = eng.generate(prompts, 8)
        np.testing.assert_array_equal(np.asarray(out1["tokens"]),
                                      np.asarray(out2["tokens"]))
        assert out1["tokens"].shape == (2, 8)

    def test_eviction_keeps_position_under_budget(self):
        cfg = get_smoke_config("llama3.2-3b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        sc = ServeConfig(
            seq_len=96, batch=2, kv_cache_dtype="float32",
            eviction_enabled=True, eviction_budget=48,
            eviction_window=16, rmq_chunk=16, rmq_threshold=4,
        )
        eng = ServeEngine(cfg, params, sc)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                     cfg.vocab_size)
        out = eng.generate(prompts, 48)
        assert out["evicted"] > 0
        assert out["final_pos"] <= 48 + 1  # budget enforced

    def test_ssm_arch_serves_without_eviction(self):
        """mamba2 (attention-free): the technique is inapplicable — the
        engine must serve without an eviction manager (DESIGN.md
        §Arch-applicability)."""
        cfg = get_smoke_config("mamba2-1.3b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        sc = ServeConfig(seq_len=48, batch=2, kv_cache_dtype="float32")
        eng = ServeEngine(cfg, params, sc)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                     cfg.vocab_size)
        out = eng.generate(prompts, 8)
        assert out["tokens"].shape == (2, 8)


class TestMoEInvariants:
    def test_router_probabilities_and_aux_loss(self):
        cfg = get_smoke_config("qwen2-moe-a2.7b")
        key = jax.random.PRNGKey(0)
        from repro.models.moe import moe_init

        p = moe_init(key, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model),
                              jnp.float32)
        y, aux = moe_apply(p, x, cfg)
        assert y.shape == x.shape
        assert float(aux) >= 0.0
        assert bool(jnp.isfinite(y).all())

    def test_capacity_formula(self):
        cfg = get_smoke_config("qwen2-moe-a2.7b")
        cap = _capacity(cfg, 4096)
        expected = 4096 * cfg.num_experts_per_tok * cfg.capacity_factor \
            / cfg.num_experts
        assert cap >= expected
        assert cap % 128 == 0  # shardable slots

    def test_no_drop_capacity_matches_dense_compute(self):
        """With capacity >= T*k the MoE layer must route every token."""
        cfg = dataclasses.replace(
            get_smoke_config("qwen2-moe-a2.7b"),
            capacity_factor=float(get_smoke_config(
                "qwen2-moe-a2.7b").num_experts),
        )
        from repro.models.moe import moe_init

        p = moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
        y_all, _ = moe_apply(p, x, cfg)
        # same input twice -> deterministic routing
        y_again, _ = moe_apply(p, x, cfg)
        np.testing.assert_array_equal(np.asarray(y_all),
                                      np.asarray(y_again))
