"""Per-architecture smoke tests (assignment requirement).

Each of the 10 assigned archs: instantiate the REDUCED same-family config,
run one forward pass and one train step on CPU, assert output shapes and
finiteness; plus prefill→decode equivalence against the full forward.
"""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, TrainConfig, get_config, get_smoke_config
from repro.models import decode_step, forward, init_params, prefill
from repro.models.frontends import synthetic_frontend_embeddings
from repro.train import build_train_step, init_train_state


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_smoke_config(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        b, s = 2, 32
        tokens = jax.random.randint(
            jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size
        )
        pre = synthetic_frontend_embeddings(cfg, b)
        logits, aux = forward(cfg, params, tokens, prefix_embeddings=pre)
        f = cfg.frontend_tokens if cfg.frontend else 0
        assert logits.shape == (b, s + f, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits).all())
        assert bool(jnp.isfinite(aux))

    def test_one_train_step(self, arch):
        cfg = get_smoke_config(arch)
        tc = TrainConfig(total_steps=4, warmup_steps=1, seq_len=32,
                         global_batch=2)
        state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
        step = jax.jit(build_train_step(cfg, tc))
        batch = {
            "tokens": jax.random.randint(
                jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size
            )
        }
        pre = synthetic_frontend_embeddings(cfg, 2)
        if pre is not None:
            batch["prefix"] = pre
        state, metrics = step(state, batch)
        assert bool(jnp.isfinite(metrics["loss"]))
        assert bool(jnp.isfinite(metrics["grad_norm"]))
        assert int(metrics["step"]) == 1
        # params actually changed
        leaf = jax.tree.leaves(state.params)[0]
        assert bool(jnp.isfinite(leaf).all())

    def test_prefill_decode_matches_forward(self, arch):
        cfg = get_smoke_config(arch)
        if cfg.uses_moe:
            # exact equivalence requires no capacity drops
            cfg = dataclasses.replace(
                cfg, capacity_factor=float(cfg.num_experts)
            )
        params = init_params(cfg, jax.random.PRNGKey(0))
        b, s = 2, 16
        f = cfg.frontend_tokens if cfg.frontend else 0
        pre = synthetic_frontend_embeddings(cfg, b)
        toks = jax.random.randint(
            jax.random.PRNGKey(1), (b, s + 2), 0, cfg.vocab_size
        )
        logits_full, _ = forward(cfg, params, toks, prefix_embeddings=pre)
        lg, cache = prefill(
            cfg, params, toks[:, :s], cache_len=32 + f,
            prefix_embeddings=pre, cache_dtype=jnp.float32,
        )
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full[:, f + s - 1]),
            atol=1e-3, rtol=1e-3,
        )
        for i, sp in enumerate([s, s + 1]):
            lg, cache, _ = decode_step(
                cfg, params, toks[:, sp], cache, pos=f + sp
            )
            np.testing.assert_allclose(
                np.asarray(lg), np.asarray(logits_full[:, f + sp]),
                atol=1e-3, rtol=1e-3,
            )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_geometry(arch):
    """Full configs match the assigned geometry (no allocation)."""
    cfg = get_config(arch)
    assigned = {
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 202048),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 151936),
        "internvl2-2b": (24, 2048, 16, 8, 92553),
        "command-r-plus-104b": (64, 12288, 96, 8, 256000),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 151936),
        "llama3.2-3b": (28, 3072, 24, 8, 128256),
        "minicpm3-4b": (62, 2560, 40, 40, 73448),
        "musicgen-medium": (48, 1536, 24, 24, 2048),
        "mamba2-1.3b": (48, 2048, 0, 0, 50280),
        "hymba-1.5b": (32, 1600, 25, 5, 32001),
    }[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads,
            cfg.num_kv_heads, cfg.vocab_size) == assigned


def test_param_counts_match_names():
    """Sanity: computed parameter counts sit near the model names."""
    budgets = {
        "llama4-maverick-400b-a17b": (3.3e11, 4.7e11),
        "command-r-plus-104b": (0.9e11, 1.2e11),
        "llama3.2-3b": (2.5e9, 4.3e9),
        "qwen1.5-0.5b": (4e8, 8e8),
        "minicpm3-4b": (3e9, 5e9),
        "mamba2-1.3b": (1.0e9, 1.8e9),
        "hymba-1.5b": (1.0e9, 2.2e9),
        "musicgen-medium": (1.2e9, 2.4e9),
        "internvl2-2b": (1.5e9, 2.7e9),
        "qwen2-moe-a2.7b": (1.2e10, 1.7e10),
    }
    for arch, (lo, hi) in budgets.items():
        n = get_config(arch).num_params()
        assert lo <= n <= hi, (arch, f"{n:.3e}", lo, hi)
    # MoE active params far below total
    cfg = get_config("llama4-maverick-400b-a17b")
    assert cfg.num_active_params() < 0.1 * cfg.num_params()
