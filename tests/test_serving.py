"""Serving-tier tests: deadline scheduling, snapshot isolation,
admission control, telemetry — plus the QueryService regressions the
tier's arrival pinned down (mixed-retry stats accounting, per-index
unclaimed-result bounds with the drop hook).

Scheduler semantics are tested deterministically: an injected fake
clock plus manual ``ServingTier.step(now)`` calls make flush triggers
(deadline / size / mutation) exact, and the ``on_flush`` hook — which
fires after the snapshot is pinned and staged mutations swapped, before
the read batch executes — is the seam where "mutation admitted
mid-flush must not change this flush's answers" is observable without
racing threads.  The threaded stress test then does race threads, and
checks every ticket against a numpy oracle replayed at the ticket's
recorded snapshot generation.
"""

import threading
import time

import numpy as np
import pytest

from repro.core.api import RMQ
from repro.qe import QueryService
from repro.qe.executors import INDEX, VALUE
from repro.serving import (
    Backpressure,
    Metrics,
    ServingTier,
    SnapshotSlot,
    TenantConfig,
)


def _tied_values(rng, n):
    """Integer-valued floats: ties make leftmost-position breaks decisive."""
    return rng.integers(-4, 4, n).astype(np.float32)


def _random_spans(rng, n, m):
    ls = rng.integers(0, n, m)
    rs = np.minimum(ls + rng.integers(0, n, m), n - 1)
    return (np.minimum(ls, rs).astype(np.int32),
            np.maximum(ls, rs).astype(np.int32))


def _fused(x, with_positions=True):
    return RMQ.build(x, c=8, t=2, with_positions=with_positions,
                     backend="fused")


class FakeClock:
    def __init__(self, now=0.0):
        self.now = float(now)

    def __call__(self):
        return self.now

    def advance(self, dt):
        self.now += dt
        return self.now


def _oracle_replay(base, mutation_log):
    """generation -> quiesced array, applying staged batches in order
    (sequential writes: duplicate indices are last-wins, the indexes'
    documented contract)."""
    snaps = {0: base.copy()}
    arr = base.copy()
    for gen, (idxs, vals) in enumerate(mutation_log, start=1):
        arr = arr.copy()
        for i, v in zip(idxs, vals):
            arr[int(i)] = v
        snaps[gen] = arr
    return snaps


# ---------------------------------------------------------------------------
# SnapshotSlot: the double buffer on its own
# ---------------------------------------------------------------------------
class TestSnapshotSlot:
    def test_stage_is_invisible_until_swap(self):
        rng = np.random.default_rng(0)
        x = _tied_values(rng, 300)
        slot = SnapshotSlot(_fused(x))
        old_front = slot.front
        slot.stage_update(np.array([3], np.int32),
                          np.array([-99.0], np.float32))
        slot.stage_update(np.array([7], np.int32),
                          np.array([-98.0], np.float32))
        assert slot.front is old_front          # readers unaffected
        assert slot.staged == 2
        front, applied = slot.swap()
        assert applied == 2
        assert front is slot.front is not old_front
        assert slot.generation == 2             # one successor per record
        assert slot.staged == 0
        assert slot.swap() == (front, 0)        # idempotent when empty

    def test_pinned_reader_keeps_old_front_across_swap(self):
        rng = np.random.default_rng(1)
        x = _tied_values(rng, 300)
        slot = SnapshotSlot(_fused(x))
        snap = slot.pin()
        assert slot.pins == 1
        slot.stage_update(np.array([0], np.int32),
                          np.array([-99.0], np.float32))
        slot.swap()
        assert snap.index is not slot.front     # old generation survives
        assert snap.generation == 0
        assert slot.generation == 1
        snap.release()
        assert slot.pins == 0

    def test_release_without_pin_raises(self):
        slot = SnapshotSlot(_fused(np.zeros(64, np.float32)))
        with pytest.raises(RuntimeError, match="matching pin"):
            slot._release()

    def test_replace_supersedes_earlier_staged_ops(self):
        rng = np.random.default_rng(2)
        x = _tied_values(rng, 300)
        y = _tied_values(rng, 300)
        slot = SnapshotSlot(_fused(x))
        # this update is superseded by the wholesale replacement...
        slot.stage_update(np.array([0], np.int32),
                          np.array([-99.0], np.float32))
        slot.stage_replace(_fused(y))
        # ...but ops staged AFTER the replacement apply on top of it
        slot.stage_update(np.array([5], np.int32),
                          np.array([-77.0], np.float32))
        front, applied = slot.swap()
        assert applied == 2                     # replace + trailing update
        got = np.asarray(front.query(np.array([0, 5], np.int32),
                                     np.array([0, 5], np.int32)))
        assert got[0] == y[0]                   # -99 never applied
        assert got[1] == -77.0


# ---------------------------------------------------------------------------
# deterministic scheduler: fake clock + manual step()
# ---------------------------------------------------------------------------
class TestDeadlineScheduler:
    def _tier(self, x, clock, **tenant_kw):
        tier = ServingTier(clock=clock)
        tier.register_tenant("a", _fused(x), **tenant_kw)
        return tier

    def test_deadline_flush_fires_at_slo_not_before(self):
        rng = np.random.default_rng(3)
        x = _tied_values(rng, 500)
        clock = FakeClock()
        tier = self._tier(x, clock, slo_ms=5.0)
        tk = tier.submit("a", np.array([0]), np.array([499]))
        assert tier.step(clock.advance(0.004)) == pytest.approx(0.005)
        assert not tk.done()                    # 4ms < 5ms SLO: queued
        tier.step(clock.advance(0.0015))        # 5.5ms: due
        assert tk.done()
        assert float(tk.result(0)[0]) == x.min()
        t = tier.stats()["tenants"]["a"]
        assert t["flushes"] == 1
        assert t["flushes_deadline"] == 1
        assert t["flushes_size"] == 0

    def test_size_flush_fires_before_deadline(self):
        rng = np.random.default_rng(4)
        x = _tied_values(rng, 500)
        clock = FakeClock()
        tier = self._tier(x, clock, slo_ms=1000.0, max_queue=64,
                          max_batch=8)
        ls, rs = _random_spans(rng, 500, 8)
        tks = [tier.submit("a", ls[i:i + 4], rs[i:i + 4])
               for i in (0, 4)]
        tier.step(clock.now)                    # zero time has passed
        assert all(tk.done() for tk in tks)
        t = tier.stats()["tenants"]["a"]
        assert t["flushes_size"] == 1
        assert t["flushes_deadline"] == 0

    def test_mutation_only_flush_swaps_on_slo(self):
        rng = np.random.default_rng(5)
        x = _tied_values(rng, 500)
        clock = FakeClock()
        tier = self._tier(x, clock, slo_ms=5.0)
        pos = int(np.argmin(x))
        tier.update("a", np.array([pos], np.int32),
                    np.array([50.0], np.float32))
        tier.step(clock.advance(0.003))
        assert tier.stats()["tenants"]["a"]["snapshot_swaps"] == 0
        tier.step(clock.advance(0.003))         # past the mutation SLO
        t = tier.stats()["tenants"]["a"]
        assert t["snapshot_swaps"] == 1
        assert t["flushes_mutation"] == 1
        assert t["mutations_applied"] == 1
        # the published generation serves subsequent reads
        tk = tier.submit("a", np.array([0]), np.array([499]))
        tier.drain("a")
        want = x.copy()
        want[pos] = 50.0
        assert float(tk.result(0)[0]) == want.min()
        assert tk.generation == 1

    def test_step_reports_earliest_deadline_across_tenants(self):
        rng = np.random.default_rng(6)
        clock = FakeClock()
        tier = ServingTier(clock=clock)
        assert tier.step(clock.now) is None     # no tenants: idle
        tier.register_tenant("slow", _fused(_tied_values(rng, 200)),
                             slo_ms=50.0)
        tier.register_tenant("fast", _fused(_tied_values(rng, 200)),
                             slo_ms=2.0)
        tier.submit("slow", np.array([0]), np.array([10]))
        tier.submit("fast", np.array([0]), np.array([10]))
        assert tier.step(clock.now) == pytest.approx(0.002)

    def test_drain_resolves_everything_now(self):
        rng = np.random.default_rng(7)
        x = _tied_values(rng, 500)
        clock = FakeClock()
        tier = self._tier(x, clock, slo_ms=1000.0)
        ls, rs = _random_spans(rng, 500, 6)
        tk_v = tier.submit("a", ls, rs, VALUE)
        tk_i = tier.submit("a", ls, rs, INDEX)
        assert tier.drain("a") == 2
        np.testing.assert_array_equal(
            np.asarray(tk_v.result(0)),
            [x[l:r + 1].min() for l, r in zip(ls, rs)],
        )
        np.testing.assert_array_equal(
            np.asarray(tk_i.result(0)),
            [l + int(np.argmin(x[l:r + 1])) for l, r in zip(ls, rs)],
        )
        assert tier.stats()["tenants"]["a"]["flushes_forced"] == 1
        assert tier.drain("a") == 0             # nothing left: no-op


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------
class TestAdmission:
    def test_tenant_config_validation(self):
        with pytest.raises(ValueError, match="slo_ms"):
            TenantConfig(slo_ms=0.0)
        with pytest.raises(ValueError, match="max_batch"):
            TenantConfig(max_queue=4, max_batch=8)
        with pytest.raises(ValueError, match="quota_qps"):
            TenantConfig(quota_qps=-1.0)

    def test_queue_bound_rejects_with_retry_after(self):
        rng = np.random.default_rng(8)
        x = _tied_values(rng, 300)
        clock = FakeClock()
        tier = ServingTier(clock=clock)
        tier.register_tenant("a", _fused(x), slo_ms=5.0, max_queue=8,
                             max_batch=8)
        ls, rs = _random_spans(rng, 300, 8)
        tier.submit("a", ls, rs)
        with pytest.raises(Backpressure) as ei:
            tier.submit("a", np.array([0]), np.array([1]))
        assert ei.value.reason == "queue_full"
        assert ei.value.tenant == "a"
        # retry_after points at the head-of-queue deadline (5ms SLO)
        assert 0 < ei.value.retry_after <= 0.006
        assert tier.stats()["tenants"]["a"]["rejected_queue_full"] == 1
        # a flush frees the queue and admission recovers
        tier.drain("a")
        tier.submit("a", np.array([0]), np.array([1]))

    def test_quota_token_bucket_refills_with_clock(self):
        rng = np.random.default_rng(9)
        x = _tied_values(rng, 300)
        clock = FakeClock()
        tier = ServingTier(clock=clock)
        tier.register_tenant("a", _fused(x), quota_qps=100.0,
                             quota_burst=4.0)
        ls, rs = _random_spans(rng, 300, 4)
        tier.submit("a", ls, rs)                # burst fully spent
        with pytest.raises(Backpressure) as ei:
            tier.submit("a", np.array([0]), np.array([1]))
        assert ei.value.reason == "quota"
        assert ei.value.retry_after == pytest.approx(1 / 100.0)
        clock.advance(0.05)                     # 5 tokens accrue, cap 4
        tier.submit("a", ls, rs)
        assert tier.stats()["tenants"]["a"]["rejected_quota"] == 1

    def test_registry_errors(self):
        rng = np.random.default_rng(10)
        tier = ServingTier()
        tier.register_tenant("a", _fused(_tied_values(rng, 200)))
        with pytest.raises(ValueError, match="already registered"):
            tier.register_tenant("a", _fused(_tied_values(rng, 200)))
        with pytest.raises(KeyError, match="no tenant"):
            tier.submit("nope", np.array([0]), np.array([1]))
        with pytest.raises(KeyError):
            tier.tenant_config("nope")

    def test_unregister_drains_then_rejects(self):
        rng = np.random.default_rng(11)
        x = _tied_values(rng, 300)
        tier = ServingTier()
        tier.register_tenant("a", _fused(x), slo_ms=1000.0)
        tk = tier.submit("a", np.array([0]), np.array([299]))
        tier.unregister_tenant("a")
        assert float(tk.result(0)[0]) == x.min()   # drained, not dropped
        with pytest.raises(KeyError):
            tier.submit("a", np.array([0]), np.array([1]))


# ---------------------------------------------------------------------------
# oversized read-only submissions: bulk routing instead of rejection
# ---------------------------------------------------------------------------
class TestOversizedSubmissions:
    def test_oversized_submission_resolves_via_bulk_not_backpressure(self):
        """Regression: a read batch wider than ``max_queue`` used to be
        unadmittable forever (queue_full with no queue state to drain).
        It now routes through the engine's bulk path and comes back
        already resolved, bit-identical to the per-span oracle."""
        rng = np.random.default_rng(30)
        n = 600
        x = _tied_values(rng, n)
        clock = FakeClock()
        tier = ServingTier(clock=clock)
        tier.register_tenant("a", _fused(x), max_queue=64, max_batch=32,
                             bulk_crossover=1, cache_size=0)
        m = 256                                 # > max_queue: old code
        ls, rs = _random_spans(rng, n, m)       # rejected this forever
        tk = tier.submit("a", ls, rs)
        assert tk.done()                        # resolved inline
        assert tk.generation == 0
        np.testing.assert_array_equal(
            np.asarray(tk.result(0)),
            [x[l:r + 1].min() for l, r in zip(ls, rs)],
        )
        tk_i = tier.submit("a", ls, rs, INDEX)
        np.testing.assert_array_equal(
            np.asarray(tk_i.result(0)),
            [l + int(np.argmin(x[l:r + 1])) for l, r in zip(ls, rs)],
        )
        t = tier.stats()["tenants"]["a"]
        assert t["bulk_routed"] == 2
        assert t["rejected_queue_full"] == 0
        assert t["queued_queries"] == 0         # never touched the queue
        assert t["flushes"] == 0                # and never forced a flush
        assert t["latency_s"]["count"] == 2

    def test_oversized_reads_current_generation_not_staged(self):
        """Bulk bypass answers against the front generation; staged
        mutations wait for the next flush — same semantics as a queued
        read admitted before the swap."""
        rng = np.random.default_rng(31)
        n = 500
        x = _tied_values(rng, n)
        clock = FakeClock()
        tier = ServingTier(clock=clock)
        tier.register_tenant("a", _fused(x), max_batch=16,
                             bulk_crossover=1, cache_size=0)
        pos = int(np.argmin(x))
        tier.update("a", np.array([pos], np.int32),
                    np.array([99.0], np.float32))
        ls = np.zeros(64, np.int32)
        rs = np.full(64, n - 1, np.int32)
        tk = tier.submit("a", ls, rs)           # staged, not applied
        assert float(tk.result(0)[0]) == x.min()
        assert tk.generation == 0
        tier.drain("a")                         # swap applies the update
        want = x.copy()
        want[pos] = 99.0
        tk2 = tier.submit("a", ls, rs)
        assert float(tk2.result(0)[0]) == want.min()
        assert tk2.generation == 1

    def test_oversized_still_pays_quota(self):
        """Only the queue bound is bypassed — the token bucket is rate
        admission and still rejects an oversized burst."""
        rng = np.random.default_rng(32)
        x = _tied_values(rng, 300)
        clock = FakeClock()
        tier = ServingTier(clock=clock)
        tier.register_tenant("a", _fused(x), max_batch=8,
                             quota_qps=100.0, quota_burst=16.0)
        ls, rs = _random_spans(rng, 300, 32)    # > max_batch AND > burst
        with pytest.raises(Backpressure) as ei:
            tier.submit("a", ls, rs)
        assert ei.value.reason == "quota"
        assert tier.stats()["tenants"]["a"]["bulk_routed"] == 0

    def test_small_submissions_still_queue_alongside_bulk(self):
        """Coexistence: an oversized bypass must not flush, reorder, or
        starve the deadline queue it skipped."""
        rng = np.random.default_rng(33)
        n = 400
        x = _tied_values(rng, n)
        clock = FakeClock()
        tier = ServingTier(clock=clock)
        tier.register_tenant("a", _fused(x), slo_ms=5.0, max_batch=16,
                             bulk_crossover=1, cache_size=0)
        small = tier.submit("a", np.array([0]), np.array([n - 1]))
        big_ls, big_rs = _random_spans(rng, n, 64)
        big = tier.submit("a", big_ls, big_rs)
        assert big.done() and not small.done()  # queue untouched
        assert tier.stats()["tenants"]["a"]["queued_queries"] == 1
        tier.step(clock.advance(0.006))         # deadline flush as usual
        assert float(small.result(0)[0]) == x.min()
        t = tier.stats()["tenants"]["a"]
        assert t["flushes_deadline"] == 1
        assert t["bulk_routed"] == 1
        assert t["submits"] == 2


# ---------------------------------------------------------------------------
# snapshot isolation: the tentpole's correctness claim
# ---------------------------------------------------------------------------
class TestSnapshotIsolation:
    def test_mutation_admitted_mid_flush_does_not_change_answers(self):
        """A mutation staged while a flush is executing (after the
        snapshot pin — the on_flush hook's exact position) must leave
        that flush's answers on the pinned generation, and apply to the
        next one."""
        rng = np.random.default_rng(12)
        x = _tied_values(rng, 600)
        clock = FakeClock()
        pos = int(np.argmin(x))
        staged = {"done": False}
        events = []

        def mid_flush(ev):
            events.append(ev)
            if not staged["done"]:
                staged["done"] = True
                # admitted MID-FLUSH: reads for this flush already
                # pinned generation 0
                tier.update("a", np.array([pos], np.int32),
                            np.array([99.0], np.float32))

        tier = ServingTier(clock=clock, on_flush=mid_flush)
        tier.register_tenant("a", _fused(x), slo_ms=5.0)
        tk1 = tier.submit("a", np.array([0]), np.array([599]))
        tier.step(clock.advance(0.006))
        assert float(tk1.result(0)[0]) == x.min()   # pre-mutation answer
        assert tk1.generation == 0
        assert events[0].generation == 0
        assert events[0].applied_mutations == 0

        tk2 = tier.submit("a", np.array([0]), np.array([599]))
        tier.step(clock.advance(0.006))
        want = x.copy()
        want[pos] = 99.0
        assert float(tk2.result(0)[0]) == want.min()
        assert tk2.generation == 1
        assert events[1].applied_mutations == 1
        assert tier.stats()["tenants"]["a"]["snapshot_swaps"] == 1

    def test_threaded_stress_differential_vs_generation_oracle(self):
        """Real threads, real clock: concurrent submitters + a mutator
        against the running tier.  Every ticket's answers must be
        bit-identical (values AND leftmost-tie positions) to a numpy
        oracle replayed at the ticket's recorded generation."""
        rng = np.random.default_rng(13)
        n = 1500
        x = _tied_values(rng, n)
        tier = ServingTier(idle_tick=0.001)
        tier.register_tenant("a", _fused(x), slo_ms=2.0,
                             max_queue=1 << 14, cache_size=0)
        mutation_log = []
        answered = []
        ans_lock = threading.Lock()
        stop = threading.Event()

        def mutator():
            mrng = np.random.default_rng(14)
            while not stop.is_set():
                idxs = mrng.integers(0, n, 4).astype(np.int32)
                vals = _tied_values(mrng, 4)
                mutation_log.append((idxs, vals))
                tier.update("a", idxs, vals)
                time.sleep(0.002)

        def reader(seed):
            rrng = np.random.default_rng(seed)
            got = []
            for j in range(8):
                ls, rs = _random_spans(rrng, n, 6)
                op = INDEX if j % 2 else VALUE
                tk = tier.submit("a", ls, rs, op)
                got.append((tk, ls, rs, op,
                            np.asarray(tk.result(timeout=30.0))))
            with ans_lock:
                answered.extend(got)

        readers = [threading.Thread(target=reader, args=(20 + i,))
                   for i in range(3)]
        mut = threading.Thread(target=mutator)
        with tier:
            mut.start()
            for r in readers:
                r.start()
            for r in readers:
                r.join()
            stop.set()
            mut.join()

        snaps = _oracle_replay(x, mutation_log)
        gens = set()
        for tk, ls, rs, op, res in answered:
            assert tk.generation is not None
            gens.add(tk.generation)
            arr = snaps[tk.generation]
            for l, r, v in zip(ls, rs, res):
                want = (arr[l:r + 1].min() if op == VALUE
                        else l + int(np.argmin(arr[l:r + 1])))
                assert v == want, (tk.generation, op, l, r, v, want)
        assert len(answered) == 24
        # the mutator really did move the array under the readers
        assert tier.stats()["tenants"]["a"]["snapshot_swaps"] > 0


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------
class TestTelemetry:
    def test_metrics_primitives(self):
        m = Metrics()
        c = m.counter("hits")
        c.inc()
        c.inc(3)
        h = m.histogram("lat", (0.1, 1.0, 10.0))
        for v in (0.05, 0.5, 0.5, 20.0):
            h.record(v)
        d = m.as_dict()
        assert d["hits"] == 4
        assert d["lat"]["count"] == 4
        assert d["lat"]["max"] == 20.0
        assert d["lat"]["p50"] <= d["lat"]["p99"]
        assert m.counter("hits") is c           # lazy registry, stable

    def test_tier_stats_shape(self):
        rng = np.random.default_rng(15)
        x = _tied_values(rng, 400)
        clock = FakeClock()
        tier = ServingTier(clock=clock)
        tier.register_tenant("a", _fused(x), slo_ms=5.0)
        tk = tier.submit("a", np.array([0, 5]), np.array([9, 50]))
        tier.step(clock.advance(0.01))
        tk.result(0)
        s = tier.stats()
        t = s["tenants"]["a"]
        assert t["submits"] == 1
        assert t["submitted_queries"] == 2
        assert t["flushes"] == 1
        assert t["latency_s"]["count"] == 1
        assert t["flush_queries"]["count"] == 1
        assert t["snapshot"]["generation"] == 0
        assert t["snapshot"]["pins"] == 0
        assert t["queued_queries"] == 0
        assert s["service"]["flushes"] == 1
        assert s["steps"] == 1

    def test_tier_counts_service_result_drops(self):
        """The unclaimed-FIFO drop hook reaches tenant telemetry (the
        serving tier is the warning consumer the service's silent drops
        needed)."""
        rng = np.random.default_rng(16)
        x = _tied_values(rng, 400)
        svc = QueryService(auto_flush=False, max_unclaimed=1)
        tier = ServingTier(service=svc)
        tier.register_tenant("a", _fused(x))
        # drive the service directly, never claiming: results age out
        for i in range(3):
            svc.submit("a", np.array([i]), np.array([i + 5]))
            svc.flush(names=("a",))
        assert tier.stats()["tenants"]["a"]["dropped_results"] == 2


# ---------------------------------------------------------------------------
# QueryService regressions pinned by this PR
# ---------------------------------------------------------------------------
class TestServiceRegressions:
    def _submit_pairs(self, svc, rng, x, nv, ni):
        n = x.shape[0]
        tickets = []
        for _ in range(nv):
            ls, rs = _random_spans(rng, n, 3)
            tickets.append((svc.submit("a", ls, rs, VALUE), ls, rs, VALUE))
        for _ in range(ni):
            ls, rs = _random_spans(rng, n, 3)
            tickets.append((svc.submit("a", ls, rs, INDEX), ls, rs, INDEX))
        return tickets

    def _flaky_mixed(self, engine):
        """Make the first query_mixed call fail, then restore parity."""
        orig = engine.query_mixed
        state = {"calls": 0}

        def flaky(ls, rs, flags):
            state["calls"] += 1
            if state["calls"] == 1:
                raise RuntimeError("transient mixed-kernel failure")
            return orig(ls, rs, flags)

        engine.query_mixed = flaky
        return state

    def test_mixed_retry_counts_coalescing_once_multirequest(self):
        """Regression: when a merged mixed flush fails and retries per
        op, the admission-coalesced group must count as ONE coalesced
        batch — the old delegation to run_group double-counted it (once
        per multi-request op group)."""
        rng = np.random.default_rng(17)
        x = _tied_values(rng, 900)
        svc = QueryService()
        engine = svc.register("a", _fused(x), cache_size=0)
        state = self._flaky_mixed(engine)
        tickets = self._submit_pairs(svc, rng, x, nv=2, ni=2)
        res = svc.flush()           # retries succeed: no error surfaces
        assert state["calls"] == 1
        s = svc.stats()
        assert s["mixed_retries"] == 1
        assert s["flushes"] == 1
        assert s["coalesced_batches"] == 1      # was 2 before the fix
        for tk, ls, rs, op in tickets:
            want = ([x[l:r + 1].min() for l, r in zip(ls, rs)]
                    if op == VALUE else
                    [l + int(np.argmin(x[l:r + 1]))
                     for l, r in zip(ls, rs)])
            np.testing.assert_array_equal(np.asarray(res[tk]), want)

    def test_mixed_retry_counts_coalescing_once_singletons(self):
        """Regression twin: one value + one index request.  The merged
        admission coalesced two requests, so the count is 1 even though
        each per-op retry group is a singleton — the old delegation
        reported 0 on this shape."""
        rng = np.random.default_rng(18)
        x = _tied_values(rng, 900)
        svc = QueryService()
        engine = svc.register("a", _fused(x), cache_size=0)
        self._flaky_mixed(engine)
        tickets = self._submit_pairs(svc, rng, x, nv=1, ni=1)
        svc.flush()
        s = svc.stats()
        assert s["mixed_retries"] == 1
        assert s["coalesced_batches"] == 1      # was 0 before the fix
        for tk, *_ in tickets:
            svc.take(tk)                        # both answered

    def test_mixed_retry_with_real_op_failure_counts_once(self):
        """The genuinely-failing shape (value-only successor lands after
        admission): the healthy VALUE group survives the retry, the
        stats still count the coalesced admission exactly once, and the
        retry is visible in ``mixed_retries``."""
        rng = np.random.default_rng(19)
        x = _tied_values(rng, 900)
        svc = QueryService()
        svc.register("a", _fused(x), cache_size=0)
        t_v = svc.submit("a", np.array([0]), np.array([899]))
        t_i1 = svc.submit("a", np.array([1]), np.array([50]), op=INDEX)
        t_i2 = svc.submit("a", np.array([2]), np.array([60]), op=INDEX)
        svc.attach("a", _fused(x, with_positions=False),
                   reset_cache=True)
        with pytest.raises(RuntimeError, match="claimable"):
            svc.flush()
        s = svc.stats()
        assert s["mixed_retries"] == 1
        assert s["coalesced_batches"] == 1
        assert float(svc.take(t_v)[0]) == x.min()
        for tk in (t_i1, t_i2):
            with pytest.raises(KeyError):
                svc.take(tk)

    def test_unclaimed_bound_is_per_index_with_drop_hook(self):
        """Regression: flooding one index's unclaimed results must not
        evict another index's (the bound was global), and every drop
        reports through ``on_dropped_result`` instead of vanishing."""
        rng = np.random.default_rng(20)
        xa = _tied_values(rng, 400)
        xb = _tied_values(rng, 400)
        svc = QueryService(auto_flush=False, max_unclaimed=2)
        svc.register("a", _fused(xa))
        svc.register("b", _fused(xb))
        drops = []
        svc.on_dropped_result = lambda name, tk: drops.append((name, tk))
        t_b = svc.submit("b", np.array([0]), np.array([399]))
        svc.flush()
        flooded = []
        for i in range(5):
            flooded.append(svc.submit("a", np.array([i]),
                                      np.array([i + 5])))
            svc.flush()
        # 'b' survived the flood of 'a' results (per-index FIFO bound)
        assert float(svc.take(t_b)[0]) == xb.min()
        assert [name for name, _ in drops] == ["a", "a", "a"]
        assert [tk for _, tk in drops] == flooded[:3]
        assert svc.stats()["dropped_results"] == 3
        assert svc.stats()["unclaimed_results"] == 2
        for tk in flooded[3:]:
            svc.take(tk)                        # recent ones claimable

    def test_selective_flush_leaves_other_tenants_queued(self):
        """flush(names=...) — the serving tier's per-tenant deadline
        flush must not drag other tenants' batches along."""
        rng = np.random.default_rng(21)
        xa = _tied_values(rng, 400)
        xb = _tied_values(rng, 400)
        svc = QueryService(auto_flush=False)
        svc.register("a", _fused(xa))
        svc.register("b", _fused(xb))
        t_a = svc.submit("a", np.array([0]), np.array([399]))
        t_b = svc.submit("b", np.array([0]), np.array([399]))
        res = svc.flush(names=("a",))
        assert t_a in res
        assert t_b not in res
        assert svc.stats()["pending_requests"] == 1   # b still queued
        with pytest.raises(KeyError):
            svc.take(t_b)
        svc.flush(names=("b",))
        assert float(svc.take(t_b)[0]) == xb.min()
