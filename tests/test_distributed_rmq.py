"""Distributed RMQ tests: run shard_map paths on fake CPU device meshes.

Multi-device cases run in a subprocess so the fake-device XLA flag never
leaks into this test process (smoke tests must see 1 device).

The streaming/engine additions (sharded update/append, engine routing)
share one small 2-level geometry — the first compile of a 3-level
distributed walk is pathologically slow on CPU XLA, and the pre-existing
tests below already cover that depth.
"""

import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.distributed import DistributedRMQ
from repro.qe import CROSSING, SEG_LOCAL, QueryService


def test_distributed_on_1x1_mesh_matches_naive():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(1)
    n = 4096
    x = rng.random(n).astype(np.float32)
    d = DistributedRMQ.build(x, mesh, c=16, t=8, with_positions=True)
    ls = rng.integers(0, n, 64)
    rs = np.minimum(ls + rng.integers(0, n, 64), n - 1)
    ls, rs = np.minimum(ls, rs), np.maximum(ls, rs)
    got = np.asarray(d.query(ls, rs))
    want = np.array([x[l : r + 1].min() for l, r in zip(ls, rs)])
    np.testing.assert_allclose(got, want)
    gotp = np.asarray(d.query_index(ls, rs))
    wantp = np.array([l + np.argmin(x[l : r + 1]) for l, r in zip(ls, rs)])
    np.testing.assert_array_equal(gotp, wantp)


_SUBPROCESS_PROG = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.distributed import DistributedRMQ

mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(2)
n = 10001  # not divisible by segments -> exercises padding
x = rng.random(n).astype(np.float32)
d = DistributedRMQ.build(x, mesh, c=16, t=8, with_positions=True)
m_q = 128
ls = rng.integers(0, n, m_q)
rs = np.minimum(ls + rng.integers(0, n, m_q), n - 1)
ls, rs = np.minimum(ls, rs), np.maximum(ls, rs)
got = np.asarray(d.query(ls, rs))
want = np.array([x[l:r+1].min() for l, r in zip(ls, rs)])
assert np.allclose(got, want), float(np.abs(got - want).max())
gotp = np.asarray(d.query_index(ls, rs))
wantp = np.array([l + np.argmin(x[l:r+1]) for l, r in zip(ls, rs)])
assert (gotp == wantp).all()
# cross-segment tie-break stays leftmost
xz = np.zeros(8000, dtype=np.float32)
dz = DistributedRMQ.build(xz, mesh, c=16, t=8, with_positions=True)
p = np.asarray(dz.query_index(np.array([100, 3000]), np.array([7999, 7999])))
assert p.tolist() == [100, 3000], p.tolist()
print("SUBPROCESS_OK")
"""


def _run_fake_mesh_subprocess(prog: str) -> None:
    res = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True,
        text=True,
        env={
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
            "PYTHONPATH": "src",
            "PATH": "/usr/bin:/bin:/usr/local/bin",
            "JAX_PLATFORMS": "cpu",
        },
        cwd="/root/repo",
        timeout=300,
    )
    assert "SUBPROCESS_OK" in res.stdout, res.stdout + res.stderr


def test_distributed_on_2x4_fake_mesh():
    _run_fake_mesh_subprocess(_SUBPROCESS_PROG)


_MUTATION_PROG = r"""
import numpy as np, jax
from repro.core.distributed import DistributedRMQ

mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(5)
n = 2901  # not divisible by 4 segments
x = rng.random(n).astype(np.float32)
x[rng.integers(0, n, 600)] = 0.25  # cross-segment ties
kw = dict(c=16, t=16, with_positions=True, capacity=4000)
d = DistributedRMQ.build(x, mesh, **kw)
assert d.num_segments == 4 and d.segment_capacity == 1000

# sharded update (dups last-wins) + boundary-straddling append vs fresh:
# the 300-element tail fills global slots 2901..3200, crossing the
# segment 2 -> 3 boundary at 3000, so both owners repair their shard
idxs = rng.integers(0, n, 64).astype(np.int32); idxs[5] = idxs[4]
vals = (rng.random(64) - 0.5).astype(np.float32)
tail = (rng.random(300) - 0.2).astype(np.float32)
d2 = d.update(idxs, vals).append(tail)
assert d2.generation == 2 and d2.n == n + 300
x2 = x.copy()
for i, v in zip(idxs, vals):
    x2[i] = v
x2 = np.concatenate([x2, tail])
ref = DistributedRMQ.build(x2, mesh, **kw)
m = 192
ls = rng.integers(0, d2.n, m)
rs = np.minimum(ls + rng.integers(0, d2.n, m), d2.n - 1)
ls, rs = np.minimum(ls, rs).astype(np.int32), np.maximum(ls, rs).astype(np.int32)
np.testing.assert_array_equal(np.asarray(d2.query(ls, rs)),
                              np.asarray(ref.query(ls, rs)))
np.testing.assert_array_equal(np.asarray(d2.query_index(ls, rs)),
                              np.asarray(ref.query_index(ls, rs)))

# engine routing: seg-local answers skip the all-reduce, crossing spans
# take it; both bit-identical to the monolithic oracle
eng = d2.engine()
np.testing.assert_array_equal(np.asarray(eng.query(ls, rs)),
                              np.asarray(d2.query(ls, rs)))
np.testing.assert_array_equal(np.asarray(eng.query_index(ls, rs)),
                              np.asarray(d2.query_index(ls, rs)))
cc = eng.stats()["class_counts"]
assert cc["seg_local"] > 0 and cc["crossing"] > 0, cc

# engine stale-cache regression across a mutation on the fake mesh
l0, r0 = 50, 2500
before = float(eng.query(np.array([l0]), np.array([r0]))[0])
d3 = d2.update(np.array([1500]), np.array([-9.0], np.float32))
eng.attach(d3)
assert float(eng.query(np.array([l0]), np.array([r0]))[0]) == -9.0
assert int(eng.query_index(np.array([l0]), np.array([r0]))[0]) == 1500
print("SUBPROCESS_OK")
"""


def test_distributed_mutation_and_engine_on_2x4_fake_mesh():
    _run_fake_mesh_subprocess(_MUTATION_PROG)


_FUSED_PROG = r"""
import numpy as np, jax
from repro.core.distributed import DistributedRMQ

# backend='fused' on a REAL multi-segment mesh: shard-local construction
# AND shard-local queries run the fused single-launch lowering under
# shard_map — the 1x1 coverage in test_differential.py can't catch a
# wrong seg_start globalization or a crossing/contained split bug.
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(11)
n = 2901
x = rng.integers(-3, 3, n).astype(np.float32)  # heavy cross-segment ties
d = DistributedRMQ.build(x, mesh, c=8, t=16, with_positions=True,
                         capacity=3200, backend="fused")
assert d.backend == "fused" and d.num_segments == 4
m = 96
ls = rng.integers(0, n, m)
rs = np.minimum(ls + rng.integers(0, n, m), n - 1)
ls, rs = np.minimum(ls, rs).astype(np.int32), np.maximum(ls, rs).astype(np.int32)
want_v = np.array([x[l:r+1].min() for l, r in zip(ls, rs)], np.float32)
want_p = np.array([l + np.argmin(x[l:r+1]) for l, r in zip(ls, rs)], np.int32)
np.testing.assert_array_equal(np.asarray(d.query(ls, rs)), want_v)
np.testing.assert_array_equal(np.asarray(d.query_index(ls, rs)), want_p)
eng = d.engine(cache_size=0)
np.testing.assert_array_equal(np.asarray(eng.query(ls, rs)), want_v)
np.testing.assert_array_equal(np.asarray(eng.query_index(ls, rs)), want_p)
cc = eng.stats()["class_counts"]
assert cc["seg_local"] > 0 and cc["crossing"] > 0, cc
# mutation on the fused sharded index stays bit-exact vs numpy
idxs = rng.integers(0, n, 24); vals = rng.integers(-5, 5, 24).astype(np.float32)
tail = rng.integers(-2, 2, 150).astype(np.float32)  # straddles 3000
d2 = d.update(idxs, vals).append(tail)
x2 = x.copy()
for i, v in zip(idxs, vals):
    x2[i] = v
x2 = np.concatenate([x2, tail])
n2 = x2.shape[0]
ls2 = rng.integers(0, n2, m)
rs2 = np.minimum(ls2 + rng.integers(0, n2, m), n2 - 1)
ls2, rs2 = np.minimum(ls2, rs2).astype(np.int32), np.maximum(ls2, rs2).astype(np.int32)
np.testing.assert_array_equal(
    np.asarray(d2.query(ls2, rs2)),
    np.array([x2[l:r+1].min() for l, r in zip(ls2, rs2)], np.float32))
np.testing.assert_array_equal(
    np.asarray(d2.query_index(ls2, rs2)),
    np.array([l + np.argmin(x2[l:r+1]) for l, r in zip(ls2, rs2)], np.int32))
print("SUBPROCESS_OK")
"""


def test_distributed_fused_backend_on_2x4_fake_mesh():
    _run_fake_mesh_subprocess(_FUSED_PROG)


def test_process_sees_one_device():
    """Guard: the fake-device flag must never leak into the test process."""
    assert jax.device_count() == 1


# ---------------------------------------------------------------------------
# streaming mutation (sharded update/append) + engine routing, 1x1 mesh
# ---------------------------------------------------------------------------
N = 800
CAP = 1000  # ceil(1000/16) = 63 <= c*t: exactly 2 levels
GEOM = dict(c=16, t=4, with_positions=True)


def _mixed_queries(rng, n, m):
    ls = rng.integers(0, n, m)
    rs = np.minimum(ls + rng.integers(0, n, m), n - 1)
    ls, rs = np.minimum(ls, rs), np.maximum(ls, rs)
    return ls.astype(np.int32), rs.astype(np.int32)


@pytest.fixture(scope="module")
def dist_setup():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(7)
    x = rng.random(N).astype(np.float32)
    x[rng.integers(0, N, N // 4)] = 0.25  # plant ties
    d = DistributedRMQ.build(x, mesh, capacity=CAP, **GEOM)
    return mesh, rng, x, d


def _assert_matches_fresh_build(d, x, mesh, rng):
    """Mutated index must be bit-identical to a from-scratch build —
    values AND leftmost-tie positions."""
    ref = DistributedRMQ.build(x, mesh, capacity=CAP, **GEOM)
    ls, rs = _mixed_queries(rng, len(x), 128)
    np.testing.assert_array_equal(
        np.asarray(d.query(ls, rs)), np.asarray(ref.query(ls, rs))
    )
    np.testing.assert_array_equal(
        np.asarray(d.query_index(ls, rs)),
        np.asarray(ref.query_index(ls, rs)),
    )
    # and both match naive numpy (incl. leftmost ties)
    want = np.array([x[l : r + 1].min() for l, r in zip(ls, rs)])
    wantp = np.array(
        [l + np.argmin(x[l : r + 1]) for l, r in zip(ls, rs)]
    )
    np.testing.assert_allclose(np.asarray(d.query(ls, rs)), want)
    np.testing.assert_array_equal(np.asarray(d.query_index(ls, rs)), wantp)


class TestShardedMutation:
    def test_update_matches_fresh_build(self, dist_setup):
        mesh, rng, x, d = dist_setup
        idxs = rng.integers(0, N, 64).astype(np.int32)
        vals = (rng.random(64) - 0.5).astype(np.float32)
        # duplicate indices: last wins, as on every other implementation
        idxs[5] = idxs[4]
        d2 = d.update(idxs, vals)
        assert d2.generation == d.generation + 1
        assert d2.n == d.n
        x2 = x.copy()
        for i, v in zip(idxs, vals):  # sequential => last wins
            x2[i] = v
        _assert_matches_fresh_build(d2, x2, mesh, rng)
        # the source index is unmodified (pure-functional successor)
        assert float(d.query(np.array([0]), np.array([N - 1]))[0]) \
            == x.min()

    def test_append_matches_fresh_build(self, dist_setup):
        mesh, rng, x, d = dist_setup
        tail = (rng.random(120) - 0.2).astype(np.float32)
        d2 = d.append(tail)
        assert d2.n == N + 120 and d2.generation == d.generation + 1
        _assert_matches_fresh_build(
            d2, np.concatenate([x, tail]), mesh, rng
        )

    def test_interleaved_mutations_match_fresh_build(self, dist_setup):
        mesh, rng, x, d = dist_setup
        cur = x.copy()
        for _ in range(3):
            idxs = rng.integers(0, d.n, 32).astype(np.int32)
            vals = (rng.random(32) - 0.5).astype(np.float32)
            d = d.update(idxs, vals)
            cur[idxs] = vals
            tail = rng.random(40).astype(np.float32)
            d = d.append(tail)
            cur = np.concatenate([cur, tail])
        _assert_matches_fresh_build(d, cur, mesh, rng)

    def test_append_overflow_raises(self, dist_setup):
        _, _, _, d = dist_setup
        with pytest.raises(ValueError, match="overflows capacity"):
            d.append(np.zeros(CAP - N + 1, np.float32))

    def test_empty_batches_are_noops(self, dist_setup):
        _, _, _, d = dist_setup
        assert d.update(
            np.zeros(0, np.int32), np.zeros(0, np.float32)
        ) is d
        assert d.append(np.zeros(0, np.float32)) is d

    def test_capacity_layout(self, dist_setup):
        _, _, _, d = dist_setup
        assert d.capacity == d.segment_capacity * d.num_segments
        assert d.capacity >= CAP
        assert d.length == N

    def test_build_refuses_int32_overflowing_capacity(self, dist_setup):
        """Bounds/positions are int32 throughout — same loud contract as
        the engine's attach guard, at build time."""
        mesh, _, _, _ = dist_setup
        with pytest.raises(ValueError, match="int32 query index space"):
            DistributedRMQ.build(
                np.zeros(8, np.float32), mesh, c=16, t=4, capacity=2**31
            )


class TestEngineOverDistributed:
    def test_parity_with_monolithic_oracle(self, dist_setup):
        _, rng, x, d = dist_setup
        engine = d.engine()
        ls, rs = _mixed_queries(rng, N, 160)
        ls[10:30], rs[10:30] = ls[0], rs[0]  # dedup scatter-back
        np.testing.assert_array_equal(
            np.asarray(engine.query(ls, rs)), np.asarray(d.query(ls, rs))
        )
        np.testing.assert_array_equal(
            np.asarray(engine.query_index(ls, rs)),
            np.asarray(d.query_index(ls, rs)),
        )
        counts = engine.stats()["class_counts"]
        # 1x1 mesh: every span is contained in the single segment, so
        # nothing pays the all-reduce
        assert counts[SEG_LOCAL] > 0 and counts[CROSSING] == 0

    def test_stale_cache_regression_after_update(self, dist_setup):
        """Same (l, r) served from cache must invalidate on attach of a
        mutated successor — keyed by generation."""
        _, _, x, d = dist_setup
        engine = d.engine()
        l, r = 100, 700
        before = float(engine.query(np.array([l]), np.array([r]))[0])
        assert before == x[l : r + 1].min()
        h0 = engine.cache.hits
        engine.query(np.array([l]), np.array([r]))
        assert engine.cache.hits == h0 + 1  # cached
        d2 = d.update(np.array([300]), np.array([-5.0], np.float32))
        engine.attach(d2)
        assert float(
            engine.query(np.array([l]), np.array([r]))[0]
        ) == -5.0
        assert int(
            engine.query_index(np.array([l]), np.array([r]))[0]
        ) == 300

    def test_stale_cache_regression_after_append(self, dist_setup):
        _, _, x, d = dist_setup
        engine = d.engine()
        v0 = float(engine.query(np.array([0]), np.array([N - 1]))[0])
        d2 = d.append(np.array([-7.0], np.float32))
        engine.attach(d2)
        assert float(
            engine.query(np.array([0]), np.array([N - 1]))[0]
        ) == v0
        assert float(engine.query(np.array([0]), np.array([N]))[0]) \
            == -7.0
        assert int(
            engine.query_index(np.array([0]), np.array([N]))[0]
        ) == N

    def test_parity_after_interleaved_mutations(self, dist_setup):
        _, rng, x, d = dist_setup
        engine = d.engine()
        for _ in range(2):
            idxs = rng.integers(0, d.n, 24).astype(np.int32)
            vals = (rng.random(24) - 0.5).astype(np.float32)
            d = d.update(idxs, vals).append(
                rng.random(40).astype(np.float32)
            )
            engine.attach(d)
            ls, rs = _mixed_queries(rng, d.n, 128)
            np.testing.assert_array_equal(
                np.asarray(engine.query(ls, rs)),
                np.asarray(d.query(ls, rs)),
            )
            np.testing.assert_array_equal(
                np.asarray(engine.query_index(ls, rs)),
                np.asarray(d.query_index(ls, rs)),
            )

    def test_service_register_attach_surface(self, dist_setup):
        """The same register()/attach() surface as RMQ/StreamingRMQ."""
        _, _, x, d = dist_setup
        svc = QueryService()
        svc.register("dist", d)
        got = float(svc.query("dist", np.array([0]), np.array([N - 1]))[0])
        assert got == x.min()
        d2 = d.update(
            np.array([int(np.argmax(x))]), np.array([-2.0], np.float32)
        )
        svc.attach("dist", d2)
        assert float(
            svc.query("dist", np.array([0]), np.array([N - 1]))[0]
        ) == -2.0
        t = svc.submit("dist", np.array([3]), np.array([40]), op="index")
        svc.flush()
        assert int(svc.take(t)[0]) == 3 + int(
            np.argmin(np.where(np.arange(N) == int(np.argmax(x)), -2.0,
                               x)[3:41])
        )

    def test_value_only_build_refuses_index_ops(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        d = DistributedRMQ.build(
            np.random.default_rng(0).random(300).astype(np.float32),
            mesh, c=16, t=4,
        )
        with pytest.raises(ValueError, match="without positions"):
            d.query_index(np.array([0]), np.array([10]))
        with pytest.raises(ValueError, match="without positions"):
            d.engine().query_index(np.array([0]), np.array([10]))
