"""Distributed RMQ tests: run shard_map paths on fake CPU device meshes.

Multi-device cases run in a subprocess so the fake-device XLA flag never
leaks into this test process (smoke tests must see 1 device).
"""

import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.distributed import DistributedRMQ


def test_distributed_on_1x1_mesh_matches_naive():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(1)
    n = 4096
    x = rng.random(n).astype(np.float32)
    d = DistributedRMQ.build(x, mesh, c=16, t=8, with_positions=True)
    ls = rng.integers(0, n, 64)
    rs = np.minimum(ls + rng.integers(0, n, 64), n - 1)
    ls, rs = np.minimum(ls, rs), np.maximum(ls, rs)
    got = np.asarray(d.query(ls, rs))
    want = np.array([x[l : r + 1].min() for l, r in zip(ls, rs)])
    np.testing.assert_allclose(got, want)
    gotp = np.asarray(d.query_index(ls, rs))
    wantp = np.array([l + np.argmin(x[l : r + 1]) for l, r in zip(ls, rs)])
    np.testing.assert_array_equal(gotp, wantp)


_SUBPROCESS_PROG = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.distributed import DistributedRMQ

mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(2)
n = 10001  # not divisible by segments -> exercises padding
x = rng.random(n).astype(np.float32)
d = DistributedRMQ.build(x, mesh, c=16, t=8, with_positions=True)
m_q = 128
ls = rng.integers(0, n, m_q)
rs = np.minimum(ls + rng.integers(0, n, m_q), n - 1)
ls, rs = np.minimum(ls, rs), np.maximum(ls, rs)
got = np.asarray(d.query(ls, rs))
want = np.array([x[l:r+1].min() for l, r in zip(ls, rs)])
assert np.allclose(got, want), float(np.abs(got - want).max())
gotp = np.asarray(d.query_index(ls, rs))
wantp = np.array([l + np.argmin(x[l:r+1]) for l, r in zip(ls, rs)])
assert (gotp == wantp).all()
# cross-segment tie-break stays leftmost
xz = np.zeros(8000, dtype=np.float32)
dz = DistributedRMQ.build(xz, mesh, c=16, t=8, with_positions=True)
p = np.asarray(dz.query_index(np.array([100, 3000]), np.array([7999, 7999])))
assert p.tolist() == [100, 3000], p.tolist()
print("SUBPROCESS_OK")
"""


def test_distributed_on_2x4_fake_mesh():
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_PROG],
        capture_output=True,
        text=True,
        env={
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
            "PYTHONPATH": "src",
            "PATH": "/usr/bin:/bin:/usr/local/bin",
            "JAX_PLATFORMS": "cpu",
        },
        cwd="/root/repo",
        timeout=300,
    )
    assert "SUBPROCESS_OK" in res.stdout, res.stdout + res.stderr


def test_process_sees_one_device():
    """Guard: the fake-device flag must never leak into the test process."""
    assert jax.device_count() == 1
