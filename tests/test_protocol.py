"""The common index protocol: conformance + shared validation helpers.

Every index implementation must satisfy :class:`repro.core.RMQIndex`
(the engine routes over the protocol, not concrete types), the mutable
ones additionally :class:`repro.core.MutableRMQIndex`, and all of them
must reject malformed mutation batches through the *shared* validators —
one error surface, not four drifting copies.
"""

import numpy as np
import pytest
import jax

from repro.core import (
    RMQ,
    MutableRMQIndex,
    RMQIndex,
    is_distributed,
    live_length,
    supports_mutation,
)
from repro.core import protocol as px
from repro.core.hybrid import HybridRMQ
from repro.streaming import StreamingRMQ


@pytest.fixture(scope="module")
def x():
    return np.random.default_rng(0).random(900).astype(np.float32)


@pytest.fixture(scope="module")
def indices(x):
    rmq = RMQ.build(x, c=16, t=4, with_positions=True, backend="jax",
                    capacity=1200)
    srm = StreamingRMQ.from_array(x, c=16, t=4, with_positions=True,
                                  backend="jax", capacity=1200)
    hyb = HybridRMQ.build(x, c=16, t=64, with_positions=True)
    return rmq, srm, hyb


class TestConformance:
    def test_read_protocol(self, indices):
        for idx in indices:
            assert isinstance(idx, RMQIndex), type(idx)

    def test_mutation_capability(self, indices):
        rmq, srm, hyb = indices
        assert supports_mutation(rmq) and isinstance(rmq, MutableRMQIndex)
        assert supports_mutation(srm)
        # the hybrid is read-only: a point update can move top-level
        # minima, which would invalidate sparse-table rows wholesale
        assert not supports_mutation(hyb)

    def test_distributed_marker(self, indices):
        from repro.core.distributed import DistributedRMQ

        for idx in indices:
            assert not is_distributed(idx)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        d = DistributedRMQ.build(
            np.zeros(200, np.float32), mesh, c=16, t=4
        )
        assert is_distributed(d)
        assert isinstance(d, RMQIndex)
        assert isinstance(d, MutableRMQIndex)

    def test_live_length_normalization(self, indices, x):
        rmq, srm, hyb = indices
        assert rmq.length == len(x)  # build sets the live length
        for idx in indices:
            assert live_length(idx) == len(x)
        # RMQ with length=None means "the build length"
        import dataclasses

        assert live_length(dataclasses.replace(rmq, length=None)) == len(x)
        assert live_length(rmq.append(np.float32([1.0]))) == len(x) + 1

    def test_canonical_query_spellings(self, indices, x):
        ls = np.array([0, 17, 100], np.int32)
        rs = np.array([5, 600, 899], np.int32)
        for idx in indices:
            np.testing.assert_array_equal(
                np.asarray(idx.query_value_batch(ls, rs)),
                np.asarray(idx.query(ls, rs)),
            )
            np.testing.assert_array_equal(
                np.asarray(idx.query_index_batch(ls, rs)),
                np.asarray(idx.query_index(ls, rs)),
            )

    def test_shared_introspection(self, indices, x):
        rmq, srm, hyb = indices
        assert rmq.capacity == srm.capacity == 1200
        for idx in indices:
            assert idx.with_positions
            assert np.dtype(idx.value_dtype) == np.float32
            assert idx.generation == 0


class TestSharedValidation:
    def test_update_batch_shape_mismatch_everywhere(self, indices):
        rmq, srm, _ = indices
        for idx in (rmq, srm):
            with pytest.raises(ValueError, match="matching 1-D"):
                idx.update(np.array([1, 2]), np.array([0.5], np.float32))

    def test_update_batch_integer_dtype(self):
        with pytest.raises(TypeError, match="integers"):
            px.validate_update_batch(
                np.array([0.5]), np.array([1.0], np.float32)
            )

    def test_append_batch_rank(self):
        with pytest.raises(ValueError, match="1-D"):
            px.validate_append_batch(
                np.zeros((2, 2), np.float32), length=0, capacity=100
            )

    def test_append_batch_overflow(self, indices):
        rmq, srm, _ = indices
        for idx in (rmq, srm):
            with pytest.raises(ValueError, match="overflows capacity"):
                idx.append(np.zeros(301, np.float32))  # 900 + 301 > 1200

    def test_resolve_backend(self):
        assert px.resolve_backend("jax") == "jax"
        assert px.resolve_backend("pallas") == "pallas"
        assert px.resolve_backend("auto") in ("jax", "pallas")
        with pytest.raises(ValueError, match="unknown backend"):
            px.resolve_backend("cuda")

    def test_coerce_values(self):
        out = px.coerce_values(np.arange(4))
        assert out.dtype == np.float32
        with pytest.raises(ValueError, match="rank-1"):
            px.coerce_values(np.zeros((2, 2)))


class TestValidationMessageParity:
    """Regression for the PR 2 follow-up: every mutable implementation
    rejects malformed update/append batches through the SHARED
    ``validate_update_batch``/``validate_append_batch`` — so the error
    text must be *identical* across indexes, including the sharded one.
    A reintroduced private copy (with drifting wording) fails here.
    """

    @pytest.fixture(scope="class")
    def mutables(self):
        from repro.core.distributed import DistributedRMQ

        x = np.random.default_rng(2).random(900).astype(np.float32)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        return (
            RMQ.build(x, c=16, t=4, backend="jax", capacity=1200),
            StreamingRMQ.from_array(
                x, c=16, t=4, backend="jax", capacity=1200
            ),
            DistributedRMQ.build(x, mesh, c=16, t=4, capacity=1200),
        )

    def _messages(self, mutables, exc, fn):
        msgs = []
        for idx in mutables:
            with pytest.raises(exc) as ei:
                fn(idx)
            msgs.append(str(ei.value))
        return msgs

    def test_update_shape_mismatch_identical(self, mutables):
        msgs = self._messages(
            mutables, ValueError,
            lambda i: i.update(np.array([1, 2]),
                               np.array([0.5], np.float32)),
        )
        assert len(set(msgs)) == 1, msgs
        assert "matching 1-D batches" in msgs[0]

    def test_update_dtype_identical(self, mutables):
        msgs = self._messages(
            mutables, TypeError,
            lambda i: i.update(np.array([0.5]),
                               np.array([1.0], np.float32)),
        )
        assert len(set(msgs)) == 1, msgs
        assert "idxs must be integers" in msgs[0]

    def test_append_overflow_identical(self, mutables):
        # all three share length 900 / capacity 1200, so the shared
        # validator renders byte-identical text for each
        msgs = self._messages(
            mutables, ValueError,
            lambda i: i.append(np.zeros(301, np.float32)),
        )
        assert len(set(msgs)) == 1, msgs
        assert "overflows capacity 1200 (live length 900)" in msgs[0]

    def test_append_rank_identical(self, mutables):
        msgs = self._messages(
            mutables, ValueError,
            lambda i: i.append(np.zeros((2, 2), np.float32)),
        )
        assert len(set(msgs)) == 1, msgs
        assert "vals must be 1-D" in msgs[0]


class TestCapacityGuardParity:
    """Every int32-ceiling guard routes through the SHARED
    ``protocol.check_capacity_limit`` — so the refusal text must be
    *byte-identical* at every site (engine attach, distributed build,
    the pallas update/append wrappers, the fused position build).  A
    reintroduced private copy with drifting wording fails here, exactly
    like the mutation-validator parity class above.
    """

    CAP = 2**31

    def _forged(self):
        """A tiny real index whose plan *claims* capacity = 2**31.

        All guards fire on plan metadata before touching the arrays, so
        no giant allocation happens.
        """
        import dataclasses as dc

        # multi-level on purpose: a single-level (pure scan) plan would
        # route the fused build through the scan branch, which guards via
        # pos_dtype_for instead of the shared capacity guard under test
        x = np.random.default_rng(3).random(4096).astype(np.float32)
        rmq = RMQ.build(x, c=16, t=2, with_positions=True, backend="jax")
        plan = dc.replace(rmq.plan, capacity=self.CAP)
        return dc.replace(
            rmq, hierarchy=dc.replace(rmq.hierarchy, plan=plan)
        )

    def _collect(self):
        from repro.core.distributed import DistributedRMQ
        from repro.kernels.hierarchy_update.ops import (
            append_hierarchy_pallas,
            update_hierarchy_pallas,
        )
        from repro.kernels.hierarchy_fused.ops import build_hierarchy_fused
        from repro.qe import QueryEngine
        import dataclasses as dc

        forged = self._forged()
        msgs = {}

        with pytest.raises(ValueError) as ei:
            px.check_capacity_limit(self.CAP)
        msgs["protocol"] = str(ei.value)

        with pytest.raises(ValueError) as ei:
            QueryEngine(forged)
        msgs["engine_attach"] = str(ei.value)

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with pytest.raises(ValueError) as ei:
            DistributedRMQ.build(
                np.zeros(8, np.float32), mesh, c=16, t=4, capacity=self.CAP
            )
        msgs["distributed_build"] = str(ei.value)

        with pytest.raises(ValueError) as ei:
            update_hierarchy_pallas(
                forged.hierarchy,
                np.array([0], np.int32), np.array([0.0], np.float32),
            )
        msgs["pallas_update"] = str(ei.value)

        with pytest.raises(ValueError) as ei:
            append_hierarchy_pallas(
                forged.hierarchy, np.array([0.0], np.float32), 64
            )
        msgs["pallas_append"] = str(ei.value)

        # fused build guards on the synthesized level-0 extent
        # (padded_lens[0] * c); forge it to the same 2**31 so the
        # rendered message matches the other sites byte-for-byte
        plan = forged.plan
        fused_plan = dc.replace(
            plan,
            padded_lens=(self.CAP // plan.c,) + plan.padded_lens[1:],
        )
        with pytest.raises(ValueError) as ei:
            build_hierarchy_fused(
                np.zeros(64, np.float32), fused_plan, with_positions=True
            )
        msgs["fused_build"] = str(ei.value)
        return msgs

    def test_guard_message_byte_identical_everywhere(self):
        msgs = self._collect()
        assert msgs["protocol"] == px.capacity_limit_message(self.CAP)
        assert len(set(msgs.values())) == 1, msgs
        # the pinned substring older tests match against must survive
        assert "int32 query index space" in msgs["protocol"]
        # and the remedy must name the escape hatch
        assert "x64" in msgs["protocol"]
