"""Dry-run machinery tests (reduced scale; the production 512-device runs
live in launch/dryrun.py and are logged in EXPERIMENTS.md).

Runs in subprocesses so the fake-device flag never leaks into pytest."""

import json
import subprocess
import sys

import jax
import pytest

from repro.launch.cells import (
    SHAPES,
    cell_is_skipped,
    collective_bytes_from_hlo,
)
from repro.configs.base import ARCH_IDS


def test_shape_grid_is_the_assigned_40_cells():
    cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    assert len(cells) == 40
    skips = [c for c in cells if cell_is_skipped(*c)]
    assert len(skips) == 8  # long_500k for the 8 full-attention archs
    assert all(s == "long_500k" for _, s in skips)
    for arch in ("mamba2-1.3b", "hymba-1.5b"):
        assert cell_is_skipped(arch, "long_500k") is None


def test_collective_parser():
    hlo = """
  %ag = bf16[64,2816]{1,0} all-gather(bf16[4,2816]{1,0} %p), replica_groups={}
  %ar.1 = f32[128]{0} all-reduce(f32[128]{0} %x), to_apply=%add
  %rs = (f32[16]{0}, f32[16]{0}) reduce-scatter(f32[256]{0} %y, f32[256]{0} %z)
  %nothing = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %b)
"""
    got = collective_bytes_from_hlo(hlo)
    assert got["all-gather"] == 64 * 2816 * 2
    assert got["all-reduce"] == 128 * 4
    assert got["reduce-scatter"] == 2 * 16 * 4
    assert "add" not in got


def test_process_sees_one_device():
    assert jax.device_count() == 1


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json, jax
from repro.compat import cost_analysis_dict
from repro.launch.mesh import make_test_mesh
from repro.launch.cells import train_cell, decode_cell, collective_bytes_from_hlo
from repro.configs.base import get_smoke_config, TrainConfig

mesh = make_test_mesh((2, 4), ("data", "model"))
cfg = get_smoke_config("llama3.2-3b")

# train cell on the reduced config: lower + compile + analyses
tc = TrainConfig(seq_len=64, global_batch=8, remat_policy="full")
fn, args, _ = train_cell(cfg, mesh, 64, 8, tc=tc)
with mesh:
    lowered = jax.jit(fn, donate_argnums=(0,)).lower(*args)
    compiled = lowered.compile()
ca = cost_analysis_dict(compiled)
ma = compiled.memory_analysis()
assert ca.get("flops", 0) > 0
assert ma.argument_size_in_bytes > 0
colls = collective_bytes_from_hlo(compiled.as_text())
assert sum(colls.values()) > 0, colls

# decode cell
fn, args = decode_cell(cfg, mesh, 128, 8)
with mesh:
    compiled = jax.jit(fn, donate_argnums=(2,)).lower(*args).compile()
assert cost_analysis_dict(compiled).get("flops", 0) > 0
print("DRYRUN_SMOKE_OK")
"""


def test_dryrun_cells_compile_on_fake_mesh():
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROC],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert "DRYRUN_SMOKE_OK" in res.stdout, res.stdout + res.stderr


def test_production_dryrun_artifacts_if_present():
    """When the full 512-device sweeps have been run, validate them."""
    import os

    path = "results/dryrun_multi.jsonl"
    if not os.path.exists(path):
        pytest.skip("full dry-run artifacts not generated in this checkout")
    recs = [json.loads(l) for l in open(path)]
    by_cell = {(r["arch"], r["shape"]): r for r in recs}
    assert len(by_cell) == 40
    for (arch, shape), r in by_cell.items():
        if cell_is_skipped(arch, shape):
            assert r.get("skipped"), (arch, shape)
        else:
            assert r.get("ok"), (arch, shape, r.get("error"))
            assert r["flops_per_device"] > 0
