"""Geometry autotuner + tuning cache (repro.tune): schema, resolution,
end-to-end consumption, and the miss-falls-back-bit-identically contract.

The cache's core promise is *graceful*: a present entry reconfigures
geometry/backend/planner knobs from measured winners; a missing entry
(or a wholly empty cache) leaves every consumer — ``make_plan``,
``RMQ.build(c="auto")``, ``QueryEngine(tuning=...)`` — byte-for-byte on
today's defaults.  A malformed cache *file* must instead fail loudly
(schema-validated on load), never silently mis-tune production geometry.
"""

import dataclasses
import json

import numpy as np
import pytest
import jax

from repro.core.api import RMQ
from repro.core.distributed import DistributedRMQ
from repro.core.hybrid import HybridRMQ
from repro.core.plan import LevelSplit, make_plan
from repro.kernels.profiling import count_launches, launch_registry
from repro.obs.metrics import Metrics
from repro.qe import QueryEngine, QueryService
from repro.streaming import StreamingRMQ
from repro.tune import (
    Autotuner,
    SCHEMA_VERSION,
    TINY_GEOMETRIES,
    TunedConfig,
    TuningCache,
    TuningCacheError,
    n_bucket,
)


def _entry(platform="cpu", nb=13, mix="mixed", **over):
    e = {
        "platform": platform, "n_bucket": nb, "span_mix": mix,
        "c": 32, "t": 8, "backend": "jax", "planner": "routed",
        "long_cutoff": None, "scan_chunks": 2, "sparse_top": True,
        "ns_per_query": 100.0,
    }
    e.update(over)
    return e


def _doc(*entries):
    return {"schema_version": SCHEMA_VERSION, "entries": list(entries)}


# ---------------------------------------------------------------------------
# config + cache semantics
# ---------------------------------------------------------------------------
class TestTunedConfig:
    def test_validation(self):
        TunedConfig(c=8, t=8)  # valid
        with pytest.raises(ValueError):
            TunedConfig(c=12, t=8)          # not a power of two
        with pytest.raises(ValueError):
            TunedConfig(c=8, t=0)
        with pytest.raises(ValueError):
            TunedConfig(c=8, t=8, backend="cuda")
        with pytest.raises(ValueError):
            TunedConfig(c=8, t=8, planner="hybrid")
        with pytest.raises(ValueError):
            TunedConfig(c=8, t=8, scan_chunks=3)
        with pytest.raises(ValueError):
            TunedConfig(c=8, t=8, long_cutoff=0)

    def test_level_split_expansion(self):
        cfg = TunedConfig(c=8, t=8, backend="fused", planner="fused",
                          long_cutoff=512, scan_chunks=1)
        split = cfg.level_split()
        assert split == LevelSplit(scan_chunks=1, sparse_top=True,
                                   long_cutoff=512, fused=True)

    def test_level_split_validation(self):
        with pytest.raises(ValueError):
            LevelSplit(scan_chunks=3)
        with pytest.raises(ValueError):
            LevelSplit(long_cutoff=-5)


class TestCacheResolution:
    def test_exact_hit(self):
        cache = TuningCache()
        cfg = TunedConfig(c=32, t=8)
        cache.put("cpu", 8000, "short", cfg)       # bucket 12
        assert cache.lookup("cpu", 8191, "short") is cfg
        assert n_bucket(8000) == n_bucket(8191) == 12

    def test_span_mix_falls_back_to_mixed(self):
        cache = TuningCache()
        mixed = TunedConfig(c=32, t=8)
        cache.put("cpu", 8000, "mixed", mixed)
        assert cache.lookup("cpu", 8000, "long") is mixed

    def test_nearest_bucket_fallback_prefers_requested_mix(self):
        cache = TuningCache()
        near_mixed = TunedConfig(c=64, t=8)
        far_short = TunedConfig(c=8, t=8)
        cache.put("cpu", 2**14, "mixed", near_mixed)
        cache.put("cpu", 2**18, "short", far_short)
        # bucket 16 request: bucket-14 mixed is nearer than bucket-18
        assert cache.lookup("cpu", 2**16, "mixed") is near_mixed
        # but for "short" the exact-mix entry wins at equal specificity
        cache.put("cpu", 2**14, "short", far_short)
        assert cache.lookup("cpu", 2**16, "short") is far_short

    def test_platform_never_crosses(self):
        cache = TuningCache()
        cache.put("tpu", 8000, "mixed", TunedConfig(c=32, t=8))
        assert cache.lookup("cpu", 8000, "mixed") is None

    def test_empty_cache_misses(self):
        assert TuningCache().lookup("cpu", 10_000) is None


class TestCacheSchema:
    def test_round_trip(self, tmp_path):
        cache = TuningCache()
        cache.put("cpu", 2**13, "mixed",
                  TunedConfig(c=32, t=8, backend="fused", planner="fused",
                              long_cutoff=900, ns_per_query=55.5))
        path = str(tmp_path / "cache.json")
        cache.save(path)
        loaded = TuningCache.load(path)
        assert len(loaded) == 1
        cfg = loaded.lookup("cpu", 2**13, "mixed")
        assert cfg == cache.lookup("cpu", 2**13, "mixed")
        # the file is versioned
        with open(path) as f:
            assert json.load(f)["schema_version"] == SCHEMA_VERSION

    def test_unknown_version_rejected(self):
        with pytest.raises(TuningCacheError, match="schema_version"):
            TuningCache.from_json({"schema_version": 99, "entries": []})

    def test_missing_key_rejected(self):
        e = _entry()
        del e["backend"]
        with pytest.raises(TuningCacheError, match="backend"):
            TuningCache.from_json(_doc(e))

    def test_wrong_type_rejected(self):
        with pytest.raises(TuningCacheError, match="'c' must be int"):
            TuningCache.from_json(_doc(_entry(c="128")))
        # bools are ints in Python; the schema still rejects them
        with pytest.raises(TuningCacheError, match="'t' must be int"):
            TuningCache.from_json(_doc(_entry(t=True)))

    def test_bad_span_mix_rejected(self):
        with pytest.raises(TuningCacheError, match="span_mix"):
            TuningCache.from_json(_doc(_entry(mix="huge")))

    def test_invalid_config_rejected(self):
        with pytest.raises(TuningCacheError, match="power of two"):
            TuningCache.from_json(_doc(_entry(c=12)))

    def test_not_json_rejected(self, tmp_path):
        path = tmp_path / "bad.json"
        path.write_text("{nope")
        with pytest.raises(TuningCacheError, match="not valid JSON"):
            TuningCache.load(str(path))


# ---------------------------------------------------------------------------
# consumption: make_plan / RMQ.build / QueryEngine
# ---------------------------------------------------------------------------
class TestTunedPlan:
    def test_miss_keeps_defaults(self):
        plan = make_plan(50_000, c="auto", tuning=TuningCache(),
                         platform="cpu")
        ref = make_plan(50_000)
        assert (plan.c, plan.t) == (ref.c, ref.t) == (128, 64)
        assert plan.level_split is None

    def test_hit_resolves_geometry_and_split(self):
        cache = TuningCache()
        cache.put("cpu", 50_000, "mixed",
                  TunedConfig(c=32, t=8, backend="fused", planner="fused",
                              long_cutoff=700))
        plan = make_plan(50_000, c="auto", tuning=cache, platform="cpu")
        assert (plan.c, plan.t) == (32, 8)
        assert plan.level_split == LevelSplit(
            scan_chunks=2, sparse_top=True, long_cutoff=700, fused=True)
        # geometry matches an explicitly-built twin
        twin = make_plan(50_000, c=32, t=8)
        assert plan.level_lens == twin.level_lens
        assert plan.offsets == twin.offsets

    def test_tuned_flag_with_numeric_c(self):
        # tuned=True + a miss keeps the numeric c the caller passed
        plan = make_plan(50_000, c=64, tuned=True, tuning=TuningCache(),
                         platform="cpu")
        assert plan.c == 64 and plan.level_split is None


class TestTunedBuild:
    def test_auto_miss_is_bit_identical_to_default(self):
        rng = np.random.default_rng(0)
        x = rng.integers(-4, 4, 30_000).astype(np.float32)
        default = RMQ.build(x, with_positions=True)
        tuned = RMQ.build(x, c="auto", with_positions=True,
                          tuning=TuningCache())
        assert tuned.plan == default.plan
        assert tuned.backend == default.backend
        np.testing.assert_array_equal(
            np.asarray(tuned.hierarchy.upper),
            np.asarray(default.hierarchy.upper))

    def test_auto_hit_adopts_geometry_and_backend(self):
        cache = TuningCache()
        cache.put(jax.default_backend(), 30_000, "mixed",
                  TunedConfig(c=32, t=8, backend="fused",
                              planner="fused"))
        x = np.random.default_rng(1).random(30_000).astype(np.float32)
        rmq = RMQ.build(x, c="auto", tuning=cache)
        assert (rmq.plan.c, rmq.plan.t) == (32, 8)
        assert rmq.backend == "fused"
        assert rmq.plan.level_split.fused
        # an explicit backend is NOT overridden by the cache
        rmq2 = RMQ.build(x, c="auto", tuning=cache, backend="jax")
        assert rmq2.backend == "jax"


class TestEngineSelfConfig:
    def _cache(self, n, **over):
        cache = TuningCache()
        kw = dict(c=32, t=8, backend="fused", planner="fused")
        kw.update(over)
        cache.put(jax.default_backend(), n, "mixed", TunedConfig(**kw))
        return cache

    def test_adopts_tuned_backend_over_any_build(self):
        n = 20_000
        x = np.random.default_rng(2).random(n).astype(np.float32)
        rmq = RMQ.build(x, c=32, t=8, backend="jax")
        engine = QueryEngine(rmq, cache_size=0, tuning=self._cache(n))
        assert engine.backend == "fused"
        assert engine.planner.fused
        assert engine.tuned["source"] == "cache"
        ls = np.array([0, 5, 100], np.int32)
        rs = np.array([n - 1, 4_000, 131], np.int32)
        np.testing.assert_array_equal(
            np.asarray(engine.query(ls, rs)),
            [x[l:r + 1].min() for l, r in zip(ls, rs)])

    def test_explicit_kwargs_outrank_cache(self):
        n = 20_000
        x = np.random.default_rng(2).random(n).astype(np.float32)
        rmq = RMQ.build(x, c=32, t=8, backend="jax")
        engine = QueryEngine(rmq, cache_size=0, tuning=self._cache(n),
                             backend="jax")
        assert engine.backend == "jax"
        assert not engine.planner.fused

    def test_config_recorded_in_registry_and_metrics(self):
        # geometry unique to this test: the launch counter records at
        # trace time, so a jit-cache hit from a sibling test would
        # otherwise record nothing
        n = 21_017
        x = np.random.default_rng(2).random(n).astype(np.float32)
        rmq = RMQ.build(x, c=32, t=8, backend="jax")
        m = Metrics()
        with launch_registry() as reg, count_launches() as counts:
            engine = QueryEngine(rmq, cache_size=0,
                                 tuning=self._cache(n),
                                 metrics=m.scope("engine"))
            engine.query(np.array([0], np.int32),
                         np.array([n - 1], np.int32))
        configs = reg.as_dict()["configs"]
        assert configs and configs[0]["name"] == "engine_tuned_config"
        assert configs[0]["backend"] == "fused"
        # config records must NOT pollute the launch-count contract
        assert counts == {"rmq_fused": 1}
        prom = m.to_prometheus()
        assert 'repro_engine_tuned_config{' in prom
        assert 'backend="fused"' in prom
        assert engine.stats()["tuned"]["backend"] == "fused"

    def test_plan_level_split_configures_untuned_engine(self):
        # a split baked into the plan at build time reaches an engine
        # constructed with no cache at all
        n = 20_000
        cache = self._cache(n, backend="jax", planner="routed",
                            long_cutoff=3_000)
        x = np.random.default_rng(3).random(n).astype(np.float32)
        rmq = RMQ.build(x, c="auto", tuning=cache)
        engine = QueryEngine(rmq, cache_size=0)
        assert engine.planner.effective_long_cutoff() == 3_000
        assert engine.tuned["source"] == "plan"

    def test_service_and_tier_plumb_tuning(self):
        from repro.serving import ServingTier

        n = 20_000
        cache = self._cache(n)
        x = np.random.default_rng(4).random(n).astype(np.float32)
        rmq = RMQ.build(x, c=32, t=8, backend="jax")
        svc = QueryService(tuning=cache)
        svc.register("a", rmq)
        assert svc.engine("a").backend == "fused"

        tier = ServingTier(tuning=cache)
        tier.register_tenant("a", rmq)
        assert tier.service.engine("a").backend == "fused"
        with pytest.raises(ValueError):
            ServingTier(service=QueryService(), tuning=cache)


# ---------------------------------------------------------------------------
# the acceptance contract: a miss falls back bit-identically, all indexes
# ---------------------------------------------------------------------------
class TestMissFallbackDifferential:
    @pytest.mark.parametrize("kind", ("rmq", "streaming", "hybrid",
                                      "distributed"))
    def test_empty_cache_engine_matches_numpy_oracle(self, kind):
        rng = np.random.default_rng(hash(kind) % 2**31)
        n, c, t = 6_000, 16, 8
        x = rng.integers(-4, 4, n).astype(np.float32)  # heavy ties
        if kind == "rmq":
            idx = RMQ.build(x, c=c, t=t, with_positions=True)
        elif kind == "streaming":
            idx = StreamingRMQ.from_array(x, c=c, t=t,
                                          with_positions=True)
        elif kind == "hybrid":
            idx = HybridRMQ.build(x, c=c, t=t, with_positions=True)
        else:
            mesh = jax.make_mesh((1, 1), ("data", "model"))
            idx = DistributedRMQ.build(x, mesh, c=c, t=t,
                                       with_positions=True)
        empty = TuningCache()
        tuned_engine = QueryEngine(idx, cache_size=0, tuning=empty)
        plain_engine = QueryEngine(idx, cache_size=0)
        assert tuned_engine.backend == plain_engine.backend
        ls = rng.integers(0, n, 300)
        rs = np.minimum(ls + rng.integers(0, n, 300), n - 1)
        ls = np.minimum(ls, rs).astype(np.int32)
        rs = np.maximum(ls, rs).astype(np.int32)
        expect_v = np.array(
            [x[l:r + 1].min() for l, r in zip(ls, rs)], np.float32)
        expect_i = np.array(
            [l + int(np.argmin(x[l:r + 1])) for l, r in zip(ls, rs)],
            np.int32)
        np.testing.assert_array_equal(
            np.asarray(tuned_engine.query(ls, rs)), expect_v)
        np.testing.assert_array_equal(
            np.asarray(tuned_engine.query_index(ls, rs)), expect_i)
        np.testing.assert_array_equal(
            np.asarray(tuned_engine.query(ls, rs)),
            np.asarray(plain_engine.query(ls, rs)))


# ---------------------------------------------------------------------------
# the autotuner itself (tiny smoke)
# ---------------------------------------------------------------------------
class TestAutotuner:
    def test_tiny_search_produces_valid_cache(self, tmp_path):
        tuner = Autotuner(geometries=TINY_GEOMETRIES, m=128, repeats=1,
                          crossover_points=2)
        cache, report = tuner.search([2**11], platform="cpu")
        # a winner for every span mix, each a valid TunedConfig
        assert len(cache) == 4
        for mix in ("short", "mid", "long", "mixed"):
            cfg = cache.lookup("cpu", 2**11, mix)
            assert cfg is not None
            assert cfg.ns_per_query > 0
        # measurements cover geometries x backends x mixes
        assert len(report["measurements"]) == 3 * 2 * 4
        # round-trips through the schema
        path = str(tmp_path / "cache.json")
        cache.save(path)
        assert len(TuningCache.load(path)) == 4

    def test_skipped_configs_are_reported(self):
        # c*t >= n: (32, 8) at n=256 degenerates and must be REPORTED
        tuner = Autotuner(geometries=((8, 8), (32, 8)), m=64, repeats=1,
                          crossover_points=2, span_mixes=("mixed",))
        _cache, report = tuner.search([256], platform="cpu")
        assert len(report["skipped"]) == 1
        skip = report["skipped"][0]
        assert (skip["c"], skip["t"]) == (32, 8)
        assert "c*t" in skip["reason"]

    def test_workload_is_shared_across_geometries(self):
        # the winner comparison is only meaningful on ONE workload: the
        # reference chunk must not follow the candidate geometry
        tuner = Autotuner()
        assert tuner.reference_c(2**18) == 128
        assert tuner.reference_c(300) < 128


def test_rmq_build_auto_smoke():
    # c="auto" against whatever cache is committed (or none): must build
    # a working index either way — this is the README quickstart path
    x = np.random.default_rng(5).random(4_000).astype(np.float32)
    rmq = RMQ.build(x, c="auto")
    v = np.asarray(rmq.query(np.array([7], np.int32),
                             np.array([3_999], np.int32)))
    assert v[0] == x[7:].min()
