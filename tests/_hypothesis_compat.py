"""Import hypothesis if available, else degrade its tests to skips.

The tier-1 environment does not guarantee hypothesis; without this shim the
mere import made two whole test modules fail collection and masked every
other test in them.  Property-style coverage that must always run is written
with numpy RNG loops instead (see tests/test_streaming.py).
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(_f):
            return pytest.mark.skip(reason="hypothesis not installed")(_f)

        return deco

    def settings(*_args, **_kwargs):
        return lambda f: f

    class _Strategy:
        """Stand-in whose methods absorb any strategy construction."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategy()
