"""Fault tolerance: heartbeats, stragglers, elastic re-mesh, restart drill."""

import subprocess
import sys

import numpy as np
import pytest

from repro.distributed.fault_tolerance import (
    HeartbeatMonitor,
    global_batch_for,
    plan_remesh,
)


class TestHeartbeat:
    def test_straggler_detection(self):
        mon = HeartbeatMonitor(num_hosts=4, straggler_threshold=2.0)
        t = 0.0
        for step in range(8):
            for h in range(4):
                dt = 1.0 if h != 2 else 5.0  # host 2 is slow
                mon.report(h, step, t + dt * step)
        assert mon.stragglers() == [2]

    def test_dead_host_detection(self):
        mon = HeartbeatMonitor(num_hosts=3, dead_timeout=10.0)
        now = 1000.0
        mon.report(0, 1, now - 1)
        mon.report(1, 1, now - 50)   # silent too long
        # host 2 never reported
        assert set(mon.dead(now)) == {1, 2}

    def test_exclusion(self):
        mon = HeartbeatMonitor(num_hosts=2)
        mon.exclude(1)
        mon.report(1, 0)  # ignored
        assert mon.active_hosts == 1
        assert not mon._beats[1]


class TestElasticRemesh:
    def test_ladder_preserves_model_axis(self):
        for chips in (512, 500, 256, 230, 128, 17):
            shape, axes = plan_remesh(chips)
            assert shape[axes.index("model")] == 16
            total = int(np.prod(shape))
            assert total <= chips

    def test_degrade_sequence(self):
        assert plan_remesh(512)[0] == (2, 16, 16)
        assert plan_remesh(511)[0] == (1, 16, 16)
        assert plan_remesh(255)[0] == (8, 16)
        with pytest.raises(RuntimeError):
            plan_remesh(8)

    def test_elastic_batch_policy(self):
        shape, axes = plan_remesh(512)
        assert global_batch_for(shape, axes, 8) == 2 * 16 * 8
        shape, axes = plan_remesh(256)
        assert global_batch_for(shape, axes, 8) == 16 * 8


class TestRestartDrill:
    def test_train_survives_injected_failure(self, tmp_path):
        """Failure at step 6 -> restart from checkpoint -> completes."""
        cmd = [
            sys.executable, "-m", "repro.launch.train",
            "--arch", "qwen1.5-0.5b", "--smoke",
            "--steps", "10", "--seq-len", "32", "--global-batch", "4",
            "--checkpoint-every", "3", "--log-every", "5",
            "--checkpoint-dir", str(tmp_path),
            "--inject-failure-at", "6",
        ]
        res = subprocess.run(
            cmd, capture_output=True, text=True, timeout=600,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                 "JAX_PLATFORMS": "cpu"},
            cwd="/root/repo",
        )
        out = res.stdout + res.stderr
        assert res.returncode == 0, out
        assert "FAILURE" in out and "restart 1" in out
        assert "restored checkpoint @ step 6" in out
        assert "done" in out
