"""Streaming RMQ: incremental updates/appends vs. from-scratch rebuilds.

The central invariant: after ANY sequence of batched point updates,
appends, and retirements, the maintained hierarchy is bit-identical —
values and leftmost-tie positions — to ``build_hierarchy`` of the mutated
array under the same plan.  Checked for both the pure-JAX path (the
oracle) and the Pallas update kernels (interpret mode).
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import RMQ, build_hierarchy, make_plan, pos_dtype_for
from repro.streaming import StreamingRMQ, update_hierarchy
from repro.kernels.hierarchy_update.ops import (
    append_hierarchy_pallas,
    update_hierarchy_pallas,
)


def _assert_hierarchies_equal(ref, got, with_pos=True):
    """Bit-exact comparison (treating +inf padding as equal)."""
    for name, a, b in [("base", ref.base, got.base),
                       ("upper", ref.upper, got.upper)]:
        a, b = np.asarray(a), np.asarray(b)
        np.testing.assert_array_equal(
            np.isfinite(a), np.isfinite(b), err_msg=name
        )
        finite = np.isfinite(a)
        np.testing.assert_array_equal(a[finite], b[finite], err_msg=name)
    if with_pos:
        np.testing.assert_array_equal(
            np.asarray(ref.upper_pos), np.asarray(got.upper_pos),
            err_msg="upper_pos",
        )


PLANS = [
    (100_000, 128, 64, None),
    (4096, 8, 2, None),
    (999, 2, 1, 2048),
    (12_345, 16, 4, 20_000),
    (257, 4, 1, 257),
]


class TestUpdateMatchesRebuild:
    @pytest.mark.parametrize("n,c,t,cap", PLANS)
    @pytest.mark.parametrize("backend", ["jax", "pallas"])
    def test_random_update_batches(self, n, c, t, cap, backend):
        """Property test: K random update batches == rebuild, bit-exact."""
        rng = np.random.default_rng(n + c)
        x = rng.random(n).astype(np.float32)
        plan = make_plan(n, c=c, t=t, capacity=cap)
        h = build_hierarchy(jnp.asarray(x), plan, with_positions=True)
        for round_ in range(4):
            bsz = int(rng.integers(1, 200))
            idxs = rng.integers(0, n, bsz)
            vals = rng.random(bsz).astype(np.float32)
            x[idxs] = vals  # numpy fancy assignment is also last-wins
            if backend == "pallas":
                h = update_hierarchy_pallas(
                    h, jnp.asarray(idxs), jnp.asarray(vals), interpret=True
                )
            else:
                h = update_hierarchy(h, jnp.asarray(idxs), jnp.asarray(vals))
            ref = build_hierarchy(jnp.asarray(x), plan, with_positions=True)
            _assert_hierarchies_equal(ref, h)

    def test_duplicate_indices_last_wins(self):
        n = 1000
        x = np.zeros(n, np.float32) + 0.5
        plan = make_plan(n, c=8, t=2)
        h = build_hierarchy(jnp.asarray(x), plan, with_positions=True)
        idxs = np.array([7, 7, 7, 123, 123], np.int64)
        vals = np.array([0.1, 0.9, 0.3, 0.8, 0.2], np.float32)
        h = update_hierarchy(h, jnp.asarray(idxs), jnp.asarray(vals))
        x[idxs] = vals
        assert float(h.base[7]) == pytest.approx(0.3)
        assert float(h.base[123]) == pytest.approx(0.2)
        ref = build_hierarchy(jnp.asarray(x), plan, with_positions=True)
        _assert_hierarchies_equal(ref, h)

    def test_update_without_positions(self):
        rng = np.random.default_rng(3)
        n = 5000
        x = rng.random(n).astype(np.float32)
        plan = make_plan(n, c=16, t=2)
        h = build_hierarchy(jnp.asarray(x), plan)
        idxs = rng.integers(0, n, 64)
        vals = rng.random(64).astype(np.float32)
        x[idxs] = vals
        for hh in (
            update_hierarchy(h, jnp.asarray(idxs), jnp.asarray(vals)),
            update_hierarchy_pallas(
                h, jnp.asarray(idxs), jnp.asarray(vals), interpret=True
            ),
        ):
            ref = build_hierarchy(jnp.asarray(x), plan)
            _assert_hierarchies_equal(ref, hh, with_pos=False)


class TestStreamingStructure:
    @pytest.mark.parametrize("backend", ["jax", "pallas"])
    def test_mixed_update_append_property(self, backend):
        """Random interleavings of update/append == rebuild of the array."""
        rng = np.random.default_rng(11)
        n, cap, c, t = 1500, 6000, 8, 2
        arr = list(rng.random(n).astype(np.float32))
        s = StreamingRMQ.from_array(
            np.asarray(arr, np.float32), c=c, t=t, capacity=cap,
            with_positions=True, backend=backend,
        )
        for round_ in range(6):
            if round_ % 2 == 0:
                bsz = int(rng.integers(1, 64))
                tail = rng.random(bsz).astype(np.float32)
                s = s.append(tail)
                arr += list(tail)
            else:
                bsz = int(rng.integers(1, 100))
                idxs = rng.integers(0, len(arr), bsz)
                vals = rng.random(bsz).astype(np.float32)
                s = s.update(jnp.asarray(idxs), jnp.asarray(vals))
                for i, v in zip(idxs, vals):
                    arr[i] = v
            assert s.length == len(arr)
            plan = make_plan(len(arr), c=c, t=t, capacity=cap)
            ref = build_hierarchy(
                jnp.asarray(np.asarray(arr, np.float32)), plan,
                with_positions=True,
            )
            _assert_hierarchies_equal(ref, s.hierarchy)
        # queries answer over the mutated array
        a = np.asarray(arr, np.float32)
        ls = rng.integers(0, len(arr), 64)
        rs = np.minimum(ls + rng.integers(0, len(arr), 64), len(arr) - 1)
        ls, rs = (np.minimum(ls, rs).astype(np.int32),
                  np.maximum(ls, rs).astype(np.int32))
        np.testing.assert_array_equal(
            np.asarray(s.query(ls, rs)),
            np.array([a[l:r + 1].min() for l, r in zip(ls, rs)]),
        )
        np.testing.assert_array_equal(
            np.asarray(s.query_index(ls, rs)),
            np.array([l + np.argmin(a[l:r + 1]) for l, r in zip(ls, rs)]),
        )

    def test_append_overflow_raises(self):
        s = StreamingRMQ.from_array(
            np.ones(10, np.float32), c=4, t=1, capacity=12, backend="jax"
        )
        s = s.append(np.ones(2, np.float32))
        with pytest.raises(ValueError, match="capacity"):
            s.append(np.ones(1, np.float32))

    def test_retire_slides_window(self):
        rng = np.random.default_rng(5)
        n = 800
        x = rng.random(n).astype(np.float32)
        s = StreamingRMQ.from_array(
            x, c=8, t=2, with_positions=True, backend="jax"
        )
        s = s.retire(100)
        assert s.start == 100
        # retired entries never win
        arr = x.copy()
        arr[:100] = np.inf
        got = float(s.query(np.array([0], np.int32),
                            np.array([n - 1], np.int32))[0])
        assert got == arr.min()
        gotp = int(s.query_index(np.array([50], np.int32),
                                 np.array([n - 1], np.int32))[0])
        assert gotp == 100 + int(np.argmin(arr[100:]))
        # hierarchy is exactly the rebuild of the tombstoned array
        ref = build_hierarchy(
            jnp.asarray(arr), s.plan, with_positions=True
        )
        _assert_hierarchies_equal(ref, s.hierarchy)

    def test_empty_update_and_append_are_noops(self):
        s = StreamingRMQ.from_array(np.ones(100, np.float32), c=4, t=1)
        assert s.update(jnp.zeros((0,), jnp.int32),
                        jnp.zeros((0,), jnp.float32)) is s
        assert s.append(jnp.zeros((0,), jnp.float32)) is s

    def test_bad_update_args_rejected(self):
        s = StreamingRMQ.from_array(np.ones(100, np.float32), c=4, t=1)
        with pytest.raises(TypeError, match="integer"):
            s.update(jnp.zeros(3), jnp.zeros(3))
        with pytest.raises(ValueError, match="1-D"):
            s.update(jnp.zeros((3, 1), jnp.int32), jnp.zeros((3, 1)))

    def test_oob_update_rejected_in_debug_mode(self, monkeypatch):
        monkeypatch.setenv("REPRO_RMQ_DEBUG", "1")
        s = StreamingRMQ.from_array(np.ones(100, np.float32), c=4, t=1,
                                    capacity=200)
        with pytest.raises(ValueError, match="out of range"):
            s.update(jnp.asarray([150], jnp.int32),  # < capacity, >= live
                     jnp.asarray([0.5], jnp.float32))
        with pytest.raises(ValueError, match="out of range"):
            RMQ.build(np.ones(100, np.float32), c=4, t=1,
                      backend="jax").update(
                jnp.asarray([-1], jnp.int32), jnp.asarray([0.5]))

    def test_plan_and_capacity_conflict_rejected(self):
        plan = make_plan(100, c=4, t=1)
        with pytest.raises(ValueError, match="make_plan"):
            StreamingRMQ.from_array(np.ones(100, np.float32), plan=plan,
                                    capacity=200)
        with pytest.raises(ValueError, match="make_plan"):
            RMQ.build(np.ones(100, np.float32), plan=plan, capacity=200)


class TestUpdateKernelUnits:
    def test_update_level_direct(self):
        from repro.kernels.hierarchy_update.kernel import update_level
        from repro.kernels.hierarchy_update.ref import update_level_ref

        rng = np.random.default_rng(0)
        for c, m, b in [(128, 16, 5), (8, 64, 17), (256, 4, 4)]:
            x = jnp.asarray(rng.random(c * m).astype(np.float32))
            ids = jnp.asarray(rng.integers(0, m, b), jnp.int32)
            got = update_level(x, ids, c=c, interpret=True)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(update_level_ref(x, ids, c))
            )

    def test_update_level_with_positions_direct(self):
        from repro.kernels.hierarchy_update.kernel import (
            update_level_with_positions,
        )
        from repro.kernels.hierarchy_update.ref import (
            update_level_with_positions_ref,
        )

        rng = np.random.default_rng(1)
        c, m, b = 16, 32, 9
        # heavy duplication to exercise the leftmost tie-break
        x = jnp.asarray(
            rng.integers(0, 3, c * m).astype(np.float32)
        )
        # positions must be increasing within each chunk (the invariant
        # carried positions satisfy by construction)
        p = jnp.asarray(np.arange(c * m, dtype=np.int32))
        ids = jnp.asarray(rng.integers(0, m, b), jnp.int32)
        gv, gp = update_level_with_positions(x, p, ids, c=c, interpret=True)
        wv, wp = update_level_with_positions_ref(x, p, ids, c)
        np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
        np.testing.assert_array_equal(np.asarray(gp), np.asarray(wp))

    def test_update_level0_positions_direct(self):
        from repro.kernels.hierarchy_update.kernel import (
            update_level0_with_positions,
        )
        from repro.kernels.hierarchy_update.ref import (
            update_level0_with_positions_ref,
        )

        rng = np.random.default_rng(2)
        c, m, cap, b = 8, 16, 123, 11  # cap not chunk-aligned
        x = np.full(c * m, np.inf, np.float32)
        x[:cap] = rng.integers(0, 2, cap).astype(np.float32)
        x = jnp.asarray(x)
        ids = jnp.asarray(rng.integers(0, m, b), jnp.int32)
        gv, gp = update_level0_with_positions(
            x, ids, c=c, cap=cap, pos_dtype=jnp.int32, interpret=True
        )
        wv, wp = update_level0_with_positions_ref(x, ids, c, cap)
        np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
        np.testing.assert_array_equal(np.asarray(gp), np.asarray(wp))

    def test_append_pallas_matches_jax(self):
        from repro.streaming.updates import append_hierarchy

        rng = np.random.default_rng(4)
        n, cap = 900, 2000
        x = rng.random(n).astype(np.float32)
        plan = make_plan(n, c=16, t=1, capacity=cap)
        h = build_hierarchy(jnp.asarray(x), plan, with_positions=True)
        tail = jnp.asarray(rng.random(150).astype(np.float32))
        a = append_hierarchy(h, tail, jnp.int32(n))
        b = append_hierarchy_pallas(h, tail, jnp.int32(n), interpret=True)
        _assert_hierarchies_equal(a, b)


class TestRMQFacadeStreaming:
    def test_update_and_append_via_facade(self):
        rng = np.random.default_rng(21)
        n, cap = 3000, 5000
        x = rng.random(n).astype(np.float32)
        r = RMQ.build(x, c=16, t=8, with_positions=True, backend="jax",
                      capacity=cap)
        assert r.n == n
        idxs = rng.integers(0, n, 40)
        vals = rng.random(40).astype(np.float32)
        r = r.update(jnp.asarray(idxs), jnp.asarray(vals))
        x[idxs] = vals
        tail = rng.random(500).astype(np.float32)
        r = r.append(jnp.asarray(tail))
        x = np.concatenate([x, tail])
        assert r.n == n + 500
        ls = rng.integers(0, r.n, 64)
        rs = np.minimum(ls + rng.integers(0, r.n, 64), r.n - 1)
        ls, rs = (np.minimum(ls, rs).astype(np.int32),
                  np.maximum(ls, rs).astype(np.int32))
        np.testing.assert_array_equal(
            np.asarray(r.query(ls, rs)),
            np.array([x[l:r2 + 1].min() for l, r2 in zip(ls, rs)]),
        )
        np.testing.assert_array_equal(
            np.asarray(r.query_index(ls, rs)),
            np.array([l + np.argmin(x[l:r2 + 1]) for l, r2 in zip(ls, rs)]),
        )

    def test_append_without_capacity_raises(self):
        r = RMQ.build(np.ones(64, np.float32), c=8, t=1, backend="jax")
        with pytest.raises(ValueError, match="capacity"):
            r.append(np.ones(1, np.float32))


class TestOutOfRangeUpdates:
    """Out-of-range indices must be dropped entirely — not clamp-scatter
    into a different level's region of the contiguous upper buffer."""

    @pytest.mark.parametrize("backend", ["jax", "pallas"])
    def test_oob_update_is_a_noop(self, backend):
        rng = np.random.default_rng(9)
        n, c, t = 4096, 16, 4
        x = rng.random(n).astype(np.float32)
        x[1600] = 0.01
        plan = make_plan(n, c=c, t=t)
        h0 = build_hierarchy(jnp.asarray(x), plan, with_positions=True)
        oob = jnp.asarray([n + 100, -5, 2 * n], jnp.int32)
        vals = jnp.asarray([0.5, 0.5, 0.5], jnp.float32)
        if backend == "pallas":
            h1 = update_hierarchy_pallas(h0, oob, vals, interpret=True)
        else:
            h1 = update_hierarchy(h0, oob, vals)
        _assert_hierarchies_equal(h0, h1)
        # a full-range query still finds the true minimum
        s = StreamingRMQ(hierarchy=h1, backend="jax", length=n)
        assert float(s.query(np.array([0], np.int32),
                             np.array([n - 1], np.int32))[0]) == x.min()

    def test_mixed_oob_and_valid_updates(self):
        rng = np.random.default_rng(10)
        n = 1000
        x = rng.random(n).astype(np.float32)
        plan = make_plan(n, c=8, t=2)
        h = build_hierarchy(jnp.asarray(x), plan, with_positions=True)
        idxs = jnp.asarray([5, n + 7, 900], jnp.int32)
        vals = jnp.asarray([0.001, 0.002, 0.003], jnp.float32)
        h = update_hierarchy(h, idxs, vals)
        x[5], x[900] = 0.001, 0.003  # the OOB write is dropped
        ref = build_hierarchy(jnp.asarray(x), plan, with_positions=True)
        _assert_hierarchies_equal(ref, h)


class TestPosDtypeGuard:
    def test_int32_below_2_31(self):
        assert pos_dtype_for(1000) == jnp.int32
        assert pos_dtype_for(2**31 - 1) == jnp.int32

    def test_large_n_requires_x64(self):
        import jax

        if jax.config.x64_enabled:
            assert pos_dtype_for(2**31) == jnp.int64
        else:
            with pytest.raises(ValueError, match="x64"):
                pos_dtype_for(2**31)

    def test_value_only_build_unaffected_by_guard(self):
        """with_positions=False never materializes positions, so huge
        value-only builds must trace (eval_shape: no allocation)."""
        import functools
        import jax

        if jax.config.x64_enabled:
            pytest.skip("guard only fires with x64 disabled")
        big = 2**31 + 128
        plan = make_plan(big, c=128, t=64)
        spec = jax.ShapeDtypeStruct((big,), jnp.float32)
        out = jax.eval_shape(
            functools.partial(
                build_hierarchy, plan=plan, with_positions=False
            ),
            spec,
        )
        assert out.upper.shape[0] == plan.upper_size
        with pytest.raises(ValueError, match="x64"):
            jax.eval_shape(
                functools.partial(
                    build_hierarchy, plan=plan, with_positions=True
                ),
                spec,
            )
