"""Cross-backend differential harness (ISSUE 5's test centerpiece).

Random op sequences — build → update/append → query_value/query_index
over random spans — run against a plain **numpy oracle**, sweeping every
index implementation (``RMQ``, ``StreamingRMQ``, ``HybridRMQ``,
1×1-mesh ``DistributedRMQ``) × every backend (``jax``, ``pallas``,
``fused``), asserting bit-identical values AND leftmost-tie positions at
every step.  The oracle is deliberately dumb (``min`` / ``argmin`` over
the live slice): any divergence in window math, padding, tie-breaking,
mutation propagation, or backend lowering fails here.

Also in this module (the fused-query PR's acceptance contract):

* single-launch accounting — a mixed short/mid/long batch through a
  fused-backend engine records exactly ONE ``rmq_fused`` launch, for
  each of the four index implementations;
* targeted edge-case seams the fused path must preserve (``l == r``,
  the exact two-aligned-chunk short/mid boundary, full-array spans, the
  ``capacity > n`` +inf tail, stale-cache regressions after
  update/append through the fused executor).

Must-run coverage is numpy-RNG parametrized sweeps; hypothesis (when
installed) adds randomized geometry/op-sequence depth on the cheap
backends.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core.api import RMQ
from repro.core.distributed import DistributedRMQ
from repro.core.hybrid import HybridRMQ
from repro.core.query import rmq_index_batch, rmq_value_batch
from repro.kernels.profiling import count_launches
from repro.qe import FUSED, QueryEngine
from repro.streaming import StreamingRMQ

INDEX_KINDS = ("rmq", "streaming", "hybrid", "distributed")
BACKENDS = ("jax", "pallas", "fused")


# ---------------------------------------------------------------------------
# the numpy oracle: the dumbest possible correct RMQ
# ---------------------------------------------------------------------------
class NumpyOracle:
    """Live array + O(span) min/argmin answers; last-wins updates."""

    def __init__(self, x):
        self.x = np.asarray(x, np.float32).copy()

    @property
    def n(self):
        return self.x.shape[0]

    def update(self, idxs, vals):
        # apply sequentially so duplicate indices are last-wins by
        # construction (the indexes' documented contract)
        for i, v in zip(idxs, vals):
            self.x[int(i)] = v

    def append(self, vals):
        self.x = np.concatenate([self.x, np.asarray(vals, np.float32)])

    def query_value(self, ls, rs):
        return np.array(
            [self.x[l : r + 1].min() for l, r in zip(ls, rs)], np.float32
        )

    def query_index(self, ls, rs):
        return np.array(
            [l + int(np.argmin(self.x[l : r + 1]))
             for l, r in zip(ls, rs)],
            np.int32,
        )


def _tied_values(rng, n):
    """Integer-valued floats: heavy ties make leftmost breaks decisive."""
    return rng.integers(-4, 4, n).astype(np.float32)


def _random_spans(rng, n, m):
    ls = rng.integers(0, n, m)
    rs = np.minimum(ls + rng.integers(0, n, m), n - 1)
    return (np.minimum(ls, rs).astype(np.int32),
            np.maximum(ls, rs).astype(np.int32))


# ---------------------------------------------------------------------------
# index adapters (build / mutate / query through one surface)
# ---------------------------------------------------------------------------
def _build_index(kind, backend, x, c, t, cap,
                 packed_pos=None, summary_dtype=None):
    layout = dict(packed_pos=packed_pos, summary_dtype=summary_dtype)
    if kind == "rmq":
        return RMQ.build(x, c=c, t=t, with_positions=True,
                         backend=backend, capacity=cap, **layout)
    if kind == "streaming":
        return StreamingRMQ.from_array(x, c=c, t=t, with_positions=True,
                                       backend=backend, capacity=cap,
                                       **layout)
    if kind == "hybrid":
        # read-only: no capacity reservation; mutations rebuild (below)
        return HybridRMQ.build(x, c=c, t=t, with_positions=True,
                               backend=backend, **layout)
    if kind == "distributed":
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        return DistributedRMQ.build(np.asarray(x), mesh, c=c, t=t,
                                    with_positions=True, capacity=cap,
                                    backend=backend, **layout)
    raise ValueError(kind)


def _mutate_index(kind, backend, idx, oracle, c, t, idxs, vals, tail,
                  packed_pos=None, summary_dtype=None):
    """Apply (update, append) to the index; hybrid rebuilds instead."""
    if kind == "hybrid":
        # the hybrid is read-only by design (a point update can move
        # top-level minima); its differential story is rebuild-per-step
        return HybridRMQ.build(oracle.x, c=c, t=t, with_positions=True,
                               backend=backend, packed_pos=packed_pos,
                               summary_dtype=summary_dtype)
    if idxs.shape[0]:
        idx = idx.update(idxs, vals)
    if tail.shape[0]:
        idx = idx.append(tail)
    return idx


def _check_parity(idx, oracle, ls, rs):
    np.testing.assert_array_equal(
        np.asarray(idx.query_value_batch(ls, rs)),
        oracle.query_value(ls, rs),
    )
    np.testing.assert_array_equal(
        np.asarray(idx.query_index_batch(ls, rs)),
        oracle.query_index(ls, rs),
    )


def _run_sequence(kind, backend, *, n, c, t, cap, seed, steps, m=48,
                  packed_pos=None, summary_dtype=None):
    """build → (update/append → queries)* against the numpy oracle."""
    rng = np.random.default_rng(seed)
    oracle = NumpyOracle(_tied_values(rng, n))
    idx = _build_index(kind, backend, oracle.x, c, t, cap,
                       packed_pos=packed_pos, summary_dtype=summary_dtype)

    ls, rs = _random_spans(rng, oracle.n, m)
    _check_parity(idx, oracle, ls, rs)

    headroom = cap - n
    layout = dict(packed_pos=packed_pos, summary_dtype=summary_dtype)
    for step in range(steps):
        nn = oracle.n
        idxs = rng.integers(0, nn, 12)
        # duplicate index with two values: last must win everywhere
        if idxs.shape[0] >= 2:
            idxs[1] = idxs[0]
        vals = _tied_values(rng, 12)
        take = min(headroom // max(steps, 1), 20)
        tail = _tied_values(rng, take)
        if kind == "hybrid":
            oracle.update(idxs, vals)
            oracle.append(tail)
            idx = _mutate_index(kind, backend, idx, oracle, c, t,
                                idxs, vals, tail, **layout)
        else:
            idx = _mutate_index(kind, backend, idx, oracle, c, t,
                                idxs, vals, tail, **layout)
            oracle.update(idxs, vals)
            oracle.append(tail)
        assert oracle.n == (idx.plan.n if kind == "hybrid"
                            else int(idx.length))
        ls, rs = _random_spans(rng, oracle.n, m)
        _check_parity(idx, oracle, ls, rs)


# ---------------------------------------------------------------------------
# the sweep: 4 implementations x 3 backends, mutations included
# ---------------------------------------------------------------------------
class TestDifferentialSweep:
    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("kind", INDEX_KINDS)
    def test_random_op_sequence(self, kind, backend):
        # distributed: 2-level local plan (the first compile of a
        # 3-level distributed walk is minutes on CPU XLA — see
        # test_distributed_rmq.py); everything else gets 3 levels.
        if kind == "distributed":
            geo = dict(n=257, c=8, t=8, cap=400)
        else:
            geo = dict(n=257, c=8, t=2, cap=400)
        seed = INDEX_KINDS.index(kind) * 11 + BACKENDS.index(backend)
        _run_sequence(kind, backend, seed=seed, steps=3, **geo)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_engine_routed_sequence(self, backend):
        """The same differential, but queried through the span-routed /
        fused engine with attach-after-mutation (cache invalidation is
        part of the contract under test)."""
        rng = np.random.default_rng(99)
        n, c, t, cap = 300, 8, 2, 450
        oracle = NumpyOracle(_tied_values(rng, n))
        idx = _build_index("rmq", backend, oracle.x, c, t, cap)
        engine = idx.engine(cache_size=256)
        for step in range(3):
            ls, rs = _random_spans(rng, oracle.n, 40)
            np.testing.assert_array_equal(
                np.asarray(engine.query(ls, rs)),
                oracle.query_value(ls, rs),
            )
            np.testing.assert_array_equal(
                np.asarray(engine.query_index(ls, rs)),
                oracle.query_index(ls, rs),
            )
            idxs = rng.integers(0, oracle.n, 8)
            vals = _tied_values(rng, 8)
            tail = _tied_values(rng, 10)
            idx = idx.update(idxs, vals).append(tail)
            oracle.update(idxs, vals)
            oracle.append(tail)
            engine.attach(idx)

    @pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis")
    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(min_value=2, max_value=600),
        log_c=st.integers(min_value=1, max_value=4),
        t=st.integers(min_value=1, max_value=4),
        headroom=st.integers(min_value=0, max_value=120),
        kind=st.sampled_from(("rmq", "streaming")),
        backend=st.sampled_from(("jax", "fused")),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_property_random_geometry(self, n, log_c, t, headroom, kind,
                                      backend, seed):
        """Randomized geometry depth on the cheap backends (pallas
        interpret-mode retraces per geometry would dominate runtime;
        its coverage is the fixed-geometry sweep above)."""
        _run_sequence(kind, backend, n=n, c=2 ** log_c, t=t,
                      cap=n + headroom, seed=seed, steps=2, m=24)


# ---------------------------------------------------------------------------
# compact plane layouts through the same harness (bit-packed positions,
# bf16 summaries with exact recovery) — the PR's acceptance sweep
# ---------------------------------------------------------------------------
class TestCompactLayoutSweep:
    """The identical random-op differential, but with the compact index
    planes switched on: ``packed_pos=True`` (log2(c)-bit chunk-local
    offsets), ``summary_dtype='bfloat16'`` (half-width upper values with
    exact level-0 recovery), and both together.  Same oracle, same
    bit-identical assertion on values AND leftmost-tie positions, same
    post-update/append staleness coverage — compactness must never move
    a bit.
    """

    LAYOUTS = {
        "packed": dict(packed_pos=True),
        "bf16": dict(summary_dtype="bfloat16"),
        "packed_bf16": dict(packed_pos=True, summary_dtype="bfloat16"),
    }

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("kind", INDEX_KINDS)
    def test_packed_positions(self, kind, backend):
        if kind == "distributed":
            geo = dict(n=257, c=8, t=8, cap=400)
        else:
            geo = dict(n=257, c=8, t=2, cap=400)
        seed = 60 + INDEX_KINDS.index(kind) * 11 + BACKENDS.index(backend)
        _run_sequence(kind, backend, seed=seed, steps=2,
                      packed_pos=True, **geo)

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("kind", INDEX_KINDS)
    def test_bf16_exact_recovery(self, kind, backend):
        if kind == "hybrid":
            # by design: the sparse-table top would compare quantized
            # values — the refusal must be loud, not silently lossy
            with pytest.raises(ValueError, match="bf16"):
                HybridRMQ.build(np.zeros(300, np.float32), c=8, t=2,
                                with_positions=True, backend=backend,
                                summary_dtype="bfloat16")
            return
        if kind == "distributed":
            geo = dict(n=257, c=8, t=8, cap=400)
        else:
            geo = dict(n=257, c=8, t=2, cap=400)
        seed = 70 + INDEX_KINDS.index(kind) * 11 + BACKENDS.index(backend)
        _run_sequence(kind, backend, seed=seed, steps=2,
                      summary_dtype="bfloat16", **geo)

    @pytest.mark.parametrize("kind",
                             ("rmq", "streaming", "distributed"))
    def test_packed_and_bf16_together(self, kind):
        """Both compactions at once, on the coordinate-exact jax walk."""
        if kind == "distributed":
            geo = dict(n=257, c=8, t=8, cap=400)
        else:
            geo = dict(n=257, c=8, t=2, cap=400)
        seed = 80 + INDEX_KINDS.index(kind)
        _run_sequence(kind, "jax", seed=seed, steps=2,
                      packed_pos=True, summary_dtype="bfloat16", **geo)

    def test_packed_plane_is_bitwise_classic(self):
        """Not just query parity: the packed plane must UNPACK to the
        classic absolute plane word-for-word — after build and after
        mutations."""
        from repro.core import bitpack

        rng = np.random.default_rng(90)
        x = _tied_values(rng, 300)
        classic = RMQ.build(x, c=8, t=2, with_positions=True,
                            backend="jax", capacity=400)
        packed = RMQ.build(x, c=8, t=2, with_positions=True,
                           backend="jax", capacity=400, packed_pos=True)
        assert packed.hierarchy.upper_pos.dtype == jnp.uint32
        np.testing.assert_array_equal(
            np.asarray(bitpack.resolve_positions(
                packed.hierarchy.upper_pos, packed.plan)),
            np.asarray(classic.hierarchy.upper_pos),
        )
        idxs = rng.integers(0, 300, 16).astype(np.int32)
        vals = _tied_values(rng, 16)
        tail = _tied_values(rng, 40)
        classic = classic.update(idxs, vals).append(tail)
        packed = packed.update(idxs, vals).append(tail)
        np.testing.assert_array_equal(
            np.asarray(bitpack.resolve_positions(
                packed.hierarchy.upper_pos, packed.plan)),
            np.asarray(classic.hierarchy.upper_pos),
        )
        np.testing.assert_array_equal(
            np.asarray(packed.hierarchy.upper),
            np.asarray(classic.hierarchy.upper),
        )

    def test_bf16_plane_really_is_bf16(self):
        """The compact build must actually store bf16 upper values (and
        the packed plane must actually be smaller) — guards against a
        silently-classic build passing the parity sweep."""
        rng = np.random.default_rng(91)
        x = _tied_values(rng, 700)
        r = RMQ.build(x, c=8, t=2, with_positions=True, backend="jax",
                      packed_pos=True, summary_dtype="bfloat16")
        assert r.hierarchy.upper.dtype == jnp.bfloat16
        assert r.hierarchy.base.dtype == jnp.float32  # level 0 stays exact
        assert r.hierarchy.upper_pos.dtype == jnp.uint32
        classic = RMQ.build(x, c=8, t=2, with_positions=True,
                            backend="jax")
        assert (r.hierarchy.upper_pos.size
                < classic.hierarchy.upper_pos.size)
        assert r.plan.auxiliary_bytes_planned(True) \
            < classic.plan.auxiliary_bytes_planned(True)


# ---------------------------------------------------------------------------
# the 2^31 ceiling: plan accounting now, real builds under x64
# ---------------------------------------------------------------------------
class TestPast2Pow31:
    """Plan-level accounting just past the int32 ceiling (pure host
    math — no giant allocation), plus the x64-gated coordinate-dtype
    story.  The actual multi-GiB build is env-gated
    (``REPRO_RMQ_BIG=1``): CI asserts the plumbing, a workstation can
    assert the build.
    """

    N_BIG = 2**31 + 4096

    def test_plan_accounting_past_2pow31(self):
        from repro.core.plan import make_plan

        classic = make_plan(self.N_BIG, c=128, t=64)
        packed = make_plan(self.N_BIG, c=128, t=64, packed_pos=True)
        assert packed.pos_bits() == 7
        # classic absolute positions widen to int64 past 2^31 …
        assert classic.position_plane_bytes() \
            == classic.upper_size * 8
        # … while the packed plane stays at 7 bits/entry regardless
        assert packed.position_plane_bytes() \
            == ((packed.upper_size * 7 + 31) // 32) * 4
        ratio = (classic.position_plane_bytes()
                 / packed.position_plane_bytes())
        assert ratio > 9.0, ratio
        # the honest total: value plane + positions, still way under 30%
        for plan in (classic, packed):
            overhead = (plan.auxiliary_bytes_planned(True)
                        / plan.input_bytes())
            assert overhead < 0.30, (plan.packed_pos, overhead)

    def test_x64_coordinate_dtype_selection(self):
        """Under x64 the coordinate plane is int64 and the capacity
        guard admits >= 2^31 on the jax path; without it both refuse
        loudly.  Runs in a subprocess so the x64 flag never leaks into
        this process (same discipline as the fake-mesh tests)."""
        import subprocess
        import sys

        prog = r"""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from repro.core import protocol as px
from repro.core.hierarchy import pos_dtype_for

N = 2**31 + 4096
assert pos_dtype_for(N) == jnp.int64
assert pos_dtype_for(N, strict=False) == jnp.int64
px.check_capacity_limit(N, allow_x64=True)       # passes under x64
try:
    px.check_capacity_limit(N)                   # strict sites still refuse
except ValueError as e:
    assert "int32 query index space" in str(e)
else:
    raise AssertionError("strict guard must refuse regardless of x64")

# small build under x64: coordinates widen, results do not move
import numpy as np
rng = np.random.default_rng(0)
x = rng.integers(-4, 4, 515).astype(np.float32)
from repro.core.api import RMQ
r = RMQ.build(x, c=8, t=2, with_positions=True, backend="jax",
              packed_pos=True)
ls = rng.integers(0, 515, 64); rs = rng.integers(0, 515, 64)
ls, rs = np.minimum(ls, rs), np.maximum(ls, rs)
want_v = np.array([x[l:r+1].min() for l, r in zip(ls, rs)])
want_p = np.array([l + np.argmin(x[l:r+1]) for l, r in zip(ls, rs)])
assert np.array_equal(np.asarray(r.query(ls, rs)), want_v)
assert np.array_equal(np.asarray(r.query_index(ls, rs)), want_p)

import os
if os.environ.get("REPRO_RMQ_BIG") == "1":
    # the real thing: an out-of-core build just past the ceiling
    # (needs ~10 GiB host RAM; not a CI job)
    def source(a, b):
        return np.zeros(b - a, np.float32)
    big = RMQ.build_out_of_core(source, N, c=128, t=64,
                                with_positions=True, packed_pos=True)
    assert int(big.query_index(np.array([N - 10]),
                               np.array([N - 1]))[0]) == N - 10
print("X64_OK")
"""
        out = subprocess.run(
            [sys.executable, "-c", prog],
            capture_output=True, text=True, timeout=600,
        )
        assert out.returncode == 0, out.stderr
        assert "X64_OK" in out.stdout

    def test_without_x64_everything_refuses(self):
        """This process has x64 off: every entry to >= 2^31 index space
        must refuse loudly rather than wrap."""
        from repro.core.hierarchy import pos_dtype_for
        from repro.core import protocol as px

        with pytest.raises(ValueError, match="x64"):
            pos_dtype_for(2**31)
        with pytest.raises(ValueError, match="int32 query index space"):
            px.check_capacity_limit(2**31, allow_x64=True)
        # strict=False is the query-side fallback: int32, never wraps up
        assert pos_dtype_for(2**31, strict=False) == jnp.int32


# ---------------------------------------------------------------------------
# acceptance: ONE recorded launch for a mixed span batch, all 4 indexes
# ---------------------------------------------------------------------------
def _mixed_span_batch(rng, n, c, m=90):
    """Spans pinned across short / mid / long classes, shuffled."""
    third = m // 3
    spans = np.concatenate([
        rng.integers(1, c + 1, third),                  # short
        rng.integers(2 * c + 2, max(n // 3, 2 * c + 3), third),  # mid
        rng.integers(max(2 * n // 3, 2), n + 1, m - 2 * third),  # long
    ])
    rng.shuffle(spans)
    ls = (rng.random(m) * np.maximum(n - spans + 1, 1)).astype(np.int64)
    rs = np.minimum(ls + spans - 1, n - 1)
    return ls.astype(np.int32), rs.astype(np.int32)


class TestFusedSingleLaunch:
    """A mixed short/mid/long batch through a fused-backend engine is
    bit-identical to the engine oracle (values + leftmost-tie indices)
    and costs exactly ONE recorded ``rmq_fused`` launch — for every
    index implementation.  Geometries are unique to this class so the
    first-trace launch accounting is fresh (see kernels/profiling).
    """

    def _assert_one_launch(self, engine, oracle_x, n, rng):
        c = engine.index.plan.c
        ls, rs = _mixed_span_batch(rng, n, c)
        oracle = NumpyOracle(oracle_x)
        with count_launches() as counts:
            got_v = np.asarray(engine.query(ls, rs))
        assert counts == {"rmq_fused": 1}, counts
        with count_launches() as counts:
            got_p = np.asarray(engine.query_index(ls, rs))
        # index queries are a separate (track_pos) specialization:
        # still one launch, never more
        assert counts == {"rmq_fused": 1}, counts
        np.testing.assert_array_equal(got_v, oracle.query_value(ls, rs))
        np.testing.assert_array_equal(got_p, oracle.query_index(ls, rs))

    def test_rmq(self):
        rng = np.random.default_rng(0)
        n = 2113
        x = _tied_values(rng, n)
        r = RMQ.build(x, c=8, t=8, with_positions=True, backend="fused",
                      capacity=2400)
        self._assert_one_launch(r.engine(cache_size=0), x, n, rng)

    def test_streaming(self):
        rng = np.random.default_rng(1)
        n = 2129
        x = _tied_values(rng, n)
        s = StreamingRMQ.from_array(x, c=8, t=8, with_positions=True,
                                    backend="fused", capacity=2500)
        self._assert_one_launch(s.engine(cache_size=0), x, n, rng)

    def test_hybrid(self):
        # the hybrid's own backend is always 'jax' (its walk is pure
        # JAX); the engine still prefers the fused executor when asked
        rng = np.random.default_rng(2)
        n = 2141
        x = _tied_values(rng, n)
        h = HybridRMQ.build(x, c=8, t=8, with_positions=True,
                            backend="fused")
        engine = QueryEngine(h, backend="fused", cache_size=0)
        self._assert_one_launch(engine, x, n, rng)

    def test_distributed(self):
        # 1x1 mesh: every span is segment-contained, so the engine's
        # no-collective fast path answers the whole batch — through the
        # fused lowering, in one launch per (track) specialization
        rng = np.random.default_rng(3)
        n = 2153
        x = _tied_values(rng, n)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        d = DistributedRMQ.build(x, mesh, c=8, t=64, with_positions=True,
                                 backend="fused")
        self._assert_one_launch(d.engine(cache_size=0), x, n, rng)

    def test_mixed_ops_one_launch(self):
        """Value AND index ops in one batch: one launch total (both
        output planes come out of the same kernel call)."""
        rng = np.random.default_rng(4)
        n = 2161
        x = _tied_values(rng, n)
        r = RMQ.build(x, c=8, t=8, with_positions=True, backend="fused")
        engine = r.engine(cache_size=64)
        ls, rs = _mixed_span_batch(rng, n, 8)
        is_index = rng.random(ls.shape[0]) < 0.5
        oracle = NumpyOracle(x)
        with count_launches() as counts:
            vals, poss = engine.query_mixed(ls, rs, is_index)
        assert counts == {"rmq_fused": 1}, counts
        np.testing.assert_array_equal(
            vals[~is_index], oracle.query_value(ls, rs)[~is_index]
        )
        np.testing.assert_array_equal(
            poss[is_index], oracle.query_index(ls, rs)[is_index]
        )
        # mixed results land in the per-op cache: repeats are pure hits
        h0 = engine.cache.hits
        engine.query_mixed(ls, rs, is_index)
        assert engine.cache.hits > h0

    def test_query_mixed_fallback_parity(self):
        """query_mixed on a NON-fused engine (no single-launch claim)
        still answers both planes bit-identically."""
        rng = np.random.default_rng(5)
        n = 997
        x = _tied_values(rng, n)
        r = RMQ.build(x, c=8, t=2, with_positions=True, backend="jax")
        engine = r.engine()
        assert not engine.supports_mixed
        ls, rs = _random_spans(rng, n, 64)
        is_index = rng.random(64) < 0.5
        vals, poss = engine.query_mixed(ls, rs, is_index)
        oracle = NumpyOracle(x)
        np.testing.assert_array_equal(
            vals[~is_index], oracle.query_value(ls, rs)[~is_index]
        )
        np.testing.assert_array_equal(
            poss[is_index], oracle.query_index(ls, rs)[is_index]
        )


# ---------------------------------------------------------------------------
# service-level fused coalescing
# ---------------------------------------------------------------------------
class TestFusedService:
    def test_mixed_merge_scatters_per_ticket(self):
        from repro.qe import QueryService

        rng = np.random.default_rng(20)
        n = 1500
        x = _tied_values(rng, n)
        r = RMQ.build(x, c=8, t=2, with_positions=True, backend="fused")
        svc = QueryService()
        svc.register("a", r)
        ls, rs = _random_spans(rng, n, 40)
        t_v = svc.submit("a", ls[:20], rs[:20])
        t_i = svc.submit("a", ls[20:], rs[20:], op="index")
        res = svc.flush()
        oracle = NumpyOracle(x)
        np.testing.assert_array_equal(
            np.asarray(res[t_v]), oracle.query_value(ls[:20], rs[:20])
        )
        np.testing.assert_array_equal(
            np.asarray(res[t_i]), oracle.query_index(ls[20:], rs[20:])
        )

    def test_merged_flush_keeps_per_op_failure_isolation(self):
        """A failing op group in a MERGED mixed flush must not take the
        index's healthy other-op group down with it (the PR 3
        failure-isolation contract, preserved across merging)."""
        from repro.qe import QueryService

        rng = np.random.default_rng(21)
        n = 1500
        x = _tied_values(rng, n)
        r = RMQ.build(x, c=8, t=2, with_positions=True, backend="fused")
        value_only = RMQ.build(x, c=8, t=2, backend="fused")
        svc = QueryService()
        svc.register("a", r)
        t_v = svc.submit("a", np.array([0]), np.array([n - 1]))
        t_i = svc.submit("a", np.array([1]), np.array([50]), op="index")
        # admission checked positions against the old binding; the
        # value-only successor lands before the flush
        svc.attach("a", value_only, reset_cache=True)
        with pytest.raises(RuntimeError, match="claimable"):
            svc.flush()
        # the VALUE group executed on the per-op retry and survived
        assert float(svc.take(t_v)[0]) == x.min()
        with pytest.raises(KeyError):
            svc.take(t_i)


# ---------------------------------------------------------------------------
# targeted seams the fused path must preserve
# ---------------------------------------------------------------------------
class TestFusedSeams:
    """Planner/cache seam cases routed through the fused executor."""

    def _engine(self, rng, n=520, c=8, t=2, cap=760):
        x = _tied_values(rng, n)
        r = RMQ.build(x, c=c, t=t, with_positions=True, backend="fused",
                      capacity=cap)
        return x, r, r.engine(cache_size=128)

    def test_point_and_boundary_spans(self):
        rng = np.random.default_rng(10)
        x, r, engine = self._engine(rng)
        n, c = 520, 8
        ls = np.array([
            0,            # l == r at the left edge
            n - 1,        # l == r at the right edge (capacity tail abuts)
            2 * c,        # exactly 2 aligned chunks: [2c, 4c)
            2 * c,        # one past: 2 chunks + 1 entry -> mid class
            0,            # full-array span
            3 * c - 1,    # crosses one chunk boundary (short)
        ], np.int32)
        rs = np.array([
            0,
            n - 1,
            4 * c - 1,
            4 * c,
            n - 1,
            3 * c,
        ], np.int32)
        oracle = NumpyOracle(x)
        np.testing.assert_array_equal(
            np.asarray(engine.query(ls, rs)), oracle.query_value(ls, rs)
        )
        np.testing.assert_array_equal(
            np.asarray(engine.query_index(ls, rs)),
            oracle.query_index(ls, rs),
        )

    def test_capacity_tail_never_wins(self):
        """capacity > n: the +inf-reserved tail must not leak into
        results for spans touching the live right edge — before OR
        after appends move that edge."""
        rng = np.random.default_rng(11)
        n, c, cap = 130, 8, 200
        x = _tied_values(rng, n)
        r = RMQ.build(x, c=c, t=2, with_positions=True, backend="fused",
                      capacity=cap)
        engine = r.engine()
        oracle = NumpyOracle(x)
        ls = np.array([n - 1, n - 2, 0, n - c], np.int32)
        rs = np.array([n - 1, n - 1, n - 1, n - 1], np.int32)
        np.testing.assert_array_equal(
            np.asarray(engine.query(ls, rs)), oracle.query_value(ls, rs)
        )
        np.testing.assert_array_equal(
            np.asarray(engine.query_index(ls, rs)),
            oracle.query_index(ls, rs),
        )
        # grow into the tail; the new edge behaves identically
        tail = np.full((30,), 9.0, np.float32)  # larger than any live min
        r2 = r.append(tail)
        oracle.append(tail)
        engine.attach(r2)
        n2 = oracle.n
        ls2 = np.array([n2 - 1, n2 - 30, 0], np.int32)
        rs2 = np.array([n2 - 1, n2 - 1, n2 - 1], np.int32)
        np.testing.assert_array_equal(
            np.asarray(engine.query(ls2, rs2)),
            oracle.query_value(ls2, rs2),
        )
        np.testing.assert_array_equal(
            np.asarray(engine.query_index(ls2, rs2)),
            oracle.query_index(ls2, rs2),
        )

    def test_stale_cache_after_update_through_fused(self):
        rng = np.random.default_rng(12)
        x, r, engine = self._engine(rng)
        l, r_ = 40, 480
        before = float(engine.query(np.array([l]), np.array([r_]))[0])
        assert before == x[l : r_ + 1].min()
        h0 = engine.cache.hits
        engine.query(np.array([l]), np.array([r_]))
        assert engine.cache.hits == h0 + 1          # served from cache
        pos = 222
        r2 = r.update(np.array([pos]), np.array([-9.0], np.float32))
        engine.attach(r2)
        assert float(engine.query(np.array([l]), np.array([r_]))[0]) \
            == -9.0
        assert int(
            engine.query_index(np.array([l]), np.array([r_]))[0]
        ) == pos

    def test_stale_cache_after_append_through_fused(self):
        rng = np.random.default_rng(13)
        x, r, engine = self._engine(rng)
        n = 520
        v0 = float(engine.query(np.array([0]), np.array([n - 1]))[0])
        r2 = r.append(np.array([-11.0], np.float32))
        engine.attach(r2)
        # old range unchanged; extended range sees the appended minimum
        assert float(engine.query(np.array([0]), np.array([n - 1]))[0]) \
            == v0
        assert float(engine.query(np.array([0]), np.array([n]))[0]) \
            == -11.0

    def test_value_only_fused_index_raises(self):
        x = np.random.default_rng(14).random(600).astype(np.float32)
        r = RMQ.build(x, c=8, t=2, backend="fused")  # value-only
        engine = r.engine()
        np.testing.assert_array_equal(
            np.asarray(engine.query(np.array([3]), np.array([580]))),
            np.array([x[3:581].min()], np.float32),
        )
        with pytest.raises(ValueError, match="without positions"):
            engine.query_index(np.array([0]), np.array([10]))
        with pytest.raises(ValueError, match="without positions"):
            r.query_index(np.array([0]), np.array([10]))

    def test_fused_engine_matches_core_oracle_exactly(self):
        """Belt-and-braces: fused engine vs the core jnp walk (not just
        the numpy oracle) — same values, same tie positions."""
        rng = np.random.default_rng(15)
        x, r, engine = self._engine(rng)
        ls, rs = _random_spans(rng, 520, 200)
        lsj, rsj = jnp.asarray(ls), jnp.asarray(rs)
        np.testing.assert_array_equal(
            np.asarray(engine.query(ls, rs)),
            np.asarray(rmq_value_batch(r.hierarchy, lsj, rsj)),
        )
        np.testing.assert_array_equal(
            np.asarray(engine.query_index(ls, rs)),
            np.asarray(rmq_index_batch(r.hierarchy, lsj, rsj)),
        )
        assert engine.stats()["class_counts"][FUSED] > 0


# ---------------------------------------------------------------------------
# the bulk path folded into the differential harness (PR 9)
# ---------------------------------------------------------------------------
class TestBulkDifferential:
    """``query_bulk`` — the endpoint-sorted, level-0-coalesced bucket
    sweep — against the numpy oracle AND the fused per-query path:
    values, leftmost-tie positions, mutation staleness, and the
    sort/bucket layer's degenerate shapes.  Routing is forced to the
    bulk executor with ``bulk_crossover=1`` except where the crossover
    itself is under test."""

    @pytest.mark.parametrize("kind", INDEX_KINDS)
    def test_bulk_random_sequence(self, kind):
        # same geometry policy as the main sweep: distributed keeps a
        # 2-level local plan (3-level first-compiles are minutes on CPU)
        if kind == "distributed":
            geo = dict(n=257, c=8, t=8, cap=400)
        else:
            geo = dict(n=257, c=8, t=2, cap=400)
        n, c, t, cap = geo["n"], geo["c"], geo["t"], geo["cap"]
        rng = np.random.default_rng(40 + INDEX_KINDS.index(kind))
        oracle = NumpyOracle(_tied_values(rng, n))
        idx = _build_index(kind, "fused", oracle.x, c, t, cap)
        engine = QueryEngine(idx, backend="fused", cache_size=0,
                             bulk_crossover=1)
        for step in range(3):
            ls, rs = _random_spans(rng, oracle.n, 64)
            # duplicate (l, r) pairs must come back duplicated in place
            ls[5], rs[5] = ls[4], rs[4]
            np.testing.assert_array_equal(
                np.asarray(engine.query_bulk(ls, rs)),
                oracle.query_value(ls, rs),
            )
            np.testing.assert_array_equal(
                np.asarray(engine.query_bulk(ls, rs, op="index")),
                oracle.query_index(ls, rs),
            )
            # bit-identity with the fused per-query path on the same batch
            np.testing.assert_array_equal(
                np.asarray(engine.query_bulk(ls, rs)),
                np.asarray(engine.query(ls, rs)),
            )
            # mutate; the re-attached engine must serve the new state
            # through the bulk path (no LRU to go stale, but the bucket
            # executor binds per-hierarchy — staleness IS the seam here)
            idxs = rng.integers(0, oracle.n, 8)
            vals = _tied_values(rng, 8)
            take = min(cap - oracle.n, 10)
            tail = _tied_values(rng, take)
            if kind == "hybrid":
                oracle.update(idxs, vals)
                oracle.append(tail)
                idx = _mutate_index(kind, "fused", idx, oracle, c, t,
                                    idxs, vals, tail)
            else:
                idx = _mutate_index(kind, "fused", idx, oracle, c, t,
                                    idxs, vals, tail)
                oracle.update(idxs, vals)
                oracle.append(tail)
            engine.attach(idx)

    def test_bulk_bucket_seams(self):
        """Degenerate batch shapes for the sort/bucket layer: every
        query inside ONE chunk (maximal level-0 sharing), every query a
        distinct (chunk(l), chunk(r)) pair (no sharing at all),
        duplicate (l, r) pairs, and l == r runs — all inverse-permuted
        back to submission order bit-exactly."""
        rng = np.random.default_rng(50)
        n, c = 520, 8
        x = _tied_values(rng, n)
        r = RMQ.build(x, c=c, t=2, with_positions=True, backend="fused",
                      capacity=760)
        engine = QueryEngine(r, cache_size=0, bulk_crossover=1)
        oracle = NumpyOracle(x)

        base = 3 * c
        a = base + rng.integers(0, c, 32)
        b = base + rng.integers(0, c, 32)
        one_chunk = (np.minimum(a, b).astype(np.int32),
                     np.maximum(a, b).astype(np.int32))

        i = np.arange(16)
        distinct_pairs = (
            (2 * i * c + (i % c)).astype(np.int32),
            np.minimum((2 * i + 1) * c + ((i * 3) % c), n - 1)
            .astype(np.int32),
        )

        duplicates = (
            np.array([7] * 16 + [100] * 16, np.int32),
            np.array([300] * 16 + [101] * 16, np.int32),
        )

        pts = rng.integers(0, n, 32).astype(np.int32)
        point_runs = (pts, pts.copy())

        for name, (ls, rs) in {
            "one_chunk": one_chunk,
            "distinct_pairs": distinct_pairs,
            "duplicates": duplicates,
            "point_runs": point_runs,
        }.items():
            np.testing.assert_array_equal(
                np.asarray(engine.query_bulk(ls, rs)),
                oracle.query_value(ls, rs), err_msg=name,
            )
            np.testing.assert_array_equal(
                np.asarray(engine.query_bulk(ls, rs, op="index")),
                oracle.query_index(ls, rs), err_msg=name,
            )

    def test_bulk_crossover_routes_small_batches_to_fused(self):
        """Below the crossover ``query_bulk`` is the fused path (one
        ``rmq_fused`` launch, LRU included); at or above it, one
        ``rmq_bulk`` launch per bucket.  Fresh-prime geometry keeps the
        first-trace launch accounting honest."""
        rng = np.random.default_rng(51)
        n = 2221
        x = _tied_values(rng, n)
        r = RMQ.build(x, c=8, t=8, with_positions=True, backend="fused",
                      capacity=2400)
        engine = QueryEngine(r, cache_size=0, bulk_crossover=64)
        oracle = NumpyOracle(x)
        ls, rs = _random_spans(rng, n, 32)
        with count_launches() as counts:
            small = np.asarray(engine.query_bulk(ls, rs))
        assert counts == {"rmq_fused": 1}, counts
        np.testing.assert_array_equal(small, oracle.query_value(ls, rs))
        lsb, rsb = _random_spans(rng, n, 128)
        with count_launches() as counts:
            big = np.asarray(engine.query_bulk(lsb, rsb))
        assert counts == {"rmq_bulk": 1}, counts
        np.testing.assert_array_equal(big, oracle.query_value(lsb, rsb))

    def test_bulk_kernel_interpret_parity(self):
        """The Pallas bulk kernel (interpret mode off-TPU) against the
        production jnp ladder lowering and the shared branch-free
        oracle: the conditional level-0 DMA reuse must not move a bit,
        values or leftmost-tie positions."""
        from repro.kernels.rmq_bulk.ops import rmq_bulk_batch
        from repro.kernels.rmq_bulk.ref import rmq_bulk_batch_ref

        rng = np.random.default_rng(52)
        n, c, t = 520, 8, 2
        x = _tied_values(rng, n)
        h = RMQ.build(x, c=c, t=t, with_positions=True,
                      backend="fused").hierarchy
        ls, rs = _random_spans(rng, n, 64)
        order = np.lexsort((rs // c, ls // c))   # the executor's sort
        ls, rs = ls[order], rs[order]
        for track in (False, True):
            kv, kp = rmq_bulk_batch(h, ls, rs, track_pos=track,
                                    interpret=True)
            jv, jp = rmq_bulk_batch(h, ls, rs, track_pos=track)
            rv, rp = rmq_bulk_batch_ref(
                h.plan, h.base, h.upper,
                h.upper_pos if track else None, ls, rs, track_pos=track,
            )
            np.testing.assert_array_equal(np.asarray(kv), np.asarray(rv))
            np.testing.assert_array_equal(np.asarray(jv), np.asarray(rv))
            if track:
                np.testing.assert_array_equal(np.asarray(kp),
                                              np.asarray(rp))
                np.testing.assert_array_equal(np.asarray(jp),
                                              np.asarray(rp))
