"""Unified observability layer: tracer, launch registry, metrics.

Covers the obs subsystem's contracts directly (span nesting under a fake
clock, Chrome-trace schema, Prometheus exposition, registry attribution,
the Histogram torn-read regression) plus the end-to-end wiring: one
ServingTier flush must produce the full span tree and one metrics tree
must export engine cache/span-class/padding series.
"""

from __future__ import annotations

import json
import threading

import numpy as np
import pytest

from repro.core.api import RMQ
from repro.kernels.profiling import (
    count_launches,
    launch_registry,
    operand_bytes,
    timed_dispatch,
)
from repro.obs import trace
from repro.obs.metrics import Counter, Gauge, Histogram, Metrics
from repro.obs.trace import Tracer, use_tracer
from repro.qe import QueryService
from repro.qe.cache import ResultCache
from repro.qe.executors import INDEX, VALUE
from repro.serving import ServingTier


class FakeClock:
    """Deterministic monotonic clock for exact span-time assertions."""

    def __init__(self, t: float = 100.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------
class TestTracer:
    def test_nesting_and_ordering_under_fake_clock(self):
        clock = FakeClock()
        tr = Tracer(clock=clock)
        outer = tr.begin("flush")
        clock.advance(1.0)
        inner = tr.begin("plan")
        clock.advance(0.5)
        tr.end(inner, buckets=2)
        clock.advance(0.25)
        tr.end(outer, tenant="a")
        spans = tr.spans()
        # completion order: children close before parents
        assert [s.name for s in spans] == ["plan", "flush"]
        plan, flush = spans
        assert plan.parent_id == flush.span_id
        assert flush.parent_id is None
        assert (plan.start, plan.end) == (101.0, 101.5)
        assert (flush.start, flush.end) == (100.0, 101.75)
        assert plan.duration == pytest.approx(0.5)
        assert plan.args == {"buckets": 2}
        assert flush.args == {"tenant": "a"}

    def test_sibling_spans_share_parent(self):
        tr = Tracer(clock=FakeClock())
        root = tr.begin("root")
        a = tr.begin("a")
        tr.end(a)
        b = tr.begin("b")
        tr.end(b)
        tr.end(root)
        assert a.parent_id == root.span_id
        assert b.parent_id == root.span_id

    def test_span_context_manager(self):
        tr = Tracer(clock=FakeClock())
        with tr.span("execute", cls="fused") as sp:
            pass
        assert sp.end is not None
        assert tr.spans()[0].args == {"cls": "fused"}

    def test_threads_keep_separate_parent_stacks(self):
        tr = Tracer(clock=FakeClock())
        root = tr.begin("root")

        def worker():
            sp = tr.begin("worker_span")
            tr.end(sp)

        t = threading.Thread(target=worker, name="obs-worker")
        t.start()
        t.join()
        tr.end(root)
        worker_sp = next(s for s in tr.spans() if s.name == "worker_span")
        # never adopts another thread's open span as parent
        assert worker_sp.parent_id is None
        assert worker_sp.thread == "obs-worker"

    def test_unbalanced_end_truncates_descendants(self):
        tr = Tracer(clock=FakeClock())
        outer = tr.begin("outer")
        tr.begin("leaked")          # never explicitly ended
        tr.end(outer)
        nxt = tr.begin("next")
        tr.end(nxt)
        assert nxt.parent_id is None

    def test_ring_buffer_bounds_and_dropped(self):
        tr = Tracer(clock=FakeClock(), capacity=4)
        for i in range(6):
            tr.instant(f"e{i}")
        spans = tr.spans()
        assert len(spans) == 4
        assert [s.name for s in spans] == ["e2", "e3", "e4", "e5"]
        assert tr.dropped == 2
        tr.clear()
        assert tr.spans() == [] and tr.dropped == 0

    def test_record_explicit_timestamps(self):
        tr = Tracer(clock=FakeClock())
        parent = tr.begin("flush")
        sp = tr.record("queue", 10.0, 12.5, parent=parent, queries=3)
        tr.end(parent)
        assert sp.start == 10.0 and sp.end == 12.5
        assert sp.parent_id == parent.span_id
        assert sp.args == {"queries": 3}

    def test_chrome_trace_schema(self, tmp_path):
        clock = FakeClock(0.0)
        tr = Tracer(clock=clock)
        outer = tr.begin("flush")
        clock.advance(0.002)
        inner = tr.begin("plan")
        clock.advance(0.001)
        tr.end(inner)
        tr.end(outer, tenant="a")
        doc = tr.to_chrome_trace()
        assert doc["displayTimeUnit"] == "ms"
        events = doc["traceEvents"]
        assert len(events) == 2
        for e in events:
            assert e["ph"] == "X" and e["cat"] == "repro"
            assert set(e) >= {"name", "ts", "dur", "pid", "tid", "args"}
            assert "span_id" in e["args"]
        plan = next(e for e in events if e["name"] == "plan")
        flush = next(e for e in events if e["name"] == "flush")
        assert plan["ts"] == pytest.approx(2000.0)      # microseconds
        assert plan["dur"] == pytest.approx(1000.0)
        assert plan["args"]["parent_id"] == flush["args"]["span_id"]
        assert flush["args"]["tenant"] == "a"
        # round-trips through the file export
        path = tmp_path / "trace.json"
        tr.save_chrome_trace(str(path))
        assert json.loads(path.read_text()) == doc

    def test_disabled_tracing_is_noop(self):
        assert trace.current() is None
        # module helpers: shared null context, no spans anywhere
        assert trace.span("x") is trace.span("y")
        with trace.span("x") as sp:
            assert sp is None
        assert trace.instant("x") is None
        assert trace.record("x", 0.0, 1.0) is None

    def test_use_tracer_installs_and_restores(self):
        tr = Tracer(clock=FakeClock())
        with use_tracer(tr) as got:
            assert got is tr and trace.current() is tr
            trace.instant("inside")
        assert trace.current() is None
        assert [s.name for s in tr.spans()] == ["inside"]


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------
class TestMetrics:
    def test_prometheus_counter_and_gauge(self):
        m = Metrics()
        m.counter("requests").inc(3)
        m.gauge("depth").set(7)
        text = m.to_prometheus()
        assert "# TYPE repro_requests_total counter" in text
        assert "repro_requests_total 3.0" in text
        assert "# TYPE repro_depth gauge" in text
        assert "repro_depth 7.0" in text
        assert text.endswith("\n")

    def test_gauge_callback_and_failure(self):
        m = Metrics()
        state = {"v": 2}
        g = m.gauge("live", fn=lambda: state["v"])
        assert g.value == 2.0
        state["v"] = 5
        assert g.value == 5.0
        g.set_fn(lambda: 1 / 0)
        assert g.value == 0.0          # a broken callback must not poison
        g.set(9)                       # explicit set clears the callback
        assert g.value == 9.0

    def test_prometheus_histogram_cumulative_buckets(self):
        m = Metrics()
        h = m.histogram("lat", bounds=(1.0, 2.0))
        for v in (0.5, 1.5, 5.0):
            h.record(v)
        text = m.to_prometheus()
        assert "# TYPE repro_lat histogram" in text
        assert 'repro_lat_bucket{le="1.0"} 1' in text
        assert 'repro_lat_bucket{le="2.0"} 2' in text
        assert 'repro_lat_bucket{le="+Inf"} 3' in text
        assert "repro_lat_sum 7.0" in text
        assert "repro_lat_count 3" in text

    def test_labeled_scopes(self):
        m = Metrics()
        tenants = m.scope("tenants", child_label="tenant")
        tenants.scope("search").counter("submits").inc()
        tenants.scope("ads").counter("submits").inc(2)
        text = m.to_prometheus()
        assert 'repro_tenants_submits_total{tenant="search"} 1.0' in text
        assert 'repro_tenants_submits_total{tenant="ads"} 2.0' in text
        # one TYPE line for the shared series
        assert text.count("# TYPE repro_tenants_submits_total") == 1
        # nested dict export keeps the tree shape
        assert m.as_dict()["tenants"]["ads"]["submits"] == 2

    def test_name_collisions_rejected(self):
        m = Metrics()
        m.counter("x")
        with pytest.raises(ValueError):
            m.scope("x")
        with pytest.raises(ValueError):
            m.gauge("x")
        m.scope("s")
        with pytest.raises(ValueError):
            m.counter("s")

    def test_histogram_percentiles(self):
        h = Histogram(bounds=(1.0, 2.0, 4.0))
        for v in (0.5, 1.5, 3.0, 3.5):
            h.record(v)
        assert h.percentile(0.0) == 0.0 or h.percentile(0.0) <= 1.0
        assert h.percentile(1.0) == 3.5       # clamped to observed max
        d = h.as_dict()
        assert d["count"] == 4 and d["sum"] == pytest.approx(8.5)
        assert d["min"] == 0.5 and d["max"] == 3.5

    def test_histogram_as_dict_torn_read_regression(self):
        """A concurrent record() must never yield count/sum out of sync
        (the old implementation re-read attributes after the lock)."""
        h = Histogram(bounds=(1.0,))
        stop = threading.Event()

        def writer():
            while not stop.is_set():
                h.record(1.0)

        t = threading.Thread(target=writer)
        t.start()
        try:
            for _ in range(2000):
                d = h.as_dict()
                assert d["sum"] == float(d["count"])
                assert d["mean"] in (0.0, 1.0)
        finally:
            stop.set()
            t.join()

    def test_concurrent_recording_stress(self):
        m = Metrics()
        c = m.counter("c")
        h = m.histogram("h", bounds=(0.5,))
        g = m.gauge("g")

        def work():
            for i in range(1000):
                c.inc()
                h.record(1.0)
                g.set(i)

        threads = [threading.Thread(target=work) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert c.value == 8000
        snap_counts, count, total, _, _ = h.snapshot()
        assert count == 8000 and sum(snap_counts) == 8000
        assert total == pytest.approx(8000.0)

    def test_serving_metrics_shim_reexports(self):
        # back-compat: the old import path must expose the same classes
        from repro.serving import metrics as old
        assert old.Counter is Counter
        assert old.Gauge is Gauge
        assert old.Histogram is Histogram
        assert old.Metrics is Metrics


# ---------------------------------------------------------------------------
# ResultCache thread safety
# ---------------------------------------------------------------------------
class TestResultCache:
    def test_capacity_zero_counts_misses(self):
        cache = ResultCache(0)
        cache.put(VALUE, 0, 1, 2, 3.0)
        assert cache.get(VALUE, 0, 1, 2) is None
        assert cache.stats()["misses"] == 1
        assert cache.hit_rate() == 0.0

    def test_concurrent_counters_consistent(self):
        cache = ResultCache(64)
        per_thread = 500

        def work(seed):
            rng = np.random.default_rng(seed)
            for _ in range(per_thread):
                k = int(rng.integers(0, 32))
                if cache.get(VALUE, 0, k, k + 1) is None:
                    cache.put(VALUE, 0, k, k + 1, float(k))

        threads = [threading.Thread(target=work, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        s = cache.stats()
        assert s["hits"] + s["misses"] == 8 * per_thread
        assert cache.hit_rate() == pytest.approx(
            s["hits"] / (8 * per_thread))


# ---------------------------------------------------------------------------
# Launch registry
# ---------------------------------------------------------------------------
class TestLaunchRegistry:
    def test_operand_bytes_helper(self):
        a = np.zeros((4, 8), np.float32)
        b = np.zeros(3, np.int32)
        assert operand_bytes(a, None, b) == 4 * 8 * 4 + 3 * 4

    def test_count_launches_contract_unchanged(self):
        # unique geometry: trace-time records fire on first trace only
        rng = np.random.default_rng(0)
        x = rng.random(2897).astype(np.float32)
        engine = RMQ.build(x, c=8, t=8, backend="fused").engine(
            cache_size=0)
        ls = np.array([1, 10, 100], np.int32)
        rs = np.array([5, 200, 2000], np.int32)
        with count_launches() as counts:
            engine.query(ls, rs)
        assert counts == {"rmq_fused": 1}

    def test_registry_attribution_build_and_query(self):
        rng = np.random.default_rng(1)
        x = rng.random(3331).astype(np.float32)
        ls = np.array([0, 7, 31], np.int32)
        rs = np.array([6, 300, 3000], np.int32)
        with launch_registry() as reg:
            engine = RMQ.build(
                x, c=8, t=8, with_positions=True, backend="fused"
            ).engine(cache_size=0)
            engine.query(ls, rs)
        assert reg.counts == {"hierarchy_fused": 1, "rmq_fused": 1}
        by_name = {r.name: r for r in reg.records}
        build = by_name["hierarchy_fused"].meta
        assert build["lowering"] == "pallas"
        assert build["levels"] >= 2
        assert build["operand_bytes"] > 3331 * 4
        query = by_name["rmq_fused"].meta
        # the engine pads batches to pow2 bucket lanes before dispatch,
        # so the recorded count is the bucket shape, not the raw batch
        assert query["queries"] >= 3
        assert query["operand_bytes"] > 0
        ob = reg.operand_bytes()
        assert set(ob) == {"hierarchy_fused", "rmq_fused"}
        dump = reg.as_dict()
        assert dump["counts"] == reg.counts
        assert len(dump["launches"]) == 2
        assert "timings_s" not in dump      # timing was off

    def test_timed_dispatch_records_only_when_enabled(self):
        import jax.numpy as jnp

        calls = []

        def fn(a, b):
            calls.append(1)
            return jnp.add(a, b)

        # no registry: pure passthrough
        out = timed_dispatch("k", fn, 1, 2)
        assert int(out) == 3
        # registry without timing: still passthrough
        with launch_registry() as reg:
            timed_dispatch("k", fn, 1, 2)
        assert reg.timings == {}
        # timing on: wall-clock recorded under the dispatch label
        with launch_registry(timing=True) as reg:
            timed_dispatch("k", fn, 1, 2)
            timed_dispatch("k", fn, 3, 4)
        assert len(reg.timings["k"]) == 2
        assert all(t >= 0.0 for t in reg.timings["k"])
        assert len(calls) == 4
        assert "timings_s" in reg.as_dict()


# ---------------------------------------------------------------------------
# End-to-end wiring
# ---------------------------------------------------------------------------
class TestEndToEnd:
    def test_service_engine_metrics_export(self):
        m = Metrics()
        svc = QueryService(auto_flush=False, metrics=m)
        x = np.random.default_rng(2).random(512).astype(np.float32)
        svc.register("idx", RMQ.build(x, c=8, t=8, backend="jax"),
                     cache_size=16)
        tk = svc.submit("idx", np.array([1, 5]), np.array([3, 9]), VALUE)
        svc.flush(names=("idx",))
        np.asarray(svc.take(tk))
        prom = m.to_prometheus()
        assert 'repro_engines_cache_hit_rate{index="idx"}' in prom
        assert 'repro_engines_span_class_short{index="idx"}' in prom
        assert "repro_engines_bucket_padding_waste_bucket" in prom
        assert "repro_flushes" in prom
        d = m.as_dict()
        assert d["engines"]["idx"]["queries"] >= 2
        assert d["engines"]["idx"]["span_class_short"] >= 2

    def test_tier_flush_produces_full_span_tree(self):
        clock = FakeClock()
        tracer = Tracer(clock=clock)
        tier = ServingTier(clock=clock)
        x = np.random.default_rng(3).random(256).astype(np.float32)
        tier.register_tenant(
            "t", RMQ.build(x, c=8, t=8, with_positions=True,
                           backend="fused"),
            slo_ms=5.0, cache_size=0,
        )
        with use_tracer(tracer):
            for op in (VALUE, INDEX):
                tier.submit("t", np.array([1, 9], np.int32),
                            np.array([6, 200], np.int32), op)
                clock.advance(0.001)
            tier.drain("t")
        spans = tracer.spans()
        by_id = {s.span_id: s for s in spans}
        names = {s.name for s in spans}
        assert {"submit", "admission", "queue", "flush", "snapshot_swap",
                "service_flush", "plan", "execute", "scatter"} <= names

        flush = next(s for s in spans if s.name == "flush")
        assert flush.args["requests"] == 2
        # admission nests under submit on the caller thread
        admission = next(s for s in spans if s.name == "admission")
        assert by_id[admission.parent_id].name == "submit"
        assert admission.args["admitted"] is True
        # retroactive queue spans hang off the flush and carry the real
        # submit->drain wait on the shared clock
        queues = [s for s in spans if s.name == "queue"]
        assert len(queues) == 2
        for q in queues:
            assert q.parent_id == flush.span_id
            assert q.end - q.start > 0
        # engine spans reach the flush through the parent chain
        scatter = next(s for s in spans if s.name == "scatter")
        chain = []
        cur = scatter
        while cur.parent_id is not None:
            cur = by_id[cur.parent_id]
            chain.append(cur.name)
        assert chain == ["service_flush", "flush"]
        # the whole thing exports as a valid Chrome trace
        doc = tracer.to_chrome_trace()
        assert len(doc["traceEvents"]) == len(spans)
