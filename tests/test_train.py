"""Training substrate tests: optimizer, microbatching, compression,
checkpointing, chunked loss, restart safety."""

import os
import shutil

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs import TrainConfig, get_smoke_config
from repro.data.pipeline import SyntheticTokenDataset
from repro.distributed.compression import (
    compress_grads_with_ef,
    init_error_feedback,
)
from repro.models import forward, init_params
from repro.train import build_train_step, init_train_state
from repro.train.loss import chunked_next_token_loss, next_token_loss
from repro.train.optimizer import adamw_init, adamw_update, global_norm


def _cfg():
    return get_smoke_config("llama3.2-3b")


def _batch(cfg, b=4, s=32, seed=1):
    return {
        "tokens": jax.random.randint(
            jax.random.PRNGKey(seed), (b, s), 0, cfg.vocab_size
        )
    }


class TestOptimizer:
    def test_adamw_moves_toward_minimum(self):
        tc = TrainConfig(learning_rate=0.1, warmup_steps=1, total_steps=100,
                         weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw_init(params)
        for _ in range(60):
            grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
            params, state, _ = adamw_update(grads, state, params, tc)
        assert float(jnp.abs(params["w"]).max()) < 1.0

    def test_grad_clip(self):
        tc = TrainConfig(grad_clip=1.0, warmup_steps=1)
        params = {"w": jnp.zeros(4)}
        state = adamw_init(params)
        _, _, m = adamw_update({"w": jnp.full(4, 100.0)}, state, params, tc)
        assert float(m["grad_norm"]) == pytest.approx(200.0)

    def test_bf16_state_halves_bytes(self):
        params = {"w": jnp.zeros((128, 128))}
        s32 = adamw_init(params, "float32")
        s16 = adamw_init(params, "bfloat16")
        assert s16.m["w"].dtype == jnp.bfloat16
        assert s16.m["w"].nbytes * 2 == s32.m["w"].nbytes


class TestTrainStep:
    def test_loss_decreases_20_steps(self):
        cfg = _cfg()
        tc = TrainConfig(total_steps=30, warmup_steps=3)
        state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
        step = jax.jit(build_train_step(cfg, tc))
        batch = _batch(cfg)
        losses = []
        for _ in range(20):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.5
        assert all(np.isfinite(losses))

    def test_microbatched_equals_full_batch_grads(self):
        """Grad accumulation must match the single-batch step (within the
        bf16 accumulator's tolerance)."""
        cfg = _cfg()
        batch = _batch(cfg, b=4)
        t1 = TrainConfig(microbatches=1, grad_allreduce_dtype="float32",
                         warmup_steps=1)
        t4 = TrainConfig(microbatches=4, grad_allreduce_dtype="float32",
                         warmup_steps=1)
        s1 = init_train_state(cfg, t1, jax.random.PRNGKey(0))
        s4 = init_train_state(cfg, t4, jax.random.PRNGKey(0))
        s1, m1 = jax.jit(build_train_step(cfg, t1))(s1, batch)
        s4, m4 = jax.jit(build_train_step(cfg, t4))(s4, batch)
        assert float(m1["loss"]) == pytest.approx(float(m4["loss"]),
                                                  rel=1e-5)
        w1 = jax.tree.leaves(s1.params)[0]
        w4 = jax.tree.leaves(s4.params)[0]
        np.testing.assert_allclose(np.asarray(w1), np.asarray(w4),
                                   atol=5e-5)

    def test_chunked_loss_training_path(self):
        cfg = _cfg()
        tc = TrainConfig(loss_chunk=8, warmup_steps=1)
        state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
        step = jax.jit(build_train_step(cfg, tc))
        state, m = step(state, _batch(cfg))
        assert np.isfinite(float(m["loss"]))

    def test_chunked_loss_equals_plain(self):
        cfg = _cfg()
        params = init_params(cfg, jax.random.PRNGKey(0))
        tokens = _batch(cfg, s=37)["tokens"]
        logits, _ = forward(cfg, params, tokens)
        hidden, _ = forward(cfg, params, tokens, return_hidden=True)
        l1 = float(next_token_loss(logits, tokens))
        l2 = float(chunked_next_token_loss(cfg, params, hidden, tokens,
                                           chunk=8))
        assert l1 == pytest.approx(l2, abs=2e-3)


class TestCompression:
    def test_int8_error_feedback_preserves_convergence(self):
        """EF-compressed quadratic descent reaches the optimum."""
        tc = TrainConfig(learning_rate=0.05, warmup_steps=1,
                         total_steps=200, weight_decay=0.0)
        params = {"w": jnp.array([4.0, -2.0, 1.5])}
        state = adamw_init(params)
        ef = init_error_feedback(params)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}
            grads, ef = compress_grads_with_ef(grads, ef)
            params, state, _ = adamw_update(grads, state, params, tc)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_quantize_roundtrip_error_bounded(self):
        from repro.distributed.compression import (
            dequantize_int8,
            quantize_int8,
        )

        g = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                        jnp.float32)
        q, s = quantize_int8(g)
        err = jnp.abs(dequantize_int8(q, s) - g).max()
        assert float(err) <= float(s) + 1e-6  # half-step quantization error


class TestCheckpoint:
    def test_roundtrip_and_bitwise_resume(self, tmp_path):
        cfg = _cfg()
        tc = TrainConfig(warmup_steps=1)
        state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
        step = jax.jit(build_train_step(cfg, tc))
        batch = _batch(cfg)

        # run 3 steps, checkpoint, run 2 more
        for _ in range(3):
            state, _ = step(state, batch)
        save_checkpoint(str(tmp_path), 3, state)
        cont = state
        for _ in range(2):
            cont, m_direct = step(cont, batch)

        # restore and replay the same 2 steps -> bitwise identical
        template = jax.eval_shape(
            lambda: init_train_state(cfg, tc, jax.random.PRNGKey(0))
        )
        restored = restore_checkpoint(str(tmp_path), 3, template)
        for _ in range(2):
            restored, m_replay = step(restored, batch)
        for a, b in zip(jax.tree.leaves(cont.params),
                        jax.tree.leaves(restored.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert float(m_direct["loss"]) == float(m_replay["loss"])

    def test_async_manager_publish_and_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_mode=True)
        tree = {"a": jnp.arange(10), "b": {"c": jnp.ones((4, 4))}}
        for s in (1, 2, 3):
            mgr.save(s, tree)
        mgr.wait()
        mgr.close()
        assert latest_step(str(tmp_path)) == 3
        kept = sorted(os.listdir(tmp_path))
        assert "step_00000001" not in kept  # GC'd
        restored = restore_checkpoint(str(tmp_path), 3, tree)
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))

    def test_crash_safe_no_partial_dirs(self, tmp_path):
        tree = {"a": jnp.arange(4)}
        save_checkpoint(str(tmp_path), 7, tree)
        # a .tmp dir (simulated crash) must be ignored
        os.makedirs(os.path.join(tmp_path, "step_00000009.tmp"))
        assert latest_step(str(tmp_path)) == 7


class TestTrainTeardown:
    def test_ckpt_closed_when_wait_raises_on_normal_exit(
        self, tmp_path, monkeypatch
    ):
        """A writer error surfaced by wait() on a *normal* exit must both
        propagate AND still shut the async checkpointer down — otherwise
        its worker thread outlives the (restarted) loop."""
        import argparse

        import repro.checkpoint as ck
        from repro.launch.train import train_loop

        calls = {}
        real_manager = ck.CheckpointManager

        class FailingWaitManager(real_manager):
            def wait(self):
                calls["waited"] = True
                raise RuntimeError("buffered writer error")

            def close(self):
                calls["closed"] = True
                super().close()

        monkeypatch.setattr(ck, "CheckpointManager", FailingWaitManager)
        args = argparse.Namespace(
            arch="qwen1.5-0.5b", smoke=True, steps=2, seq_len=16,
            global_batch=2, microbatches=1, remat="minimal",
            model_parallel=1, checkpoint_every=100,
            checkpoint_dir=str(tmp_path), log_every=100,
            inject_failure_at=None,
        )
        with pytest.raises(RuntimeError, match="buffered writer error"):
            train_loop(args)
        assert calls.get("waited") and calls.get("closed")


class TestDataPipeline:
    def test_deterministic_and_restart_safe(self):
        d = SyntheticTokenDataset(vocab_size=100, seq_len=16, global_batch=8)
        a = d.batch_at(5)["tokens"]
        b = d.batch_at(5)["tokens"]
        np.testing.assert_array_equal(a, b)
        assert not np.array_equal(a, d.batch_at(6)["tokens"])

    def test_shards_partition_global_batch(self):
        """Elastic contract: shard batches are disjoint slices of the same
        global stream regardless of shard count."""
        full = SyntheticTokenDataset(vocab_size=1000, seq_len=8,
                                     global_batch=8)
        sh0 = full.reshard(2, 0)
        sh1 = full.reshard(2, 1)
        b0 = sh0.batch_at(3)["tokens"]
        b1 = sh1.batch_at(3)["tokens"]
        assert b0.shape == (4, 8) and b1.shape == (4, 8)
        # different shards draw different data
        assert not np.array_equal(b0, b1)
        # same shard is stable
        np.testing.assert_array_equal(b0, full.reshard(2, 0).batch_at(3)[
            "tokens"])
