"""Adaptive batched query engine (repro.qe): parity, cache, service.

The engine's contract is *bit-identical* results — values and
leftmost-tie positions — to the monolithic ``rmq_value_batch`` /
``rmq_index_batch`` oracles, across all span classes, before and after
streaming mutations.  Must-run coverage is written as numpy RNG loops;
hypothesis adds randomized depth when installed (tier-1 environments
without it skip those only).
"""

import numpy as np
import pytest
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core.api import RMQ
from repro.core.query import rmq_index_batch, rmq_value_batch
from repro.qe import LONG, MID, SHORT, QueryEngine, QueryPlanner, QueryService
from repro.qe.cache import ResultCache


def _mixed_queries(rng, n, c, m):
    """Bounds spread across all three span classes, with ties upstream."""
    spans = np.concatenate([
        rng.integers(1, 2 * c + 1, m // 3 + 1),          # short-ish
        rng.integers(2 * c + 1, max(n // 4, 2 * c + 2), m // 3 + 1),
        rng.integers(max(n // 2, 2), n + 1, m // 3 + 1),  # long
    ])[:m]
    rng.shuffle(spans)
    ls = (rng.random(m) * np.maximum(n - spans + 1, 1)).astype(np.int64)
    rs = np.minimum(ls + spans - 1, n - 1)
    return ls.astype(np.int32), rs.astype(np.int32)


def _build(n, c, t, seed=0, ties=True, **kw):
    rng = np.random.default_rng(seed)
    x = rng.random(n).astype(np.float32)
    if ties:
        x[rng.integers(0, n, max(n // 8, 1))] = 0.5
    rmq = RMQ.build(x, c=c, t=t, with_positions=True, backend="jax", **kw)
    return rng, x, rmq


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------
class TestPlanner:
    def test_classification(self):
        p = QueryPlanner(c=128, num_levels=3)
        ls = np.array([0, 100, 127, 0, 0], np.int32)
        rs = np.array([255, 300, 128, 50_000, 2**20], np.int32)
        labels = p.classify(ls, rs)
        # (0,255): chunks 0..1; (100,300): chunks 0..2 -> mid-or-long;
        # (127,128): crosses one boundary
        assert labels[0] == SHORT and labels[2] == SHORT
        assert labels[1] == MID
        assert labels[4] == LONG
        assert p.effective_long_cutoff() == 2 * 128 * 128

    def test_long_disabled_for_single_level(self):
        p = QueryPlanner(c=128, num_levels=1)
        labels = p.classify(np.array([0]), np.array([2**20]))
        assert labels[0] == MID

    def test_bucket_shapes_bounded_pow2(self):
        p = QueryPlanner(c=8, num_levels=2, min_bucket=16, max_bucket=64)
        rng = np.random.default_rng(0)
        ls = rng.integers(0, 1000, 333).astype(np.int32)
        rs = np.minimum(ls + rng.integers(1, 500, 333), 999).astype(np.int32)
        buckets = p.plan(ls, rs)
        covered = np.concatenate([b.idxs for b in buckets])
        assert sorted(covered.tolist()) == list(range(333))
        for b in buckets:
            assert b.shape in (16, 32, 64)
            assert b.count <= b.shape
            # padded slots hold the (0, 0) sentinel
            assert (b.ls[b.count:] == 0).all() and (b.rs[b.count:] == 0).all()

    def test_long_cutoff_override_boundary(self):
        # the cutoff is inclusive: span == cutoff routes LONG, one less
        # routes MID (chunk-misaligned so neither is SHORT)
        cutoff = 1000
        p = QueryPlanner(c=128, num_levels=3, long_cutoff=cutoff)
        assert p.effective_long_cutoff() == cutoff
        ls = np.array([1, 1], np.int32)
        rs = np.array([1 + cutoff - 1, 1 + cutoff - 2], np.int32)
        labels = p.classify(ls, rs)
        assert labels[0] == LONG    # span == cutoff exactly
        assert labels[1] == MID     # span == cutoff - 1

    def test_long_cutoff_larger_than_n(self):
        # a cutoff no span can reach: the long route exists but never
        # fires — everything walks (or short-scans)
        n = 10_000
        p = QueryPlanner(c=128, num_levels=3, long_cutoff=n + 1)
        ls = np.zeros(3, np.int32)
        rs = np.array([n - 1, n // 2, 100], np.int32)
        labels = p.classify(ls, rs)
        assert LONG not in labels
        assert labels[0] == MID and labels[2] == SHORT

    def test_analytic_default_boundary(self):
        # with no override the cutoff is the analytic 2c * c^(L-2)
        p = QueryPlanner(c=8, num_levels=3)
        cutoff = 2 * 8 * 8
        assert p.effective_long_cutoff() == cutoff
        ls = np.array([1, 1], np.int32)
        rs = np.array([cutoff, cutoff - 1], np.int32)
        assert list(p.classify(ls, rs)) == [LONG, MID]

    def test_scan_chunks_one(self):
        # scan_chunks=1: only strictly chunk-contained spans are SHORT
        p = QueryPlanner(c=128, num_levels=2, scan_chunks=1)
        ls = np.array([0, 100], np.int32)
        rs = np.array([127, 200], np.int32)   # contained / crossing
        assert list(p.classify(ls, rs)) == [SHORT, MID]

    def test_cache_fed_cutoff_round_trip_through_engine(self):
        # a tuned long_cutoff from a TuningCache must land in the
        # engine's planner — and results stay bit-identical
        from repro.tune import TunedConfig, TuningCache

        n, c, t = 50_000, 128, 4
        rng, x, rmq = _build(n, c, t, seed=3)
        cutoff = 2_000
        cache = TuningCache()
        cache.put("cpu", n, "mixed", TunedConfig(
            c=c, t=t, backend="jax", planner="routed",
            long_cutoff=cutoff))
        engine = QueryEngine(rmq, cache_size=0, tuning=cache,
                             span_mix="mixed")
        assert engine.planner.effective_long_cutoff() == cutoff
        assert engine.tuned["long_cutoff"] == cutoff
        assert engine.tuned["source"] == "cache"
        ls, rs = _mixed_queries(rng, n, c, 400)
        np.testing.assert_array_equal(
            np.asarray(engine.query(ls, rs)),
            np.asarray(rmq_value_batch(
                rmq.hierarchy, jnp.asarray(ls), jnp.asarray(rs))),
        )
        # spans past the tuned cutoff actually took the long route
        assert engine.stats()["class_counts"][LONG] > 0
        # an explicit ctor override outranks the cache
        engine2 = QueryEngine(rmq, cache_size=0, tuning=cache,
                              span_mix="mixed", long_cutoff=5_000)
        assert engine2.planner.effective_long_cutoff() == 5_000


# ---------------------------------------------------------------------------
# engine parity (the acceptance contract)
# ---------------------------------------------------------------------------
class TestEngineParity:
    @pytest.mark.parametrize("n,c,t", [
        (100_000, 128, 4),   # 3 levels: all classes populated
        (50_000, 128, 64),   # 2 levels: mid structurally empty
        (4096, 8, 4),        # deep hierarchy, tiny chunks
        (700, 16, 2),
        (300, 128, 64),      # single level: everything mid/short
    ])
    def test_bit_identical_mixed_spans(self, n, c, t):
        rng, x, rmq = _build(n, c, t, seed=n)
        engine = rmq.engine()
        ls, rs = _mixed_queries(rng, n, c, 600)
        # inject duplicates to exercise dedup scatter-back
        ls[50:80], rs[50:80] = ls[0], rs[0]
        lsj, rsj = jnp.asarray(ls), jnp.asarray(rs)
        np.testing.assert_array_equal(
            np.asarray(engine.query(ls, rs)),
            np.asarray(rmq_value_batch(rmq.hierarchy, lsj, rsj)),
        )
        np.testing.assert_array_equal(
            np.asarray(engine.query_index(ls, rs)),
            np.asarray(rmq_index_batch(rmq.hierarchy, lsj, rsj)),
        )

    def test_all_classes_exercised(self):
        rng, x, rmq = _build(100_000, 128, 4, seed=1)
        engine = rmq.engine(cache_size=0)
        ls, rs = _mixed_queries(rng, 100_000, 128, 900)
        engine.query(ls, rs)
        counts = engine.stats()["class_counts"]
        assert counts[SHORT] > 0 and counts[MID] > 0 and counts[LONG] > 0

    def test_pallas_backend_interpret(self):
        """Routing through the Pallas kernels (interpret mode) matches."""
        rng, x, rmq = _build(20_000, 128, 4, seed=2)
        engine = QueryEngine(rmq, backend="pallas", interpret=True,
                             cache_size=0, max_bucket=256)
        ls, rs = _mixed_queries(rng, 20_000, 128, 120)
        lsj, rsj = jnp.asarray(ls), jnp.asarray(rs)
        np.testing.assert_array_equal(
            np.asarray(engine.query(ls, rs)),
            np.asarray(rmq_value_batch(rmq.hierarchy, lsj, rsj)),
        )
        np.testing.assert_array_equal(
            np.asarray(engine.query_index(ls, rs)),
            np.asarray(rmq_index_batch(rmq.hierarchy, lsj, rsj)),
        )

    def test_value_only_index_raises(self):
        x = np.random.default_rng(0).random(5000).astype(np.float32)
        rmq = RMQ.build(x, c=16, t=4, backend="jax")
        with pytest.raises(ValueError, match="without positions"):
            rmq.engine().query_index(np.array([0]), np.array([10]))

    def test_empty_batch(self):
        _, _, rmq = _build(1000, 16, 4)
        out = rmq.engine().query(np.zeros((0,), np.int32),
                                 np.zeros((0,), np.int32))
        assert out.shape == (0,)

    def test_int32_capacity_guard(self):
        """Capacities past int32 index space are refused loudly (the
        query stack — planner packing, short kernel, core walk — does
        int32 index math; silent wraps would break parity)."""
        import dataclasses as dc

        _, _, rmq = _build(1000, 16, 4)
        huge_plan = dc.replace(rmq.plan, capacity=2**31)
        huge = dc.replace(
            rmq, hierarchy=dc.replace(rmq.hierarchy, plan=huge_plan)
        )
        with pytest.raises(ValueError, match="int32 query index space"):
            QueryEngine(huge)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(min_value=2, max_value=3000),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_property_parity(self, n, seed):
        rng = np.random.default_rng(seed)
        x = rng.integers(-4, 4, n).astype(np.float32)  # heavy ties
        rmq = RMQ.build(x, c=8, t=2, with_positions=True, backend="jax")
        engine = rmq.engine()
        m = 64
        ls = rng.integers(0, n, m)
        rs = np.minimum(ls + rng.integers(0, n, m), n - 1)
        ls = np.minimum(ls, rs).astype(np.int32)
        rs = np.maximum(ls, rs).astype(np.int32)
        lsj, rsj = jnp.asarray(ls), jnp.asarray(rs)
        np.testing.assert_array_equal(
            np.asarray(engine.query(ls, rs)),
            np.asarray(rmq_value_batch(rmq.hierarchy, lsj, rsj)),
        )
        np.testing.assert_array_equal(
            np.asarray(engine.query_index(ls, rs)),
            np.asarray(rmq_index_batch(rmq.hierarchy, lsj, rsj)),
        )


# ---------------------------------------------------------------------------
# streaming mutations + cache invalidation
# ---------------------------------------------------------------------------
class TestMutationInvalidation:
    def test_update_invalidates_cached_result(self):
        """The stale-cache regression: same (l, r) before/after update."""
        rng, x, rmq = _build(50_000, 128, 4, seed=3, ties=False)
        engine = rmq.engine()
        l, r = 1000, 30_000
        before = float(engine.query(np.array([l]), np.array([r]))[0])
        assert before == x[l : r + 1].min()
        # repeat -> served from cache
        h0 = engine.cache.hits
        engine.query(np.array([l]), np.array([r]))
        assert engine.cache.hits == h0 + 1
        # mutate: plant a new global minimum inside the range
        pos = 17_000
        rmq2 = rmq.update(np.array([pos]), np.array([-3.0], np.float32))
        assert rmq2.generation == rmq.generation + 1
        engine.attach(rmq2)
        after = engine.query(np.array([l]), np.array([r]))
        assert float(after[0]) == -3.0
        assert int(engine.query_index(np.array([l]), np.array([r]))[0]) \
            == pos

    def test_append_invalidates_and_extends(self):
        rng, x, rmq = _build(5000, 64, 4, seed=4, capacity=8192)
        engine = rmq.engine()
        v0 = float(engine.query(np.array([0]), np.array([4999]))[0])
        rmq2 = rmq.append(np.array([-7.0], np.float32))
        engine.attach(rmq2)
        # old range: unchanged result, new range: sees the appended min
        assert float(engine.query(np.array([0]), np.array([4999]))[0]) == v0
        assert float(engine.query(np.array([0]), np.array([5000]))[0]) \
            == -7.0

    def test_parity_after_interleaved_mutations(self):
        """Bit-identical to the oracle after update+append interleavings."""
        rng, x, rmq = _build(20_000, 128, 4, seed=5, capacity=30_000)
        engine = rmq.engine()
        for step in range(4):
            idxs = rng.integers(0, rmq.n, 50)
            vals = rng.random(50).astype(np.float32) - 0.5
            rmq = rmq.update(idxs, vals)
            rmq = rmq.append(rng.random(100).astype(np.float32))
            engine.attach(rmq)
            ls, rs = _mixed_queries(rng, rmq.n, 128, 300)
            lsj, rsj = jnp.asarray(ls), jnp.asarray(rs)
            np.testing.assert_array_equal(
                np.asarray(engine.query(ls, rs)),
                np.asarray(rmq_value_batch(rmq.hierarchy, lsj, rsj)),
            )
            np.testing.assert_array_equal(
                np.asarray(engine.query_index(ls, rs)),
                np.asarray(rmq_index_batch(rmq.hierarchy, lsj, rsj)),
            )

    def test_attach_non_successor_clears_cache(self):
        _, _, rmq_a = _build(3000, 16, 4, seed=6)
        _, _, rmq_b = _build(3000, 16, 4, seed=7)
        engine = rmq_a.engine()
        engine.query(np.array([0]), np.array([100]))
        assert len(engine.cache) > 0
        engine.attach(rmq_b)   # same generation (0): not a successor
        assert len(engine.cache) == 0


# ---------------------------------------------------------------------------
# cache + dedup accounting
# ---------------------------------------------------------------------------
class TestCache:
    def test_lru_eviction_order(self):
        cache = ResultCache(capacity=2)
        cache.put("value", 0, 0, 1, 1.0)
        cache.put("value", 0, 0, 2, 2.0)
        assert cache.get("value", 0, 0, 1) == 1.0   # refresh (0,1)
        cache.put("value", 0, 0, 3, 3.0)            # evicts (0,2)
        assert cache.get("value", 0, 0, 2) is None
        assert cache.get("value", 0, 0, 1) == 1.0
        assert cache.stats()["evictions"] == 1

    def test_capacity_zero_disables(self):
        cache = ResultCache(capacity=0)
        cache.put("value", 0, 0, 1, 1.0)
        assert cache.get("value", 0, 0, 1) is None
        assert len(cache) == 0

    def test_engine_dedup_and_hits(self):
        rng, x, rmq = _build(10_000, 64, 4, seed=8)
        engine = rmq.engine()
        ls = np.full((64,), 10, np.int32)
        rs = np.full((64,), 500, np.int32)
        out = np.asarray(engine.query(ls, rs))
        assert (out == out[0]).all()
        s = engine.stats()
        assert s["dedup_saved"] == 63           # 64 copies, 1 executed
        out2 = np.asarray(engine.query(ls, rs))
        np.testing.assert_array_equal(out, out2)
        assert engine.stats()["cache"]["hits"] >= 1
        # value and index results are cached under distinct ops
        engine.query_index(ls[:1], rs[:1])
        assert np.asarray(engine.query(ls[:1], rs[:1]))[0] == out[0]


# ---------------------------------------------------------------------------
# service: registry + micro-batching
# ---------------------------------------------------------------------------
class TestService:
    def test_coalesce_and_scatter_back(self):
        rng, xa, rmq_a = _build(20_000, 128, 4, seed=9)
        _, xb, rmq_b = _build(3000, 16, 4, seed=10)
        svc = QueryService()
        svc.register("a", rmq_a)
        svc.register("b", rmq_b)
        la, ra = _mixed_queries(rng, 20_000, 128, 40)
        t1 = svc.submit("a", la[:25], ra[:25])
        t2 = svc.submit("a", la[25:], ra[25:])
        t3 = svc.submit("b", np.array([5]), np.array([2500]), op="index")
        res = svc.flush()
        want = np.asarray(rmq_value_batch(
            rmq_a.hierarchy, jnp.asarray(la), jnp.asarray(ra)
        ))
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(res[t1]), np.asarray(res[t2])]),
            want,
        )
        assert int(res[t3][0]) == 5 + int(np.argmin(xb[5:2501]))
        s = svc.stats()
        assert s["coalesced_batches"] == 1      # the two "a" requests
        assert s["requests"] == 3 and s["flushes"] == 1
        # one engine batch served both "a" requests
        assert s["engines"]["a"]["batches"] == 1

    def test_auto_flush_on_max_pending(self):
        _, x, rmq = _build(5000, 64, 4, seed=11)
        svc = QueryService(max_pending=8)
        svc.register("a", rmq)
        tickets = [
            svc.submit("a", np.array([i]), np.array([i + 100]))
            for i in range(8)
        ]
        assert svc.stats()["pending_queries"] == 0   # auto-flushed
        got = np.array([float(svc.take(t)[0]) for t in tickets])
        want = np.array([x[i : i + 101].min() for i in range(8)])
        np.testing.assert_array_equal(got, want)

    def test_unknown_name_and_pending_unregister(self):
        _, _, rmq = _build(1000, 16, 4, seed=12)
        svc = QueryService()
        svc.register("a", rmq)
        with pytest.raises(KeyError, match="no index registered"):
            svc.submit("zzz", np.array([0]), np.array([1]))
        svc.submit("a", np.array([0]), np.array([1]))
        with pytest.raises(ValueError, match="pending"):
            svc.unregister("a")
        with pytest.raises(ValueError, match="pending"):
            svc.register("a", rmq)   # replacement would orphan tickets
        svc.flush()
        svc.unregister("a")

    def test_submit_rejects_index_op_on_value_only(self):
        """Bad requests fail at admission, not detached at flush time."""
        x = np.random.default_rng(15).random(2000).astype(np.float32)
        rmq = RMQ.build(x, c=16, t=4, backend="jax")   # value-only
        svc = QueryService()
        svc.register("a", rmq)
        with pytest.raises(ValueError, match="without positions"):
            svc.submit("a", np.array([0]), np.array([10]), op="index")

    def test_flush_isolates_failing_group(self):
        """One group failing must not lose other groups' results."""
        _, xa, rmq_a = _build(3000, 16, 4, seed=16)
        _, _, rmq_b = _build(3000, 16, 4, seed=17)
        x_plain = np.random.default_rng(18).random(3000).astype(np.float32)
        value_only = RMQ.build(x_plain, c=16, t=4, backend="jax")
        svc = QueryService()
        svc.register("a", rmq_a)
        svc.register("b", rmq_b)
        t_a = svc.submit("a", np.array([0]), np.array([2999]))
        t_b = svc.submit("b", np.array([1]), np.array([50]), op="index")
        # admission-time check passed for "b", but the binding races:
        # a value-only successor lands before the flush
        svc.attach("b", value_only, reset_cache=True)
        with pytest.raises(RuntimeError, match="claimable"):
            svc.flush()
        # group "a" executed and its result survived the failure
        assert float(svc.take(t_a)[0]) == xa.min()
        with pytest.raises(KeyError):
            svc.take(t_b)

    def test_sync_query_survives_unrelated_group_failure(self):
        """query()'s own stored result must be returned even when an
        unrelated (index, op) group fails in the same flush."""
        _, xa, rmq_a = _build(3000, 16, 4, seed=19)
        _, _, rmq_b = _build(3000, 16, 4, seed=20)
        x_plain = np.random.default_rng(21).random(3000).astype(np.float32)
        value_only = RMQ.build(x_plain, c=16, t=4, backend="jax")
        svc = QueryService()
        svc.register("a", rmq_a)
        svc.register("b", rmq_b)
        # queue a request that will fail at flush time (value-only
        # successor lands after admission)
        t_b = svc.submit("b", np.array([1]), np.array([50]), op="index")
        svc.attach("b", value_only, reset_cache=True)
        got = float(svc.query("a", np.array([0]), np.array([2999]))[0])
        assert got == xa.min()
        with pytest.raises(KeyError):
            svc.take(t_b)   # the failed group's ticket stays unanswered

    def test_unclaimed_results_bounded(self):
        """Unconsumed flush results age out instead of leaking forever."""
        _, _, rmq = _build(1000, 16, 4, seed=14)
        svc = QueryService(max_unclaimed=3)
        svc.register("a", rmq)
        tickets = []
        for i in range(6):
            tickets.append(svc.submit("a", np.array([i]), np.array([i + 5])))
            svc.flush()
        s = svc.stats()
        assert s["unclaimed_results"] == 3
        assert s["dropped_results"] == 3
        with pytest.raises(KeyError, match="aged out|no result"):
            svc.take(tickets[0])
        svc.take(tickets[-1])   # recent results still claimable

    def test_attach_successor_via_service(self):
        _, x, rmq = _build(5000, 64, 4, seed=13, ties=False)
        svc = QueryService()
        svc.register("a", rmq)
        before = float(svc.query("a", np.array([0]), np.array([4999]))[0])
        assert before == x.min()
        pos = int(np.argmax(x))
        svc.attach("a", rmq.update(np.array([pos]),
                                   np.array([-2.0], np.float32)))
        after = float(svc.query("a", np.array([0]), np.array([4999]))[0])
        assert after == -2.0
