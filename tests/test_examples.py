"""Smoke the runnable examples (reduced sizes; full runs are documented
in README). The distributed example runs in a subprocess (fake devices)."""

import subprocess
import sys

import numpy as np
import jax.numpy as jnp


def test_quickstart_path():
    from repro.core import RMQ

    rng = np.random.default_rng(0)
    x = rng.random(1 << 14, dtype=np.float32)
    rmq = RMQ.build(x, c=128, t=64, with_positions=True, backend="jax")
    ls = rng.integers(0, 1 << 14, 64).astype(np.int32)
    rs = np.minimum(ls + rng.integers(1, 1 << 13, 64), (1 << 14) - 1)
    vals = np.asarray(rmq.query(jnp.asarray(ls), jnp.asarray(rs)))
    for i in range(8):
        assert vals[i] == x[ls[i]:rs[i] + 1].min()


def test_chaining_recovers_chains():
    sys.path.insert(0, "examples")
    try:
        from chaining import (
            chain_scores_naive,
            chain_scores_rmq,
            make_anchors,
        )
    finally:
        sys.path.pop(0)
    x = make_anchors(n=512)
    score, _, nq = chain_scores_rmq(x, block=128)
    naive = chain_scores_naive(x)
    assert nq > 0
    assert score.max() > 5 * 20
    assert score.max() >= 0.6 * naive.max()  # generational relaxation


def test_query_engine_example():
    """Reduced-size pass through examples/query_engine.py's flow."""
    sys.path.insert(0, "examples")
    try:
        from query_engine import mixed_workload
    finally:
        sys.path.pop(0)
    from repro.core import RMQ
    from repro.core.query import rmq_value_batch
    from repro.qe import QueryService

    rng = np.random.default_rng(0)
    n, c = 1 << 14, 64
    x = rng.random(n, dtype=np.float32)
    rmq = RMQ.build(x, c=c, t=64, with_positions=True, backend="jax")
    engine = rmq.engine()
    ls, rs = mixed_workload(rng, n, c, 512)
    got = np.asarray(engine.query(ls, rs))
    want = np.asarray(
        rmq_value_batch(rmq.hierarchy, jnp.asarray(ls), jnp.asarray(rs))
    )
    assert np.array_equal(got, want)
    assert engine.stats()["class_counts"]["short"] > 0

    svc = QueryService()
    svc.register("scores", rmq)
    t1 = svc.submit("scores", ls[:8], rs[:8])
    t2 = svc.submit("scores", ls[8:16], rs[8:16])
    res = svc.flush()
    assert np.array_equal(
        np.concatenate([np.asarray(res[t1]), np.asarray(res[t2])]),
        want[:16],
    )


def test_distributed_example_subprocess():
    res = subprocess.run(
        [sys.executable, "examples/distributed_rmq.py"],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
        cwd="/root/repo",
    )
    assert "spot-checks OK" in res.stdout, res.stdout + res.stderr


def test_serve_example_objects():
    """serve_lm's engine path with tiny sizes (full example in README)."""
    import jax

    sys.path.insert(0, "examples")
    try:
        from serve_lm import small_lm
    finally:
        sys.path.pop(0)
    from repro.configs.base import ServeConfig
    from repro.models.lm import init_params
    from repro.serve.engine import ServeEngine

    cfg = small_lm()
    params = init_params(cfg, jax.random.PRNGKey(0))
    sc = ServeConfig(seq_len=48, batch=2, kv_cache_dtype="float32",
                     eviction_enabled=True, eviction_budget=32,
                     eviction_window=8, rmq_chunk=8, rmq_threshold=4)
    eng = ServeEngine(cfg, params, sc)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                 cfg.vocab_size)
    out = eng.generate(prompts, 24)
    assert out["tokens"].shape == (2, 24)
    assert out["final_pos"] <= 33


def test_serve_example_via_serving_tier():
    """serve_lm's serving-tier mode: eviction scans ride a tier tenant
    and the generation is bit-identical to the private-engine path."""
    import jax

    sys.path.insert(0, "examples")
    try:
        from serve_lm import small_lm
    finally:
        sys.path.pop(0)
    from repro.configs.base import ServeConfig
    from repro.models.lm import init_params
    from repro.serve.engine import ServeEngine
    from repro.serving import ServingTier

    cfg = small_lm()
    params = init_params(cfg, jax.random.PRNGKey(0))
    sc = ServeConfig(seq_len=48, batch=2, kv_cache_dtype="float32",
                     eviction_enabled=True, eviction_budget=32,
                     eviction_window=8, rmq_chunk=8, rmq_threshold=4)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                 cfg.vocab_size)
    tier = ServingTier()
    eng = ServeEngine(cfg, params, sc, serving_tier=tier)
    with tier:
        out = eng.generate(prompts, 24)
    assert out["final_pos"] <= 33
    assert out["evicted"] > 0
    t = tier.stats()["tenants"]["kv-eviction"]
    assert t["flushes"] > 0
    assert t["snapshot_swaps"] > 0
    # differential vs the private-engine path: same victims, same tokens
    ref = ServeEngine(cfg, params, sc).generate(prompts, 24)
    assert ref["final_pos"] == out["final_pos"]
    assert ref["evicted"] == out["evicted"]
    assert (np.asarray(ref["tokens"]) == np.asarray(out["tokens"])).all()


def test_serving_async_example():
    """Reduced-size run of examples/serving_async.py: two tenants with
    different SLOs, background mutator, snapshot-isolation differential
    (the assertions live inside ``run``)."""
    import asyncio

    sys.path.insert(0, "examples")
    try:
        from serving_async import run
    finally:
        sys.path.pop(0)

    out = asyncio.run(run(n=1 << 10, rounds=8))
    assert out["trading_checked"] == 32
    assert out["analytics_requests"] == 8
    assert len(out["generations_seen"]) >= 2  # mutations landed mid-run
    tenants = out["stats"]["tenants"]
    assert tenants["trading"]["flushes"] > 0
    assert tenants["analytics"]["snapshot_swaps"] > 0
    assert tenants["analytics"]["mutations_applied"] > 0
