"""Compat shims: the shard_map keyword must be detected by *support*.

Regression coverage for the mid-band JAX hazard: releases where
``shard_map`` already lives at ``jax.shard_map`` but still only accepts
``check_rep`` (the ``check_vma`` rename landed later).  Probing by
attribute location would pass the wrong keyword on those versions; the
shim must inspect the signature instead.
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat


# -- signature fakes (each spelling the shim must cope with) ---------------
def _modern(f, *, mesh, in_specs, out_specs, check_vma=True):
    return ("check_vma", check_vma)


def _mid_band(f, *, mesh, in_specs, out_specs, check_rep=True):
    # the hazard: modern *location*, legacy *keyword*
    return ("check_rep", check_rep)


def _kwargs_only(f, **kwargs):
    return ("kwargs", kwargs.get("check_vma"))


def _no_knob(f, *, mesh, in_specs, out_specs):
    return ("none", None)


def _call(**kw):
    return compat.shard_map(
        lambda: None, mesh="m", in_specs="i", out_specs="o", **kw
    )


def test_modern_signature_gets_check_vma(monkeypatch):
    monkeypatch.setattr(jax, "shard_map", _modern, raising=False)
    assert _call(check_vma=False) == ("check_vma", False)
    assert _call() == ("check_vma", True)


def test_mid_band_check_rep_only_gets_check_rep(monkeypatch):
    """jax.shard_map exists but only accepts check_rep — the regression."""
    monkeypatch.setattr(jax, "shard_map", _mid_band, raising=False)
    assert _call(check_vma=False) == ("check_rep", False)
    assert _call(check_vma=True) == ("check_rep", True)


def test_uninspectable_kwargs_passthrough(monkeypatch):
    monkeypatch.setattr(jax, "shard_map", _kwargs_only, raising=False)
    assert _call(check_vma=False) == ("kwargs", False)


def test_signature_without_knob_omits_it(monkeypatch):
    monkeypatch.setattr(jax, "shard_map", _no_knob, raising=False)
    assert _call(check_vma=False) == ("none", None)


def test_real_shard_map_roundtrip():
    """The shim drives the actually-installed JAX end to end."""
    mesh = jax.make_mesh((1,), ("x",))
    f = compat.shard_map(
        lambda a: a * 2.0,
        mesh=mesh,
        in_specs=P("x"),
        out_specs=P("x"),
        check_vma=False,
    )
    out = jax.jit(f)(jnp.arange(4.0))
    np.testing.assert_array_equal(np.asarray(out), np.arange(4.0) * 2.0)
