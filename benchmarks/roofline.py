"""Roofline analysis from dry-run artifacts (thin caller).

The analysis machinery lives in :mod:`repro.tune.roofline` (shared with
the autotuner package); this benchmark only resolves the input path,
renders the table, and writes the ``results/`` artifacts.
"""

from __future__ import annotations

import json
import os
import sys

from repro.tune.roofline import analyse_record, load_results, render_table


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else (
        "results/dryrun_single_opt.jsonl"
        if os.path.exists("results/dryrun_single_opt.jsonl")
        else "results/dryrun_single.jsonl"
    )
    chips = 256
    recs = load_results(path)
    if not recs:
        print(f"no dry-run results at {path}; run "
              "`python -m repro.launch.dryrun --all --calibrate --out "
              f"{path}` first")
        return
    rows = [analyse_record(r, chips) for r in recs.values()]
    print(render_table(rows))
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.md", "w") as f:
        f.write(render_table(rows) + "\n")
    with open("results/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
