"""Paper Fig. 12: tuning chunk size c and cutoff t (VL vs CL analogue).

Thin caller over :class:`repro.tune.Autotuner` — the sweep machinery,
timing discipline (warmup + median, shared with every other benchmark
via ``repro.tune.measure.time_fn``), and winner selection live in the
package; this module renders the CSV, checks the paper's relative
claims, and commits the machine-readable artifact.

Reproduces the paper's findings:

* no single configuration is optimal for every n;
* small c (the VL regime, c=8: vector-width-sized chunks) wins at small n;
* hardware-atom-aligned c wins at large n (paper: c=32 ⇒ 128 B GPU cache
  line; TPU: c=128/256 ⇒ (8,128) f32 VMEM tile multiples);
* smaller t is uniformly better (fewer top-level entries to scan).

Beyond the historical jax-only sweep, both the routed ("jax") and the
single-launch ("fused") engines race on every geometry — the cache is
built from the numbers we actually serve.  Configs skipped because
``c * t >= n`` (single-level degenerate plans) are *reported*, not
silently dropped, and full-mode runs write ``BENCH_tuning.json`` at the
repo root (same committed-trajectory discipline as ``BENCH_query.json``).

``REPRO_BENCH_TINY=1`` shrinks sizes for the CI smoke run.
"""

from __future__ import annotations

import os

import jax

from benchmarks.common import atomic_write_json, csv_row, tiny_mode
from repro.tune import Autotuner, TINY_GEOMETRIES

# Committed perf-trajectory artifact: anchored at the repo root (not the
# CWD) and refreshed only by full-mode runs.
BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_tuning.json",
)


def run(sizes=(2**16, 2**20, 2**23), m=2**13, tiny=False):
    """Sweep geometries × backends per size; returns (rows, report)."""
    if tiny:
        tuner = Autotuner(geometries=TINY_GEOMETRIES, m=min(m, 512),
                          repeats=1, crossover_points=3)
    else:
        tuner = Autotuner(m=m, repeats=3)
    _cache, report = tuner.search(sizes)
    rows = []
    for n in report["sizes"]:
        meas = [m_ for m_ in report["measurements"]
                if m_["n"] == n and m_["span_mix"] == "mixed"]
        best = min(m_["ns_per_query"] for m_ in meas)
        for m_ in sorted(meas, key=lambda r: (r["c"], r["t"],
                                              r["backend"])):
            rows.append({
                "n": n, "c": m_["c"], "t": m_["t"],
                "backend": m_["backend"],
                "ns_per_query": m_["ns_per_query"],
                "slowdown": m_["ns_per_query"] / best,
            })
    return rows, report


def main() -> dict:
    tiny = tiny_mode()
    if tiny:
        sizes, m = (2**13,), 512
    else:
        sizes, m = (2**16, 2**20, 2**23), 2**13
    rows, report = run(sizes=sizes, m=m, tiny=tiny)

    print("name,us_per_call,derived")
    best_by_n = {}
    for r in rows:
        print(csv_row(
            f"tuning_n{r['n']}_c{r['c']}_t{r['t']}_{r['backend']}",
            r["ns_per_query"] / 1e3,
            f"slowdown={r['slowdown']:.2f}x",
        ))
        key = r["n"]
        if key not in best_by_n or r["slowdown"] < best_by_n[key][3]:
            best_by_n[key] = (r["c"], r["t"], r["backend"], r["slowdown"])
    for n, (c, t, backend, _) in sorted(best_by_n.items()):
        print(f"tuning_best_n{n},0,c={c}|t={t}|backend={backend}")
    # no silent caps: every config excluded from the sweep is reported
    for s in report["skipped"]:
        print(csv_row(
            f"tuning_skipped_n{s['n']}_c{s['c']}_t{s['t']}", 0,
            "c*t>=n",
        ))
    print(csv_row("tuning_skipped_total", 0,
                  f"count={len(report['skipped'])}"))

    # paper claim: smaller t at least as good for fixed c (check c=128
    # on the routed backend, where the top-level scan length is t-bound)
    if not tiny:
        for n in {r["n"] for r in rows}:
            t8 = [r for r in rows if r["n"] == n and r["c"] == 128
                  and r["t"] == 8 and r["backend"] == "jax"]
            t64 = [r for r in rows if r["n"] == n and r["c"] == 128
                   and r["t"] == 64 and r["backend"] == "jax"]
            if t8 and t64:
                assert (t8[0]["ns_per_query"]
                        <= t64[0]["ns_per_query"] * 1.35), (
                    n, t8[0]["ns_per_query"], t64[0]["ns_per_query"]
                )

    payload = {
        "benchmark": "tuning",
        "tiny": tiny,
        "platform": jax.default_backend(),
        "unit": "ns_per_query",
        "m": report["m"],
        "geometries": report["geometries"],
        "backends": report["backends"],
        "rows": rows,
        "skipped": report["skipped"],
        "winners": report["winners"],
    }
    if not tiny:
        # tiny-mode numbers are meaningless for the trajectory; only
        # full-mode runs refresh the committed artifact
        atomic_write_json(BENCH_JSON, payload)
        print(f"# wrote {BENCH_JSON}")
    return payload


if __name__ == "__main__":
    main()
