"""Paper Fig. 12: tuning chunk size c and cutoff t (VL vs CL analogue).

Sweeps (c, t) over several array sizes and reports per-size slowdown
relative to the best config, reproducing the paper's findings:

* no single configuration is optimal for every n;
* small c (the VL regime, c=8: vector-width-sized chunks) wins at small n;
* hardware-atom-aligned c wins at large n (paper: c=32 ⇒ 128 B GPU cache
  line; TPU: c=128/256 ⇒ (8,128) f32 VMEM tile multiples);
* smaller t is uniformly better (fewer top-level entries to scan).
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import csv_row, make_input_array, make_queries, time_fn
from repro.core.api import RMQ


def run(sizes=(2**16, 2**20, 2**23), m=2**13):
    configs = [
        (8, 8), (8, 64),
        (32, 8), (32, 64),
        (128, 8), (128, 64),
        (256, 8), (256, 64),
        (512, 8),
    ]
    rows = []
    for n in sizes:
        x = jnp.asarray(make_input_array(n))
        ls, rs = make_queries(n, m, "mixed")
        lsj, rsj = jnp.asarray(ls), jnp.asarray(rs)
        times = {}
        for c, t in configs:
            if c * t >= n:
                continue
            rmq = RMQ.build(x, c=c, t=t, backend="jax")
            times[(c, t)] = time_fn(lambda: rmq.query(lsj, rsj), repeats=3)
        best = min(times.values())
        for (c, t), tt in sorted(times.items()):
            rows.append({
                "n": n, "c": c, "t": t,
                "ns_per_query": tt / m * 1e9,
                "slowdown": tt / best,
            })
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    best_by_n = {}
    for r in rows:
        print(csv_row(
            f"tuning_n{r['n']}_c{r['c']}_t{r['t']}",
            r["ns_per_query"] / 1e3,
            f"slowdown={r['slowdown']:.2f}x",
        ))
        key = r["n"]
        if key not in best_by_n or r["slowdown"] < best_by_n[key][2]:
            best_by_n[key] = (r["c"], r["t"], r["slowdown"])
    for n, (c, t, _) in sorted(best_by_n.items()):
        print(f"tuning_best_n{n},0,c={c}|t={t}")
    # paper claim: smaller t at least as good for fixed c (check c=128)
    for n in {r["n"] for r in rows}:
        t8 = [r for r in rows if r["n"] == n and r["c"] == 128
              and r["t"] == 8]
        t64 = [r for r in rows if r["n"] == n and r["c"] == 128
               and r["t"] == 64]
        if t8 and t64:
            assert t8[0]["ns_per_query"] <= t64[0]["ns_per_query"] * 1.35, (
                n, t8[0]["ns_per_query"], t64[0]["ns_per_query"]
            )


if __name__ == "__main__":
    main()
