"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract; the
roofline module additionally writes results/roofline.{md,json} from the
dry-run artifacts when present.
"""

import sys
import traceback

MODULES = [
    ("memory_footprint", "Fig. 15 memory footprint"),
    ("construction", "Fig. 17 construction time"),
    ("update_throughput", "streaming updates vs full rebuild"),
    ("throughput", "Fig. 16 RMQ throughput by range class"),
    ("tuning", "Fig. 12 (c, t) tuning"),
    ("query_assignment", "Fig. 14 multi-load vs WLQ"),
    ("coalesced_access", "Fig. 4 access coalescing microbench"),
    ("overlap_ablation", "Fig. 13 hybrid top-level ablation"),
    ("roofline", "LM framework roofline (from dry-run artifacts)"),
]


def main() -> None:
    failures = []
    for mod_name, desc in MODULES:
        print(f"# === {mod_name}: {desc} ===", flush=True)
        try:
            mod = __import__(f"benchmarks.{mod_name}",
                             fromlist=["main"])
            mod.main()
        except Exception as e:
            failures.append((mod_name, e))
            print(f"# FAILED {mod_name}: {e}")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
