"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract; the
roofline module additionally writes results/roofline.{md,json} from the
dry-run artifacts when present.

Usage::

    python benchmarks/run.py                 # run everything
    python benchmarks/run.py throughput tuning   # run a subset by name
    python benchmarks/run.py --json out.json     # machine-readable report

``--json <path>`` writes a structured report next to the CSV output:
per-module wall time and status, whatever dict payload each module's
``main()`` returns, and the kernel-launch registry captured around the
module (``repro.kernels.profiling.launch_registry`` — trace-time records,
so a module only shows the launches whose geometry it traced first).

Set ``REPRO_BENCH_TINY=1`` to shrink problem sizes in the modules that
support it (CI smoke: exercises the harness without paper-scale runs).
"""

import json
import os
import sys
import time
import traceback

MODULES = [
    ("memory_footprint", "Fig. 15 memory footprint"),
    ("construction", "Fig. 17 construction time (jax/pallas/fused)"),
    ("update_throughput", "streaming updates vs full rebuild"),
    ("throughput", "Fig. 16 RMQ throughput by range class"),
    ("engine_throughput",
     "routed vs fused vs monolithic query paths (+ BENCH_query.json)"),
    ("distributed_engine", "distributed routing + sharded update cost"),
    ("serving_qps",
     "deadline-batched serving tier vs flush-per-request QPS/p99"),
    ("tuning", "Fig. 12 (c, t) tuning"),
    ("query_assignment", "Fig. 14 multi-load vs WLQ"),
    ("coalesced_access", "Fig. 4 access coalescing microbench"),
    ("bulk_queries",
     "offline bulk path: endpoint-sorted sweep vs fused (+ BENCH_bulk.json)"),
    ("overlap_ablation", "Fig. 13 hybrid top-level ablation"),
    ("roofline", "LM framework roofline (from dry-run artifacts)"),
]


def select(argv):
    """The (name, desc) list to run, honouring CLI module-name args."""
    if not argv:
        return MODULES
    by_name = dict(MODULES)
    unknown = [a for a in argv if a not in by_name]
    if unknown:
        names = ", ".join(name for name, _ in MODULES)
        raise SystemExit(
            f"unknown benchmark module(s) {unknown}; available: {names}"
        )
    # preserve registry order regardless of CLI order
    return [(n, d) for n, d in MODULES if n in set(argv)]


def _jsonable(o):
    """json.dump fallback for numpy scalars/arrays in module payloads."""
    if hasattr(o, "item") and getattr(o, "shape", None) in ((), None):
        return o.item()
    if hasattr(o, "tolist"):
        return o.tolist()
    return str(o)


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv):
            raise SystemExit("--json requires an output path")
        json_path = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]

    report = {
        "tiny": os.environ.get("REPRO_BENCH_TINY") == "1",
        "modules": [],
    }
    failures = []
    for mod_name, desc in select(argv):
        print(f"# === {mod_name}: {desc} ===", flush=True)
        entry = {"name": mod_name, "desc": desc}
        t0 = time.perf_counter()
        try:
            mod = __import__(f"benchmarks.{mod_name}",
                             fromlist=["main"])
            if json_path is not None:
                from repro.kernels.profiling import launch_registry
                with launch_registry() as reg:
                    payload = mod.main()
                entry["launches"] = reg.as_dict()
            else:
                payload = mod.main()
            entry["status"] = "ok"
            if isinstance(payload, dict):
                entry["payload"] = payload
        except Exception as e:
            failures.append((mod_name, e))
            entry["status"] = "failed"
            entry["error"] = f"{type(e).__name__}: {e}"
            print(f"# FAILED {mod_name}: {e}")
            traceback.print_exc()
        entry["seconds"] = round(time.perf_counter() - t0, 6)
        report["modules"].append(entry)

    if json_path is not None:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2, default=_jsonable)
            f.write("\n")
        print(f"# wrote {json_path}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
