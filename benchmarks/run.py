"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract; the
roofline module additionally writes results/roofline.{md,json} from the
dry-run artifacts when present.

Usage::

    python benchmarks/run.py                 # run everything
    python benchmarks/run.py throughput tuning   # run a subset by name

Set ``REPRO_BENCH_TINY=1`` to shrink problem sizes in the modules that
support it (CI smoke: exercises the harness without paper-scale runs).
"""

import sys
import traceback

MODULES = [
    ("memory_footprint", "Fig. 15 memory footprint"),
    ("construction", "Fig. 17 construction time (jax/pallas/fused)"),
    ("update_throughput", "streaming updates vs full rebuild"),
    ("throughput", "Fig. 16 RMQ throughput by range class"),
    ("engine_throughput",
     "routed vs fused vs monolithic query paths (+ BENCH_query.json)"),
    ("distributed_engine", "distributed routing + sharded update cost"),
    ("serving_qps",
     "deadline-batched serving tier vs flush-per-request QPS/p99"),
    ("tuning", "Fig. 12 (c, t) tuning"),
    ("query_assignment", "Fig. 14 multi-load vs WLQ"),
    ("coalesced_access", "Fig. 4 access coalescing microbench"),
    ("overlap_ablation", "Fig. 13 hybrid top-level ablation"),
    ("roofline", "LM framework roofline (from dry-run artifacts)"),
]


def select(argv):
    """The (name, desc) list to run, honouring CLI module-name args."""
    if not argv:
        return MODULES
    by_name = dict(MODULES)
    unknown = [a for a in argv if a not in by_name]
    if unknown:
        names = ", ".join(name for name, _ in MODULES)
        raise SystemExit(
            f"unknown benchmark module(s) {unknown}; available: {names}"
        )
    # preserve registry order regardless of CLI order
    return [(n, d) for n, d in MODULES if n in set(argv)]


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    failures = []
    for mod_name, desc in select(argv):
        print(f"# === {mod_name}: {desc} ===", flush=True)
        try:
            mod = __import__(f"benchmarks.{mod_name}",
                             fromlist=["main"])
            mod.main()
        except Exception as e:
            failures.append((mod_name, e))
            print(f"# FAILED {mod_name}: {e}")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
