"""Paper Fig. 14: multi-load vs warp-local-queuing (WLQ) query assignment.

TPU mapping (kernel.py docstring): WLQ == one Pallas program per
QUERY_BLOCK queries whose bounds arrive in SMEM via a single block DMA;
multi-load == QUERY_BLOCK = 1 (one program and one bounds transfer per
query, the grid itself re-reads bounds).

Two measurements:

1. **Modeled bounds traffic** at the paper's batch (2^26 queries): the
   mechanism the paper measures is memory traffic for query bounds —
   multi-load moves g× more bound bytes than WLQ (g = 16 in the paper;
   QUERY_BLOCK amortization is the TPU analogue).  This is exact
   arithmetic, hardware-independent.
2. **Interpret-mode wall clock** of the actual Pallas kernel at
   QUERY_BLOCK ∈ {1, 16, 256} on a small batch — a structural signal for
   per-program overhead (grid dispatch dominates at qb=1, amortizes at
   larger qb).  CPU-interpret timings are NOT TPU timings; the claim
   checked is the ordering, which is determined by program count.
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import (
    csv_row,
    make_input_array,
    make_queries,
    time_fn,
    tiny_mode,
)
from repro.core.hierarchy import build_hierarchy
from repro.core.plan import make_plan
from repro.kernels.rmq_scan.ops import rmq_value_batch_pallas


def modeled_traffic(m=2**26, g=16):
    bounds_bytes = 8  # two int32 per query
    multi_load = m * g * bounds_bytes   # every thread in the group loads
    wlq = m * bounds_bytes              # one load per query, shuffled
    return multi_load, wlq


def run(n=2**18, m=4096):
    if tiny_mode():
        n, m = 2**14, 256
    x = jnp.asarray(make_input_array(n))
    plan = make_plan(n, c=128, t=8)
    h = build_hierarchy(x, plan)
    ls, rs = make_queries(n, m, "mixed")
    lsj, rsj = jnp.asarray(ls), jnp.asarray(rs)
    rows = []
    for qb in (1, 16, 256):
        t = time_fn(
            lambda: rmq_value_batch_pallas(h, lsj, rsj, qb=qb,
                                           interpret=True),
            repeats=2,
        )
        rows.append({"qb": qb, "ns_per_query": t / m * 1e9})
    return rows


def main():
    print("name,us_per_call,derived")
    ml, wlq = modeled_traffic()
    print(csv_row("query_assignment_traffic_multiload_GiB", 0,
                  f"{ml/2**30:.2f}GiB"))
    print(csv_row("query_assignment_traffic_wlq_GiB", 0,
                  f"{wlq/2**30:.2f}GiB|saving={ml/wlq:.0f}x"))
    rows = run()
    for r in rows:
        print(csv_row(f"query_assignment_interpret_qb{r['qb']}",
                      r["ns_per_query"] / 1e3, ""))
    # structural claim: block-staged bounds (qb > 1) beat per-query
    # programs.  Checked as best-staged vs qb=1 — the qb=256 config
    # alone can lose to noise in interpret mode (its serial fori_loop
    # trades program count for per-program work), which is a lowering
    # artifact, not the mechanism under test.  Not checked at
    # REPRO_BENCH_TINY sizes, where m=256/repeats=2 distributions
    # overlap and CI would flake; the smoke run only guards bit-rot.
    if not tiny_mode():
        staged = min(r["ns_per_query"] for r in rows[1:])
        assert staged < rows[0]["ns_per_query"], rows


if __name__ == "__main__":
    main()
