"""Distributed index: engine routing + sharded update throughput.

Two claims from the distributed refactor, measured on whatever mesh this
process has (a 1×1 ``data×model`` mesh on CPU CI — the same code path as
the production meshes, minus real collectives):

* **routing** — the engine answers spans contained in one segment
  through the grouped segment-local path (zero collectives), vs. the
  monolithic path that replicates every query to every segment and pays
  an all-reduce(min) per batch.  Reported per span kind: ``contained``
  (fits in one segment) and ``crossing`` (straddles a boundary; must
  all-reduce on either path).
* **update cost** — sharded batched point updates re-reduce
  O(batch · log_c n_local) shard-local chunks; a from-scratch
  ``DistributedRMQ.build`` re-reduces every chunk.  The ratio grows with
  n at fixed batch — updates are the flat curve (demonstrating the
  no-rebuild, no-cross-segment-communication contract).

``REPRO_BENCH_TINY=1`` shrinks sizes for the CI smoke run.  Absolute
numbers on CPU are not the paper's; orderings and scaling shapes are the
reproducible content (see benchmarks/common.py).
"""

from __future__ import annotations

import numpy as np
import jax

from benchmarks.common import csv_row, make_input_array, time_fn, tiny_mode
from repro.core.distributed import DistributedRMQ


def make_span_queries(n: int, seg_cap: int, m: int, kind: str, seed: int = 1):
    """Query batches pinned inside / across segment boundaries.

    Returns ``None`` for ``kind="crossing"`` when the live data occupies a
    single segment (nothing *can* cross — e.g. the 1-device CI mesh).
    """
    rng = np.random.default_rng(seed)
    live_segs = -(-n // seg_cap)
    if kind == "contained":
        # short spans placed to never straddle a segment boundary
        s = rng.integers(1, max(min(seg_cap, n) // 8, 2), m)
        seg = rng.integers(0, live_segs, m)
        lo = seg * seg_cap
        hi = np.minimum(lo + seg_cap, n)
        s = np.minimum(s, hi - lo)
        ls = lo + (rng.random(m) * (hi - lo - s + 1)).astype(np.int64)
        rs = ls + s - 1
    elif kind == "crossing":
        if live_segs < 2:
            return None
        # force every span across a boundary b = j*seg_cap: l < b <= r
        b = rng.integers(1, live_segs, m) * seg_cap
        ls = b - rng.integers(1, seg_cap + 1, m)
        rs = np.minimum(b + rng.integers(0, seg_cap, m), n - 1)
        ls = np.maximum(ls, 0)
    else:
        raise ValueError(kind)
    return ls.astype(np.int32), rs.astype(np.int32)


def run(n: int, m: int, batch: int, c: int, t: int):
    mesh = jax.make_mesh(
        (1, jax.device_count()), ("data", "model")
    )
    x = make_input_array(n)
    d = DistributedRMQ.build(
        x, mesh, c=c, t=t, with_positions=True, capacity=2 * n
    )
    engine = d.engine(cache_size=0)
    rows = []
    for kind in ("contained", "crossing"):
        q = make_span_queries(n, d.segment_capacity, m, kind)
        if q is None:
            continue  # single live segment: nothing can cross
        ls, rs = q
        t_mono = time_fn(lambda: d.query(ls, rs), repeats=3)
        t_eng = time_fn(lambda: engine.query(ls, rs), repeats=3)
        rows.append(
            {"kind": kind, "mono_ns": t_mono / m * 1e9,
             "engine_ns": t_eng / m * 1e9}
        )
    cc = engine.stats()["class_counts"]

    # update vs rebuild at fixed batch, growing n_local
    rng = np.random.default_rng(3)
    upd_rows = []
    for scale in (1, 4):
        nn = n * scale
        xx = make_input_array(nn, seed=scale)
        dd = DistributedRMQ.build(
            xx, mesh, c=c, t=t, with_positions=True, capacity=2 * nn
        )
        idxs = rng.integers(0, nn, batch).astype(np.int32)
        vals = rng.random(batch).astype(np.float32)
        t_upd = time_fn(lambda: dd.update(idxs, vals).base, repeats=3)
        t_build = time_fn(
            lambda: DistributedRMQ.build(
                xx, mesh, c=c, t=t, with_positions=True, capacity=2 * nn
            ).base,
            repeats=3,
        )
        upd_rows.append(
            {"n": nn, "upd_us": t_upd * 1e6, "build_us": t_build * 1e6}
        )
    return rows, cc, upd_rows


def main() -> None:
    if tiny_mode():
        # t=64 keeps the local plan at 2 levels across the scaling loop
        # (first compile of a 3-level distributed walk is minutes on CPU
        # XLA — fine for paper runs, not for a CI smoke step)
        rows, cc, upd = run(n=2**12, m=1024, batch=64, c=16, t=64)
    else:
        rows, cc, upd = run(n=2**18, m=4096, batch=256, c=128, t=64)
    print("name,us_per_call,derived")
    for r in rows:
        speedup = r["mono_ns"] / r["engine_ns"]
        print(csv_row(f"dist_monolithic_{r['kind']}",
                      r["mono_ns"] / 1e3, ""))
        print(csv_row(f"dist_engine_{r['kind']}",
                      r["engine_ns"] / 1e3, f"speedup={speedup:.2f}x"))
    print(csv_row(
        "dist_engine_class_split", 0,
        f"seg_local={cc['seg_local']}|crossing={cc['crossing']}",
    ))
    for r in upd:
        ratio = r["build_us"] / max(r["upd_us"], 1e-9)
        print(csv_row(f"dist_update_b_n{r['n']}", r["upd_us"],
                      f"rebuild={r['build_us']:.1f}us|x{ratio:.1f}"))
    # structural claims:
    # (1) the contained-span batch really routed around the all-reduce,
    #     and (on multi-segment meshes) the crossing batch really paid it;
    assert cc["seg_local"] > 0
    if any(r["kind"] == "crossing" for r in rows):
        assert cc["crossing"] > 0, cc
    # (2) incremental update beats a from-scratch rebuild, and the gap
    #     widens with n at fixed batch (O(B log n_local) vs O(n_local));
    #     orderings only at full size — tiny CI sizes are noise-level
    #     and guard bit-rot, not perf (same policy as engine_throughput).
    if not tiny_mode():
        for r in upd:
            assert r["upd_us"] < r["build_us"], r


if __name__ == "__main__":
    main()
