"""Streaming update throughput: incremental maintenance vs full rebuild.

The paper's construction is already cheap (a few chunked reductions); the
streaming claim is that a *batch of B point updates* costs
O(B log_c n) chunk re-reductions, so for B ≪ n/c it should beat
rebuilding by a widening margin as n grows.  This benchmark sweeps batch
size and n, reporting updates/sec for the incremental path and the
equivalent full-rebuild baseline, plus the crossover ratio.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, make_input_array, time_fn
from repro.core.hierarchy import build_hierarchy
from repro.core.plan import make_plan
from repro.streaming.updates import update_hierarchy


def run(sizes=(2**18, 2**22), batches=(16, 256, 4096), c=128, t=64):
    rows = []
    rng = np.random.default_rng(0)
    for n in sizes:
        x = jnp.asarray(make_input_array(n))
        plan = make_plan(n, c=c, t=t)
        h = build_hierarchy(x, plan, with_positions=True)
        jax.block_until_ready(h.upper)
        t_rebuild = time_fn(
            lambda: build_hierarchy(x, plan, with_positions=True).upper
        )
        for b in batches:
            idxs = jnp.asarray(rng.integers(0, n, b), jnp.int32)
            vals = jnp.asarray(rng.random(b).astype(np.float32))
            t_update = time_fn(
                lambda: update_hierarchy(h, idxs, vals).upper
            )
            rows.append({
                "n": n,
                "batch": b,
                "update_us": t_update * 1e6,
                "rebuild_us": t_rebuild * 1e6,
                "updates_per_sec": b / t_update,
                "speedup_vs_rebuild": t_rebuild / t_update,
            })
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        print(csv_row(
            f"update_n{r['n']}_b{r['batch']}",
            r["update_us"],
            f"rebuild={r['rebuild_us']:.0f}us"
            f"|upd_per_s={r['updates_per_sec']:.0f}"
            f"|speedup={r['speedup_vs_rebuild']:.2f}x",
        ))
    # shape claim: small-batch incremental updates must beat the rebuild,
    # and the advantage must grow with n (the rebuild is O(n/c), the
    # update O(B log_c n)).
    small = {r["n"]: r["speedup_vs_rebuild"]
             for r in rows if r["batch"] == min(r2["batch"] for r2 in rows)}
    ns = sorted(small)
    assert small[ns[-1]] > 1.0, rows
    assert small[ns[-1]] >= small[ns[0]] * 0.8, rows


if __name__ == "__main__":
    main()
