"""Serving-tier QPS: deadline micro-batching vs flush-per-request.

The serving tier's throughput claim, measured: ``W`` closed-loop client
threads fire small mixed value/index batches at one fused-backend index
while a mutator thread streams point updates the whole time.  Two ways
to serve the same workload:

* ``flush_per_request`` — the pre-tier shape: clients share one
  ``QueryService`` behind a lock and pay one fused launch per request
  (submit → flush → take, serialized);
* ``deadline_tier``     — clients submit to the ``ServingTier`` and
  block on their tickets; the deadline scheduler coalesces every
  client's requests (and the mutator's staged updates) into one fused
  launch per flush cycle.

Outside ``REPRO_BENCH_TINY`` the run *asserts* the acceptance bar:
deadline batching sustains >= 3x the QPS of flush-per-request at equal
or better p99 (the tier's p99 is one SLO window + one launch; the
baseline's is the whole lock convoy).  Both modes additionally assert:

* snapshot parity — every tier answer is bit-identical to a numpy
  replay of the mutation log at the ticket's recorded generation
  (snapshot isolation under concurrent mutation, end to end);
* the launch contract — one ``ServingTier.drain`` flush of a mixed
  read+mutation backlog records exactly ONE ``rmq_fused`` launch
  (fresh geometry so the trace-time counter fires; see
  ``repro.kernels.profiling``).

The deadline-tier run doubles as the observability smoke: a
``repro.obs.trace.Tracer`` is installed around it and the run exports
``results/serving_trace.json`` (Chrome trace of every flush's span tree:
submit → admission → queue → flush → snapshot_swap → plan → execute →
scatter) plus ``results/serving_metrics.prom`` (the tier's full
Prometheus exposition, per-engine cache/span-class/padding-waste series
included).  Both exports are validated in tiny mode too.
"""

from __future__ import annotations

import os
import threading
import time

import numpy as np

from benchmarks.common import csv_row, tiny_mode
from repro.core.api import RMQ
from repro.kernels.profiling import count_launches
from repro.obs.trace import Tracer, use_tracer
from repro.qe import QueryService
from repro.qe.executors import INDEX, VALUE
from repro.serving import ServingTier

# Observability exports from the measured deadline-tier run — anchored at
# the repo root like BENCH_query.json (results/ is gitignored).
RESULTS_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "results"
)
TRACE_PATH = os.path.join(RESULTS_DIR, "serving_trace.json")
PROM_PATH = os.path.join(RESULTS_DIR, "serving_metrics.prom")

# Every flush cycle must show this span vocabulary in the exported trace
# (submit/admission on caller threads, queue retroactive, the rest under
# the flush) — asserted in tiny mode too so CI catches a dropped hook.
EXPECTED_SPANS = frozenset({
    "submit", "admission", "queue", "flush", "snapshot_swap",
    "plan", "execute", "scatter",
})


def _workload(rng, n: int, workers: int, requests: int, q: int):
    """Per-worker request list: (ls, rs, op) of ``q`` random spans."""
    plans = []
    for _ in range(workers):
        reqs = []
        for j in range(requests):
            s = rng.integers(1, max(2, n // 4), q)
            ls = (rng.random(q) * (n - s)).astype(np.int32)
            rs = (ls + s - 1).astype(np.int32)
            reqs.append((ls, rs, INDEX if j % 3 == 2 else VALUE))
        plans.append(reqs)
    return plans


class _Mutator:
    """Background point-update stream with an ordered log for replay."""

    def __init__(self, rng, n: int, batch: int = 32,
                 interval_s: float = 0.002):
        self.rng, self.n, self.batch = rng, n, batch
        self.interval_s = interval_s
        self.log = []            # [(idxs, vals)] in staging order
        self._stop = threading.Event()
        self._thread = None

    def next_batch(self):
        idxs = self.rng.integers(0, self.n, self.batch).astype(np.int32)
        vals = self.rng.random(self.batch).astype(np.float32)
        self.log.append((idxs, vals))
        return idxs, vals

    def run(self, stage) -> None:
        self._thread = threading.Thread(
            target=self._loop, args=(stage,), daemon=True
        )
        self._thread.start()

    def _loop(self, stage) -> None:
        while not self._stop.is_set():
            stage(*self.next_batch())
            time.sleep(self.interval_s)

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()


def _percentile(lat, p):
    return float(np.percentile(np.asarray(lat), p))


def _warmup_spans(rng, n: int, q: int):
    s = rng.integers(1, max(2, n // 4), q)
    ls = (rng.random(q) * (n - s)).astype(np.int32)
    return ls, (ls + s - 1).astype(np.int32)


# Bucket geometries the measured phase can hit (executors pad to pow2
# buckets).  Warmed untimed in both strategies so the comparison is
# steady-state serving, not who paid which jit compile when — the same
# warmup discipline as ``common.time_fn``.
def _warmup_sizes(tiny: bool):
    return (4, 8, 16) if tiny else (4, 16, 32, 64)


def run_flush_per_request(x, plans, mut_interval: float, seed: int,
                          warm_sizes=(4,)):
    """Baseline: shared service + lock, one flush (= one launch) per
    request; the mutator attaches successors under the same lock."""
    n = x.shape[0]
    svc = QueryService(auto_flush=False)
    svc.register("bench", RMQ.build(x, c=128, t=64, with_positions=True,
                                    backend="fused"), cache_size=0)
    lock = threading.Lock()
    lat = []
    lat_lock = threading.Lock()

    def stage(idxs, vals):
        with lock:
            svc.attach("bench", svc.snapshot("bench").update(idxs, vals))

    # untimed warmup: compile the request geometry + the update path
    wrng = np.random.default_rng(17)
    for q_w in warm_sizes:
        for op in (VALUE, INDEX):
            ls, rs = _warmup_spans(wrng, n, q_w)
            tk = svc.submit("bench", ls, rs, op)
            svc.flush(names=("bench",))
            np.asarray(svc.take(tk))
    stage(np.arange(8, dtype=np.int32),
          wrng.random(8).astype(np.float32))

    def worker(reqs):
        mine = []
        for ls, rs, op in reqs:
            t0 = time.perf_counter()
            with lock:
                tk = svc.submit("bench", ls, rs, op)
                svc.flush(names=("bench",))
                np.asarray(svc.take(tk))
            mine.append(time.perf_counter() - t0)
        with lat_lock:
            lat.extend(mine)

    mut = _Mutator(np.random.default_rng(seed), n,
                   interval_s=mut_interval)
    threads = [threading.Thread(target=worker, args=(reqs,))
               for reqs in plans]
    t0 = time.perf_counter()
    mut.run(stage)
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    elapsed = time.perf_counter() - t0
    mut.stop()
    nq = sum(len(r[0]) for reqs in plans for r in reqs)
    return {"qps": nq / elapsed, "p99_ms": _percentile(lat, 99) * 1e3,
            "p50_ms": _percentile(lat, 50) * 1e3, "launches": len(lat)}


def run_deadline_tier(x, plans, mut_interval: float, seed: int,
                      slo_ms: float = 2.0, warm_sizes=(4,),
                      backend: str = "fused"):
    """Tier: closed-loop clients block on tickets; the deadline
    scheduler coalesces all of them (plus mutations) per flush.

    ``backend`` contrasts the tier on the fused single-launch engine
    (one ``rmq_fused`` launch per flush) against the routed class-split
    engine (one launch per span class per op group).
    """
    n = x.shape[0]
    tier = ServingTier()
    tier.register_tenant(
        "bench",
        RMQ.build(x, c=128, t=64, with_positions=True, backend=backend),
        slo_ms=slo_ms, max_queue=1 << 16, max_batch=1 << 14,
        cache_size=0,
    )
    lat, answered = [], []
    lat_lock = threading.Lock()

    mut = _Mutator(np.random.default_rng(seed), n,
                   interval_s=mut_interval)

    def warmup(wrng):
        """Compile every bucket geometry a coalesced flush can hit
        (pure-value, pure-index, and merged mixed buckets) plus the
        staged-update fold.  Warmup mutations go through the mutator's
        logged ``next_batch`` so generation replay stays exact."""
        for q_w in warm_sizes:
            for ops in ((VALUE,), (INDEX,), (VALUE, INDEX)):
                tks = []
                for op in ops:
                    ls, rs = _warmup_spans(wrng, n, q_w)
                    tks.append(tier.submit("bench", ls, rs, op))
                tier.drain("bench")
                for tk in tks:
                    np.asarray(tk.result(timeout=60.0))
        tier.update("bench", *mut.next_batch())
        tier.drain("bench")

    def worker(reqs):
        mine, got = [], []
        for ls, rs, op in reqs:
            t0 = time.perf_counter()
            tk = tier.submit("bench", ls, rs, op)
            res = np.asarray(tk.result(timeout=60.0))
            mine.append(time.perf_counter() - t0)
            got.append((tk.generation, ls, rs, op, res))
        with lat_lock:
            lat.extend(mine)
            answered.extend(got)

    warmup(np.random.default_rng(17))
    threads = [threading.Thread(target=worker, args=(reqs,))
               for reqs in plans]
    with tier:
        t0 = time.perf_counter()
        mut.run(lambda i, v: tier.update("bench", i, v))
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        elapsed = time.perf_counter() - t0
        mut.stop()
    nq = len(answered) and sum(len(a[1]) for a in answered)
    stats = tier.stats()["tenants"]["bench"]
    return {
        "qps": nq / elapsed, "p99_ms": _percentile(lat, 99) * 1e3,
        "p50_ms": _percentile(lat, 50) * 1e3,
        "launches": stats["flushes"], "swaps": stats["snapshot_swaps"],
        "answered": answered, "mutation_log": mut.log, "base": x,
        # full-stack Prometheus exposition: tier counters/histograms,
        # service scope, per-engine cache/span-class/padding series
        "metrics_prom": tier.metrics.to_prometheus(),
    }


def check_snapshot_parity(tier_out) -> int:
    """Every tier answer == numpy oracle at the ticket's generation."""
    snaps = {0: tier_out["base"].copy()}
    arr = tier_out["base"].copy()
    for gen, (idxs, vals) in enumerate(tier_out["mutation_log"], 1):
        arr = arr.copy()
        arr[idxs] = vals
        snaps[gen] = arr
    checked = 0
    for gen, ls, rs, op, res in tier_out["answered"]:
        arr = snaps[gen]
        for l, r, v in zip(ls, rs, res):
            want = (arr[l:r + 1].min() if op == VALUE
                    else l + int(np.argmin(arr[l:r + 1])))
            assert v == want, (
                f"snapshot violation: gen={gen} op={op} span=({l},{r}) "
                f"got {v} want {want}"
            )
            checked += 1
    return checked


def check_single_launch_per_flush() -> dict:
    """One drained flush of a mixed read+mutation backlog = ONE
    ``rmq_fused`` launch.  Unique geometry keeps the trace-time counter
    fresh (it records on first trace only)."""
    rng = np.random.default_rng(11)
    n, c, t = 4799, 8, 8
    x = rng.random(n).astype(np.float32)
    tier = ServingTier()   # never started: drained manually below
    tier.register_tenant(
        "contract",
        RMQ.build(x, c=c, t=t, with_positions=True, backend="fused"),
        slo_ms=1e6, cache_size=0,
    )
    q = 37                                   # batch size unique to this check
    s = rng.integers(1, n // 2, q)
    ls = (rng.random(q) * (n - s)).astype(np.int32)
    rs = (ls + s - 1).astype(np.int32)
    tickets = [tier.submit("contract", ls, rs, VALUE),
               tier.submit("contract", ls, rs, INDEX)]
    tier.update("contract", np.arange(16, dtype=np.int32),
                rng.random(16).astype(np.float32))
    with count_launches() as counts:
        tier.drain("contract")
    for tk in tickets:
        np.asarray(tk.result(timeout=30.0))
    if counts.get("rmq_fused") != 1:
        raise AssertionError(
            f"one flush of a mixed backlog must record exactly ONE "
            f"rmq_fused launch, recorded {counts}"
        )
    return dict(counts)


def export_observability(tracer: Tracer, prom_text: str) -> None:
    """Write the Chrome trace + Prometheus dump, asserting both carry
    the serving-path signal (span vocabulary; cache/span-class/padding
    series).  Runs in tiny mode too — this is the CI observability
    smoke's substrate."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    missing = EXPECTED_SPANS - {s.name for s in tracer.spans()}
    assert not missing, f"tier trace is missing spans: {sorted(missing)}"
    tracer.save_chrome_trace(TRACE_PATH)
    for series in ("cache_hit_rate", "span_class_", "bucket_padding_waste",
                   "flushes_total", "latency_s_bucket"):
        assert series in prom_text, (
            f"Prometheus dump is missing the {series!r} series"
        )
    with open(PROM_PATH, "w") as f:
        f.write(prom_text)
    print(f"# wrote {TRACE_PATH}")
    print(f"# wrote {PROM_PATH}")


def main() -> dict:
    tiny = tiny_mode()
    if tiny:
        n, workers, requests, q = 1 << 12, 4, 6, 4
        mut_interval = 0.005
    else:
        n, workers, requests, q = 1 << 16, 16, 30, 4
        mut_interval = 0.002
    rng = np.random.default_rng(3)
    x = rng.random(n).astype(np.float32)
    plans = _workload(rng, n, workers, requests, q)
    warm = _warmup_sizes(tiny)

    base = run_flush_per_request(x, plans, mut_interval, seed=5,
                                 warm_sizes=warm)
    tracer = Tracer(capacity=1 << 17)
    with use_tracer(tracer):
        tier = run_deadline_tier(x, plans, mut_interval, seed=5,
                                 warm_sizes=warm)
    checked = check_snapshot_parity(tier)
    launches = check_single_launch_per_flush()
    export_observability(tracer, tier["metrics_prom"])

    nq = workers * requests * q
    payload = {
        "benchmark": "serving_qps",
        "tiny": tiny,
        "geometry": {"n": n, "workers": workers, "requests": requests,
                     "queries_per_request": q},
        "flush_per_request": {
            k: base[k] for k in ("qps", "p50_ms", "p99_ms", "launches")
        },
        "deadline_tier": {
            k: tier[k]
            for k in ("qps", "p50_ms", "p99_ms", "launches", "swaps")
        },
        "snapshot_parity_checked": checked,
        "fused_launches_per_flush": launches,
        "trace_path": TRACE_PATH,
        "trace_spans": len(tracer.spans()),
        "metrics_path": PROM_PATH,
    }
    print(csv_row(
        "serving_flush_per_request", 1e6 / base["qps"],
        f"qps={base['qps']:.0f}|p50_ms={base['p50_ms']:.2f}"
        f"|p99_ms={base['p99_ms']:.2f}|launches={base['launches']}",
    ))
    print(csv_row(
        "serving_deadline_tier", 1e6 / tier["qps"],
        f"qps={tier['qps']:.0f}|p50_ms={tier['p50_ms']:.2f}"
        f"|p99_ms={tier['p99_ms']:.2f}|launches={tier['launches']}"
        f"|swaps={tier['swaps']}",
    ))
    print(csv_row(
        "serving_snapshot_parity", 0,
        f"queries_checked={checked}|generations="
        f"{len({g for g, *_ in tier['answered']})}",
    ))
    print(csv_row("serving_fused_launches_per_flush", 0,
                  f"rmq_fused={launches['rmq_fused']}"))

    if not tiny:
        # the routed class-split engine through the same tier — shows
        # how much of the serving win the fused single-launch path
        # contributes on top of deadline batching itself
        routed = run_deadline_tier(x, plans, mut_interval, seed=5,
                                   warm_sizes=warm, backend="jax")
        print(csv_row(
            "serving_deadline_tier_routed", 1e6 / routed["qps"],
            f"qps={routed['qps']:.0f}|p50_ms={routed['p50_ms']:.2f}"
            f"|p99_ms={routed['p99_ms']:.2f}"
            f"|launches={routed['launches']}",
        ))
        payload["deadline_tier_routed"] = {
            k: routed[k]
            for k in ("qps", "p50_ms", "p99_ms", "launches", "swaps")
        }
        # acceptance bar: >=3x sustained QPS at equal-or-better p99.
        # tiny-mode runs are too small for stable percentiles, so the
        # perf gate (like every other module's) is full-mode only.
        speedup = tier["qps"] / base["qps"]
        assert speedup >= 3.0, (
            f"deadline batching must sustain >=3x flush-per-request QPS "
            f"({tier['qps']:.0f} vs {base['qps']:.0f}, {speedup:.2f}x)"
        )
        assert tier["p99_ms"] <= base["p99_ms"] * 1.05, (
            f"tier p99 {tier['p99_ms']:.2f}ms must not exceed "
            f"flush-per-request p99 {base['p99_ms']:.2f}ms"
        )
        print(csv_row("serving_qps_speedup", 0,
                      f"speedup={speedup:.2f}x|checked={nq}"))
        payload["speedup"] = speedup
    return payload


if __name__ == "__main__":
    main()
