"""Paper Fig. 17: data-structure construction time vs array size.

Measures hierarchy build (ours, both backends) against the sparse-table
build (the LCA-profile baseline).  The paper's claim: GPU-RMQ construction
is a few parallel chunked reductions — 50–2400× cheaper than competitors
and nearly flat in n; sparse-table is log2(n) full passes.
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import csv_row, make_input_array, time_fn
from repro.core.baselines import SparseTable
from repro.core.hierarchy import build_hierarchy
from repro.core.plan import make_plan
from repro.kernels.hierarchy_build.ops import build_hierarchy_pallas


def run(sizes=(2**18, 2**20, 2**22, 2**24), c=128, t=64):
    rows = []
    for n in sizes:
        x = jnp.asarray(make_input_array(n))
        plan = make_plan(n, c=c, t=t)
        t_build = time_fn(lambda: build_hierarchy(x, plan).upper)
        t_sparse = time_fn(lambda: SparseTable.build(x).table)
        rows.append({
            "n": n,
            "gpu_rmq_build_ms": t_build * 1e3,
            "sparse_build_ms": t_sparse * 1e3,
            "speedup": t_sparse / t_build,
        })
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        print(csv_row(
            f"construction_n{r['n']}",
            r["gpu_rmq_build_ms"] * 1e3,
            f"sparse={r['sparse_build_ms']:.1f}ms"
            f"|speedup={r['speedup']:.1f}x",
        ))
    # paper-shape claim: our build must beat the memory-heavy baseline,
    # increasingly so at scale
    assert rows[-1]["speedup"] > 2.0, rows[-1]


if __name__ == "__main__":
    main()
