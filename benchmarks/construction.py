"""Paper Fig. 17: data-structure construction time vs array size.

Times the hierarchy build through **all three pipeline backends** —
``jax`` (pure-JAX fused pass), ``pallas`` (one launch per level) and
``fused`` (ONE launch total) — against the sparse-table build (the
LCA-profile baseline).  The paper's claim: GPU-RMQ construction is a few
parallel chunked reductions — 50–2400× cheaper than competitors and
nearly flat in n; sparse-table is log2(n) full passes.

Also asserts the fused path's launch contract via the trace-time counter
(``repro.kernels.profiling``): exactly ONE kernel launch per build, vs
``num_levels - 1`` for the per-level path — this is what the CI tiny
smoke run guards against bit-rot.

On non-TPU hosts the Pallas backends run in interpret mode (a
correctness harness, not a performance path), so their absolute times
are only meaningful on TPU; the jax-vs-sparse comparison carries the
paper-shape claim everywhere.

``REPRO_BENCH_TINY=1`` shrinks sizes *and* the (c, t) geometry so plans
stay multi-level (the launch-count assertion needs upper levels).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, make_input_array, time_fn, tiny_mode
from repro.core.baselines import SparseTable
from repro.core.hierarchy import build_hierarchy
from repro.core.plan import make_plan
from repro.kernels.hierarchy_build.ops import build_hierarchy_pallas
from repro.kernels.hierarchy_fused.ops import build_hierarchy_fused
from repro.kernels.profiling import count_launches


def _timed_with_launches(fn):
    """(median seconds, launches traced on the first call)."""
    with count_launches() as counts:
        jax.block_until_ready(fn())
    return time_fn(fn), sum(counts.values())


def run(sizes=None, c=None, t=None):
    if sizes is None:
        # tiny geometry keeps plans multi-level at tiny sizes
        sizes = (2**12, 2**14) if tiny_mode() else (2**18, 2**20, 2**22)
    if c is None:
        c = 32 if tiny_mode() else 128
    if t is None:
        t = 4 if tiny_mode() else 64
    rows = []
    for n in sizes:
        x = jnp.asarray(make_input_array(n))
        plan = make_plan(n, c=c, t=t)
        t_jax, l_jax = _timed_with_launches(
            lambda: build_hierarchy(x, plan).upper
        )
        t_pal, l_pal = _timed_with_launches(
            lambda: build_hierarchy_pallas(x, plan).upper
        )
        t_fused, l_fused = _timed_with_launches(
            lambda: build_hierarchy_fused(x, plan).upper
        )
        t_sparse = time_fn(lambda: SparseTable.build(x).table)
        rows.append({
            "n": n,
            "num_levels": plan.num_levels,
            "jax_build_ms": t_jax * 1e3,
            "pallas_build_ms": t_pal * 1e3,
            "fused_build_ms": t_fused * 1e3,
            "sparse_build_ms": t_sparse * 1e3,
            "jax_launches": l_jax,
            "pallas_launches": l_pal,
            "fused_launches": l_fused,
            "speedup": t_sparse / t_jax,
        })
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        print(csv_row(
            f"construction_n{r['n']}",
            r["jax_build_ms"] * 1e3,
            f"pallas={r['pallas_build_ms']:.1f}ms"
            f"|fused={r['fused_build_ms']:.1f}ms"
            f"|sparse={r['sparse_build_ms']:.1f}ms"
            f"|speedup_vs_sparse={r['speedup']:.1f}x"
            f"|launches_fused={r['fused_launches']}"
            f"|launches_pallas={r['pallas_launches']}",
        ))
    for r in rows:
        # the pipeline's launch contract (guards fused-path bit-rot):
        # one launch total, vs one per upper level
        assert r["fused_launches"] == 1, r
        assert r["pallas_launches"] == r["num_levels"] - 1, r
    if not tiny_mode():
        # paper-shape claim: our build must beat the memory-heavy
        # baseline, increasingly so at scale
        assert rows[-1]["speedup"] > 2.0, rows[-1]


if __name__ == "__main__":
    main()
