"""Paper Fig. 15: total memory footprint of all methods vs array size.

GPU-RMQ's claim: auxiliary memory stays <= ~30% over the raw input (and
~3% at production c=128), while the LCA-profile (sparse table) explodes by
log2(n)× and becomes infeasible first.  Exact byte accounting — no timing,
so this runs at full paper scales.

The accounting is the plan's own (``HierarchyPlan.value_plane_bytes`` /
``position_plane_bytes`` / ``auxiliary_bytes_planned``) so the benchmark
cannot drift from what builds actually allocate.  Position-tracking
builds are counted honestly: the classic absolute plane costs 4 bytes
per upper entry below ``2**31`` and 8 bytes past it (int64 coordinates
under x64), while the bit-packed chunk-local plane costs
``ceil(log2 c)`` bits per entry at every scale.  Three layout rows per
size:

* ``value_only``  — upper value plane, no positions;
* ``abs_pos``     — values + absolute positions (int32/int64);
* ``packed_pos``  — values + bit-packed chunk-local positions.

Full-mode runs refresh the committed ``BENCH_memory.json`` (atomic
write, same discipline as ``BENCH_bulk.json``); the paper-claim asserts
run in every mode — the sweep is pure arithmetic.
"""

from __future__ import annotations

import os

import jax

from common import atomic_write_json, csv_row, tiny_mode
from repro.core.plan import make_plan

# Committed memory-trajectory artifact: repo-root anchored, full-mode only
# (same discipline as BENCH_bulk.json).
BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_memory.json",
)

SIZES = (2**20, 2**22, 2**24, 2**26, 2**28, 2**30, 2**31)


def _sparse_aux_bytes(n: int) -> int:
    """LCA-profile sparse table: n * log2(n) position entries.

    Counted with the same honesty rule as our planes: 4-byte entries
    below ``2**31``, 8-byte past it (the stored values ARE array
    indices, so they hit the int32 ceiling exactly when we do).
    """
    itemsize = 8 if n >= 2**31 else 4
    return max(1, n.bit_length() - 1) * n * itemsize


def layout_rows(n: int, c: int = 128, t: int = 64) -> dict:
    """Per-layout byte accounting for one array size (plan-level only)."""
    classic = make_plan(n, c=c, t=t)
    packed = make_plan(n, c=c, t=t, packed_pos=True)
    bf16 = make_plan(n, c=c, t=t, packed_pos=True,
                     summary_dtype="bfloat16")
    input_bytes = classic.input_bytes()
    return {
        "n": n,
        "c": c,
        "input_gib": input_bytes / 2**30,
        "pos_bits": packed.pos_bits(),
        "layouts": {
            "value_only": {
                "aux_bytes": classic.auxiliary_bytes_planned(False),
                "bytes_per_element":
                    classic.auxiliary_bytes_planned(False) / n,
            },
            "abs_pos": {
                "aux_bytes": classic.auxiliary_bytes_planned(True),
                "bytes_per_element":
                    classic.auxiliary_bytes_planned(True) / n,
                "pos_itemsize": 8 if n >= 2**31 else 4,
            },
            "packed_pos": {
                "aux_bytes": packed.auxiliary_bytes_planned(True),
                "bytes_per_element":
                    packed.auxiliary_bytes_planned(True) / n,
            },
            "packed_pos_bf16": {
                "aux_bytes": bf16.auxiliary_bytes_planned(True),
                "bytes_per_element":
                    bf16.auxiliary_bytes_planned(True) / n,
            },
        },
        "pos_plane_ratio_abs_over_packed": (
            classic.position_plane_bytes() / packed.position_plane_bytes()
        ),
    }


def run(sizes=SIZES) -> list:
    rows = []
    for n in sizes:
        r = layout_rows(n)
        input_bytes = n * 4
        plan_vl = make_plan(n, c=8, t=8)     # VL-config from paper §5.3
        ours_aux = r["layouts"]["abs_pos"]["aux_bytes"]
        sparse_aux = _sparse_aux_bytes(n)
        r.update({
            "full_scan_total_gib": input_bytes / 2**30,
            "gpu_rmq_cl_total_gib": (input_bytes + ours_aux) / 2**30,
            "gpu_rmq_vl_total_gib":
                (input_bytes
                 + plan_vl.auxiliary_bytes_planned(False)) / 2**30,
            "gpu_rmq_packed_total_gib":
                (input_bytes
                 + r["layouts"]["packed_pos"]["aux_bytes"]) / 2**30,
            "two_level_total_gib":
                (input_bytes + -(-n // 256) * 4) / 2**30,
            "sparse_table_total_gib": (input_bytes + sparse_aux) / 2**30,
            "gpu_rmq_overhead_pct": 100 * ours_aux / input_bytes,
            "sparse_overhead_x": sparse_aux / input_bytes,
        })
        rows.append(r)
    return rows


def check_claims(rows: list) -> None:
    """The paper/PR acceptance claims — run in every mode (pure math)."""
    last = rows[-1]
    assert last["n"] == 2**31
    # honest accounting: <30% total overhead WITH positions at n = 2^31
    assert last["gpu_rmq_overhead_pct"] < 30.0, (
        "paper: <= 30% overhead incl. positions", last)
    # packed chunk-local plane beats the absolute plane ~4x at c=128
    # (32 bits -> 7 bits: 4.57x below 2^31, 9.1x past it where the
    # absolute plane widens to int64)
    for r in rows:
        assert r["pos_plane_ratio_abs_over_packed"] >= 4.0, r
    # 24 GB GPU feasibility frontier (paper: LCA/RTXRMQ die at 2^28..2^29,
    # GPU-RMQ reaches 2^31)
    for r in rows:
        if r["n"] == 2**28:
            assert r["sparse_table_total_gib"] >= 24, (
                "sparse-table profile must exceed 24GB", r)
        if r["n"] == 2**31:
            assert r["gpu_rmq_cl_total_gib"] < 24, (
                "GPU-RMQ must still fit at 2^31 (paper §5.5)", r)
            assert r["gpu_rmq_packed_total_gib"] < 24, r


def main() -> dict:
    tiny = tiny_mode()
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        lay = r["layouts"]
        print(csv_row(
            f"memory_footprint_n{r['n']}", 0,
            f"rmq={r['gpu_rmq_cl_total_gib']:.3f}GiB"
            f"|packed={r['gpu_rmq_packed_total_gib']:.3f}GiB"
            f"|sparse={r['sparse_table_total_gib']:.3f}GiB"
            f"|overhead={r['gpu_rmq_overhead_pct']:.2f}%",
        ))
        print(csv_row(
            f"memory_layouts_n{r['n']}", 0,
            f"value_only={lay['value_only']['bytes_per_element']:.4f}B/el"
            f"|abs_pos={lay['abs_pos']['bytes_per_element']:.4f}B/el"
            f"|packed={lay['packed_pos']['bytes_per_element']:.4f}B/el"
            f"|pos_ratio={r['pos_plane_ratio_abs_over_packed']:.2f}x",
        ))
    check_claims(rows)

    payload = {
        "benchmark": "memory_footprint",
        "tiny": tiny,
        "platform": jax.default_backend(),
        "unit": "bytes",
        "geometry": {"c": 128, "t": 64},
        "rows": rows,
        "claims": {
            "overhead_pct_at_2pow31":
                rows[-1]["gpu_rmq_overhead_pct"],
            "packed_vs_abs_pos_ratio_at_c128":
                rows[0]["pos_plane_ratio_abs_over_packed"],
        },
    }
    if not tiny:
        atomic_write_json(BENCH_JSON, payload)
        print(f"# wrote {BENCH_JSON}")
    return payload


if __name__ == "__main__":
    main()
