"""Paper Fig. 15: total memory footprint of all methods vs array size.

GPU-RMQ's claim: auxiliary memory stays <= ~30% over the raw input (and
~3% at production c=128), while the LCA-profile (sparse table) explodes by
log2(n)× and becomes infeasible first.  Exact byte accounting — no timing,
so this runs at full paper scales.
"""

from __future__ import annotations

import math

from repro.core.api import RMQ
from repro.core.baselines import FullScan, SparseTable, TwoLevelBlocks
from repro.core.plan import make_plan


def run(sizes=(2**20, 2**22, 2**24, 2**26, 2**28, 2**30, 2**31)) -> list:
    rows = []
    for n in sizes:
        input_bytes = n * 4
        # plan-level accounting (no allocation -> full paper scales)
        plan = make_plan(n, c=128, t=64)
        ours_aux = plan.upper_size * 4
        plan_vl = make_plan(n, c=8, t=8)     # VL-config from paper §5.3
        ours_vl_aux = plan_vl.upper_size * 4
        sparse_aux = max(1, n.bit_length() - 1) * n * 4
        two_level_aux = math.ceil(n / 256) * 4
        rows.append({
            "n": n,
            "input_gib": input_bytes / 2**30,
            "full_scan_total_gib": input_bytes / 2**30,
            "gpu_rmq_cl_total_gib": (input_bytes + ours_aux) / 2**30,
            "gpu_rmq_vl_total_gib": (input_bytes + ours_vl_aux) / 2**30,
            "two_level_total_gib": (input_bytes + two_level_aux) / 2**30,
            "sparse_table_total_gib": (input_bytes + sparse_aux) / 2**30,
            "gpu_rmq_overhead_pct": 100 * ours_aux / input_bytes,
            "sparse_overhead_x": sparse_aux / input_bytes,
        })
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        print(
            f"memory_footprint_n{r['n']},0,"
            f"rmq={r['gpu_rmq_cl_total_gib']:.3f}GiB"
            f"|sparse={r['sparse_table_total_gib']:.3f}GiB"
            f"|overhead={r['gpu_rmq_overhead_pct']:.2f}%"
        )
    # paper claims to check:
    last = rows[-1]
    assert last["gpu_rmq_overhead_pct"] < 30.0, "paper: <= 30% overhead"
    # 24 GB GPU feasibility frontier (paper: LCA/RTXRMQ die at 2^28..2^29,
    # GPU-RMQ reaches 2^31)
    for r in rows:
        fits_ours = r["gpu_rmq_cl_total_gib"] < 24
        fits_sparse = r["sparse_table_total_gib"] < 24
        if r["n"] == 2**28:
            assert not fits_sparse, "sparse-table profile must exceed 24GB"
        if r["n"] == 2**31:
            assert fits_ours, "GPU-RMQ must still fit at 2^31 (paper §5.5)"


if __name__ == "__main__":
    main()
