"""Bulk-analytics query path: endpoint-sorted coalesced sweep vs fused.

The offline regime (Grabowski & Kowalski, "Faster batched range minimum
queries"): at 10^6+ queries per batch the right strategy stops being
per-query decomposition (``rmq_fused``) and becomes *sorting the batch
by ``(chunk(l), chunk(r))`` and answering it in coalesced passes that
share level-0 traffic across queries* (``kernels/rmq_bulk`` through
``QueryEngine.query_bulk``).  This module grew out of
``coalesced_access.py`` (paper Fig. 4): that microbenchmark shows the
memory hierarchy rewards grouped access; this one shows the query stack
harvesting the reward end to end.

Full mode sweeps batch size 2^10..2^22 over an n=2^20 index and reports
bulk vs fused ns/query per size, the measured crossover, and the
committed tuning cache's ``bulk_crossover`` for the same geometry; the
structural claims:

* bulk strictly beats fused at the large end (batch >= 2^20) — the
  acceptance criterion for the bulk path's existence;
* fused wins at the small end (2^10) — i.e. the engine's size-based
  crossover routing is load-bearing, not decorative;
* results stay bit-identical to the fused path at every probed size.

Tiny mode (CI smoke) skips the timing sweep and gates the execution
contract instead: one recorded ``rmq_bulk`` launch per bucket — a
single-bucket batch records exactly one launch, a forced two-bucket
batch exactly two, and submission-order results match the fused oracle.
Only full-mode runs refresh the committed ``BENCH_bulk.json``.
"""

from __future__ import annotations

import os

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (
    atomic_write_json,
    csv_row,
    make_input_array,
    make_span_queries,
    time_fn,
    tiny_mode,
)
from repro.core.api import RMQ
from repro.kernels.profiling import count_launches
from repro.qe import BulkExecutor, QueryEngine
from repro.tune import default_cache
from repro.tune.cache import current_platform

# Committed perf-trajectory artifact: repo-root anchored, full-mode only
# (same discipline as BENCH_query.json).
BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_bulk.json",
)

NOISE = 1.15


def run(n: int, c: int = 128, t: int = 64,
        batch_exps=range(10, 23, 2)):
    """Race fused vs bulk per batch size; returns (rows, crossover)."""
    x = jnp.asarray(make_input_array(n))
    index = RMQ.build(x, c=c, t=t, backend="fused")
    fused = QueryEngine(index, cache_size=0)
    bulk = QueryEngine(index, cache_size=0, bulk_crossover=1)
    rows = []
    crossover = None
    for e in batch_exps:
        m = 1 << e
        ls, rs = make_span_queries(n, m, c, "mixed", seed=3)
        t_fused = time_fn(lambda: fused.query(ls, rs), repeats=3)
        t_bulk = time_fn(lambda: bulk.query_bulk(ls, rs), repeats=3)
        rows.append({
            "batch": m,
            "fused_ns": t_fused / m * 1e9,
            "bulk_ns": t_bulk / m * 1e9,
        })
        if crossover is None and t_bulk < t_fused:
            crossover = m
        # parity at every probed size, not just where it's fast
        sample = np.asarray(bulk.query_bulk(ls[:4096], rs[:4096]))
        np.testing.assert_array_equal(
            sample, np.asarray(fused.query(ls[:4096], rs[:4096])))
    return rows, crossover


def check_launch_contract() -> dict:
    """One ``rmq_bulk`` launch per bucket, asserted at benchmark time.

    Fresh geometry (primes unused elsewhere) keeps the trace-time
    launch counter honest — see ``repro.kernels.profiling``.
    """
    rng = np.random.default_rng(11)
    n, c, t = 2203, 8, 8
    x = rng.integers(-4, 4, n).astype(np.float32)
    index = RMQ.build(x, c=c, t=t, backend="fused")
    engine = QueryEngine(index, cache_size=0, bulk_crossover=1)
    m = 512
    a, b = rng.integers(0, n, m), rng.integers(0, n, m)
    ls = np.minimum(a, b).astype(np.int32)
    rs = np.maximum(a, b).astype(np.int32)

    with count_launches() as counts:
        res = engine.query_bulk(ls, rs)
    if counts != {"rmq_bulk": 1}:
        raise AssertionError(
            f"a single-bucket query_bulk batch must record exactly ONE "
            f"rmq_bulk launch, recorded {counts}"
        )
    np.testing.assert_array_equal(
        np.asarray(res), np.asarray(engine.query(ls, rs)))
    single = dict(counts)

    # A batch wider than max_bucket splits into ceil(m/max) bucket
    # passes.  The counter records *traces*, so the buckets here are
    # deliberately unequal (384 -> 256 + 128): each shape must trace —
    # and record — its own single launch.  (Equal-shaped buckets
    # sharing one compilation is the desired steady state, not a gap.)
    ex = BulkExecutor(max_bucket=256)
    with count_launches() as counts:
        res2 = ex.run(index.hierarchy, ls[:384], rs[:384], "value")
    if counts != {"rmq_bulk": 2}:
        raise AssertionError(
            f"a 384-query batch over max_bucket=256 must record exactly "
            f"TWO rmq_bulk launches (256 + 128), recorded {counts}"
        )
    np.testing.assert_array_equal(res2, np.asarray(res)[:384])
    return {"single_bucket": single, "two_bucket": dict(counts)}


def main() -> dict:
    tiny = tiny_mode()
    launches = check_launch_contract()
    print("name,us_per_call,derived")
    print(csv_row(
        "bulk_launches_per_bucket", 0,
        f"single={launches['single_bucket']['rmq_bulk']}"
        f"|split={launches['two_bucket']['rmq_bulk']}",
    ))

    n, c, t = 2**20, 128, 64
    cached = default_cache().lookup(current_platform(), n, "mixed")
    cached_crossover = (
        cached.bulk_crossover if cached is not None else None
    )
    payload = {
        "benchmark": "bulk_queries",
        "tiny": tiny,
        "platform": jax.default_backend(),
        "unit": "ns_per_query",
        "geometry": {"n": n, "c": c, "t": t},
        "launch_contract": launches,
        "tuned_bulk_crossover": cached_crossover,
    }
    if tiny:
        # CI smoke: the contract above is the whole point; timing a
        # 2^22-query sweep in CI would be all noise and no signal
        return payload

    rows, crossover = run(n, c=c, t=t)
    for r in rows:
        faster = r["fused_ns"] / r["bulk_ns"]
        print(csv_row(
            f"bulk_batch{r['batch']}", r["bulk_ns"] / 1e3,
            f"fused_ns={r['fused_ns']:.1f}|bulk_ns={r['bulk_ns']:.1f}"
            f"|bulk_speedup={faster:.2f}x",
        ))
    print(csv_row(
        "bulk_crossover", 0,
        f"measured={crossover}|tuning_cache={cached_crossover}",
    ))
    payload["rows"] = rows
    payload["measured_crossover"] = crossover

    # the acceptance claims (full mode only; tiny sizes are noise)
    big = [r for r in rows if r["batch"] >= 2**20]
    assert big, rows
    for r in big:
        assert r["bulk_ns"] < r["fused_ns"], (
            "bulk must strictly beat fused at batch >= 2^20", r)
    small = next(r for r in rows if r["batch"] == 2**10)
    assert small["fused_ns"] < small["bulk_ns"] * NOISE, (
        "fused should win (or tie) at the small end — otherwise the "
        "crossover routing is pointing the wrong way", small)
    assert crossover is not None, rows

    atomic_write_json(BENCH_JSON, payload)
    print(f"# wrote {BENCH_JSON}")
    return payload


if __name__ == "__main__":
    main()
