"""Paper Fig. 16: average time per RMQ for batched queries.

Runs all methods × range-size classes (small/medium/large/mixed) over a
range of n.  Checks the paper's relative claims:

* GPU-RMQ beats Full Scan by orders of magnitude on large ranges;
* GPU-RMQ's time per query is nearly range-size independent (paper §5.8),
  unlike Full Scan (linear in range size);
* the hierarchy stays within a small factor of the O(1)-query sparse
  table while using ~100× less auxiliary memory.
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import csv_row, make_input_array, make_queries, time_fn
from repro.core.api import RMQ
from repro.core.baselines import FullScan, SparseTable


def run(sizes=(2**18, 2**20, 2**22), m=2**14, kinds=("small", "medium",
                                                     "large", "mixed")):
    rows = []
    for n in sizes:
        x = jnp.asarray(make_input_array(n))
        rmq = RMQ.build(x, c=128, t=64, backend="jax")
        sparse = SparseTable.build(x)
        full = FullScan.build(x)
        for kind in kinds:
            ls, rs = make_queries(n, m, kind)
            lsj, rsj = jnp.asarray(ls), jnp.asarray(rs)
            t_ours = time_fn(lambda: rmq.query(lsj, rsj)) / m
            t_sparse = time_fn(lambda: sparse.query_batch(lsj, rsj)) / m
            # full scan is slow: fewer queries
            mf = min(m, 512)
            lf, rf = jnp.asarray(ls[:mf]), jnp.asarray(rs[:mf])
            t_full = time_fn(lambda: full.query_batch(lf, rf),
                             repeats=3) / mf
            rows.append({
                "n": n, "kind": kind,
                "ours_ns": t_ours * 1e9,
                "sparse_ns": t_sparse * 1e9,
                "full_ns": t_full * 1e9,
            })
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        print(csv_row(
            f"throughput_n{r['n']}_{r['kind']}",
            r["ours_ns"] / 1e3,
            f"sparse={r['sparse_ns']:.0f}ns|full={r['full_ns']:.0f}ns"
            f"|vs_full={r['full_ns']/r['ours_ns']:.1f}x",
        ))
    # paper-shape claims
    big = [r for r in rows if r["n"] == max(x["n"] for x in rows)]
    large = next(r for r in big if r["kind"] == "large")
    small = next(r for r in big if r["kind"] == "small")
    assert large["full_ns"] / large["ours_ns"] > 50, (
        "hierarchy must beat full scan by >50x on large ranges at 4M",
        large,
    )
    # range-size independence (paper §5.8: GPU-RMQ behaves almost
    # identically across range sizes once n is large)
    ratio_ours = large["ours_ns"] / small["ours_ns"]
    assert ratio_ours < 10, ratio_ours
    # NOTE (hardware adaptation): the paper's Full GPU Scan slows with
    # range size because CUDA threads exit early per-query; a fixed-shape
    # masked scan on SIMD hardware does O(n) work per query regardless,
    # so range dependence does NOT reproduce for the full-scan baseline
    # here — recorded in EXPERIMENTS.md instead of asserted.


if __name__ == "__main__":
    main()
