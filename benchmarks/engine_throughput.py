"""Routed query engine vs. monolithic walk vs. fused single-launch path,
per span class (engine analogue of the paper's Fig. 16 by-range-class
throughput) — now emitting machine-readable ``BENCH_query.json`` so the
query-side perf trajectory accumulates across PRs.

Four execution strategies over the same array:

* ``monolithic`` — ``rmq_value_batch`` (every query pays the full walk,
  including the ``c·t``-entry top scan);
* ``routed``     — the PR 2 engine: host-side short/mid/long class
  split, per-class executors (``rmq_short`` direct scan, the walk, the
  hybrid O(1) top), one dispatch per class bucket;
* ``fused``      — the single-launch path (``kernels/rmq_fused``): no
  class split at all, the whole mixed batch in ONE dispatch that
  decomposes spans internally (on TPU one ``pallas_call``; off-TPU one
  jitted program whose in-program sparse top plays the VMEM-resident-top
  role);
* ``tuned``      — ``RMQ.build(c="auto", span_mix=<class>)`` over the
  committed tuning cache: geometry, backend, and planner knobs
  self-configured per workload from measured winners (the routed and
  fused columns above are exactly the candidates the autotuner raced).

Engine timings keep the result cache disabled so the measurement is
routing + execution, not cache hits.  The structural claims checked
outside ``REPRO_BENCH_TINY``:

* routed short-span batches beat the full walk (PR 2's claim, kept);
* the fused path is at least as fast as the routed engine on long
  spans (small slack for host-side timing noise) — the class split must
  never *beat* the kernel that subsumes it;
* the tuned engine's per-class choice is never slower than the fixed
  ``(c=128, t=64)`` routed default for ANY span class, beats (or
  matches within noise) the committed fused mixed-batch baseline, and
  beats the fused short-class number by routing — the autotuner must
  actually exploit the routed/fused crossover, not merely exist;
* a fused-backend batch records exactly ONE ``rmq_fused`` launch — this
  contract check runs in tiny mode too and *hard-fails* the job when a
  refactor sneaks a second dispatch in.

``REPRO_BENCH_TINY=1`` shrinks sizes for the CI smoke run.
"""

from __future__ import annotations

import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (
    atomic_write_json,
    csv_row,
    make_input_array,
    make_span_queries,
    time_fn,
    tiny_mode,
)
from repro.core.api import RMQ
from repro.core.query import rmq_value_batch
from repro.kernels.profiling import count_launches
from repro.tune import default_cache

# Committed perf-trajectory artifact: anchored at the repo root (not the
# CWD) and refreshed only by full-mode runs — a tiny/CI smoke run must
# never clobber curated full-mode numbers.
BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_query.json",
)

# Slack for committed-baseline and cross-strategy comparisons: CPU
# wall-clock on a shared container lands within ~10-15% run to run, so
# the gates catch real regressions (a wrong routing choice costs 2x+)
# without refereeing coin flips.
NOISE = 1.15


def run(n: int, m: int, c: int = 128, t: int = 64, tuning=None):
    x = jnp.asarray(make_input_array(n))
    rmq = RMQ.build(x, c=c, t=t, backend="jax")
    routed = rmq.engine(cache_size=0)
    rmq_fused = RMQ.build(x, c=c, t=t, backend="fused")
    fused = rmq_fused.engine(cache_size=0)
    cache = tuning if tuning is not None else default_cache()
    rows = []
    tuned_configs = {}
    for kind in ("short", "mid", "long", "mixed"):
        ls, rs = make_span_queries(n, m, c, kind)
        lsj, rsj = jnp.asarray(ls), jnp.asarray(rs)
        # per-class self-configured engine: geometry + backend + planner
        # knobs resolved from the cache for THIS span mix (falls back to
        # the fixed default on a cache miss, i.e. tuned == routed)
        tuned = RMQ.build(
            x, c="auto", span_mix=kind, tuning=cache
        ).engine(cache_size=0)
        tuned_configs[kind] = tuned.tuned
        t_mono = time_fn(
            lambda: rmq_value_batch(rmq.hierarchy, lsj, rsj), repeats=3
        )
        t_routed = time_fn(lambda: routed.query(ls, rs), repeats=3)
        t_fused = time_fn(lambda: fused.query(ls, rs), repeats=3)
        t_tuned = time_fn(lambda: tuned.query(ls, rs), repeats=3)
        rows.append({
            "kind": kind,
            "mono_ns": t_mono / m * 1e9,
            "routed_ns": t_routed / m * 1e9,
            "fused_ns": t_fused / m * 1e9,
            "tuned_ns": t_tuned / m * 1e9,
        })
    return rows, routed, fused, tuned_configs


def check_single_launch() -> dict:
    """The 1-launch contract, asserted at benchmark time (tiny included).

    Geometry is unique to this check so the trace-time launch counter
    is fresh (see ``repro.kernels.profiling``).  Raises — failing the
    benchmark job — if a fused-backend batch ever records more than one
    ``rmq_fused`` launch.
    """
    rng = np.random.default_rng(7)
    n, c, t = 5003, 8, 8
    x = rng.random(n).astype(np.float32)
    engine = RMQ.build(x, c=c, t=t, backend="fused").engine(cache_size=0)
    ls, rs = make_span_queries(n, 512, c, "mixed")
    with count_launches() as counts:
        engine.query(ls, rs)
    if counts != {"rmq_fused": 1}:
        raise AssertionError(
            f"fused-backend batch must record exactly ONE rmq_fused "
            f"launch, recorded {counts}"
        )
    return dict(counts)


def main() -> dict:
    tiny = tiny_mode()
    if tiny:
        # small n with a small chunk keeps a big (1024-entry) top level,
        # and enough queries to amortize the engine's per-batch host
        # work, so the routed-vs-walk ordering survives the reduction
        n, m, c, t = 2**14, 4096, 16, 64
    else:
        n, m, c, t = 2**18, 8192, 128, 64
    # the committed trajectory is the acceptance baseline — read it
    # BEFORE this run overwrites it
    committed = None
    if os.path.exists(BENCH_JSON):
        with open(BENCH_JSON) as f:
            committed = json.load(f)

    rows, routed, fused, tuned_configs = run(n=n, m=m, c=c, t=t)
    launches = check_single_launch()

    print("name,us_per_call,derived")
    for r in rows:
        print(csv_row(f"engine_monolithic_{r['kind']}",
                      r["mono_ns"] / 1e3, ""))
        print(csv_row(
            f"engine_routed_{r['kind']}", r["routed_ns"] / 1e3,
            f"speedup={r['mono_ns'] / r['routed_ns']:.2f}x",
        ))
        print(csv_row(
            f"engine_fused_{r['kind']}", r["fused_ns"] / 1e3,
            f"speedup={r['mono_ns'] / r['fused_ns']:.2f}x",
        ))
        cfg = tuned_configs[r["kind"]] or {}
        print(csv_row(
            f"engine_tuned_{r['kind']}", r["tuned_ns"] / 1e3,
            f"speedup={r['mono_ns'] / r['tuned_ns']:.2f}x"
            f"|c={cfg.get('c')}|backend={cfg.get('backend')}"
            f"|source={cfg.get('source')}",
        ))
    cc = routed.stats()["class_counts"]
    print(csv_row(
        "engine_class_split", 0,
        f"short={cc['short']}|mid={cc['mid']}|long={cc['long']}",
    ))
    print(csv_row("fused_launches_per_batch", 0,
                  f"rmq_fused={launches['rmq_fused']}"))

    payload = {
        "benchmark": "engine_throughput",
        "tiny": tiny,
        "platform": jax.default_backend(),
        "fused_lowering": (
            "pallas_kernel" if jax.default_backend() == "tpu"
            else "jnp_one_dispatch"
        ),
        "geometry": {"n": n, "m": m, "c": c, "t": t},
        "unit": "ns_per_query",
        "rows": rows,
        "routed_class_counts": {k: int(v) for k, v in cc.items()},
        "fused_launches_per_batch": launches,
        "tuned_configs": tuned_configs,
    }
    if not tiny:
        # tiny-mode numbers are meaningless for the trajectory; only
        # full-mode runs refresh the committed artifact
        atomic_write_json(BENCH_JSON, payload)
        print(f"# wrote {BENCH_JSON}")

    # structural claims — not checked at REPRO_BENCH_TINY sizes, where
    # margins are noise-level and CI would flake (the smoke run guards
    # bit-rot + the launch contract only, same policy as before).
    if not tiny:
        short = next(r for r in rows if r["kind"] == "short")
        assert short["routed_ns"] < short["mono_ns"], short
        # fused >= routed on long spans, as a REGRESSION guard: on CPU
        # both paths are one dispatch + an O(1) top, so repeated runs
        # land within host noise of each other (observed both ~0.8x
        # and ~1.13x under load) — the slack is sized to catch the
        # real failure mode (losing the O(1) top puts fused at >3x
        # routed), not to referee a coin flip.  On TPU the kernel's
        # single-launch margin is the measurement of interest.
        long_ = next(r for r in rows if r["kind"] == "long")
        assert long_["fused_ns"] <= long_["routed_ns"] * 1.5, long_
        # the structural CPU win is the mixed batch: routed pays one
        # dispatch per span class, fused exactly one per bucket
        mixed = next(r for r in rows if r["kind"] == "mixed")
        assert mixed["fused_ns"] <= mixed["routed_ns"] * 1.25, mixed

        # -- the autotuner acceptance gate ----------------------------
        # (1) per-class: the tuned choice is never slower than the
        # fixed (c=128, t=64) routed default, for ANY span class
        for r in rows:
            assert r["tuned_ns"] <= r["routed_ns"] * NOISE, (
                "tuned engine slower than the fixed routed default",
                r, tuned_configs[r["kind"]],
            )
        short = next(r for r in rows if r["kind"] == "short")
        # (2) mixed batches: at least match this run's fused number
        # (the strategy the tuner must pick or beat for the mix)
        assert mixed["tuned_ns"] <= mixed["fused_ns"] * NOISE, (
            mixed, tuned_configs["mixed"])
        # (3) short batches: beat the fused path by ROUTING — the
        # crossover the fixed-strategy engines leave on the table
        assert short["tuned_ns"] < short["fused_ns"], (
            short, tuned_configs["short"])
        # (4) committed-baseline trajectory: never regress past noise
        # against the curated full-mode numbers (same platform only)
        if (committed and not committed.get("tiny")
                and committed.get("platform") == payload["platform"]
                and committed.get("geometry", {}).get("n") == n):
            prev = {r["kind"]: r for r in committed["rows"]}
            assert (mixed["tuned_ns"]
                    <= prev["mixed"]["fused_ns"] * NOISE), (
                "tuned mixed regressed vs committed fused baseline",
                mixed, prev["mixed"],
            )
            assert (short["tuned_ns"]
                    <= prev["short"]["fused_ns"] * NOISE), (
                "tuned short regressed vs committed fused baseline",
                short, prev["short"],
            )
    return payload


if __name__ == "__main__":
    main()
