"""Routed query engine vs. monolithic walk, per span class (engine analogue
of the paper's Fig. 16 by-range-class throughput).

The monolithic walk costs a constant ``2c(L-1) + ct`` scanned entries
per query regardless of span.  The engine routes by span: short
(two-chunk) queries skip the hierarchy via ``rmq_short``; long queries
replace the ``ct``-entry top scan with the hybrid's O(1) sparse-table
lookup; mid queries take the unchanged walk.  Per class we time

* ``monolithic`` — ``rmq_value_batch`` (every query pays the full walk);
* ``engine``     — ``RMQ.engine()`` with the result cache disabled, so
  the measurement is pure routing + padded-bucket execution, not cache
  hits.

Geometry is the facade default (c=128, t=64): the cutoff t=64 keeps the
hierarchy shallow at the price of a top level scanned on every walk —
which is precisely the work routing avoids (short spans never reach it,
long spans replace it with two loads).  Note the engine timing includes
its host-side orchestration (classify/pack/scatter), so the speedups
are end-to-end, not kernel-only.  With a 2-level plan the planner's mid
class is structurally empty (any beyond-short query reaches the top),
so the class split reports short + long.

The structural claim checked: routed short-span batches beat the full
walk (an ordering claim, valid on CPU and TPU alike).

``REPRO_BENCH_TINY=1`` shrinks sizes for the CI smoke run (keeping a
proportionally large top so the ordering claim stays meaningful).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import csv_row, make_input_array, time_fn, tiny_mode
from repro.core.api import RMQ
from repro.core.query import rmq_value_batch


def make_span_queries(n: int, m: int, c: int, kind: str, seed: int = 1):
    """Bounds with spans pinned inside one engine class."""
    rng = np.random.default_rng(seed)
    if kind == "short":
        # at most two aligned c-chunks
        s = rng.integers(1, c + 2, m)
    elif kind == "mid":
        s = rng.integers(4 * c, min(16 * c, n), m)
    elif kind == "long":
        s = rng.integers(n // 2, n + 1, m)
    elif kind == "mixed":
        parts = [make_span_queries(n, m // 3 + 1, c, k, seed + i)[0:2]
                 for i, k in enumerate(("short", "mid", "long"))]
        ls = np.concatenate([p[0] for p in parts])[:m]
        rs = np.concatenate([p[1] for p in parts])[:m]
        order = rng.permutation(m)
        return ls[order], rs[order]
    else:
        raise ValueError(kind)
    ls = (rng.random(m) * (n - s + 1)).astype(np.int64)
    rs = ls + s - 1
    return ls.astype(np.int32), rs.astype(np.int32)


def run(n: int, m: int, c: int = 128, t: int = 64):
    x = jnp.asarray(make_input_array(n))
    rmq = RMQ.build(x, c=c, t=t, backend="jax")
    engine = rmq.engine(cache_size=0)
    rows = []
    for kind in ("short", "mid", "long", "mixed"):
        ls, rs = make_span_queries(n, m, c, kind)
        lsj, rsj = jnp.asarray(ls), jnp.asarray(rs)
        t_mono = time_fn(
            lambda: rmq_value_batch(rmq.hierarchy, lsj, rsj), repeats=3
        )
        t_eng = time_fn(lambda: engine.query(ls, rs), repeats=3)
        rows.append({
            "kind": kind,
            "mono_ns": t_mono / m * 1e9,
            "engine_ns": t_eng / m * 1e9,
        })
    return rows, engine


def main() -> None:
    if tiny_mode():
        # small n with a small chunk keeps a big (1024-entry) top level,
        # and enough queries to amortize the engine's per-batch host
        # work, so the routed-vs-walk ordering survives the reduction
        rows, engine = run(n=2**14, m=4096, c=16, t=64)
    else:
        rows, engine = run(n=2**18, m=8192)
    print("name,us_per_call,derived")
    for r in rows:
        speedup = r["mono_ns"] / r["engine_ns"]
        print(csv_row(f"engine_monolithic_{r['kind']}",
                      r["mono_ns"] / 1e3, ""))
        print(csv_row(f"engine_routed_{r['kind']}",
                      r["engine_ns"] / 1e3, f"speedup={speedup:.2f}x"))
    cc = engine.stats()["class_counts"]
    print(csv_row(
        "engine_class_split", 0,
        f"short={cc['short']}|mid={cc['mid']}|long={cc['long']}",
    ))
    # structural claim: the short-span direct scan beats the full walk.
    # Not checked at REPRO_BENCH_TINY sizes, where the margin is
    # noise-level and CI would flake — the smoke run guards bit-rot
    # only (same policy as query_assignment).
    if not tiny_mode():
        short = next(r for r in rows if r["kind"] == "short")
        assert short["engine_ns"] < short["mono_ns"], short


if __name__ == "__main__":
    main()
