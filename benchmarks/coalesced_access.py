"""Paper Fig. 4: the access-coalescing microbenchmark, TPU/CPU analogue.

Paper setup: 2^25 threads in groups of 2^m adjacent threads; each group
reads 2^m consecutive entries at a random position (coalesced into one
transaction) — doubling group size halves runtime up to the transaction
width.

Memory-hierarchy analogue here: gather `total` f32 entries from a 2^24
array as `total / 2^m` random blocks of 2^m consecutive entries.  Larger
blocks ⇒ fewer distinct cache lines / DMA descriptors ⇒ faster, saturating
at the transfer-granule size (GPU: 128 B transaction; TPU: (8,128) tile;
CPU here: 64 B cache line × prefetch streams).  The claim checked is the
paper's *shape*: monotone speedup with group size, flattening past the
hardware granule.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn


def run(n=2**24, total=2**22, max_group_exp=8):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.random(n, dtype=np.float32))
    rows = []
    for m in range(0, max_group_exp + 1):
        g = 1 << m
        groups = total // g
        starts = rng.integers(0, n - g, groups).astype(np.int32)
        idx = (starts[:, None] + np.arange(g, dtype=np.int32)[None, :])
        idxj = jnp.asarray(idx.reshape(-1))

        fn = jax.jit(lambda i: jnp.take(x, i).sum())
        t = time_fn(lambda: fn(idxj), repeats=3)
        rows.append({"group": g, "ms": t * 1e3})
    return rows


def main():
    rows = run()
    print("name,us_per_call,derived")
    base = rows[0]["ms"]
    for r in rows:
        print(csv_row(
            f"coalesced_group{r['group']}",
            r["ms"] * 1e3,
            f"speedup_vs_g1={base/r['ms']:.2f}x",
        ))
    # paper-shape claim: grouped access must be substantially faster than
    # fully random scalar access, monotonically (allowing 15% noise)
    assert rows[-1]["ms"] < rows[0]["ms"] / 2, rows
    for a, b in zip(rows, rows[1:]):
        assert b["ms"] < a["ms"] * 1.15, (a, b)


if __name__ == "__main__":
    main()
