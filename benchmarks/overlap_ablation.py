"""Paper Fig. 13 analogue: is a *hybrid* top-level engine worth it?

The paper replaces the top hierarchy level with an RT-core triangle scene
and finds the OptiX overhead negates the benefit (§5.4).  TPUs have no
second compute engine (DESIGN.md §2.1), so the faithful analogue asks the
same *design question* with TPU-available mechanisms:

  (a) unified      — top level scanned inside the same query pass (ours);
  (b) two-phase    — the query pass plus a separate dispatched call over
                     its results (models handing the top level to a
                     different engine: extra dispatch + intermediate
                     materialization — the OptiX-overhead analogue);
  (c) hybrid-index — replace the top-level scan with a sparse-table O(1)
                     lookup structure (a different index for the top —
                     the closest analogue of the BVH top): we report its
                     *extra build cost* and the top level's size, which
                     bound the best case.

Expected reproduction of the paper's negative result: (b) never beats (a)
— the top level is tiny and VMEM/cache-resident, so there is nothing for
a second engine to win back, and its dispatch overhead is pure loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, make_input_array, make_queries, time_fn
from repro.core.api import RMQ
from repro.core.baselines import SparseTable
from repro.core.hierarchy import build_hierarchy
from repro.core.plan import make_plan
from repro.core.query import _rmq_batch


def run(n=2**22, m=2**13, c=128, t=64):
    x = jnp.asarray(make_input_array(n))
    plan = make_plan(n, c=c, t=t)
    h = build_hierarchy(x, plan)
    ls, rs = make_queries(n, m, "mixed")
    lsj, rsj = jnp.asarray(ls), jnp.asarray(rs)

    # (a) unified
    rmq = RMQ(hierarchy=h, backend="jax")
    t_unified = time_fn(lambda: rmq.query(lsj, rsj))

    # (b) two-phase: full pass + a separate dispatched combine step
    @jax.jit
    def phase1(ls, rs):
        mvals, _ = _rmq_batch(plan, h.base, h.upper, None, ls, rs,
                              track_pos=False)
        return mvals

    @jax.jit
    def phase2(vals):  # stands in for the separate top-engine dispatch
        return jnp.minimum(vals, jnp.inf)

    t_twophase = time_fn(lambda: phase2(phase1(lsj, rsj)))

    # (c) hybrid-index: sparse-table top (core/hybrid.py), larger t so
    # the O(1) top replaces a whole level (paper §4.5 implication (1))
    from repro.core.hybrid import HybridRMQ

    hyb = HybridRMQ.build(x, c=c, t=max(t * 16, 1024))
    t_hybrid = time_fn(lambda: hyb.query(lsj, rsj))
    top_off, top_len = plan.offsets[-1], plan.padded_lens[-1]
    top = h.upper[top_off : top_off + top_len]
    t_hybrid_build = time_fn(lambda: SparseTable.build(top).table, repeats=3)

    return {
        "unified_ns": t_unified / m * 1e9,
        "two_phase_ns": t_twophase / m * 1e9,
        "hybrid_ns": t_hybrid / m * 1e9,
        "hybrid_levels": hyb.plan.num_levels,
        "unified_levels": plan.num_levels,
        "top_sparse_build_ms": t_hybrid_build * 1e3,
        "top_len": int(top_len),
    }


def main():
    r = run()
    print("name,us_per_call,derived")
    print(csv_row("overlap_unified", r["unified_ns"] / 1e3, ""))
    print(csv_row("overlap_two_phase", r["two_phase_ns"] / 1e3,
                  f"overhead={r['two_phase_ns']/r['unified_ns']:.2f}x"))
    print(csv_row("overlap_hybrid_sparse_top", r["hybrid_ns"] / 1e3,
                  f"levels={r['hybrid_levels']}vs{r['unified_levels']}"
                  f"|vs_unified={r['hybrid_ns']/r['unified_ns']:.2f}x"))
    print(csv_row("overlap_top_sparse_build", r["top_sparse_build_ms"] * 1e3,
                  f"top_len={r['top_len']}"))
    # the paper's negative result: the separate-engine dispatch adds
    # overhead instead of speedup
    assert r["two_phase_ns"] >= r["unified_ns"] * 0.95, r


if __name__ == "__main__":
    main()
