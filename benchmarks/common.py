"""Shared benchmark utilities: timing, the paper's workload generators.

Workloads follow paper §5.1:
* input arrays: i.i.d. uniform [0, 1) float32;
* query range-size classes — large (uniform in [1, n]),
  medium (log-normal, mu = ln(n^0.6), sigma = 0.3),
  small (log-normal, mu = ln(n^0.3), sigma = 0.3),
  mixed (equal thirds);
* left borders uniform in [0, n - s].

Timings are wall-clock medians over repeats with a warmup call
(block_until_ready), reported as ns/query like the paper's "time per RMQ".
This container is CPU-only, so absolute numbers are NOT the paper's GPU
numbers — benchmarks reproduce the paper's *relative* claims (scaling
shapes, method orderings, parameter trade-offs) and the harness runs
unchanged on a TPU host.
"""

from __future__ import annotations

import os
import time
from typing import Callable, Dict, Tuple

import numpy as np
import jax


def tiny_mode() -> bool:
    """CI-smoke size reduction (``REPRO_BENCH_TINY=1``)."""
    return os.environ.get("REPRO_BENCH_TINY", "0") not in ("", "0")


def time_fn(fn: Callable, repeats: int = 5) -> float:
    """Median wall-clock seconds of fn() with one warmup."""
    out = fn()
    jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def make_input_array(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.random(n, dtype=np.float32)


def make_queries(
    n: int, m: int, kind: str = "mixed", seed: int = 1
) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)

    def sizes(kind, count):
        if kind == "large":
            return rng.integers(1, n + 1, count)
        if kind == "medium":
            s = rng.lognormal(np.log(n ** 0.6), 0.3, count)
            return np.clip(s.astype(np.int64), 1, n)
        if kind == "small":
            s = rng.lognormal(np.log(n ** 0.3), 0.3, count)
            return np.clip(s.astype(np.int64), 1, n)
        if kind == "mixed":
            parts = [sizes(k, count // 3 + 1)
                     for k in ("large", "medium", "small")]
            s = np.concatenate(parts)[:count]
            rng.shuffle(s)
            return s
        raise ValueError(kind)

    s = sizes(kind, m)
    ls = (rng.random(m) * (n - s + 1)).astype(np.int64)
    rs = ls + s - 1
    return ls.astype(np.int32), rs.astype(np.int32)


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.4f},{derived}"
