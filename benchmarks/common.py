"""Shared benchmark utilities (thin caller over ``repro.tune.measure``).

The timing discipline and the paper §5.1 workload generators moved into
:mod:`repro.tune.measure` so the autotuner and the benchmarks share ONE
implementation — the tuning cache is built from exactly the numbers the
benchmarks report.  This module keeps the benchmark-only helpers
(tiny-mode detection, CSV formatting) and re-exports the rest for
existing callers.

This container is CPU-only, so absolute numbers are NOT the paper's GPU
numbers — benchmarks reproduce the paper's *relative* claims (scaling
shapes, method orderings, parameter trade-offs) and the harness runs
unchanged on a TPU host.
"""

from __future__ import annotations

import json
import os

from repro.tune.measure import (  # noqa: F401  (re-exports)
    make_input_array,
    make_queries,
    make_span_queries,
    time_fn,
)


def tiny_mode() -> bool:
    """CI-smoke size reduction (``REPRO_BENCH_TINY=1``)."""
    return os.environ.get("REPRO_BENCH_TINY", "0") not in ("", "0")


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.4f},{derived}"


def atomic_write_json(path: str, payload: dict) -> None:
    """Write a committed baseline atomically (tmp + ``os.replace``).

    The ``BENCH_*.json`` files gate later runs: a full-mode run killed
    mid-write must leave the previous baseline intact, never a truncated
    JSON that fails every subsequent comparison.
    """
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    os.replace(tmp, path)
