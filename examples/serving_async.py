"""Async serving tier demo: two tenants, two SLOs, one live mutator.

    PYTHONPATH=src python examples/serving_async.py

A single event loop serves two tenants from one fused-backend
``QueryService``:

* ``trading`` — tight 2ms SLO, small bursts of point-ish range-min
  probes (latency-sensitive);
* ``analytics`` — relaxed 25ms SLO, bigger mixed value/index scans
  (throughput-shaped: the deadline batcher coalesces many requests into
  one fused launch).

A background task mutates the ``analytics`` array the whole time —
updates stage in O(1) and swap in *between* flushes, so every response
is bit-identical to some single generation of the array (checked here
against numpy replays of the staged mutations: snapshot isolation as an
assertion, not a slogan).
"""

import asyncio

import numpy as np

from repro.core.api import RMQ
from repro.serving import ServingTier
from repro.serving.aio import AsyncServingTier


def build_tier(n: int = 1 << 14, seed: int = 0):
    """Two fused-backend tenants on one tier (reduced sizes in tests)."""
    rng = np.random.default_rng(seed)
    trading = rng.integers(-1000, 1000, n).astype(np.float32)
    analytics = rng.integers(-1000, 1000, n).astype(np.float32)
    tier = ServingTier(idle_tick=0.002)
    tier.register_tenant(
        "trading",
        RMQ.build(trading, c=64, t=16, with_positions=True,
                  backend="fused"),
        slo_ms=2.0, max_queue=4096,
    )
    tier.register_tenant(
        "analytics",
        RMQ.build(analytics, c=64, t=16, with_positions=True,
                  backend="fused"),
        slo_ms=25.0, max_queue=8192,
    )
    return tier, trading, analytics


def oracle_snapshots(base: np.ndarray, mutations):
    """generation -> array, replaying the staged mutation log."""
    snaps = {0: base.copy()}
    arr = base.copy()
    for gen, (idxs, vals) in enumerate(mutations, start=1):
        arr = arr.copy()
        arr[np.asarray(idxs)] = np.asarray(vals)
        snaps[gen] = arr
    return snaps


async def run(n: int = 1 << 14, rounds: int = 40, seed: int = 0):
    tier, trading, analytics = build_tier(n, seed)
    aio = AsyncServingTier(tier)
    rng = np.random.default_rng(seed + 1)
    stop = asyncio.Event()
    pump = asyncio.create_task(aio.pump(stop))
    mutation_log = []

    async def mutator():
        """Stages an update batch every ~5ms for the analytics tenant."""
        while not stop.is_set():
            idxs = rng.integers(0, n, 32).astype(np.int32)
            vals = rng.integers(-1000, 1000, 32).astype(np.float32)
            mutation_log.append((idxs.copy(), vals.copy()))
            aio.update("analytics", idxs, vals)
            await asyncio.sleep(0.005)

    async def trading_client():
        checked = 0
        for _ in range(rounds):
            ls = rng.integers(0, n - 64, 4).astype(np.int32)
            rs = ls + rng.integers(1, 64, 4).astype(np.int32)
            t = aio.submit("trading", ls, rs)
            res = np.asarray(await aio.wait(t))
            for l, r, v in zip(ls, rs, res):
                assert v == trading[l:r + 1].min()   # tenant is unmutated
            checked += len(ls)
            await asyncio.sleep(0.001)
        return checked

    async def analytics_client():
        """Mixed value/index scans, verified against the generation the
        tier answered from — the pinned snapshot, not the live array."""
        log = []
        span = min(2048, n // 2)
        for _ in range(rounds):
            ls = rng.integers(0, n - span, 16).astype(np.int32)
            rs = ls + rng.integers(16, span, 16).astype(np.int32)
            op = "index" if rng.random() < 0.5 else "value"
            t = aio.submit("analytics", ls, rs, op=op)
            log.append((t, ls, rs, op, np.asarray(await aio.wait(t))))
            await asyncio.sleep(0.002)
        return log

    mut = asyncio.create_task(mutator())
    n_trading, analytics_log = await asyncio.gather(
        trading_client(), analytics_client()
    )
    stop.set()
    await asyncio.gather(pump, mut)

    # -- snapshot-isolation differential: every analytics answer must be
    # bit-identical to the QUIESCED oracle at the ticket's generation
    snaps = oracle_snapshots(analytics, mutation_log)
    for t, ls, rs, op, res in analytics_log:
        arr = snaps[t.generation]
        for l, r, v in zip(ls, rs, res):
            want = (arr[l:r + 1].min() if op == "value"
                    else l + int(np.argmin(arr[l:r + 1])))
            assert v == want, (t.generation, op, l, r, v, want)

    stats = tier.stats()
    return {
        "stats": stats,
        "trading_checked": n_trading,
        "analytics_requests": len(analytics_log),
        "generations_seen": sorted(
            {t.generation for t, *_ in analytics_log}
        ),
    }


def main():
    out = asyncio.run(run())
    for name in ("trading", "analytics"):
        t = out["stats"]["tenants"][name]
        print(
            f"tenant {name:10s} submits={t['submits']:4d} "
            f"flushes={t['flushes']:4d} "
            f"swaps={t['snapshot_swaps']:3d} "
            f"p50={t['latency_s']['p50'] * 1e3:6.2f}ms "
            f"p99={t['latency_s']['p99'] * 1e3:6.2f}ms"
        )
    gens = out["generations_seen"]
    print(
        f"analytics answered from {len(gens)} snapshot generations "
        f"(first {gens[0]}, last {gens[-1]}); every answer bit-identical "
        "to its generation's quiesced oracle — snapshot isolation OK"
    )


if __name__ == "__main__":
    main()
