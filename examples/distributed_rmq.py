"""Distributed RMQ: shard a large array across a device mesh and answer
query batches with per-segment hierarchies + a min all-reduce.

    PYTHONPATH=src python examples/distributed_rmq.py

On this CPU container the mesh uses 8 fake devices (set before jax
import); on a real pod the same code runs on the production mesh from
repro.launch.mesh.  This is the piece that removes the paper's single-GPU
memory ceiling: capacity scales linearly in devices, communication per
batch is one all-reduce(min) of (batch,) floats — independent of n.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.distributed import DistributedRMQ


def main():
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    n = 1 << 22  # 4M elements across 4 segments
    x = rng.random(n, dtype=np.float32)

    d = DistributedRMQ.build(x, mesh, segment_axis="model",
                             query_axes=("data",), c=128, t=32,
                             with_positions=True)
    print(f"n = {n} sharded into {mesh.shape['model']} segments of "
          f"{d.local_plan.n}; per-device footprint "
          f"{d.memory_bytes_per_device() / 2**20:.1f} MiB")

    m = 1 << 12
    ls = rng.integers(0, n, m)
    rs = np.minimum(ls + rng.integers(1, n, m), n - 1)
    ls, rs = np.minimum(ls, rs), np.maximum(ls, rs)

    vals = np.asarray(d.query(ls, rs))
    idxs = np.asarray(d.query_index(ls, rs))
    # spot check vs naive
    for i in rng.integers(0, m, 16):
        seg = x[ls[i]:rs[i] + 1]
        assert vals[i] == seg.min()
        assert idxs[i] == ls[i] + int(np.argmin(seg))
    print(f"answered {m} cross-segment queries; spot-checks OK")
    print(f"example: RMQ({ls[0]}, {rs[0]}) = {vals[0]:.6f} @ {idxs[0]} "
          f"(spans segments {ls[0] // d.segment_capacity}.."
          f"{rs[0] // d.segment_capacity})")

    # --- sharded streaming: updates routed to their owning segment ------
    upd_at = rng.integers(0, n, 4096).astype(np.int32)
    d = d.update(upd_at, np.full(4096, 0.5, np.float32))
    d = d.update(np.array([n // 3], np.int32),
                 np.array([-1.0], np.float32))
    v, p = d.query(np.array([0]), np.array([n - 1])), \
        d.query_index(np.array([0]), np.array([n - 1]))
    assert float(v[0]) == -1.0 and int(p[0]) == n // 3
    print(f"sharded update batch applied (generation {d.generation}); "
          f"global min now {float(v[0])} @ {int(p[0])}")

    # --- engine routing: contained spans skip the all-reduce ------------
    engine = d.engine()
    ev = np.asarray(engine.query(ls, rs))
    ep = np.asarray(engine.query_index(ls, rs))
    ov = np.asarray(d.query(ls, rs))
    op = np.asarray(d.query_index(ls, rs))
    assert (ev == ov).all() and (ep == op).all()
    cc = engine.stats()["class_counts"]
    print(f"engine routed {cc['seg_local']} spans segment-locally "
          f"(no all-reduce) and {cc['crossing']} through the pmin path; "
          "bit-identical to the monolithic oracle")


if __name__ == "__main__":
    main()
