"""Minimap2-style anchor chaining accelerated with batched RMQ_index.

The paper's §1 motivation: minimap2's chaining module solves RMQs and
costs up to 35% of the aligner's runtime.  Chaining DP over anchors
(sorted by reference position):

    score[i] = max_{j < i, x_i - x_j <= G} score[j] + match - gap(i, j)

With a linear gap cost g·(x_i - x_j) the recurrence folds into

    score[i] = (max_{j in window} score[j] + g·x_j) + match - g·x_i

so the inner max is a range-MAX query over the *transformed* running
score array h[j] = score[j] + g·x_j — a range-MIN query on -h, answered
here with the GPU-RMQ hierarchy in *generations*: anchors are processed
in blocks; the hierarchy over all previous blocks' h-values is rebuilt
once per block (construction is the paper's cheap operation, §5.6), and
within a block one batch of RMQ_index queries finds every anchor's best
predecessor at once.

    PYTHONPATH=src python examples/chaining.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import RMQ


def make_anchors(n=4096, seed=0):
    """Synthetic anchors: a few true chains + noise, sorted by position."""
    rng = np.random.default_rng(seed)
    xs = []
    for start in rng.integers(0, 80_000, 6):  # 6 true chains
        step = rng.integers(15, 40)
        xs.append(start + np.arange(n // 8) * step)
    xs.append(rng.integers(0, 100_000, n - len(xs) * (n // 8)))
    x = np.sort(np.concatenate(xs)[:n])
    return x.astype(np.int64)


def chain_scores_rmq(x, match=20, gap_coef=0.01, window=5000, block=256):
    """Blocked chaining DP with batched RMQ over the score prefix."""
    n = len(x)
    score = np.full(n, float(match), dtype=np.float32)
    best_pred = np.full(n, -1, dtype=np.int64)
    total_queries = 0

    for lo in range(block, n, block):
        hi = min(lo + block, n)
        # hierarchy over h = score + g·x (negated: RMQ_index == arg MAX h)
        h = score[:lo] + gap_coef * x[:lo].astype(np.float32)
        rmq = RMQ.build(-h, c=64, t=16, with_positions=True,
                        backend="jax")
        # one query per anchor in the block: predecessors within `window`
        ls = np.searchsorted(x[:lo], x[lo:hi] - window).astype(np.int32)
        rs = np.minimum(
            np.searchsorted(x[:lo], x[lo:hi], side="left") - 1, lo - 1
        ).astype(np.int32)
        valid = rs >= ls
        ls_q = np.where(valid, ls, 0)
        rs_q = np.where(valid, np.maximum(rs, ls_q), 0)
        pred = np.asarray(rmq.query_index(jnp.asarray(ls_q),
                                          jnp.asarray(rs_q)))
        total_queries += int(valid.sum())

        for k, i in enumerate(range(lo, hi)):
            # (a) best predecessor in the frozen prefix, via batched RMQ
            cands = []
            if valid[k]:
                j = int(pred[k])
                cands.append((score[j] + match
                              - gap_coef * (x[i] - x[j]), j))
            # (b) best predecessor inside the live block (a block is tiny
            # — this is the part a frozen hierarchy cannot answer; the
            # paper's static-batched regime maps to the prefix part)
            base = np.searchsorted(x[lo:i], x[i] - window) + lo
            if base < i:
                h_live = score[base:i] + gap_coef * x[base:i].astype(
                    np.float32)
                jl = base + int(np.argmax(h_live))
                cands.append((score[jl] + match
                              - gap_coef * (x[i] - x[jl]), jl))
            for cand, j in cands:
                if cand > score[i]:
                    score[i] = cand
                    best_pred[i] = j
    return score, best_pred, total_queries


def chain_scores_naive(x, match=20, gap_coef=0.01, window=5000):
    n = len(x)
    score = np.full(n, float(match), dtype=np.float32)
    for i in range(1, n):
        lo = np.searchsorted(x[:i], x[i] - window)
        if lo < i:
            h = score[lo:i] + gap_coef * x[lo:i].astype(np.float32)
            j = lo + int(np.argmax(h))
            cand = score[j] + match - gap_coef * (x[i] - x[j])
            if cand > score[i]:
                score[i] = cand
    return score


def main():
    x = make_anchors(n=2048)
    score, pred, nq = chain_scores_rmq(x)
    print(f"chained {len(x)} anchors with {nq} batched RMQ_index queries")
    print(f"best chain score: {score.max():.1f} "
          f"(singleton score = 20.0)")

    # correctness note: blocked RMQ uses scores frozen at block start — a
    # standard DP relaxation; verify it still recovers long chains
    naive = chain_scores_naive(x)
    print(f"naive DP best: {naive.max():.1f}")
    assert score.max() > 5 * 20, "must find chains much better than "\
        "singletons"
    ratio = score.max() / naive.max()
    print(f"blocked-RMQ / exact-DP score ratio: {ratio:.2f} "
          "(cross-block links see block-start scores — the standard "
          "generational relaxation)")
    assert ratio >= 0.8, (score.max(), naive.max())
    # trace back the best chain
    i = int(score.argmax())
    chain = []
    while i >= 0 and len(chain) < 10:
        chain.append(int(x[i]))
        i = int(pred[i])
    print(f"best chain tail positions: {chain[::-1]}")


if __name__ == "__main__":
    main()
