"""Adaptive batched query engine: span routing, caching, multi-index serving.

    PYTHONPATH=src python examples/query_engine.py

Walks the repro.qe layer end to end: build an index, route a mixed-span
workload through the engine (short spans skip the hierarchy, long spans
take the O(1) hybrid top), watch the dedup/cache counters, mutate the
index and see the generation-keyed cache invalidate, then serve two
indices through the micro-batching ``QueryService``.
"""

import numpy as np
import jax.numpy as jnp

from repro.core import RMQ
from repro.core.query import rmq_value_batch
from repro.qe import QueryService


def mixed_workload(rng, n, c, m):
    """Bounds drawn from all three span classes, shuffled together."""
    spans = np.concatenate([
        rng.integers(1, 2 * c + 1, m // 3),        # short: <= two chunks
        rng.integers(4 * c, n // 8, m // 3),       # mid
        rng.integers(n // 2, n + 1, m - 2 * (m // 3)),  # long
    ])
    rng.shuffle(spans)
    ls = (rng.random(m) * (n - spans + 1)).astype(np.int64)
    rs = ls + spans - 1
    return ls.astype(np.int32), rs.astype(np.int32)


def main():
    rng = np.random.default_rng(0)
    n, c = 1 << 18, 128
    x = rng.random(n, dtype=np.float32)

    # --- one index, one engine -------------------------------------------
    # explicit geometry here so the walkthrough is deterministic; see the
    # tuned section below (and `python -m repro.tune`) for c="auto"
    rmq = RMQ.build(x, c=c, t=64, with_positions=True, backend="jax")
    engine = rmq.engine()
    print(f"index: n={n}, {rmq.plan.num_levels} levels, "
          f"long cutoff = {engine.planner.effective_long_cutoff()}")

    ls, rs = mixed_workload(rng, n, c, 4096)
    ls[100:400] = ls[0]  # duplicate queries (hot keys)
    rs[100:400] = rs[0]
    vals = engine.query(ls, rs)
    # bit-identical to the monolithic walk
    want = rmq_value_batch(rmq.hierarchy, jnp.asarray(ls), jnp.asarray(rs))
    assert np.array_equal(np.asarray(vals), np.asarray(want))
    s = engine.stats()
    print(f"routed {s['queries']} queries: class split {s['class_counts']}"
          f", dedup saved {s['dedup_saved']}")

    # --- repeat traffic hits the result cache -----------------------------
    engine.query(ls[:512], rs[:512])
    print(f"repeat batch: {engine.stats()['cache']['hits']} cache hits")

    # --- mutations invalidate by generation --------------------------------
    l0, r0 = 1000, 200_000
    before = float(engine.query(np.array([l0]), np.array([r0]))[0])
    rmq = rmq.update(np.array([150_000]), np.array([-1.0], np.float32))
    engine.attach(rmq)     # successor: generation 0 -> 1
    after = float(engine.query(np.array([l0]), np.array([r0]))[0])
    assert after == -1.0 and before >= 0.0
    print(f"update invalidated cached min: {before:.4f} -> {after:.1f} "
          f"(generation {engine.generation})")

    # --- many indices, micro-batched requests ------------------------------
    svc = QueryService(max_pending=8192)
    svc.register("scores", rmq)
    svc.register("latencies",
                 RMQ.build(rng.random(1 << 14, dtype=np.float32),
                           c=64, t=64, with_positions=True, backend="jax"))
    tickets = [
        svc.submit("scores", *mixed_workload(rng, n, c, 64))
        for _ in range(16)
    ] + [
        svc.submit("latencies", np.array([10]), np.array([5000]), op="index")
    ]
    results = svc.flush()     # one coalesced execution per (index, op)
    assert all(t in results for t in tickets)
    st = svc.stats()
    print(f"service: {st['requests']} requests -> "
          f"{st['engines']['scores']['batches']} engine batch(es) for "
          f"'scores', coalesced {st['coalesced_batches']} group(s)")

    # --- fused runtime backend: the whole mix, ONE launch per batch --------
    from repro.kernels.profiling import count_launches

    fused_rmq = RMQ.build(x, c=c, t=64, with_positions=True,
                          backend="fused")
    fused_engine = fused_rmq.engine(cache_size=0)
    ls_m, rs_m = mixed_workload(rng, n, c, 1024)
    with count_launches() as counts:   # first trace records launches
        fused_vals = np.asarray(fused_engine.query(ls_m, rs_m))
    x_np = np.asarray(x)
    for i in range(0, 1024, 64):       # spot-check vs the naive scan
        assert fused_vals[i] == x_np[ls_m[i] : rs_m[i] + 1].min()
    # value + index ops answered from the same single-launch buckets
    is_index = rng.random(1024) < 0.5
    vals_mx, poss_mx = fused_engine.query_mixed(ls_m, rs_m, is_index)
    print(f"fused backend: mixed batch in {counts} "
          f"(class split {fused_engine.stats()['class_counts']})")

    # --- autotuned: geometry/backend/planner from the tuning cache ---------
    # c="auto" consults results/tuning_cache.json (regenerate with
    # `python -m repro.tune`); on a cache miss this is bit-identical to
    # the c=128, t=64 default above.
    tuned_rmq = RMQ.build(x, c="auto", with_positions=True)
    tuned_engine = tuned_rmq.engine(cache_size=0)
    cfg = tuned_engine.tuned or {"source": "default (cache miss)"}
    print(f"tuned build: c={tuned_rmq.plan.c}, t={tuned_rmq.plan.t}, "
          f"backend={tuned_engine.backend} (config source: "
          f"{cfg.get('source')})")
    tv = np.asarray(tuned_engine.query(ls_m, rs_m))
    assert np.array_equal(tv, np.asarray(fused_engine.query(ls_m, rs_m)))
    print("query engine demo OK")


if __name__ == "__main__":
    main()
