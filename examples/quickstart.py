"""Quickstart: build a GPU-RMQ index and answer batched queries.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import RMQ, make_plan
from repro.core.baselines import FullScan, SparseTable


def main():
    rng = np.random.default_rng(0)
    n = 1 << 20
    x = rng.random(n, dtype=np.float32)

    # --- build (paper §4.1: hierarchy of chunk minima) -------------------
    # c="auto" resolves geometry from the committed tuning cache for
    # this platform and input size (falls back to c=128, t=64 on a
    # cache miss); pass explicit c/t to pin a geometry instead.
    rmq = RMQ.build(x, c="auto", with_positions=True)
    plan = rmq.plan
    print(f"n = {n}: geometry c={plan.c}, t={plan.t} "
          f"(tuned: {plan.level_split is not None}), "
          f"{plan.num_levels} levels, level sizes {plan.level_lens}")
    print(f"auxiliary memory: {rmq.auxiliary_bytes() / 2**20:.2f} MiB "
          f"({100 * plan.overhead_fraction():.2f}% of the input — "
          f"paper bound n/(c-1) = {100 / (plan.c - 1):.2f}%)")

    # --- batched queries (paper §2.1) -------------------------------------
    m = 4096
    ls = rng.integers(0, n, m).astype(np.int32)
    rs = np.minimum(ls + rng.integers(1, n // 2, m), n - 1).astype(np.int32)
    vals = rmq.query(jnp.asarray(ls), jnp.asarray(rs))
    idxs = rmq.query_index(jnp.asarray(ls), jnp.asarray(rs))
    print(f"answered {m} RMQs; "
          f"example: RMQ({ls[0]}, {rs[0]}) = {float(vals[0]):.6f} "
          f"at position {int(idxs[0])}")

    # --- sanity vs naive ---------------------------------------------------
    for i in range(8):
        want = x[ls[i]:rs[i] + 1].min()
        assert float(vals[i]) == want
        assert int(idxs[i]) == ls[i] + int(np.argmin(x[ls[i]:rs[i] + 1]))
    print("spot-checks vs naive scan: OK")

    # --- the space/time landscape (paper Fig. 15/16) -----------------------
    sparse = SparseTable.build(jnp.asarray(x))
    print(f"sparse-table (LCA-profile) auxiliary memory: "
          f"{sparse.auxiliary_bytes() / 2**20:.0f} MiB "
          f"({sparse.auxiliary_bytes() / rmq.auxiliary_bytes():.0f}x ours)")


if __name__ == "__main__":
    main()
