"""End-to-end driver: train a ~100M-param llama-style LM for a few hundred
steps on CPU, with checkpointing and restart safety.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

This uses the real production substrate (train_step builder, AdamW,
deterministic data pipeline, async checkpointing) on a single device; the
same code path runs on the production mesh via launch/train.py.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.checkpoint import CheckpointManager
from repro.data.pipeline import SyntheticTokenDataset
from repro.train import build_train_step, init_train_state


def lm_100m() -> ModelConfig:
    """~100M params: 12L, d=640, llama3-style."""
    return ModelConfig(
        name="llama-100m",
        family="dense",
        num_layers=12,
        d_model=640,
        num_heads=10,
        num_kv_heads=5,
        head_dim=64,
        d_ff=2048,
        vocab_size=32000,
        rope_theta=500_000.0,
        dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = lm_100m()
    n_params = cfg.num_params()
    print(f"model: {cfg.name}, ~{n_params/1e6:.0f}M params")

    tc = TrainConfig(
        learning_rate=6e-4,
        warmup_steps=30,
        total_steps=args.steps,
        seq_len=args.seq_len,
        global_batch=args.batch,
        remat_policy="minimal",
        checkpoint_every=100,
        checkpoint_dir=args.ckpt_dir,
    )
    state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(cfg, tc), donate_argnums=(0,))
    data = SyntheticTokenDataset(
        vocab_size=cfg.vocab_size, seq_len=tc.seq_len,
        global_batch=tc.global_batch,
    )
    ckpt = CheckpointManager(tc.checkpoint_dir, async_mode=True)

    t0 = time.time()
    first = None
    for i in range(tc.total_steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, metrics = step(state, batch)
        loss = float(metrics["loss"])
        if first is None:
            first = loss
        if (i + 1) % 25 == 0:
            toks = tc.global_batch * tc.seq_len * 25
            dt = time.time() - t0
            t0 = time.time()
            print(f"step {i+1:4d}  loss {loss:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"{toks/dt:,.0f} tok/s")
        if (i + 1) % tc.checkpoint_every == 0:
            ckpt.save(i + 1, state)
    ckpt.wait()
    ckpt.close()
    print(f"loss: {first:.3f} -> {loss:.3f} "
          f"(random-chance NLL = ln({cfg.vocab_size}) = "
          f"{jnp.log(cfg.vocab_size):.2f})")
    assert loss < first, "training must reduce loss"


if __name__ == "__main__":
    main()
