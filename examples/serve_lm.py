"""Serve a small LM with batched requests + RMQ-backed KV eviction.

    PYTHONPATH=src python examples/serve_lm.py

Demonstrates the paper's data structure as a first-class serving feature
(DESIGN.md §4): during decode, per-token attention mass accumulates into
importance scores; when the live context exceeds the budget the engine
answers a batch of RMQ_index queries over the score array to find
minimum-importance tokens, evicts them, and keeps decoding.

Three modes: eviction off, eviction through a private query engine, and
eviction as a *tenant* of the async serving tier (``repro.serving``) —
the production shape, where each round's windowed-argmin batch rides the
tier's deadline batcher and snapshot swap alongside any other tenants.
"""

import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ServeConfig
from repro.models.lm import init_params
from repro.serve.engine import ServeEngine
from repro.serving import ServingTier


def small_lm() -> ModelConfig:
    return ModelConfig(
        name="serve-demo-60m",
        family="dense",
        num_layers=8,
        d_model=512,
        num_heads=8,
        num_kv_heads=2,
        head_dim=64,
        d_ff=1536,
        vocab_size=8192,
        dtype="float32",
    )


def main():
    cfg = small_lm()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch, prompt_len, max_new = 8, 64, 160
    budget = 160

    for mode in ("off", "engine", "serving-tier"):
        evict = mode != "off"
        sc = ServeConfig(
            seq_len=prompt_len + max_new + 8,
            batch=batch,
            kv_cache_dtype="float32",
            eviction_enabled=evict,
            eviction_budget=budget,
            eviction_window=32,
            rmq_chunk=16,
            rmq_threshold=4,
        )
        tier = ServingTier() if mode == "serving-tier" else None
        engine = ServeEngine(cfg, params, sc, serving_tier=tier)
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab_size
        )
        t0 = time.time()
        if tier is not None:
            with tier:                  # deadline flusher thread
                out = engine.generate(prompts, max_new)
        else:
            out = engine.generate(prompts, max_new)
        dt = time.time() - t0
        total = batch * max_new
        print(
            f"[eviction {mode:12s}] {total} tokens in {dt:5.1f}s "
            f"({total/dt:6.1f} tok/s)  live_ctx={out['final_pos']:4d}  "
            f"evicted={out['evicted']}"
        )
        if evict:
            assert out["final_pos"] <= budget + 1
            assert out["evicted"] > 0
        if tier is not None:
            t = tier.stats()["tenants"]["kv-eviction"]
            print(
                f"  tenant kv-eviction: flushes={t['flushes']} "
                f"snapshot_swaps={t['snapshot_swaps']} "
                f"p99={t['latency_s']['p99'] * 1e3:.2f}ms "
                f"rejected={t['rejected_queue_full']}"
            )


if __name__ == "__main__":
    main()
