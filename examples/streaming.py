"""Streaming RMQ: keep a minima hierarchy in sync with a mutating array.

    PYTHONPATH=src python examples/streaming.py

Demonstrates the three online operations — batched point updates, appends
into reserved capacity, and sliding-window retirement — and checks the
incrementally-maintained index against fresh rebuilds.
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import build_hierarchy, make_plan
from repro.streaming import StreamingRMQ


def main():
    rng = np.random.default_rng(0)
    n, capacity = 1 << 18, 1 << 19
    x = rng.random(n, dtype=np.float32)

    # --- build once, reserving capacity for appends ----------------------
    s = StreamingRMQ.from_array(
        x, c=128, t=64, capacity=capacity, with_positions=True,
        backend="jax",
    )
    print(f"built over n={n} with capacity={capacity} "
          f"({s.plan.num_levels} levels)")

    # --- batched point updates: O(B log_c n) chunk re-reductions ---------
    bsz = 256
    idxs = rng.integers(0, n, bsz)
    vals = rng.random(bsz).astype(np.float32)
    t0 = time.perf_counter()
    s = s.update(jnp.asarray(idxs), jnp.asarray(vals))
    jax.block_until_ready(s.hierarchy.upper)
    t_upd = time.perf_counter() - t0
    x[idxs] = vals
    print(f"updated {bsz} points in {t_upd * 1e3:.2f} ms "
          "(first call includes compilation)")

    # --- append into the reserved tail -----------------------------------
    tail = rng.random(4096).astype(np.float32)
    s = s.append(jnp.asarray(tail))
    x = np.concatenate([x, tail])
    print(f"appended {tail.size}: live length {s.length}")

    # --- retire the oldest entries (sliding window) ----------------------
    s = s.retire(1024)
    x[:1024] = np.inf
    print(f"retired 1024: live window [{s.start}, {s.length})")

    # --- verify against a from-scratch rebuild ---------------------------
    plan = make_plan(s.length, c=128, t=64, capacity=capacity)
    ref = build_hierarchy(jnp.asarray(x), plan, with_positions=True)
    u1, u2 = np.asarray(ref.upper), np.asarray(s.hierarchy.upper)
    finite = np.isfinite(u1)
    assert np.array_equal(finite, np.isfinite(u2))
    assert np.array_equal(u1[finite], u2[finite])
    assert np.array_equal(np.asarray(ref.upper_pos),
                          np.asarray(s.hierarchy.upper_pos))

    # --- queries over the live window ------------------------------------
    ls = rng.integers(s.start, s.length, 1024).astype(np.int32)
    rs = np.minimum(ls + rng.integers(1, 4096, 1024), s.length - 1)
    rs = rs.astype(np.int32)
    got = np.asarray(s.query(ls, rs))
    for i in range(16):
        assert got[i] == x[ls[i]:rs[i] + 1].min()
    print(f"answered {ls.size} queries over the live window; "
          "incremental index == rebuild, spot-checks OK")


if __name__ == "__main__":
    main()
