"""Mixture-of-Experts layer: top-k routing, capacity dispatch, shared expert.

Dispatch strategy (TPU/GSPMD-conscious):

* routing + dispatch are *local to each data shard* — tokens never cross
  the data axis.  The dispatch buffer is built with gather/scatter of
  token vectors (memory O(T·k·D)), never a (T, E, C) one-hot tensor
  (which is O(T·E·C) and infeasible at production T).
* expert FFNs run as batched einsums over the expert dim, so expert
  weights can be sharded over the ``model`` axis on either the expert dim
  (EP) or the ``d_ff`` dim (TP); the sharding rules in
  ``repro.distributed.shardings`` pick TP-experts by default — the
  contraction then needs exactly one reduce over ``model``, the same
  collective pattern as a dense TP FFN (and GSPMD inserts it from the
  sharding constraints; no manual collectives needed here).
* capacity follows GShard: C = ceil(T·k·capacity_factor / E); overflow
  tokens fall back to the shared expert / residual (dropped from routed
  compute), underflow slots are zero-padded.

Router style notes per assigned arch:
* qwen2-moe: softmax router, top-4, renormalized, plus a 4×-width shared
  expert with a sigmoid shared-gate.
* llama4: top-1, sigmoid gate on the selected expert, plus a shared
  expert (always on); interleaved with dense layers (period 2).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.models.layers import cast, cdtype, dense_init, mlp, mlp_init


def moe_init(key, cfg: ModelConfig):
    k_r, k_g, k_u, k_d, k_s, k_sg = jax.random.split(key, 6)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": jax.random.normal(k_r, (d, e), jnp.float32) * scale,
        "w_gate": jax.random.normal(k_g, (e, d, f), jnp.float32) * scale,
        "w_up": jax.random.normal(k_u, (e, d, f), jnp.float32) * scale,
        "w_down": jax.random.normal(k_d, (e, f, d), jnp.float32)
        * (1.0 / math.sqrt(f)),
    }
    if cfg.shared_expert_d_ff:
        p["shared"] = mlp_init(k_s, cfg, d_ff=cfg.shared_expert_d_ff)
        p["shared_gate"] = jax.random.normal(k_sg, (d, 1), jnp.float32) * scale
    return p


def _capacity(cfg: ModelConfig, tokens: int) -> int:
    c = math.ceil(
        tokens * cfg.num_experts_per_tok * cfg.capacity_factor
        / cfg.num_experts
    )
    # multiple of 128: sublane-aligned AND shardable over dp axes (the
    # dispatch buffers carry explicit sharding constraints; see §Perf H1)
    return max(128, -(-c // 128) * 128) if tokens >= 4096 else \
        max(8, -(-c // 8) * 8)


def _dispatch_and_run(cfg, w_gate, w_up, w_down, xt, top_p, top_e,
                      cap: int):
    """Local capacity dispatch + expert FFNs.  Pure; no collectives.

    ``xt (T, D)`` are this shard's tokens; weights may be F-sharded (the
    caller reduces the partial output over the tensor axis).  Rank within
    expert comes from a stable argsort — O(n log n) — never the (T·k, E)
    one-hot cumsum (it lowers to a reduce-window XLA cost-counts
    quadratically: 50× FLOPs inflation on qwen2-moe, §Perf H1).
    """
    t, d = xt.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok

    flat_e = top_e.reshape(-1)                                   # (T*k,)
    counts = jnp.bincount(flat_e, length=e)
    order = jnp.argsort(flat_e, stable=True)
    inv = jnp.zeros_like(order).at[order].set(
        jnp.arange(order.shape[0], dtype=order.dtype)
    )
    offsets = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]]
    )
    rank = inv - jnp.take(offsets, flat_e)
    keep = rank < cap
    dest = jnp.minimum(
        jnp.where(keep, flat_e * cap + rank, e * cap - 1), e * cap - 1
    )

    tok_idx = jnp.arange(t * k) // k
    gathered = jnp.take(xt, tok_idx, axis=0)                     # (T*k, D)
    contrib = jnp.where(keep[:, None], gathered, 0)
    buf = jnp.zeros((e * cap, d), dtype=xt.dtype).at[dest].add(contrib)
    h = buf.reshape(e, cap, d)

    g = jnp.einsum("ecd,edf->ecf", h, w_gate)
    u = jnp.einsum("ecd,edf->ecf", h, w_up)
    a = jax.nn.silu(g) * u
    o = jnp.einsum("ecf,efd->ecd", a, w_down)                    # (E,C,D)

    per_tk = jnp.take(o.reshape(e * cap, d), dest, axis=0)       # (T*k, D)
    w = (top_p.reshape(-1) * keep.astype(jnp.float32)).astype(per_tk.dtype)
    return jnp.sum((per_tk * w[:, None]).reshape(t, k, d), axis=1)


def moe_apply(
    p,
    x,                       # (B, S, D) or (T, D)
    cfg: ModelConfig,
    sharder=None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss).  Output has the input's shape.

    Two execution paths:
    * **single-device / decode** — plain local dispatch.
    * **meshed (sharder carries a mesh)** — dispatch runs inside
      ``shard_map``: tokens stay on their data shard (capacity is
      per-shard, as in real MoE systems), expert weights are
      FSDP-all-gathered over the data axes *inside* the mapped function
      (one layer live at a time under the scan), the F-contraction
      partials are psum'd over ``model`` once, *after* the combine
      (deferring the reduce past the linear combine shrinks it from
      (E·C, D) to (T_local, D)).  No dispatch buffer ever replicates —
      this was an 80 GiB/device temp reduction on qwen2-moe train_4k
      (§Perf H1 iter 3).
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    xt = x.reshape(-1, d)
    t = xt.shape[0]
    e, k = cfg.num_experts, cfg.num_experts_per_tok

    logits = (xt @ cast(p["router"], cfg)).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                       # (T, k)
    if k > 1:
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # ---- load-balance auxiliary loss (Switch-style) ---------------------
    counts = jnp.bincount(top_e.reshape(-1), length=e)
    me = jnp.mean(probs, axis=0)
    ce = counts.astype(jnp.float32) / t
    aux_loss = cfg.router_aux_loss_coef * e * jnp.sum(me * ce)

    mesh = getattr(sharder, "mesh", None)
    wg, wu, wd = (cast(p["w_gate"], cfg), cast(p["w_up"], cfg),
                  cast(p["w_down"], cfg))

    if mesh is not None and "model" in mesh.shape and t >= 4096:
        from jax.sharding import PartitionSpec as P

        fsdp = tuple(a for a in ("pod", "data") if a in mesh.shape)
        n_dp = 1
        for a in fsdp:
            n_dp *= mesh.shape[a]
        cap = _capacity(cfg, t // n_dp)

        def mapped(xt_l, top_p_l, top_e_l, wg_l, wu_l, wd_l):
            # manual FSDP: gather the D-shard of this layer's experts
            if fsdp:
                wg_l = jax.lax.all_gather(wg_l, fsdp, axis=1, tiled=True)
                wu_l = jax.lax.all_gather(wu_l, fsdp, axis=1, tiled=True)
                wd_l = jax.lax.all_gather(wd_l, fsdp, axis=2, tiled=True)
            y = _dispatch_and_run(cfg, wg_l, wu_l, wd_l, xt_l,
                                  top_p_l, top_e_l, cap)
            return jax.lax.psum(y, "model")

        y = shard_map(
            mapped,
            mesh=mesh,
            in_specs=(
                P(fsdp, None), P(fsdp, None), P(fsdp, None),
                P(None, fsdp, "model"),
                P(None, fsdp, "model"),
                P(None, "model", fsdp),
            ),
            out_specs=P(fsdp, None),
            check_vma=False,
        )(xt, top_p, top_e, wg, wu, wd)
    else:
        cap = _capacity(cfg, t)
        y = _dispatch_and_run(cfg, wg, wu, wd, xt, top_p, top_e, cap)

    # ---- shared expert ----------------------------------------------------
    if "shared" in p:
        gate = jax.nn.sigmoid(
            (xt @ cast(p["shared_gate"], cfg)).astype(jnp.float32)
        ).astype(y.dtype)
        y = y + gate * mlp(p["shared"], xt, cfg)

    return y.reshape(orig_shape), aux_loss
