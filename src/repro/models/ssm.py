"""Mamba-2 (SSD) block — and the SSM half of Hymba's hybrid heads.

Follows the Mamba-2 layer recipe (arXiv:2405.21060): one fused input
projection producing (z, x, B, C, dt); short depthwise-causal conv over
[x; B; C]; SSD scan over heads; gated RMSNorm; output projection.
The SSD scan itself is the Pallas kernel / chunked-ref in
``repro.kernels.ssd_scan`` (state-space duality chunk algorithm).

Decode keeps two carries per layer: the (B, H, P, N) SSM state and the
(B, conv-1, channels) conv tail — both O(1) in sequence length, which is
why the ``long_500k`` shape runs only for the SSM/hybrid archs.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.ssd_scan.ops import ssd, ssd_with_state
from repro.models.layers import cast, cdtype, dense, dense_init, rmsnorm_init, rmsnorm


def _dims(cfg: ModelConfig, d_inner: Optional[int] = None):
    di = d_inner if d_inner is not None else cfg.ssm_expand * cfg.d_model
    h = cfg.ssm_heads
    p = cfg.ssm_head_dim
    n = cfg.ssm_state
    assert h * p == di, (h, p, di)
    return di, h, p, n


def ssm_init(key, cfg: ModelConfig, d_inner: Optional[int] = None):
    di, h, p, n = _dims(cfg, d_inner)
    d = cfg.d_model
    keys = jax.random.split(key, 4)
    conv_ch = di + 2 * n
    return {
        # fused in_proj -> [z (di), x (di), B (n), C (n), dt (h)]
        "in_proj": dense_init(keys[0], d, 2 * di + 2 * n + h),
        "conv_w": jax.random.normal(
            keys[1], (cfg.ssm_conv, conv_ch), jnp.float32
        ) * (1.0 / math.sqrt(cfg.ssm_conv)),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, h).astype(jnp.float32)
        ),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(
            jnp.expm1(
                jnp.exp(
                    jax.random.uniform(keys[2], (h,), jnp.float32)
                    * (math.log(0.1) - math.log(0.001))
                    + math.log(0.001)
                )
            )
        ),
        "norm": rmsnorm_init(di),
        "out_proj": dense_init(keys[3], di, d),
    }


def _causal_conv(u, w, b, tail=None):
    """Depthwise causal conv. u: (B, L, C); w: (K, C); tail: (B, K-1, C)."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    padded = jnp.concatenate([tail, u], axis=1)
    out = jnp.zeros_like(u)
    for i in range(k):
        out = out + padded[:, i : i + u.shape[1], :] * w[i]
    new_tail = padded[:, -(k - 1) :, :] if k > 1 else tail
    return out + b, new_tail


class SSMState(NamedTuple):
    ssd: jax.Array        # (B, H, P, N) f32
    conv: jax.Array       # (B, K-1, d_inner + 2N)


def ssm_zero_state(cfg: ModelConfig, batch: int,
                   d_inner: Optional[int] = None) -> SSMState:
    di, h, p, n = _dims(cfg, d_inner)
    return SSMState(
        ssd=jnp.zeros((batch, h, p, n), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * n),
                       jnp.dtype(cfg.dtype)),
    )


def _project(p, x, cfg, di, h, n):
    zxbcdt = dense(p["in_proj"], x, cfg)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * n]
    dt_raw = zxbcdt[..., 2 * di + 2 * n :]
    return z, xbc, dt_raw


def _ssd_inputs(p, xbc, dt_raw, cfg, di, h, pd, n):
    b, l, _ = xbc.shape
    xs = xbc[..., :di]
    bm = xbc[..., di : di + n].astype(jnp.float32)
    cm = xbc[..., di + n :].astype(jnp.float32)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"]
    )                                                   # (B, L, H)
    a = -jnp.exp(p["A_log"])                            # (H,)
    log_a = a * dt                                      # (B, L, H)
    xh = xs.astype(jnp.float32).reshape(b, l, h, pd)
    dtx = xh * dt[..., None]
    return xh, dtx, log_a, bm, cm


def _pad_ssd(arrs, l, chunk):
    """Right-pad time axis (axis=1) to a multiple of chunk.

    Zero padding is state-neutral: log_a = 0 ⇒ decay 1, dtx = 0 ⇒ no state
    injection, so padded steps are identity on the recurrence.
    """
    lp = -(-l // chunk) * chunk
    if lp == l:
        return arrs, l
    return [
        jnp.pad(a, [(0, 0), (0, lp - l)] + [(0, 0)] * (a.ndim - 2))
        for a in arrs
    ], l


def ssm_apply(p, x, cfg: ModelConfig, d_inner: Optional[int] = None,
              impl: str = "auto"):
    """Full-sequence SSD block (train / prefill without state)."""
    di, h, pd, n = _dims(cfg, d_inner)
    z, xbc, dt_raw = _project(p, x, cfg, di, h, n)
    xbc, _ = _causal_conv(
        xbc, cast(p["conv_w"], cfg), cast(p["conv_b"], cfg)
    )
    xbc = jax.nn.silu(xbc)
    xh, dtx, log_a, bm, cm = _ssd_inputs(p, xbc, dt_raw, cfg, di, h, pd, n)
    l = x.shape[1]
    chunk = min(cfg.ssm_chunk, l)
    (dtx, log_a, bm, cm), _ = _pad_ssd([dtx, log_a, bm, cm], l, chunk)
    y = ssd(dtx, log_a, bm, cm, chunk=chunk, impl=impl)[:, :l]
    y = y + p["D"][None, None, :, None] * xh            # skip connection
    y = y.reshape(x.shape[0], x.shape[1], di).astype(cdtype(cfg))
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return dense(p["out_proj"], y, cfg)


def ssm_prefill(p, x, cfg: ModelConfig, d_inner: Optional[int] = None):
    """Full-sequence pass that also returns the decode state."""
    di, h, pd, n = _dims(cfg, d_inner)
    z, xbc, dt_raw = _project(p, x, cfg, di, h, n)
    xbc, conv_tail = _causal_conv(
        xbc, cast(p["conv_w"], cfg), cast(p["conv_b"], cfg)
    )
    xbc = jax.nn.silu(xbc)
    xh, dtx, log_a, bm, cm = _ssd_inputs(p, xbc, dt_raw, cfg, di, h, pd, n)
    l = x.shape[1]
    chunk = min(cfg.ssm_chunk, l)
    (dtx, log_a, bm, cm), _ = _pad_ssd([dtx, log_a, bm, cm], l, chunk)
    y, final_state = ssd_with_state(dtx, log_a, bm, cm, chunk=chunk)
    y = y[:, :l]
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(x.shape[0], x.shape[1], di).astype(cdtype(cfg))
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = dense(p["out_proj"], y, cfg)
    return out, SSMState(ssd=final_state, conv=conv_tail.astype(
        jnp.dtype(cfg.dtype)))


def ssm_decode(p, x, cfg: ModelConfig, state: SSMState,
               d_inner: Optional[int] = None):
    """One-token recurrent step. x: (B, 1, D)."""
    di, h, pd, n = _dims(cfg, d_inner)
    z, xbc, dt_raw = _project(p, x, cfg, di, h, n)
    xbc, conv_tail = _causal_conv(
        xbc, cast(p["conv_w"], cfg), cast(p["conv_b"], cfg),
        tail=state.conv.astype(cdtype(cfg)),
    )
    xbc = jax.nn.silu(xbc)
    xh, dtx, log_a, bm, cm = _ssd_inputs(p, xbc, dt_raw, cfg, di, h, pd, n)
    # one recurrence step: S = exp(log_a) S + dtx ⊗ B ; y = S @ C
    a = jnp.exp(log_a[:, 0])[:, :, None, None]          # (B, H, 1, 1)
    s = a * state.ssd + dtx[:, 0, :, :, None] * bm[:, 0, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", s, cm[:, 0])[:, None]   # (B, 1, H, P)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(x.shape[0], 1, di).astype(cdtype(cfg))
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = dense(p["out_proj"], y, cfg)
    return out, SSMState(ssd=s, conv=conv_tail.astype(jnp.dtype(cfg.dtype)))
