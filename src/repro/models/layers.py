"""Shared functional layers: norms, RoPE, dense projections, SwiGLU, GQA/MLA.

Conventions:
* every layer is an ``init_*(key, cfg, ...) -> params`` plus an
  ``apply``-style pure function;
* params are nested dicts of f32 master weights; ``cast`` converts to the
  compute dtype at use;
* attention supports three execution modes sharing one set of weights:
  full-sequence (train / prefill) and single-token decode against a cache.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention.ops import attention as attention_op

Init = jax.nn.initializers


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def cast(x, cfg: ModelConfig):
    return x.astype(cdtype(cfg))


def dense_init(key, in_dim, out_dim, bias=False, scale=None):
    if scale is None:
        scale = 1.0 / math.sqrt(in_dim)
    p = {"w": jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale}
    if bias:
        p["b"] = jnp.zeros((out_dim,), jnp.float32)
    return p


def dense(p, x, cfg):
    y = x @ cast(p["w"], cfg)
    if "b" in p:
        y = y + cast(p["b"], cfg)
    return y


def rmsnorm_init(dim):
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"]).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, jnp.float32) / dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, D) with D even; positions: (S,) or (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..,S,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, cfg.d_model, d_ff),
        "up": dense_init(k2, cfg.d_model, d_ff),
        "down": dense_init(k3, d_ff, cfg.d_model),
    }


def mlp(p, x, cfg: ModelConfig):
    return dense(
        p["down"], jax.nn.silu(dense(p["gate"], x, cfg)) *
        dense(p["up"], x, cfg), cfg,
    )


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------
def gqa_init(key, cfg: ModelConfig):
    kq, kk, kv, ko = jax.random.split(key, 4)
    h, hkv, hd, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    return {
        "q": dense_init(kq, d, h * hd, bias=cfg.qkv_bias),
        "k": dense_init(kk, d, hkv * hd, bias=cfg.qkv_bias),
        "v": dense_init(kv, d, hkv * hd, bias=cfg.qkv_bias),
        "o": dense_init(ko, h * hd, d),
    }


def _split_heads(x, num_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, num_heads, head_dim).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * hd)


def gqa_attention(
    p,
    x,                      # (B, S, D)
    cfg: ModelConfig,
    positions,              # (S,)
    window=None,            # None, python int, or traced scalar
    attn_impl: str = "auto",
    return_probs_sum: bool = False,
    sharder=None,
):
    """Full-sequence causal attention (train / prefill).

    ``window``: static int enables the Pallas flash SWA path on TPU; a
    traced scalar (hybrid archs with per-layer windows under scan) forces
    the reference path with a dynamic mask.
    Returns (out, (k, v), probs_sum) — probs_sum is the per-key attention
    mass used by the RMQ eviction manager (None unless requested).
    """
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _split_heads(dense(p["q"], x, cfg), h, hd)
    k = _split_heads(dense(p["k"], x, cfg), hkv, hd)
    v = _split_heads(dense(p["v"], x, cfg), hkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if sharder is not None:
        # pin head sharding BEFORE the blocked attention scan: without it,
        # sequence-sharded inputs hit the (S -> nq, bq) reshape and GSPMD
        # falls back to full replication of q/k/v per device
        # ("involuntary full rematerialization", ~27 GiB/layer on
        # command-r-plus — §Perf H2 iter 2)
        q = sharder(q, "act_heads")
        k = sharder(k, "act_heads")
        v = sharder(v, "act_heads")
    out = attention_op(q, k, v, window=window, impl=attn_impl)
    if sharder is not None:
        out = sharder(out, "act_heads")
    probs_sum = _attention_mass(q, k, cfg, window) if return_probs_sum \
        else None
    return dense(p["o"], _merge_heads(out), cfg), (k, v), probs_sum


def _attention_mass(q, k, cfg, window):
    """Per-key cumulative attention mass (B, S) — eviction scores."""
    h, hd = q.shape[1], q.shape[3]
    hkv = k.shape[1]
    if h // hkv > 1:
        k = jnp.repeat(k, h // hkv, axis=1)
    s = q.shape[2]
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(hd)
    row = jnp.arange(s)[:, None]
    col = jnp.arange(s)[None, :]
    mask = col <= row
    if window is not None:
        mask = mask & (col > row - window)
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return probs.sum(axis=(1, 2))


def gqa_decode(
    p,
    x,                      # (B, 1, D)
    cfg: ModelConfig,
    cache: Tuple[jax.Array, jax.Array],   # k, v: (B, Hkv, S, hd)
    pos,                    # scalar: index of the new token
    window=None,
):
    """Single-token decode against a KV cache; returns (out, new_cache)."""
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ck, cv = cache
    s_cache = ck.shape[2]
    q = _split_heads(dense(p["q"], x, cfg), h, hd)
    k = _split_heads(dense(p["k"], x, cfg), hkv, hd)
    v = _split_heads(dense(p["v"], x, cfg), hkv, hd)
    posv = jnp.full((1,), pos, jnp.int32)
    q = apply_rope(q, posv, cfg.rope_theta)
    k = apply_rope(k, posv, cfg.rope_theta)
    ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, 0, pos, 0))
    cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, 0, pos, 0))

    # grouped-query attention WITHOUT materializing the KV repeat: the
    # (B, Hq, S, hd) expanded cache forced a full copy + all-gather of the
    # sharded cache per layer (1 GiB/layer on llama3 decode_32k — §Perf H3
    # iter 1).  Fold q heads into (kv_head, group) instead.
    group = h // hkv
    qg = q.reshape(x.shape[0], hkv, group, hd)           # (B, Hkv, g, hd)
    # mixed-precision contractions: bf16 operands, f32 accumulation.
    # Casting the cache operand to f32 materialized an f32 copy of the
    # whole (sharded) cache per layer — 2x the decode step's HBM traffic
    # (§Perf H3 iter 2).
    scores = jnp.einsum(
        "bkgd,bksd->bkgs", qg.astype(ck.dtype), ck,
        preferred_element_type=jnp.float32,
    ) / math.sqrt(hd)
    col = jnp.arange(s_cache)[None, None, None, :]
    mask = col <= pos
    if window is not None:
        mask = mask & (col > pos - window)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkgs,bksd->bkgd", probs.astype(cv.dtype), cv,
        preferred_element_type=jnp.float32,
    )                                                     # (B, Hkv, g, hd)
    out = out.reshape(x.shape[0], 1, h * hd).astype(x.dtype)
    return dense(p["o"], out, cfg), (ck, cv)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, MiniCPM3 / DeepSeek style)
# ---------------------------------------------------------------------------
def mla_init(key, cfg: ModelConfig):
    keys = jax.random.split(key, 6)
    d = cfg.d_model
    h = cfg.num_heads
    qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    return {
        "q_a": dense_init(keys[0], d, cfg.q_lora_rank),
        "q_a_norm": rmsnorm_init(cfg.q_lora_rank),
        "q_b": dense_init(keys[1], cfg.q_lora_rank, h * qk),
        "kv_a": dense_init(
            keys[2], d, cfg.kv_lora_rank + cfg.qk_rope_head_dim
        ),
        "kv_a_norm": rmsnorm_init(cfg.kv_lora_rank),
        "kv_b": dense_init(
            keys[3], cfg.kv_lora_rank,
            h * (cfg.qk_nope_head_dim + cfg.v_head_dim),
        ),
        "o": dense_init(keys[4], h * cfg.v_head_dim, d),
    }


def _mla_qkv(p, x, cfg, positions):
    """Materialized (train/prefill) MLA projections."""
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    q = dense(p["q_b"], rmsnorm(p["q_a_norm"], dense(p["q_a"], x, cfg),
                                cfg.norm_eps), cfg)
    q = q.reshape(b, s, h, dn + dr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = dense(p["kv_a"], x, cfg)
    latent, k_rope = kv[..., : cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank :]
    latent = rmsnorm(p["kv_a_norm"], latent, cfg.norm_eps)
    k_rope = apply_rope(
        k_rope[:, None, :, :], positions, cfg.rope_theta
    )  # (B, 1, S, dr) shared across heads
    kvu = dense(p["kv_b"], latent, cfg).reshape(
        b, s, h, dn + dv
    ).transpose(0, 2, 1, 3)
    k_nope, v = kvu[..., :dn], kvu[..., dn:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, h, s, dr)).astype(k_nope.dtype)],
        axis=-1,
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    return q_full, k, v, latent, k_rope


def mla_attention(p, x, cfg: ModelConfig, positions,
                  return_probs_sum: bool = False, sharder=None):
    """Full-sequence MLA; cache payload is the latent + shared rope key."""
    q, k, v, latent, k_rope = _mla_qkv(p, x, cfg, positions)
    if sharder is not None:
        q = sharder(q, "act_heads")
        k = sharder(k, "act_heads")
        v = sharder(v, "act_heads")
    scale = 1.0 / math.sqrt(q.shape[-1])
    out = attention_op(q, k, v, scale=scale, impl="auto")
    if sharder is not None:
        out = sharder(out, "act_heads")
    probs_sum = None
    if return_probs_sum:
        probs_sum = _attention_mass(q, k, cfg, None)
    out = _merge_heads(out)
    return dense(p["o"], out, cfg), (latent, k_rope[:, 0]), probs_sum


def mla_decode(p, x, cfg: ModelConfig, cache, pos):
    """Latent-cache decode: cache stores (latent (B,S,R), k_rope (B,S,dr)).

    Uses the absorbed-matmul formulation: scores are computed in latent
    space, so per-head K is never materialized for cached positions.
    """
    b = x.shape[0]
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    rank = cfg.kv_lora_rank
    c_lat, c_rope = cache
    s_cache = c_lat.shape[1]

    posv = jnp.full((1,), pos, jnp.int32)
    q = dense(p["q_b"], rmsnorm(p["q_a_norm"], dense(p["q_a"], x, cfg),
                                cfg.norm_eps), cfg)
    q = q.reshape(b, 1, h, dn + dr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, posv, cfg.rope_theta)

    kv = dense(p["kv_a"], x, cfg)
    latent = rmsnorm(p["kv_a_norm"], kv[..., :rank], cfg.norm_eps)
    k_rope_new = apply_rope(
        kv[..., rank:][:, None, :, :], posv, cfg.rope_theta
    )[:, 0]
    c_lat = jax.lax.dynamic_update_slice(
        c_lat, latent.astype(c_lat.dtype), (0, pos, 0)
    )
    c_rope = jax.lax.dynamic_update_slice(
        c_rope, k_rope_new.astype(c_rope.dtype), (0, pos, 0)
    )

    # absorb kv_b's K-half into the query: q_lat (B,H,1,R)
    w_kv = cast(p["kv_b"]["w"], cfg).reshape(rank, h, dn + dv)
    w_k = w_kv[..., :dn]                       # (R, H, dn)
    w_v = w_kv[..., dn:]                       # (R, H, dv)
    q_lat = jnp.einsum("bhqd,rhd->bhqr", q_nope, w_k)
    scores = (
        jnp.einsum("bhqr,bsr->bhqs", q_lat.astype(jnp.float32),
                   c_lat.astype(jnp.float32))
        + jnp.einsum("bhqd,bsd->bhqs", q_rope.astype(jnp.float32),
                     c_rope.astype(jnp.float32))
    ) / math.sqrt(dn + dr)
    col = jnp.arange(s_cache)[None, None, None, :]
    scores = jnp.where(col <= pos, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    # output in latent space, then up-project with the V-half
    o_lat = jnp.einsum("bhqs,bsr->bhqr", probs, c_lat.astype(jnp.float32))
    out = jnp.einsum("bhqr,rhd->bhqd", o_lat.astype(cdtype(cfg)), w_v)
    return dense(p["o"], _merge_heads(out), cfg), (c_lat, c_rope)
