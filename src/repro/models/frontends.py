"""Modality frontend stubs (per assignment).

The ``[vlm]`` and ``[audio]`` architectures specify the transformer
*backbone* only; the modality frontend is a STUB whose job is to define
the shape contract: ``input_specs()`` provides precomputed patch/frame
embeddings that the trunk consumes as a prefix.

These helpers generate deterministic synthetic embeddings for smoke tests
and examples; ``launch/dryrun.py`` uses only their ShapeDtypeStructs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def frontend_embedding_shape(cfg: ModelConfig, batch: int):
    """Shape of the precomputed frontend prefix embeddings."""
    if not cfg.frontend:
        return None
    return (batch, cfg.frontend_tokens, cfg.d_model)


def synthetic_frontend_embeddings(cfg: ModelConfig, batch: int, seed: int = 0):
    """Deterministic stand-in embeddings (what a ViT / EnCodec conditioner
    would produce)."""
    shape = frontend_embedding_shape(cfg, batch)
    if shape is None:
        return None
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, shape, jnp.float32) * 0.02
