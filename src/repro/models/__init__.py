"""Model zoo: functional JAX implementations of the assigned architectures.

No flax/haiku dependency — params are plain nested dicts, every layer is an
(init, apply) pair, and layer stacks are ``jax.lax.scan``-ed over stacked
parameter pytrees so 48–64-layer configs compile as one HLO while-loop.
"""

from repro.models.lm import (
    init_params,
    forward,
    prefill,
    decode_step,
    make_decode_cache,
)

__all__ = [
    "init_params",
    "forward",
    "prefill",
    "decode_step",
    "make_decode_cache",
]
