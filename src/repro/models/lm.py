"""LM assembly: init / forward / prefill / decode for every assigned family.

One scan driver per execution mode; layer stacks are ``lax.scan``-ed over
stacked parameter pytrees (compile-time O(1) in depth).  Families:

* ``dense``   — GQA or MLA attention + SwiGLU (sequential or Cohere-style
                parallel block)
* ``moe``     — attention + routed FFN each layer, or (llama4) a period-2
                superlayer of [dense layer, MoE layer]
* ``ssm``     — Mamba-2 blocks only (attention-free)
* ``hybrid``  — Hymba: parallel attention+SSM heads fused per layer, with
                per-layer attention windows (global every k-th layer, SWA
                elsewhere) carried as scanned data
* ``vlm`` / ``audio`` — dense trunks consuming an optional prefix of
                precomputed frontend embeddings (assignment: frontends are
                stubs that provide embeddings, see ``frontends.py``)

The optional ``sharder(x, logical_name)`` callback lets the distributed
layer pin activation shardings without this module importing any mesh
machinery.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from jax.ad_checkpoint import checkpoint_name

Sharder = Callable[[jax.Array, str], jax.Array]


def _noshard(x, name):
    return x


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_dense_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    attn = (
        L.mla_init(k1, cfg)
        if cfg.attention_type == "mla"
        else L.gqa_init(k1, cfg)
    )
    p = {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "attn": attn,
        "mlp": L.mlp_init(k2, cfg),
    }
    if not cfg.parallel_block:
        p["ln2"] = L.rmsnorm_init(cfg.d_model)
    return p


def _init_moe_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "attn": L.gqa_init(k1, cfg),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "moe": MOE.moe_init(k2, cfg),
    }


def _init_ssm_layer(key, cfg: ModelConfig):
    return {
        "ln": L.rmsnorm_init(cfg.d_model),
        "ssm": SSM.ssm_init(key, cfg),
    }


def _init_hybrid_layer(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    di = cfg.d_model  # hymba: ssm path mirrors attention width (expand=1)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "attn": L.gqa_init(k1, cfg),
        "ssm": SSM.ssm_init(k2, cfg, d_inner=di),
        "norm_attn": L.rmsnorm_init(cfg.d_model),
        "norm_ssm": L.rmsnorm_init(cfg.d_model),
        "beta_attn": jnp.ones((cfg.d_model,), jnp.float32),
        "beta_ssm": jnp.ones((cfg.d_model,), jnp.float32),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "mlp": L.mlp_init(k3, cfg),
    }


def _layer_kind(cfg: ModelConfig) -> str:
    if cfg.family == "hybrid":
        return "hybrid"
    if cfg.is_attention_free:
        return "ssm"
    if cfg.uses_moe and cfg.moe_layer_period == 2:
        return "moe_period2"
    if cfg.uses_moe:
        return "moe"
    return "dense"


def _num_scan_steps(cfg: ModelConfig) -> int:
    if _layer_kind(cfg) == "moe_period2":
        assert cfg.num_layers % 2 == 0
        return cfg.num_layers // 2
    return cfg.num_layers


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    ke, kl, kh = jax.random.split(key, 3)
    kind = _layer_kind(cfg)
    steps = _num_scan_steps(cfg)
    layer_keys = jax.random.split(kl, steps)

    init_one = {
        "dense": _init_dense_layer,
        "moe": _init_moe_layer,
        "ssm": _init_ssm_layer,
        "hybrid": _init_hybrid_layer,
        "moe_period2": lambda k, c: {
            "dense": _init_dense_layer(jax.random.fold_in(k, 0), c),
            "moe": _init_moe_layer(jax.random.fold_in(k, 1), c),
        },
    }[kind]
    stacked = jax.vmap(lambda k: init_one(k, cfg))(layer_keys)

    params = {
        "embed": {
            "w": jax.random.normal(
                ke, (cfg.padded_vocab, cfg.d_model), jnp.float32
            ) * 0.02
        },
        "layers": stacked,
        "final_norm": L.rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(kh, cfg.d_model, cfg.padded_vocab)
    return params


def layer_windows(cfg: ModelConfig, seq_len: int) -> Optional[jax.Array]:
    """Per-layer attention windows for hybrid archs (scanned data)."""
    if cfg.family != "hybrid":
        return None
    full = seq_len + 1
    w = []
    for i in range(cfg.num_layers):
        is_global = (
            cfg.global_attn_every
            and i % cfg.global_attn_every == 0
        )
        w.append(full if is_global else (cfg.sliding_window or full))
    return jnp.asarray(w, jnp.int32)



def _scan_or_unroll(body, carry, xs, length: int, unroll: bool):
    """lax.scan, or a Python-unrolled equivalent (used by the dry-run
    calibration: XLA cost analysis counts while bodies once, so roofline
    numbers come from small unrolled compiles extrapolated to depth)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(length):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = None
    return carry, stacked


# ---------------------------------------------------------------------------
# Block bodies (full-sequence)
# ---------------------------------------------------------------------------
def _dense_block(cfg, p, x, positions, sharder, attn_impl):
    if cfg.parallel_block:
        h = sharder(L.rmsnorm(p["ln1"], x, cfg.norm_eps), "act_block_in")
        if cfg.attention_type == "mla":
            a, kv, _ = L.mla_attention(p["attn"], h, cfg, positions,
                                       sharder=sharder)
        else:
            a, kv, _ = L.gqa_attention(
                p["attn"], h, cfg, positions,
                window=cfg.sliding_window, attn_impl=attn_impl,
                sharder=sharder,
            )
        m = L.mlp(p["mlp"], h, cfg)
        a = checkpoint_name(a, "blk_attn")
        m = checkpoint_name(m, "blk_ffn")
        out = x + sharder(a, "act_resid") + m
        return out, kv
    h = sharder(L.rmsnorm(p["ln1"], x, cfg.norm_eps), "act_block_in")
    if cfg.attention_type == "mla":
        a, kv, _ = L.mla_attention(p["attn"], h, cfg, positions,
                                       sharder=sharder)
    else:
        a, kv, _ = L.gqa_attention(
            p["attn"], h, cfg, positions,
            window=cfg.sliding_window, attn_impl=attn_impl,
            sharder=sharder,
        )
    x = x + sharder(checkpoint_name(a, "blk_attn"), "act_resid")
    h = sharder(L.rmsnorm(p["ln2"], x, cfg.norm_eps), "act_block_in")
    x = x + sharder(checkpoint_name(L.mlp(p["mlp"], h, cfg), "blk_ffn"),
                    "act_resid")
    return x, kv


def _moe_block(cfg, p, x, positions, sharder, attn_impl):
    h = sharder(L.rmsnorm(p["ln1"], x, cfg.norm_eps), "act_block_in")
    a, kv, _ = L.gqa_attention(
        p["attn"], h, cfg, positions,
        window=cfg.sliding_window, attn_impl=attn_impl,
    )
    x = x + sharder(checkpoint_name(a, "blk_attn"), "act_resid")
    h = sharder(L.rmsnorm(p["ln2"], x, cfg.norm_eps), "act_block_in")
    y, aux = MOE.moe_apply(p["moe"], h, cfg, sharder=sharder)
    x = x + sharder(checkpoint_name(y, "blk_ffn"), "act_resid")
    return x, kv, aux


def _ssm_block(cfg, p, x, sharder):
    h = sharder(L.rmsnorm(p["ln"], x, cfg.norm_eps), "act_block_in")
    return x + sharder(
        checkpoint_name(SSM.ssm_apply(p["ssm"], h, cfg), "blk_ssm"),
        "act_resid",
    )


def _hybrid_block(cfg, p, x, positions, window, sharder):
    h = sharder(L.rmsnorm(p["ln1"], x, cfg.norm_eps), "act_block_in")
    a, kv, _ = L.gqa_attention(p["attn"], h, cfg, positions,
                               window=window, sharder=sharder)
    s = SSM.ssm_apply(p["ssm"], h, cfg, d_inner=cfg.d_model)
    fused = (
        p["beta_attn"] * L.rmsnorm(p["norm_attn"], a, cfg.norm_eps)
        + p["beta_ssm"] * L.rmsnorm(p["norm_ssm"], s, cfg.norm_eps)
    ) * 0.5
    x = x + sharder(checkpoint_name(fused.astype(x.dtype), "blk_attn"),
                    "act_resid")
    h = sharder(L.rmsnorm(p["ln2"], x, cfg.norm_eps), "act_block_in")
    x = x + sharder(checkpoint_name(L.mlp(p["mlp"], h, cfg), "blk_ffn"),
                    "act_resid")
    return x, kv


# ---------------------------------------------------------------------------
# Forward (train path): logits + aux loss
# ---------------------------------------------------------------------------
def forward(
    cfg: ModelConfig,
    params: Dict[str, Any],
    tokens: jax.Array,                       # (B, S)
    prefix_embeddings: Optional[jax.Array] = None,   # (B, F, D)
    sharder: Sharder = _noshard,
    remat: Optional[Callable] = None,
    attn_impl: str = "auto",
    unroll: bool = False,
    return_hidden: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B, S_total, V), moe_aux_loss scalar).

    ``return_hidden=True`` skips the LM head and returns the final-normed
    hidden states instead of logits (the chunked-loss path applies the
    head per sequence chunk so the (B, S, V) f32 logits never
    materialize — §Perf H2 iter 8)."""
    kind = _layer_kind(cfg)
    x = L.cast(jnp.take(params["embed"]["w"], tokens, axis=0), cfg)
    if prefix_embeddings is not None:
        x = jnp.concatenate([L.cast(prefix_embeddings, cfg), x], axis=1)
    x = sharder(x, "act_embed")
    s_total = x.shape[1]
    positions = jnp.arange(s_total, dtype=jnp.int32)
    windows = layer_windows(cfg, s_total)

    def body(carry, scanned):
        x, aux = carry
        if kind == "hybrid":
            p, w = scanned
            x, _ = _hybrid_block(cfg, p, x, positions, w, sharder)
        elif kind == "ssm":
            p = scanned
            x = _ssm_block(cfg, p, x, sharder)
        elif kind == "moe":
            p = scanned
            x, _, a = _moe_block(cfg, p, x, positions, sharder, attn_impl)
            aux = aux + a
        elif kind == "moe_period2":
            p = scanned
            x, _ = _dense_block(cfg, p["dense"], x, positions, sharder,
                                attn_impl)
            x, _, a = _moe_block(cfg, p["moe"], x, positions, sharder,
                                 attn_impl)
            aux = aux + a
        else:
            p = scanned
            x, _ = _dense_block(cfg, p, x, positions, sharder, attn_impl)
        return (x, aux), None

    if remat is not None:
        body = remat(body)

    xs = (
        (params["layers"], windows.reshape(cfg.num_layers))
        if kind == "hybrid"
        else params["layers"]
    )
    (x, aux), _ = _scan_or_unroll(
        body, (x, jnp.float32(0.0)), xs, _num_scan_steps(cfg), unroll
    )

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x, aux
    head_w = (
        params["embed"]["w"].T
        if cfg.tie_embeddings
        else params["lm_head"]["w"]
    )
    logits = x @ L.cast(head_w, cfg)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return sharder(logits.astype(jnp.float32), "logits"), aux


# ---------------------------------------------------------------------------
# Serving: caches, prefill, decode
# ---------------------------------------------------------------------------
def make_decode_cache(cfg: ModelConfig, batch: int, seq_len: int,
                      dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Zero-initialized decode cache sized for ``seq_len`` positions."""
    kind = _layer_kind(cfg)
    nl = cfg.num_layers
    cache: Dict[str, Any] = {}
    if kind in ("dense", "moe", "moe_period2", "hybrid"):
        if cfg.attention_type == "mla":
            cache["latent"] = jnp.zeros(
                (nl, batch, seq_len, cfg.kv_lora_rank), dtype
            )
            cache["rope"] = jnp.zeros(
                (nl, batch, seq_len, cfg.qk_rope_head_dim), dtype
            )
        else:
            steps = _num_scan_steps(cfg)
            per = 2 if kind == "moe_period2" else 1
            cache["k"] = jnp.zeros(
                (steps * per, batch, cfg.num_kv_heads, seq_len,
                 cfg.head_dim), dtype,
            )
            cache["v"] = jnp.zeros_like(cache["k"])
    if kind in ("ssm", "hybrid"):
        di = cfg.d_model if kind == "hybrid" else cfg.d_inner
        h = cfg.ssm_heads
        pd = cfg.ssm_head_dim
        n = cfg.ssm_state
        cache["ssd"] = jnp.zeros((nl, batch, h, pd, n), jnp.float32)
        cache["conv"] = jnp.zeros(
            (nl, batch, cfg.ssm_conv - 1, di + 2 * n), dtype
        )
    return cache


def prefill(
    cfg: ModelConfig,
    params: Dict[str, Any],
    tokens: jax.Array,                     # (B, S)
    cache_len: int,
    prefix_embeddings: Optional[jax.Array] = None,
    cache_dtype=jnp.bfloat16,
    sharder: Sharder = _noshard,
    unroll: bool = False,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Full-sequence pass that fills a decode cache of ``cache_len`` slots.

    Returns (last-position logits (B, V), cache).
    """
    kind = _layer_kind(cfg)
    batch = tokens.shape[0]
    x = L.cast(jnp.take(params["embed"]["w"], tokens, axis=0), cfg)
    if prefix_embeddings is not None:
        x = jnp.concatenate([L.cast(prefix_embeddings, cfg), x], axis=1)
    x = sharder(x, "act_embed")
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    windows = layer_windows(cfg, s)
    cache = make_decode_cache(cfg, batch, cache_len, cache_dtype)

    def pad_kv(k):
        # (B, Hkv, S, hd) -> (B, Hkv, cache_len, hd)
        return jnp.pad(
            k.astype(cache_dtype),
            ((0, 0), (0, 0), (0, cache_len - s), (0, 0)),
        )

    def body(carry, scanned):
        x = carry
        new = {}
        if kind == "hybrid":
            p, w = scanned
            h = sharder(L.rmsnorm(p["ln1"], x, cfg.norm_eps), "act_block_in")
            a, (k, v), _ = L.gqa_attention(p["attn"], h, cfg, positions,
                                           window=w, sharder=sharder)
            sout, sstate = SSM.ssm_prefill(p["ssm"], h, cfg,
                                           d_inner=cfg.d_model)
            fused = (
                p["beta_attn"] * L.rmsnorm(p["norm_attn"], a, cfg.norm_eps)
                + p["beta_ssm"] * L.rmsnorm(p["norm_ssm"], sout, cfg.norm_eps)
            ) * 0.5
            x = x + fused.astype(x.dtype)
            hh = sharder(L.rmsnorm(p["ln2"], x, cfg.norm_eps), "act_block_in")
            x = x + L.mlp(p["mlp"], hh, cfg)
            new = {"k": pad_kv(k), "v": pad_kv(v),
                   "ssd": sstate.ssd, "conv": sstate.conv}
        elif kind == "ssm":
            p = scanned
            h = sharder(L.rmsnorm(p["ln"], x, cfg.norm_eps), "act_block_in")
            sout, sstate = SSM.ssm_prefill(p["ssm"], h, cfg)
            x = x + sout
            new = {"ssd": sstate.ssd, "conv": sstate.conv}
        elif cfg.attention_type == "mla":
            p = scanned
            h = sharder(L.rmsnorm(p["ln1"], x, cfg.norm_eps), "act_block_in")
            a, (latent, k_rope), _ = L.mla_attention(p["attn"], h, cfg,
                                                     positions,
                                                     sharder=sharder)
            x = x + a
            hh = sharder(L.rmsnorm(p["ln2"], x, cfg.norm_eps), "act_block_in")
            x = x + L.mlp(p["mlp"], hh, cfg)
            new = {
                "latent": jnp.pad(
                    latent.astype(cache_dtype),
                    ((0, 0), (0, cache_len - s), (0, 0)),
                ),
                "rope": jnp.pad(
                    k_rope.astype(cache_dtype),
                    ((0, 0), (0, cache_len - s), (0, 0)),
                ),
            }
        elif kind == "moe_period2":
            p = scanned
            x, (k1, v1) = _dense_block(cfg, p["dense"], x, positions,
                                       sharder, "auto")
            x, (k2, v2), _ = _moe_block(cfg, p["moe"], x, positions,
                                        sharder, "auto")
            new = {
                "k": jnp.stack([pad_kv(k1), pad_kv(k2)]),
                "v": jnp.stack([pad_kv(v1), pad_kv(v2)]),
            }
        elif kind == "moe":
            p = scanned
            x, (k, v), _ = _moe_block(cfg, p, x, positions, sharder, "auto")
            new = {"k": pad_kv(k), "v": pad_kv(v)}
        else:
            p = scanned
            x, (k, v) = _dense_block(cfg, p, x, positions, sharder, "auto")
            new = {"k": pad_kv(k), "v": pad_kv(v)}
        return x, new

    xs = (
        (params["layers"], windows.reshape(cfg.num_layers))
        if kind == "hybrid"
        else params["layers"]
    )
    x, stacked_new = _scan_or_unroll(body, x, xs, _num_scan_steps(cfg),
                                     unroll)

    cache_out = make_decode_cache(cfg, batch, cache_len, cache_dtype)
    for key, val in stacked_new.items():
        if key in ("k", "v") and kind == "moe_period2":
            # (steps, 2, ...) -> (2*steps, ...) preserving layer order
            val = val.reshape((-1,) + val.shape[2:])
        cache_out[key] = val

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head_w = (
        params["embed"]["w"].T
        if cfg.tie_embeddings
        else params["lm_head"]["w"]
    )
    logits = (x[:, -1] @ L.cast(head_w, cfg)).astype(jnp.float32)
    return logits, cache_out


def decode_step(
    cfg: ModelConfig,
    params: Dict[str, Any],
    token: jax.Array,                # (B,) int32 — newest token
    cache: Dict[str, Any],
    pos,                             # scalar int32: write position
    sharder: Sharder = _noshard,
    return_attn_mass: bool = False,
    unroll: bool = False,
) -> Tuple[jax.Array, Dict[str, Any], Optional[jax.Array]]:
    """One decode step. Returns (logits (B, V), cache, attn_mass (B, S)|None).

    ``attn_mass`` is the per-cache-position attention probability mass
    summed over heads and averaged over layers — the importance score the
    RMQ eviction manager indexes (DESIGN.md §4).
    """
    kind = _layer_kind(cfg)
    x = L.cast(jnp.take(params["embed"]["w"], token[:, None], axis=0), cfg)
    windows = layer_windows(cfg, int(1e9)) if kind == "hybrid" else None

    def attn_probs_mass(q, kk, pos, s_cache):
        col = jnp.arange(s_cache)[None, None, None, :]
        scores = jnp.einsum(
            "bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
        ) / (q.shape[-1] ** 0.5)
        scores = jnp.where(col <= pos, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        return probs.sum(axis=(1, 2))

    def body(carry, scanned):
        x, mass = carry
        new_cache = {}
        if kind == "hybrid":
            p, w, ck, cv, cs, cc = scanned
            h = sharder(L.rmsnorm(p["ln1"], x, cfg.norm_eps), "act_block_in")
            a, (nk, nv) = L.gqa_decode(p["attn"], h, cfg, (ck, cv), pos,
                                       window=w)
            sout, sstate = SSM.ssm_decode(
                p["ssm"], h, cfg, SSM.SSMState(ssd=cs, conv=cc),
                d_inner=cfg.d_model,
            )
            fused = (
                p["beta_attn"] * L.rmsnorm(p["norm_attn"], a, cfg.norm_eps)
                + p["beta_ssm"] * L.rmsnorm(p["norm_ssm"], sout, cfg.norm_eps)
            ) * 0.5
            x = x + fused.astype(x.dtype)
            hh = sharder(L.rmsnorm(p["ln2"], x, cfg.norm_eps), "act_block_in")
            x = x + L.mlp(p["mlp"], hh, cfg)
            new_cache = {"k": nk, "v": nv, "ssd": sstate.ssd,
                         "conv": sstate.conv}
        elif kind == "ssm":
            p, cs, cc = scanned
            h = sharder(L.rmsnorm(p["ln"], x, cfg.norm_eps), "act_block_in")
            sout, sstate = SSM.ssm_decode(
                p["ssm"], h, cfg, SSM.SSMState(ssd=cs, conv=cc)
            )
            x = x + sout
            new_cache = {"ssd": sstate.ssd, "conv": sstate.conv}
        elif cfg.attention_type == "mla":
            p, clat, crope = scanned
            h = sharder(L.rmsnorm(p["ln1"], x, cfg.norm_eps), "act_block_in")
            a, (nlat, nrope) = L.mla_decode(p["attn"], h, cfg, (clat, crope),
                                            pos)
            x = x + a
            hh = sharder(L.rmsnorm(p["ln2"], x, cfg.norm_eps), "act_block_in")
            x = x + L.mlp(p["mlp"], hh, cfg)
            new_cache = {"latent": nlat, "rope": nrope}
        elif kind == "moe_period2":
            p, ck, cv = scanned   # ck/cv: (2, B, Hkv, S, hd)
            h = L.rmsnorm(p["dense"]["ln1"], x, cfg.norm_eps)
            a, (k1, v1) = L.gqa_decode(p["dense"]["attn"], h, cfg,
                                       (ck[0], cv[0]), pos)
            x = x + a
            hh = L.rmsnorm(p["dense"]["ln2"], x, cfg.norm_eps)
            x = x + L.mlp(p["dense"]["mlp"], hh, cfg)
            h = L.rmsnorm(p["moe"]["ln1"], x, cfg.norm_eps)
            a, (k2, v2) = L.gqa_decode(p["moe"]["attn"], h, cfg,
                                       (ck[1], cv[1]), pos)
            x = x + a
            hh = L.rmsnorm(p["moe"]["ln2"], x, cfg.norm_eps)
            y, _ = MOE.moe_apply(p["moe"]["moe"], hh, cfg)
            x = x + y
            new_cache = {"k": jnp.stack([k1, k2]), "v": jnp.stack([v1, v2])}
        else:
            p, ck, cv = scanned
            ln2_key = "ln2" if not cfg.parallel_block else None
            h = sharder(L.rmsnorm(p["ln1"], x, cfg.norm_eps), "act_block_in")
            a, (nk, nv) = L.gqa_decode(p["attn"], h, cfg, (ck, cv), pos,
                                       window=cfg.sliding_window)
            if return_attn_mass:
                # recompute q for the mass (cheap: one token)
                q = L._split_heads(
                    L.dense(p["attn"]["q"], h, cfg),
                    cfg.num_heads, cfg.head_dim,
                )
                q = L.apply_rope(q, jnp.full((1,), pos, jnp.int32),
                                 cfg.rope_theta)
                grp = cfg.num_heads // cfg.num_kv_heads
                kk = jnp.repeat(nk, grp, axis=1) if grp > 1 else nk
                mass = mass + attn_probs_mass(q, kk, pos, nk.shape[2])
            if kind == "moe":
                x = x + a
                hh = sharder(L.rmsnorm(p["ln2"], x, cfg.norm_eps), "act_block_in")
                y, _ = MOE.moe_apply(p["moe"], hh, cfg)
                x = x + y
            elif cfg.parallel_block:
                m = L.mlp(p["mlp"], h, cfg)
                x = x + a + m
            else:
                x = x + a
                hh = L.rmsnorm(p[ln2_key], x, cfg.norm_eps)
                x = x + L.mlp(p["mlp"], hh, cfg)
            new_cache = {"k": nk, "v": nv}
        return (x, mass), new_cache

    # assemble scanned inputs per kind
    if kind == "hybrid":
        xs = (params["layers"], windows.reshape(cfg.num_layers),
              cache["k"], cache["v"], cache["ssd"], cache["conv"])
    elif kind == "ssm":
        xs = (params["layers"], cache["ssd"], cache["conv"])
    elif cfg.attention_type == "mla":
        xs = (params["layers"], cache["latent"], cache["rope"])
    elif kind == "moe_period2":
        steps = _num_scan_steps(cfg)
        ck = cache["k"].reshape((steps, 2) + cache["k"].shape[1:])
        cv = cache["v"].reshape((steps, 2) + cache["v"].shape[1:])
        xs = (params["layers"], ck, cv)
    else:
        xs = (params["layers"], cache["k"], cache["v"])

    batch = token.shape[0]
    s_cache = 0
    if "k" in cache:
        s_cache = cache["k"].shape[-2]
    mass0 = jnp.zeros((batch, max(s_cache, 1)), jnp.float32)
    (x, mass), new_stacked = _scan_or_unroll(body, (x, mass0), xs,
                                             _num_scan_steps(cfg), unroll)

    new_cache = dict(cache)
    for key, val in new_stacked.items():
        if key in ("k", "v") and kind == "moe_period2":
            val = val.reshape((-1,) + val.shape[2:])
        new_cache[key] = val

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head_w = (
        params["embed"]["w"].T
        if cfg.tie_embeddings
        else params["lm_head"]["w"]
    )
    logits = (x[:, 0] @ L.cast(head_w, cfg)).astype(jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    if return_attn_mass and s_cache:
        mass = mass / _num_scan_steps(cfg)
        return logits, new_cache, mass
    return logits, new_cache, None
