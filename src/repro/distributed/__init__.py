from repro.distributed.shardings import (
    batch_shardings,
    cache_shardings,
    make_sharder,
    param_shardings,
    train_state_shardings,
)

__all__ = [
    "batch_shardings",
    "cache_shardings",
    "make_sharder",
    "param_shardings",
    "train_state_shardings",
]
