"""Gradient compression: int8 quantization with error feedback.

Used as an optional stage between gradient computation and the optimizer
(``launch/train.py --grad-compression int8``).  Per-tensor symmetric int8
quantization; the quantization error is carried in an error-feedback
accumulator and re-injected next step (Seide et al. / EF-SGD), which keeps
convergence intact (verified in tests/test_train.py::test_int8_error_feedback).

The bf16-accumulator path (TrainConfig.grad_allreduce_dtype) is the
always-on "cheap" compression; this module is the aggressive 4× option for
interconnect-bound regimes (the §Roofline collective term tells you when).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads_with_ef(grads: Any, ef: Any) -> Tuple[Any, Any]:
    """Returns (dequantized grads as would survive the wire, new ef)."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        restored = dequantize_int8(q, scale)
        return restored, corrected - restored

    out = jax.tree.map(one, grads, ef)
    restored = jax.tree.map(
        lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple)
    )
    new_ef = jax.tree.map(
        lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple)
    )
    return restored, new_ef
