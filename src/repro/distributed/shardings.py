"""MaxText-style logical sharding rules for params, activations, caches.

Logical axes:
* ``fsdp``   — weight sharding across the data-parallel axes
               (("pod", "data") on the multi-pod mesh, ("data",) otherwise);
               ZeRO-3: optimizer state inherits the same specs.
* ``tensor`` — the ``model`` mesh axis: attention heads / FFN width /
               MoE expert width / vocab.
* ``dp``     — activation batch dim across ("pod", "data").
* decode caches shard their *sequence* axis over ``model`` (context
  parallelism): kv-head counts (8, 5, ...) rarely divide a 16-wide tensor
  axis, sequence length always does. See DESIGN.md §5.

Rules match on the *suffix* of the flattened parameter path; stacked layer
params (leading L dim from scan) automatically get a ``None`` prepended.
"""

from __future__ import annotations

import re
from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return fsdp_axes(mesh)


# (path-suffix regex, spec builder) — first match wins. ``F`` = fsdp axes.
def _rules(F):
    T = "model"
    return [
        # embeddings / head
        (r"embed/w$",               P(T, F)),
        (r"lm_head/w$",             P(F, T)),
        # attention (GQA)
        (r"attn/(q|k|v)/w$",        P(F, T)),
        (r"attn/(q|k|v)/b$",        P(T)),
        (r"attn/o/w$",              P(T, F)),
        # attention (MLA)
        (r"attn/q_a/w$",            P(F, None)),
        (r"attn/q_b/w$",            P(None, T)),
        (r"attn/kv_a/w$",           P(F, None)),
        (r"attn/kv_b/w$",           P(None, T)),
        # dense mlp
        (r"mlp/(gate|up)/w$",       P(F, T)),
        (r"mlp/down/w$",            P(T, F)),
        # moe
        (r"moe/router$",            P(F, None)),
        (r"moe/w_(gate|up)$",       P(None, F, T)),
        (r"moe/w_down$",            P(None, T, F)),
        (r"moe/shared/(gate|up)/w$", P(F, T)),
        (r"moe/shared/down/w$",     P(T, F)),
        (r"moe/shared_gate$",       P(F, None)),
        # ssm (FSDP only; TP-over-heads is a recorded hillclimb candidate)
        (r"ssm/in_proj/w$",         P(F, None)),
        (r"ssm/out_proj/w$",        P(None, F)),
        (r"ssm/conv_w$",            P(None, None)),
        # everything 1-D (norms, biases, scalars) replicated
        (r".*",                     P()),
    ]


def _spec_for(path: str, ndim: int, rules) -> P:
    for pat, spec in rules:
        if re.search(pat, path):
            parts = tuple(spec)
            if path.startswith("layers/") and len(parts) < ndim:
                parts = (None,) * (ndim - len(parts)) + parts
            if len(parts) < ndim:
                parts = parts + (None,) * (ndim - len(parts))
            if len(parts) > ndim:
                # rule written for unstacked weights; trim leading Nones
                parts = parts[len(parts) - ndim:]
            return P(*parts)
    return P()


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def all_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data", "model") if a in mesh.shape)


def param_shardings(mesh: Mesh, params_like: Any,
                    layout: str = "tp_sp") -> Any:
    """Pytree of NamedShardings matching ``params_like``.

    layout:
    * ``tp_sp`` — tensor parallelism over ``model`` + FSDP over data axes
      (+ Megatron-SP activations via make_sharder). Right for MoE/huge
      models where per-device batch stays >= a few sequences.
    * ``fsdp``  — pure ZeRO-3: every large weight sharded over ALL mesh
      axes on its largest dim, batch sharded over all axes too; no tensor
      parallelism. Wins for big *dense* models at small per-device batch:
      weight gathers (GiB/layer) beat activation reshards (tens of
      GiB/layer) — measured 7x collective reduction on
      command-r-plus-104b train_4k (§Perf H2 iter 5).
    """
    rules = _rules(fsdp_axes(mesh))
    combined = all_axes(mesh)

    def assign(path, leaf):
        if layout == "fsdp":
            nd = len(leaf.shape)
            p_str = _path_str(path)
            if nd >= 2 and "moe/w_" not in p_str:
                # shard the largest dim over all axes (guarded below)
                big = max(range(nd), key=lambda i: leaf.shape[i])
                parts = [None] * nd
                parts[big] = combined
                spec = P(*parts)
            else:
                spec = _spec_for(p_str, nd, rules)
        else:
            spec = _spec_for(_path_str(path), len(leaf.shape), rules)
        # divisibility guard: drop sharding on axes that don't divide
        parts = list(spec)
        for i, ax in enumerate(parts):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if leaf.shape[i] % size != 0:
                parts[i] = None
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(assign, params_like)


def train_state_shardings(mesh: Mesh, state_like: Any,
                          layout: str = "tp_sp") -> Any:
    """ZeRO-3: m/v shard exactly like their params; step replicated."""
    from repro.train.train_step import TrainState
    from repro.train.optimizer import AdamWState

    return TrainState(
        params=param_shardings(mesh, state_like.params, layout),
        opt=AdamWState(
            m=param_shardings(mesh, state_like.opt.m, layout),
            v=param_shardings(mesh, state_like.opt.v, layout),
            count=NamedSharding(mesh, P()),
        ),
        step=NamedSharding(mesh, P()),
    )


def batch_shardings(mesh: Mesh, batch_like: Any,
                    layout: str = "tp_sp") -> Any:
    dp = all_axes(mesh) if layout == "fsdp" else dp_axes(mesh)

    def assign(path, leaf):
        parts = [dp] + [None] * (len(leaf.shape) - 1)
        if leaf.shape[0] % int(np.prod([mesh.shape[a] for a in dp])) != 0:
            parts[0] = dp_axes(mesh)  # fall back (e.g. batch < devices)
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(assign, batch_like)


def cache_shardings(mesh: Mesh, cache_like: Any) -> Any:
    """Decode caches: batch over dp, sequence over model."""
    dp = dp_axes(mesh)

    def assign(path, leaf):
        name = _path_str(path).split("/")[-1]
        nd = len(leaf.shape)
        if name in ("k", "v"):
            # (L, B, Hkv, S, hd)
            spec = P(None, dp, None, "model", None)
        elif name in ("latent", "rope"):
            # (L, B, S, R)
            spec = P(None, dp, "model", None)
        elif name in ("ssd", "conv"):
            # (L, B, ...) — constant-size state: batch only
            spec = P(*((None, dp) + (None,) * (nd - 2)))
        else:
            spec = P(*((None,) * nd))
        # divisibility guard
        parts = list(spec)
        for i, ax in enumerate(parts):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if leaf.shape[i] % size != 0:
                parts[i] = None
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(assign, cache_like)


def make_sharder(mesh: Mesh, sequence_sharding: bool = False,
                 layout: str = "tp_sp"):
    """Activation sharding-constraint callback for the model (lm.Sharder).

    ``sequence_sharding=True`` additionally shards the sequence dim of
    residual activations over ``model`` (SP) — bounds live-activation
    bytes for the remat'd residual stream (used by the big dense configs).
    """
    dp = all_axes(mesh) if layout == "fsdp" else dp_axes(mesh)
    if layout == "fsdp":
        sequence_sharding = False

    specs = {
        "act_embed": P(dp, "model" if sequence_sharding else None, None),
        "act_resid": P(dp, "model" if sequence_sharding else None, None),
        "logits": P(dp, None, None) if layout == "fsdp"
        else P(dp, None, "model"),
        # MoE dispatch buffers: token/slot dims over dp so the scatter
        # buffers never replicate (they dominated temp memory otherwise)
        "moe_dispatch": P(dp, None),          # (T*k, D)
        "moe_expert_in": P(None, dp, None),   # (E, cap, D)
        # NOTE (§Perf final-sweep): two explored constraints are
        # deliberately ABSENT here — "act_heads" (pin q/k/v heads over
        # model) and "act_block_in" (Megatron-SP gather at block entry).
        # Both helped the command-r TP+SP pathology they were built for,
        # but that arch moved to the fsdp layout where they're moot, and
        # on every other arch they forced extra gathers (seq-sharded MLPs
        # are already communication-free; pinning gathered them).
        # gathered LM-head weights for the chunked loss (2-D (D, V)):
        # fsdp layout gathers fully once; tp_sp keeps vocab on model
        "loss_head_w": P(None, None) if layout == "fsdp"
        else P(None, "model"),
    }

    def sharder(x, name):
        spec = specs.get(name)
        if spec is None:
            return x
        parts = list(spec)[: x.ndim]
        for i, ax in enumerate(parts):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if x.shape[i] % size != 0:
                # non-divisible: SKIP the constraint entirely — pinning
                # the remaining axes would FORCE replication of this dim,
                # which is far worse than letting GSPMD choose (it cost
                # 6x HBM traffic on 24-head archs; §Perf final-sweep note)
                return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*parts))
        )

    sharder.mesh = mesh   # used by the MoE shard_map dispatch path
    return sharder
