"""Fault tolerance: heartbeats, straggler detection, elastic re-mesh.

This module is hardware-independent host logic; the CPU test-suite
exercises it with simulated hosts and injected failures, and the same code
paths drive a real multi-host deployment (the heartbeat transport would be
the only swap — here an in-memory dict stands in for a kv-store).

Components:

* ``HeartbeatMonitor`` — hosts report per-step completion timestamps;
  ``stragglers()`` flags hosts slower than ``threshold ×`` the fleet
  median over a sliding window; ``dead()`` flags hosts silent for
  ``timeout`` seconds.  Policy hooks decide warn / exclude.
* ``plan_remesh`` — given surviving host count, pick the largest
  production mesh that fits ((2,16,16) → (1,16,16) → (8,16) ...), keeping
  the ``model`` axis intact (tensor-sharded weights must keep their axis;
  only data-parallel width shrinks — capacity degrades, math doesn't).
* ``ElasticTrainDriver`` (in launch/train.py) composes these with the
  checkpoint manager: on failure → remesh → restore latest checkpoint with
  the new mesh's shardings → reshard the data pipeline → continue.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class HeartbeatMonitor:
    num_hosts: int
    straggler_threshold: float = 2.0     # × median step time
    dead_timeout: float = 60.0           # seconds of silence
    window: int = 16

    def __post_init__(self):
        self._beats: Dict[int, List[Tuple[int, float]]] = {
            h: [] for h in range(self.num_hosts)
        }
        self._excluded: set = set()

    def report(self, host: int, step: int, t: Optional[float] = None):
        if host in self._excluded:
            return
        self._beats[host].append((step, t if t is not None else time.time()))
        self._beats[host] = self._beats[host][-self.window :]

    def step_times(self, host: int) -> List[float]:
        beats = self._beats[host]
        return [b[1] - a[1] for a, b in zip(beats, beats[1:])]

    def stragglers(self) -> List[int]:
        per_host = {
            h: (sum(ts) / len(ts))
            for h, ts in ((h, self.step_times(h))
                          for h in self._beats if h not in self._excluded)
            if ts
        }
        if len(per_host) < 2:
            return []
        med = sorted(per_host.values())[len(per_host) // 2]
        return [
            h for h, t in per_host.items()
            if t > self.straggler_threshold * med
        ]

    def dead(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.time()
        out = []
        for h, beats in self._beats.items():
            if h in self._excluded:
                continue
            if not beats or now - beats[-1][1] > self.dead_timeout:
                out.append(h)
        return out

    def exclude(self, host: int):
        self._excluded.add(host)

    @property
    def active_hosts(self) -> int:
        return self.num_hosts - len(self._excluded)


# Production mesh ladder: preserve the model axis, shrink data parallelism.
_MESH_LADDER: Sequence[Tuple[Tuple[int, ...], Tuple[str, ...]]] = (
    ((2, 16, 16), ("pod", "data", "model")),
    ((1, 16, 16), ("pod", "data", "model")),
    ((16, 16), ("data", "model")),
    ((8, 16), ("data", "model")),
    ((4, 16), ("data", "model")),
    ((2, 16), ("data", "model")),
    ((1, 16), ("data", "model")),
)


def plan_remesh(available_chips: int,
                require_model: int = 16) -> Tuple[Tuple[int, ...],
                                                  Tuple[str, ...]]:
    """Largest ladder entry that fits the surviving chip count."""
    for shape, axes in _MESH_LADDER:
        chips = 1
        for s in shape:
            chips *= s
        model = shape[axes.index("model")]
        if chips <= available_chips and model == require_model:
            return shape, axes
    raise RuntimeError(
        f"cannot build a mesh with model={require_model} from "
        f"{available_chips} chips"
    )


def global_batch_for(shape: Tuple[int, ...], axes: Tuple[str, ...],
                     per_replica_batch: int) -> int:
    """Data-parallel width × per-replica batch (elastic batch policy:
    keep per-replica batch fixed, let global batch scale with survivors —
    the alternative fixed-global policy is a flag in launch/train.py)."""
    dp = 1
    for s, a in zip(shape, axes):
        if a in ("pod", "data"):
            dp *= s
    return dp * per_replica_batch
