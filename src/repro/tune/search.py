"""The geometry/engine autotuner (paper §4 + Fig. 12, made operational).

Searches ``(c, t, backend, planner mode, long_cutoff)`` per
``(platform, n-bucket, span-mix)`` by *measuring the engines we actually
serve*: every candidate geometry is built once, then timed through a
routed :class:`~repro.qe.QueryEngine` (host-side class split) AND a
fused one (single-launch path) over span-class-pinned workloads — the
hierarchy is bit-identical across backends, so one build serves both
engines.  Winners become :class:`~repro.tune.cache.TunedConfig` entries
in a :class:`~repro.tune.cache.TuningCache`.

On top of the geometry sweep, :meth:`Autotuner.measure_crossover` finds
the *measured* routed-vs-sparse-top crossover: the smallest span where
the O(1) sparse-table top beats the hierarchy walk.  That number
replaces the planner's analytic ``2c·c^(L-2)`` guess (which describes
when a span *must* reach the top level, not when the sparse top is
actually faster) as the routed planner's ``long_cutoff``.

Configs where ``c * t >= n`` degenerate to a single level (a pure scan)
and are *skipped but reported* — no silent caps: every skip carries its
reason into the report and the benchmark output.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.tune.cache import (
    SPAN_MIXES,
    TunedConfig,
    TuningCache,
    current_platform,
)
from repro.tune.measure import (
    make_input_array,
    make_span_queries,
    time_fn,
)

__all__ = ["Autotuner", "Measurement", "SkippedConfig",
           "DEFAULT_GEOMETRIES", "TINY_GEOMETRIES"]

# The paper's Fig. 12 grid (VL regime c=8 through atom-aligned c=512).
DEFAULT_GEOMETRIES: Tuple[Tuple[int, int], ...] = (
    (8, 8), (8, 64),
    (32, 8), (32, 64),
    (128, 8), (128, 64),
    (256, 8), (256, 64),
    (512, 8),
)

# CI-smoke subset: small chunks so tiny arrays still get multi-level
# plans (same reasoning as REPRO_BENCH_TINY elsewhere).
TINY_GEOMETRIES: Tuple[Tuple[int, int], ...] = ((8, 8), (16, 8), (32, 8))


@dataclasses.dataclass(frozen=True)
class Measurement:
    """One timed candidate: a (geometry, backend) on one workload."""

    n: int
    span_mix: str
    c: int
    t: int
    backend: str
    ns_per_query: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class SkippedConfig:
    """A candidate excluded from the sweep, with its reason (reported,
    never silently dropped)."""

    n: int
    c: int
    t: int
    reason: str

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class Autotuner:
    """Measure candidate configs and produce a populated tuning cache.

    ``backends`` are *query* lowerings to race (``"jax"`` = the routed
    class-split engine, ``"fused"`` = the single-launch engine; add
    ``"pallas"`` on TPU hosts).  ``m``/``repeats`` trade search time for
    measurement stability; the defaults match the committed benchmark
    discipline (warmup + median, see :func:`repro.tune.measure.time_fn`).
    """

    def __init__(
        self,
        geometries: Sequence[Tuple[int, int]] = DEFAULT_GEOMETRIES,
        backends: Sequence[str] = ("jax", "fused"),
        span_mixes: Sequence[str] = SPAN_MIXES,
        m: int = 4096,
        repeats: int = 3,
        crossover_points: int = 5,
        seed: int = 0,
        log: Optional[Callable[[str], None]] = None,
    ):
        for mix in span_mixes:
            if mix not in SPAN_MIXES:
                raise ValueError(
                    f"span mix {mix!r} not in {SPAN_MIXES}")
        self.geometries = tuple(geometries)
        self.backends = tuple(backends)
        self.span_mixes = tuple(span_mixes)
        self.m = int(m)
        self.repeats = int(repeats)
        self.crossover_points = int(crossover_points)
        self.seed = seed
        self._log = log or (lambda msg: None)

    def reference_c(self, n: int) -> int:
        """The chunk size that *defines* the span-mix workloads.

        Every candidate geometry must race on the SAME queries or the
        winner comparison is meaningless, so spans are pinned relative
        to the served default chunk (c=128 — also what the committed
        benchmarks measure), stepped down only when ``n`` is too small
        for a valid mid-span band (``4c < n``).
        """
        c = 128
        while c > 2 and 4 * c >= n:
            c //= 2
        return c

    # -- one size ----------------------------------------------------------
    def search_size(self, n: int) -> Tuple[
            Dict[str, TunedConfig], List[Measurement], List[SkippedConfig]]:
        """Race every candidate on one array size.

        Returns ``(winners by span mix, all measurements, skipped)``.
        """
        from repro.core.api import RMQ
        from repro.qe import QueryEngine

        x = make_input_array(n)
        best: Dict[str, Tuple[float, Measurement]] = {}
        measurements: List[Measurement] = []
        skipped: List[SkippedConfig] = []
        crossover_geom: Dict[str, Tuple[int, int]] = {}
        ref_c = self.reference_c(n)
        workloads = {
            mix: make_span_queries(n, self.m, ref_c, mix,
                                   seed=self.seed + 1)
            for mix in self.span_mixes
        }

        for c, t in self.geometries:
            if c * t >= n:
                skipped.append(SkippedConfig(
                    n, c, t,
                    f"c*t = {c * t} >= n = {n}: plan degenerates to a "
                    "single level (pure scan)"))
                self._log(f"skip n={n} c={c} t={t}: c*t >= n")
                continue
            # ONE build per geometry: hierarchies are bit-identical
            # across backends, so every engine races over the same index.
            index = RMQ.build(x, c=c, t=t, backend="jax")
            engines = {
                b: QueryEngine(index, cache_size=0, backend=b)
                for b in self.backends
            }
            for mix in self.span_mixes:
                ls, rs = workloads[mix]
                for backend, engine in engines.items():
                    secs = time_fn(lambda e=engine: e.query(ls, rs),
                                   repeats=self.repeats)
                    meas = Measurement(
                        n=n, span_mix=mix, c=c, t=t, backend=backend,
                        ns_per_query=secs / self.m * 1e9)
                    measurements.append(meas)
                    self._log(
                        f"n={n} mix={mix} c={c} t={t} {backend}: "
                        f"{meas.ns_per_query:.0f} ns/q")
                    prev = best.get(mix)
                    if prev is None or meas.ns_per_query < prev[0]:
                        best[mix] = (meas.ns_per_query, meas)

        winners: Dict[str, TunedConfig] = {}
        bulk_by_geom: Dict[Tuple[int, int], Optional[int]] = {}
        for mix, (_, meas) in best.items():
            long_cutoff = None
            if meas.backend != "fused":
                geom = (meas.c, meas.t)
                if geom not in crossover_geom.values():
                    crossover_geom[mix] = geom
                long_cutoff = self.measure_crossover(n, meas.c, meas.t)
            # The bulk crossover depends on geometry, not span mix, so
            # mixes sharing a winning (c, t) share one measurement.
            geom = (meas.c, meas.t)
            if geom not in bulk_by_geom:
                bulk_by_geom[geom] = self.measure_bulk_crossover(
                    n, meas.c, meas.t)
            winners[mix] = TunedConfig(
                c=meas.c, t=meas.t, backend=meas.backend,
                planner="fused" if meas.backend == "fused" else "routed",
                long_cutoff=long_cutoff,
                ns_per_query=meas.ns_per_query,
                bulk_crossover=bulk_by_geom[geom],
            )
        return winners, measurements, skipped

    # -- the routed-vs-sparse-top crossover --------------------------------
    def measure_crossover(self, n: int, c: int, t: int) -> Optional[int]:
        """Smallest span where the O(1) sparse-table top beats the walk.

        Races two routed engines over span-pinned batches: one with the
        long route disabled (every span walks the hierarchy) and one
        whose ``long_cutoff`` admits every candidate span to the
        sparse-table top.  Returns the first candidate span the top
        wins, or ``None`` when the walk wins everywhere (the planner
        then keeps its analytic default — graceful, never worse).
        """
        from repro.core.api import RMQ
        from repro.qe import QueryEngine

        if n <= c * t:
            return None
        x = make_input_array(n)
        index = RMQ.build(x, c=c, t=t, backend="jax")
        lo = max(4 * c, 2 * c + 2)
        hi = max(n // 2, lo + 1)
        spans = sorted({
            int(s) for s in np.geomspace(lo, hi, self.crossover_points)
        })
        walk = QueryEngine(index, cache_size=0, backend="jax",
                           long_enabled=False)
        top = QueryEngine(index, cache_size=0, backend="jax",
                          long_cutoff=spans[0])
        rng = np.random.default_rng(self.seed + 2)
        for span in spans:
            ls = (rng.random(self.m) * (n - span + 1)).astype(np.int32)
            rs = (ls + span - 1).astype(np.int32)
            t_walk = time_fn(lambda: walk.query(ls, rs),
                             repeats=self.repeats)
            t_top = time_fn(lambda: top.query(ls, rs),
                            repeats=self.repeats)
            self._log(
                f"crossover n={n} c={c} span={span}: walk "
                f"{t_walk / self.m * 1e9:.0f} vs top "
                f"{t_top / self.m * 1e9:.0f} ns/q")
            if t_top < t_walk:
                return span
        return None

    # -- the bulk-vs-fused batch-size crossover ----------------------------
    def measure_bulk_crossover(self, n: int, c: int,
                               t: int) -> Optional[int]:
        """Smallest batch where the bulk coalesced sweep beats fused.

        Races the fused per-query engine against a bulk-forced engine
        (``bulk_crossover=1`` routes every batch through the
        endpoint-sorted ``rmq_bulk`` pass) over geometrically spaced
        batch sizes of the same mixed-span workload.  Returns the first
        batch size bulk wins, or ``None`` when fused wins at every
        probed size — the engine then keeps its analytic model, never a
        mis-tuned early switch.
        """
        from repro.core.api import RMQ
        from repro.qe import QueryEngine

        x = make_input_array(n)
        index = RMQ.build(x, c=c, t=t, backend="jax")
        fused = QueryEngine(index, cache_size=0, backend="fused")
        bulk = QueryEngine(index, cache_size=0, backend="fused",
                           bulk_crossover=1)
        sizes = sorted({
            int(b) for b in np.geomspace(
                self.m, 64 * self.m, self.crossover_points)
        })
        for m in sizes:
            ls, rs = make_span_queries(n, m, self.reference_c(n),
                                       "mixed", seed=self.seed + 3)
            t_fused = time_fn(lambda: fused.query(ls, rs),
                              repeats=self.repeats)
            t_bulk = time_fn(lambda: bulk.query_bulk(ls, rs),
                             repeats=self.repeats)
            self._log(
                f"bulk crossover n={n} c={c} t={t} m={m}: fused "
                f"{t_fused / m * 1e9:.0f} vs bulk "
                f"{t_bulk / m * 1e9:.0f} ns/q")
            if t_bulk < t_fused:
                return m
        return None

    # -- the full search ---------------------------------------------------
    def search(self, sizes: Sequence[int],
               platform: Optional[str] = None
               ) -> Tuple[TuningCache, dict]:
        """Populate a cache for ``sizes`` on ``platform`` (default: the
        running JAX backend).  Returns ``(cache, report)`` where the
        report carries every measurement and every skipped config."""
        platform = platform or current_platform()
        cache = TuningCache()
        report = {
            "platform": platform,
            "sizes": [int(s) for s in sizes],
            "geometries": [list(g) for g in self.geometries],
            "backends": list(self.backends),
            "m": self.m,
            "repeats": self.repeats,
            "measurements": [],
            "skipped": [],
            "winners": {},
        }
        for n in sizes:
            winners, measurements, skipped = self.search_size(int(n))
            report["measurements"] += [m.as_dict() for m in measurements]
            report["skipped"] += [s.as_dict() for s in skipped]
            for mix, cfg in winners.items():
                cache.put(platform, int(n), mix, cfg)
                report["winners"][f"n{n}_{mix}"] = cfg.as_dict()
                self._log(
                    f"winner n={n} mix={mix}: c={cfg.c} t={cfg.t} "
                    f"{cfg.backend}/{cfg.planner} "
                    f"long_cutoff={cfg.long_cutoff} "
                    f"({cfg.ns_per_query:.0f} ns/q)")
        return cache, report
