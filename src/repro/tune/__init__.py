"""``repro.tune`` — geometry autotuning for the RMQ hierarchy.

GPU-RMQ's headline design (paper §4, Fig. 12) is *hybrid*: no single
``(c, t)`` geometry or execution engine wins across array sizes and
span mixes.  This package makes that choice measured instead of
guessed:

* :mod:`repro.tune.measure` — the timing discipline + paper workload
  generators (benchmarks are thin callers over these);
* :mod:`repro.tune.search` — the :class:`Autotuner`: races candidate
  geometries through routed AND fused engines per span mix, measures
  the routed-vs-sparse-top ``long_cutoff`` crossover, reports skipped
  configs;
* :mod:`repro.tune.cache` — the versioned, schema-validated JSON
  tuning cache (:class:`TuningCache` / :class:`TunedConfig`) consumed
  by ``make_plan(..., tuned=True)``, ``RMQ.build(c="auto")``, and
  ``QueryEngine(tuning=...)``;
* :mod:`repro.tune.roofline` — the hardware roofline model.

Regenerate the committed CPU cache with ``python -m repro.tune``.
"""

from repro.tune.cache import (
    DEFAULT_CACHE_PATH,
    SCHEMA_VERSION,
    SPAN_MIXES,
    TunedConfig,
    TuningCache,
    TuningCacheError,
    current_platform,
    default_cache,
    n_bucket,
)
from repro.tune.measure import (
    make_input_array,
    make_queries,
    make_span_queries,
    time_fn,
)
from repro.tune.search import (
    DEFAULT_GEOMETRIES,
    TINY_GEOMETRIES,
    Autotuner,
    Measurement,
    SkippedConfig,
)

__all__ = [
    "Autotuner",
    "DEFAULT_CACHE_PATH",
    "DEFAULT_GEOMETRIES",
    "Measurement",
    "SCHEMA_VERSION",
    "SPAN_MIXES",
    "SkippedConfig",
    "TINY_GEOMETRIES",
    "TunedConfig",
    "TuningCache",
    "TuningCacheError",
    "current_platform",
    "default_cache",
    "make_input_array",
    "make_queries",
    "make_span_queries",
    "n_bucket",
    "time_fn",
]
