"""Roofline analysis machinery (moved from ``benchmarks/roofline.py``).

The benchmark is now a thin caller over this module so the autotuner
and the benchmark share one implementation of the hardware model.

Reads dry-run artifacts (written by ``repro.launch.dryrun --all
--calibrate``) and derives, per (arch × shape):

  compute term    = HLO_FLOPs_per_device / peak_FLOPs
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = Σ_k factor_k · collective_bytes_k_per_device / ICI_bw

Hardware constants (TPU v5e class):
  peak 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Notes on sourcing:
* FLOPs/bytes use the *calibrated* numbers (2/4-layer unrolled compiles
  extrapolated to depth) because XLA cost analysis counts while bodies
  once; the raw production-compile numbers are kept for reference.
* collective bytes are parsed from partitioned HLO result shapes
  (per-device); ring factors: all-reduce 2×(k-1)/k ≈ 2, all-gather /
  reduce-scatter / all-to-all / collective-permute (k-1)/k ≈ 1.
* MODEL_FLOPS = 6·N·D for training (N = params, D = tokens; N_active for
  MoE), 2·N_active·B per decode step, 2·N_active·D + attention for
  prefill.  The ratio MODEL_FLOPS/HLO_FLOPs flags remat / redundant
  compute (ratio < 1 ⇒ HLO does extra work: remat recompute, z-loss,
  attention, optimizer math).
"""

from __future__ import annotations

import json
import os
from typing import Dict, Optional

__all__ = [
    "PEAK_FLOPS", "HBM_BW", "ICI_BW",
    "model_flops_per_device", "analyse_record", "load_results",
    "render_table",
]

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_COLL_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def model_flops_per_device(arch: str, shape: str, chips: int) -> float:
    from repro.configs.base import get_config
    from repro.launch.cells import SHAPES

    cfg = get_config(arch)
    spec = SHAPES[shape]
    n_active = cfg.num_active_params()
    seq, gb = spec["seq_len"], spec["global_batch"]
    if spec["kind"] == "train":
        total = 6.0 * n_active * (seq * gb)
    elif spec["kind"] == "prefill":
        total = 2.0 * n_active * (seq * gb)
    else:  # decode: one token per sequence
        total = 2.0 * n_active * gb
    return total / chips


def analyse_record(rec: Dict, chips: int) -> Optional[Dict]:
    if rec.get("skipped"):
        return {
            "arch": rec["arch"], "shape": rec["shape"],
            "skipped": rec["skipped"],
        }
    if not rec.get("ok", False):
        return {
            "arch": rec["arch"], "shape": rec["shape"],
            "error": rec.get("error", "unknown"),
        }
    cal = rec.get("calibrated")
    flops = (cal or rec)["flops_per_device"]
    hbm_bytes = (cal or rec)["bytes_per_device"]
    colls = (cal or rec)["collective_bytes"]

    t_compute = flops / PEAK_FLOPS
    t_memory = hbm_bytes / HBM_BW
    t_coll = sum(
        _COLL_FACTOR.get(k, 1.0) * v for k, v in colls.items()
    ) / ICI_BW

    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], chips)
    t_ideal = max(mf / PEAK_FLOPS, 1e-12)
    t_bound = max(terms.values())
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec.get("mesh_desc", "single"),
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops_per_device": mf,
        "hlo_flops_per_device": flops,
        "useful_flops_ratio": mf / max(flops, 1.0),
        # fraction of the ideal (model-flops-only) roofline achieved if
        # the step runs at its binding term
        "roofline_fraction": t_ideal / t_bound if t_bound > 0 else 0.0,
        "calibrated": cal is not None,
        "temp_gib": rec.get("temp_bytes", 0) / 2**30,
        "args_gib": rec.get("argument_bytes", 0) / 2**30,
    }


def load_results(path: str) -> Dict:
    out = {}
    if not os.path.exists(path):
        return out
    with open(path) as f:
        for line in f:
            rec = json.loads(line)
            out[(rec["arch"], rec["shape"])] = rec  # last write wins
    return out


def render_table(rows) -> str:
    hdr = ("| arch | shape | compute(s) | memory(s) | collective(s) | "
           "bottleneck | useful-FLOPs | roofline-frac | temp GiB |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if "skipped" in r:
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | — | — "
                f"| — |"
            )
            continue
        if "error" in r:
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | "
                f"ERROR: {r['error'][:40]} | — | — | — |"
            )
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} | {r['temp_gib']:.1f} |"
        )
    return "\n".join(lines)
