"""CLI: run the autotuner and write the tuning cache.

    python -m repro.tune [--out results/tuning_cache.json]
                         [--sizes 65536 262144 1048576]
                         [--tiny] [--m 4096] [--repeats 3]
                         [--report PATH] [--platform NAME]

``--tiny`` is the CI-smoke configuration: one small size, the small-
chunk geometry subset, single repeat — it exercises the full search +
persistence path in seconds and produces a valid (if not
representative) cache.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.tune.cache import DEFAULT_CACHE_PATH
from repro.tune.search import TINY_GEOMETRIES, Autotuner


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="Measure RMQ geometry/engine winners and persist "
                    "them as a tuning cache.")
    ap.add_argument("--out", default=DEFAULT_CACHE_PATH,
                    help="cache JSON output path "
                         "(default: results/tuning_cache.json)")
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=[2**16, 2**18, 2**20],
                    help="array sizes to tune (default: 2^16 2^18 2^20)")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: one tiny size, small geometries, "
                         "single repeat")
    ap.add_argument("--m", type=int, default=4096,
                    help="queries per measurement batch")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed repeats per measurement (median)")
    ap.add_argument("--report", default=None,
                    help="also write the full measurement report JSON")
    ap.add_argument("--platform", default=None,
                    help="cache platform key (default: the running JAX "
                         "backend)")
    args = ap.parse_args(argv)

    kwargs = dict(m=args.m, repeats=args.repeats, log=print)
    sizes = args.sizes
    if args.tiny:
        sizes = [2**13]
        kwargs.update(geometries=TINY_GEOMETRIES, m=min(args.m, 512),
                      repeats=1, crossover_points=3)

    tuner = Autotuner(**kwargs)
    cache, report = tuner.search(sizes, platform=args.platform)
    cache.save(args.out)
    print(f"wrote {len(cache)} entries to {args.out}")
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
        print(f"wrote report to {args.report}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
