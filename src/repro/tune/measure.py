"""Measurement machinery: timing discipline + the paper's workloads.

Moved here from ``benchmarks/common.py`` / ``benchmarks/
engine_throughput.py`` so the autotuner and the benchmarks share ONE
implementation (the benchmarks are thin callers now) — the tuning cache
is built from exactly the numbers the benchmarks report and the engines
serve.

Workloads follow paper §5.1:

* input arrays: i.i.d. uniform [0, 1) float32;
* query range-size classes — large (uniform in [1, n]),
  medium (log-normal, mu = ln(n^0.6), sigma = 0.3),
  small (log-normal, mu = ln(n^0.3), sigma = 0.3),
  mixed (equal thirds);
* left borders uniform in [0, n - s];
* :func:`make_span_queries` additionally pins spans inside one *engine*
  class (short / mid / long by the planner's routing predicates) for
  per-class measurements.

Timing discipline (:func:`time_fn`): one untimed warmup call with a
``block_until_ready`` barrier — so jit tracing/compilation never lands
in a sample — then the median of ``repeats`` barriered wall-clock runs.
Engine measurements additionally warm through the same entry point they
time (the warmup call compiles every padded bucket shape the batch
produces), the discipline ``benchmarks/serving_qps.py`` established.
"""

from __future__ import annotations

import time
from typing import Callable, Tuple

import numpy as np

__all__ = [
    "make_input_array",
    "make_queries",
    "make_span_queries",
    "time_fn",
]


def time_fn(fn: Callable, repeats: int = 5) -> float:
    """Median wall-clock seconds of ``fn()`` with one untimed warmup."""
    import jax

    out = fn()
    jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def make_input_array(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.random(n, dtype=np.float32)


def make_queries(
    n: int, m: int, kind: str = "mixed", seed: int = 1
) -> Tuple[np.ndarray, np.ndarray]:
    """Paper §5.1 range-size classes (large / medium / small / mixed)."""
    rng = np.random.default_rng(seed)

    def sizes(kind, count):
        if kind == "large":
            return rng.integers(1, n + 1, count)
        if kind == "medium":
            s = rng.lognormal(np.log(n ** 0.6), 0.3, count)
            return np.clip(s.astype(np.int64), 1, n)
        if kind == "small":
            s = rng.lognormal(np.log(n ** 0.3), 0.3, count)
            return np.clip(s.astype(np.int64), 1, n)
        if kind == "mixed":
            parts = [sizes(k, count // 3 + 1)
                     for k in ("large", "medium", "small")]
            s = np.concatenate(parts)[:count]
            rng.shuffle(s)
            return s
        raise ValueError(kind)

    s = sizes(kind, m)
    ls = (rng.random(m) * (n - s + 1)).astype(np.int64)
    rs = ls + s - 1
    return ls.astype(np.int32), rs.astype(np.int32)


def make_span_queries(n: int, m: int, c: int, kind: str, seed: int = 1):
    """Bounds with spans pinned inside one engine span class.

    ``kind``: ``short`` (≤ two aligned ``c``-chunks — the ``rmq_short``
    route), ``mid`` (the hierarchy walk), ``long`` (≥ n/2, the sparse-top
    route), or ``mixed`` (equal thirds, shuffled).
    """
    rng = np.random.default_rng(seed)
    if kind == "short":
        # at most two aligned c-chunks
        s = rng.integers(1, c + 2, m)
    elif kind == "mid":
        s = rng.integers(4 * c, min(16 * c, n), m)
    elif kind == "long":
        s = rng.integers(n // 2, n + 1, m)
    elif kind == "mixed":
        parts = [make_span_queries(n, m // 3 + 1, c, k, seed + i)[0:2]
                 for i, k in enumerate(("short", "mid", "long"))]
        ls = np.concatenate([p[0] for p in parts])[:m]
        rs = np.concatenate([p[1] for p in parts])[:m]
        order = rng.permutation(m)
        return ls[order], rs[order]
    else:
        raise ValueError(kind)
    ls = (rng.random(m) * (n - s + 1)).astype(np.int64)
    rs = ls + s - 1
    return ls.astype(np.int32), rs.astype(np.int32)
