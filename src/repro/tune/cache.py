"""Persistent tuning cache: measured winners for (platform, n, span mix).

GPU-RMQ's headline design is *hybrid* (paper §4, Fig. 12): no single
``(c, t)`` geometry or execution engine is optimal across array sizes
and span mixes, so the system must pick geometry per workload and split
hierarchy levels across engines.  This module is the persistence layer
of that choice: the autotuner (:mod:`repro.tune.search`) measures
candidate configurations and files the winners here; ``make_plan(...,
tuned=True)`` / ``RMQ.build(c="auto")`` / ``QueryEngine(tuning=...)``
consume them.

Keying: ``(platform, n_bucket, span_mix)`` where ``platform`` is the
JAX backend name (``cpu``/``tpu``/``gpu``), ``n_bucket`` is
``floor(log2(n))`` (geometry winners are stable within a power-of-two
size band — the paper's Fig. 12 sweeps sizes on exactly that grid), and
``span_mix`` is one of ``short``/``mid``/``long``/``mixed``.  Lookup
falls back ``span_mix -> "mixed" -> nearest n_bucket``; a full miss
returns ``None`` and every consumer then uses the current hardcoded
defaults (``c=128, t=64``, analytic long cutoff) — a missing or empty
cache can never change results or make anything slower than today.

The JSON file format is versioned and schema-validated on load:
unknown versions and malformed entries raise :class:`TuningCacheError`
loudly instead of silently mis-tuning production geometry.  The
committed CPU cache lives at ``results/tuning_cache.json`` (repo root)
and is what :func:`default_cache` loads; regenerate it with
``python -m repro.tune`` (see README "Autotuning").
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
from typing import Dict, List, Optional, Tuple

__all__ = [
    "DEFAULT_CACHE_PATH",
    "SCHEMA_VERSION",
    "SPAN_MIXES",
    "TunedConfig",
    "TuningCache",
    "TuningCacheError",
    "current_platform",
    "default_cache",
]

SCHEMA_VERSION = 2

# Version 1 predates the compact-layout fields (``packed_pos``,
# ``summary_dtype``); its entries load with the classic-layout defaults
# and re-save as version 2.  Anything else fails loudly.
_READABLE_VERSIONS = (1, SCHEMA_VERSION)

SPAN_MIXES = ("short", "mid", "long", "mixed")

# Committed CPU cache, anchored at the repo root like BENCH_query.json.
DEFAULT_CACHE_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))),
    "results", "tuning_cache.json",
)


class TuningCacheError(ValueError):
    """A tuning cache file failed schema validation on load."""


def current_platform() -> str:
    """The JAX platform name used as the cache's platform key."""
    import jax

    return jax.default_backend()


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """One measured winner: geometry + engine choice for a workload.

    ``c``/``t`` are the hierarchy geometry; ``backend`` is the query
    lowering the engine should run (``jax``/``pallas``/``fused`` — the
    hierarchy is bit-identical across backends, so an engine may adopt
    a tuned backend over any build); ``planner`` records whether the
    winner executes through the host-side class split (``"routed"``) or
    the single-launch path (``"fused"``); ``long_cutoff`` is the
    *measured* routed-vs-sparse-top crossover span (``None`` keeps the
    analytic ``2c·c^(L-2)`` default); ``scan_chunks``/``sparse_top``
    parameterize the :class:`repro.core.plan.LevelSplit` the config
    expands to.  ``ns_per_query`` is the winning measurement,
    informational only.  ``bulk_crossover`` is the *measured* batch size
    at which ``QueryEngine.query_bulk``'s endpoint-sorted coalesced
    sweep starts beating the fused per-query path (``None`` keeps the
    engine's analytic model).
    """

    c: int
    t: int
    backend: str = "jax"
    planner: str = "routed"
    long_cutoff: Optional[int] = None
    scan_chunks: int = 2
    sparse_top: bool = True
    ns_per_query: Optional[float] = None
    bulk_crossover: Optional[int] = None
    # schema v2: compact index-plane layouts — bit-packed chunk-local
    # position planes and bf16 value summaries (see HierarchyPlan).
    # ``make_plan(..., tuned=True)`` adopts them unless the caller passes
    # explicit values; the classic-layout defaults keep v1 caches
    # bit-identical.
    packed_pos: bool = False
    summary_dtype: str = "float32"

    def __post_init__(self):
        if self.c < 2 or (self.c & (self.c - 1)) != 0:
            raise ValueError(f"c must be a power of two >= 2, got {self.c}")
        if self.t < 1:
            raise ValueError(f"t must be >= 1, got {self.t}")
        if self.backend not in ("jax", "pallas", "fused"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.planner not in ("routed", "fused"):
            raise ValueError(f"planner must be routed|fused, "
                             f"got {self.planner!r}")
        if self.long_cutoff is not None and self.long_cutoff < 1:
            raise ValueError(
                f"long_cutoff must be positive, got {self.long_cutoff}")
        if self.scan_chunks not in (1, 2):
            raise ValueError(
                f"scan_chunks must be 1 or 2 (the rmq_short kernel scans "
                f"at most two aligned chunks), got {self.scan_chunks}")
        if self.bulk_crossover is not None and self.bulk_crossover < 1:
            raise ValueError(
                f"bulk_crossover must be positive, "
                f"got {self.bulk_crossover}")
        if not isinstance(self.packed_pos, bool):
            raise ValueError(
                f"packed_pos must be a bool, got {self.packed_pos!r}")
        if self.summary_dtype not in ("float32", "bfloat16"):
            raise ValueError(
                f"summary_dtype must be 'float32' or 'bfloat16', "
                f"got {self.summary_dtype!r}")

    def level_split(self):
        """The :class:`repro.core.plan.LevelSplit` this config implies."""
        from repro.core.plan import LevelSplit

        return LevelSplit(
            scan_chunks=self.scan_chunks,
            sparse_top=self.sparse_top,
            long_cutoff=self.long_cutoff,
            fused=self.planner == "fused",
        )

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


_REQUIRED_ENTRY_KEYS = {
    "platform": str, "n_bucket": int, "span_mix": str,
    "c": int, "t": int, "backend": str, "planner": str,
    "scan_chunks": int, "sparse_top": bool,
}


def n_bucket(n: int) -> int:
    """The cache's size bucket for an array of length ``n``."""
    if n < 1:
        raise ValueError(f"n must be positive, got {n}")
    return int(n).bit_length() - 1


class TuningCache:
    """In-memory view of the tuning cache, with JSON (de)serialization.

    Thread-safe: engines resolve configs at attach time from whatever
    thread owns them, and the autotuner populates from the main thread.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: Dict[Tuple[str, int, str], TunedConfig] = {}
        self.source: Optional[str] = None

    def __len__(self) -> int:
        return len(self._entries)

    # -- population --------------------------------------------------------
    def put(self, platform: str, n: int, span_mix: str,
            config: TunedConfig) -> None:
        if span_mix not in SPAN_MIXES:
            raise ValueError(
                f"span_mix must be one of {SPAN_MIXES}, got {span_mix!r}")
        with self._lock:
            self._entries[(platform, n_bucket(n), span_mix)] = config

    # -- resolution --------------------------------------------------------
    def lookup(self, platform: str, n: int,
               span_mix: str = "mixed") -> Optional[TunedConfig]:
        """The tuned config for ``(platform, n, span_mix)``, or ``None``.

        Fallback ladder (most- to least-specific; a miss at every rung
        returns ``None`` and the caller keeps today's defaults):

        1. exact ``(platform, floor(log2 n), span_mix)``;
        2. same bucket, ``span_mix="mixed"`` (the general-purpose
           winner);
        3. nearest measured bucket for the platform (same span-mix
           preference), because geometry winners drift slowly in
           ``log n`` — a 2^19 array is better served by the 2^18 winner
           than by an untuned guess.
        """
        b = n_bucket(n)
        with self._lock:
            entries = dict(self._entries)
        for mix in ((span_mix, "mixed") if span_mix != "mixed"
                    else ("mixed",)):
            hit = entries.get((platform, b, mix))
            if hit is not None:
                return hit
        # nearest-bucket fallback, preferring the requested span mix
        best: Optional[Tuple[int, int, TunedConfig]] = None
        for (p, eb, mix), cfg in entries.items():
            if p != platform:
                continue
            mix_rank = 0 if mix == span_mix else (
                1 if mix == "mixed" else 2)
            if mix_rank == 2:
                continue
            key = (abs(eb - b), mix_rank)
            if best is None or key < (best[0], best[1]):
                best = (abs(eb - b), mix_rank, cfg)
        return best[2] if best is not None else None

    # -- (de)serialization -------------------------------------------------
    def as_json(self) -> dict:
        with self._lock:
            entries = sorted(self._entries.items())
        return {
            "schema_version": SCHEMA_VERSION,
            "entries": [
                {"platform": p, "n_bucket": b, "span_mix": mix,
                 **cfg.as_dict()}
                for (p, b, mix), cfg in entries
            ],
        }

    def save(self, path: str) -> None:
        """Write atomically (tmp + ``os.replace``): an interrupted save
        must never leave a truncated cache for ``default_cache`` to
        reject loudly on the next run."""
        path = os.path.abspath(path)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.as_json(), f, indent=2)
            f.write("\n")
        os.replace(tmp, path)

    @classmethod
    def from_json(cls, doc: dict, source: Optional[str] = None
                  ) -> "TuningCache":
        """Validate + materialize a cache document.

        Raises :class:`TuningCacheError` on version/shape mismatches —
        a malformed cache must fail loudly, never silently mis-tune.
        """
        where = source or "<dict>"
        if not isinstance(doc, dict):
            raise TuningCacheError(
                f"{where}: tuning cache must be a JSON object, "
                f"got {type(doc).__name__}")
        version = doc.get("schema_version")
        if version not in _READABLE_VERSIONS:
            raise TuningCacheError(
                f"{where}: unsupported tuning cache schema_version "
                f"{version!r} (this build reads versions "
                f"{_READABLE_VERSIONS}; "
                "regenerate with `python -m repro.tune`)")
        entries = doc.get("entries")
        if not isinstance(entries, list):
            raise TuningCacheError(
                f"{where}: 'entries' must be a list, "
                f"got {type(entries).__name__}")
        cache = cls()
        cache.source = source
        for i, e in enumerate(entries):
            if not isinstance(e, dict):
                raise TuningCacheError(
                    f"{where}: entry {i} must be an object")
            for key, typ in _REQUIRED_ENTRY_KEYS.items():
                if key not in e:
                    raise TuningCacheError(
                        f"{where}: entry {i} missing key {key!r}")
                if not isinstance(e[key], typ) or (
                        typ is int and isinstance(e[key], bool)):
                    raise TuningCacheError(
                        f"{where}: entry {i} key {key!r} must be "
                        f"{typ.__name__}, got {type(e[key]).__name__}")
            if e["span_mix"] not in SPAN_MIXES:
                raise TuningCacheError(
                    f"{where}: entry {i} span_mix {e['span_mix']!r} not "
                    f"in {SPAN_MIXES}")
            try:
                cfg = TunedConfig(
                    c=e["c"], t=e["t"], backend=e["backend"],
                    planner=e["planner"],
                    long_cutoff=e.get("long_cutoff"),
                    scan_chunks=e["scan_chunks"],
                    sparse_top=e["sparse_top"],
                    ns_per_query=e.get("ns_per_query"),
                    bulk_crossover=e.get("bulk_crossover"),
                    # v1 entries predate the compact layouts: classic
                    # defaults keep their behavior bit-identical.
                    packed_pos=e.get("packed_pos", False),
                    summary_dtype=e.get("summary_dtype", "float32"),
                )
            except ValueError as err:
                raise TuningCacheError(
                    f"{where}: entry {i} invalid: {err}") from err
            with cache._lock:
                cache._entries[
                    (e["platform"], e["n_bucket"], e["span_mix"])] = cfg
        return cache

    @classmethod
    def load(cls, path: str) -> "TuningCache":
        """Load + schema-validate a cache file (must exist)."""
        with open(path) as f:
            try:
                doc = json.load(f)
            except json.JSONDecodeError as err:
                raise TuningCacheError(
                    f"{path}: not valid JSON: {err}") from err
        return cls.from_json(doc, source=path)


_default_cache: Optional[TuningCache] = None
_default_lock = threading.Lock()


def default_cache(refresh: bool = False) -> TuningCache:
    """The committed tuning cache (``results/tuning_cache.json``).

    Loaded once per process; a missing file yields an *empty* cache
    (every lookup misses → every consumer keeps today's defaults), a
    present-but-invalid file raises :class:`TuningCacheError`.  Override
    the path with ``REPRO_TUNING_CACHE`` (``REPRO_TUNING_CACHE=`` —
    empty — disables loading entirely).
    """
    global _default_cache
    with _default_lock:
        if _default_cache is not None and not refresh:
            return _default_cache
        path = os.environ.get("REPRO_TUNING_CACHE", DEFAULT_CACHE_PATH)
        if path and os.path.exists(path):
            _default_cache = TuningCache.load(path)
        else:
            _default_cache = TuningCache()
        return _default_cache
