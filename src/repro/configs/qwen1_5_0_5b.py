"""qwen1.5-0.5b [dense] — 24L d=1024 16H (GQA kv=16) d_ff=2816 vocab=151936.

QKV bias enabled (Qwen1.5 family trait); tied embeddings (the 0.5B ties
lm_head to the input embedding).  [hf:Qwen/Qwen1.5-0.5B; hf]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b",
        family="dense",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=2816,
        vocab_size=151936,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-smoke",
        family="dense",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=160,
        vocab_size=512,
        qkv_bias=True,
        tie_embeddings=True,
        dtype="float32",
    )
