"""minicpm3-4b [dense, MLA] — 62L d=2560 40H d_ff=6400 vocab=73448.

Multi-head latent attention (MiniCPM3 / DeepSeek-V2 style):
q_lora_rank=768, kv_lora_rank=256, qk_nope=64, qk_rope=32, v_head=64.
The decode cache stores the 256-wide latent + 32-wide shared rope key per
position instead of per-head K/V — an 11× cache reduction vs. materialized
GQA at this geometry, which is the reason MLA archs shine on the
``decode_32k`` shape.  [hf:openbmb/MiniCPM3-4B; hf]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        num_layers=62,
        d_model=2560,
        num_heads=40,
        num_kv_heads=40,            # MLA: every head gets its own K/V view
        head_dim=96,                # qk_nope + qk_rope
        d_ff=6400,
        vocab_size=73448,
        attention_type="mla",
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-smoke",
        family="dense",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=24,
        d_ff=160,
        vocab_size=512,
        attention_type="mla",
        q_lora_rank=32,
        kv_lora_rank=16,
        qk_nope_head_dim=16,
        qk_rope_head_dim=8,
        v_head_dim=16,
        tie_embeddings=True,
        dtype="float32",
    )
