"""musicgen-medium [audio] — 48L d=1536 24H (MHA kv=24) d_ff=6144 vocab=2048.

Decoder-only transformer over EnCodec tokens (arXiv:2306.05284).  The
EnCodec tokenizer + 4-codebook delay-pattern embedder is a STUB per the
assignment: the trunk consumes token ids from the 2048-entry codebook
vocab, with an optional prefix of precomputed conditioning embeddings
(the T5 text-conditioning cross-attention is simplified to prefix
conditioning — noted in DESIGN.md §6).  [arXiv:2306.05284; hf]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        vocab_size=2048,
        frontend="encodec_stub",
        frontend_tokens=64,          # conditioning prefix length
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke",
        family="audio",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        frontend="encodec_stub",
        frontend_tokens=8,
        dtype="float32",
    )
