"""qwen2-moe-a2.7b [moe] — 24L d=2048 16H (GQA kv=16) d_ff=1408 vocab=151936.

60 routed experts top-4 (renormalized softmax router) + a 4×-width shared
expert (d_ff = 4·1408 = 5632) gated by a sigmoid shared-gate, per
Qwen1.5-MoE-A2.7B.  QKV bias on, as in the Qwen1.5 family.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,                  # routed expert width
        vocab_size=151936,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        num_experts=60,
        num_experts_per_tok=4,
        moe_d_ff=1408,
        shared_expert_d_ff=5632,
        moe_layer_period=1,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-smoke",
        family="moe",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=96,
        vocab_size=512,
        qkv_bias=True,
        num_experts=6,
        num_experts_per_tok=2,
        moe_d_ff=96,
        shared_expert_d_ff=384,
        moe_layer_period=1,
        dtype="float32",
    )
