"""llama4-maverick-400b-a17b [moe] — 48L d=5120 40H (GQA kv=8) vocab=202048.

MoE: 128 routed experts top-1 + 1 shared expert, **interleaved every 2nd
layer** (Llama-4 Maverick's interleave_moe_layer_step=2).  With all-layer
MoE the expert params alone would be ~770B; period-2 lands at ~400B total
/ ~17B active, matching the model name (DESIGN.md §6).  Dense layers use
d_ff = 16384 (2× the expert width, per Llama-4); routed/shared experts use
the assigned d_ff = 8192.  [hf:meta-llama/Llama-4 family; unverified]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,                 # dense (non-MoE) layers
        vocab_size=202048,
        rope_theta=500_000.0,
        num_experts=128,
        num_experts_per_tok=1,
        moe_d_ff=8192,
        shared_expert_d_ff=8192,
        moe_layer_period=2,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-smoke",
        family="moe",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab_size=512,
        num_experts=8,
        num_experts_per_tok=1,
        moe_d_ff=128,
        shared_expert_d_ff=128,
        moe_layer_period=2,
        dtype="float32",
    )
