from repro.configs.base import (
    ARCH_IDS,
    ModelConfig,
    RMQConfig,
    ServeConfig,
    TrainConfig,
    get_config,
    get_smoke_config,
    registry,
)

__all__ = [
    "ARCH_IDS",
    "ModelConfig",
    "RMQConfig",
    "ServeConfig",
    "TrainConfig",
    "get_config",
    "get_smoke_config",
    "registry",
]
