"""command-r-plus-104b [dense] — 64L d=12288 96H (GQA kv=8) d_ff=33792.

vocab = 256000, no biases, Cohere-style **parallel attention+FFN block**
(one shared input norm; attention and FFN both read it, residual adds
both).  [hf:CohereForAI/c4ai-command-r-plus; unverified]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b",
        family="dense",
        num_layers=64,
        d_model=12288,
        num_heads=96,
        num_kv_heads=8,
        head_dim=128,
        d_ff=33792,
        vocab_size=256000,
        parallel_block=True,
        rope_theta=75_000_000.0,
        tie_embeddings=True,       # command-r ties input/output embeddings
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-smoke",
        family="dense",
        num_layers=3,
        d_model=96,
        num_heads=6,
        num_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab_size=512,
        parallel_block=True,
        tie_embeddings=True,
        dtype="float32",
    )
