"""llama3.2-3b [dense] — 28L d=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.

Standard Llama-3 recipe: RMSNorm, SwiGLU, RoPE theta 500k, no biases.
[hf:meta-llama/Llama-3.2-3B; unverified]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b",
        family="dense",
        num_layers=28,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=128256,
        rope_theta=500_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-smoke",
        family="dense",
        num_layers=3,
        d_model=96,
        num_heads=6,
        num_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab_size=512,
        dtype="float32",
    )
