"""Config dataclasses: model architecture, training, serving, mesh.

All configs are frozen dataclasses — hashable, usable as jit static args,
and serializable to/from dicts for checkpoint manifests.  One file per
assigned architecture lives next to this module (``repro/configs/<id>.py``)
exposing ``config()`` (exact assigned geometry) and ``smoke_config()``
(reduced same-family geometry for CPU tests).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "TrainConfig", "ServeConfig", "RMQConfig",
           "registry", "get_config", "get_smoke_config", "ARCH_IDS"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str                      # dense | moe | vlm | audio | ssm | hybrid
    # trunk
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention
    attention_type: str = "gqa"      # gqa | mla | none
    qkv_bias: bool = False
    parallel_block: bool = False     # Cohere-style parallel attn+FFN
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None
    global_attn_every: Optional[int] = None   # hybrid: full attn every k-th
    logit_softcap: Optional[float] = None
    # MLA (minicpm3 / deepseek-style latent attention)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0
    shared_expert_d_ff: int = 0
    moe_layer_period: int = 1        # every k-th layer is MoE (llama4: 2)
    capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.001
    # SSM (mamba2 / hymba)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # modality frontend (assignment: stubs providing precomputed embeddings)
    frontend: Optional[str] = None   # vit_stub | encodec_stub
    frontend_tokens: int = 0         # prepended embedding positions
    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"     # master params

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding/head can
        shard over a 16-wide tensor axis (pad ids are never emitted by the
        data pipeline; their logits train toward -inf harmlessly)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def is_attention_free(self) -> bool:
        return self.attention_type == "none"

    @property
    def uses_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def num_params(self) -> int:
        """Approximate parameter count (embedding + trunk), for roofline."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        per_layer_attn = 0
        if self.attention_type == "gqa":
            per_layer_attn = (
                d * self.num_heads * self.head_dim * 2  # q, o
                + d * self.num_kv_heads * self.head_dim * 2  # k, v
            )
        elif self.attention_type == "mla":
            qk_dim = self.qk_nope_head_dim + self.qk_rope_head_dim
            per_layer_attn = (
                d * self.q_lora_rank
                + self.q_lora_rank * self.num_heads * qk_dim
                + d * (self.kv_lora_rank + self.qk_rope_head_dim)
                + self.kv_lora_rank * self.num_heads
                * (self.qk_nope_head_dim + self.v_head_dim)
                + self.num_heads * self.v_head_dim * d
            )
        dense_ffn = 3 * d * self.d_ff
        moe_ffn = (
            self.num_experts * 3 * d * self.moe_d_ff
            + (3 * d * self.shared_expert_d_ff
               if self.shared_expert_d_ff else 0)
            + d * self.num_experts
        )
        ssm = 0
        if self.ssm_state:
            di = self.d_inner
            ssm = (
                d * (2 * di + 2 * self.ssm_state + self.ssm_heads)
                + di * d + di * self.ssm_conv
            )
        for i in range(self.num_layers):
            if self.attention_type != "none":
                total += per_layer_attn
            if self.family == "hybrid":
                total += ssm
            elif self.ssm_state:
                total += ssm
                continue  # pure SSM: no FFN in mamba2
            is_moe = (
                self.uses_moe
                and (i % self.moe_layer_period == self.moe_layer_period - 1)
            )
            total += moe_ffn if is_moe else dense_ffn
        return total

    def num_active_params(self) -> int:
        """Active (per-token) parameters — 6·N_active·D roofline term."""
        if not self.uses_moe:
            return self.num_params()
        d = self.d_model
        total = self.num_params()
        # replace full expert block with top-k + shared
        moe_layers = self.num_layers // self.moe_layer_period
        all_experts = moe_layers * self.num_experts * 3 * d * self.moe_d_ff
        active = moe_layers * self.num_experts_per_tok * 3 * d * self.moe_d_ff
        return total - all_experts + active


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seq_len: int = 4096
    global_batch: int = 256
    microbatches: int = 1            # grad accumulation
    remat_policy: str = "minimal"    # none | minimal | full
    optimizer_state_dtype: str = "float32"   # float32 | bfloat16
    grad_allreduce_dtype: str = "bfloat16"   # gradient compression knob
    loss_chunk: int = 0              # >0: chunked xent, logits never full
    seed: int = 0
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    async_checkpoint: bool = True


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    seq_len: int = 32768             # KV cache length
    batch: int = 128
    kv_cache_dtype: str = "bfloat16"
    # RMQ-backed eviction (the paper's technique as a serving feature)
    eviction_enabled: bool = False
    eviction_budget: int = 0         # keep at most this many tokens
    eviction_window: int = 1024      # protected recent window
    rmq_chunk: int = 128
    rmq_threshold: int = 16


@dataclasses.dataclass(frozen=True)
class RMQConfig:
    """Standalone RMQ product surface config (paper §5.3 tuning)."""
    c: int = 128
    t: int = 64
    query_block: int = 256
    with_positions: bool = False
    backend: str = "auto"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
ARCH_IDS = (
    "llama4-maverick-400b-a17b",
    "qwen2-moe-a2.7b",
    "internvl2-2b",
    "command-r-plus-104b",
    "qwen1.5-0.5b",
    "llama3.2-3b",
    "minicpm3-4b",
    "musicgen-medium",
    "mamba2-1.3b",
    "hymba-1.5b",
)

_MODULES = {
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "internvl2-2b": "internvl2_2b",
    "command-r-plus-104b": "command_r_plus_104b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "llama3.2-3b": "llama3_2_3b",
    "minicpm3-4b": "minicpm3_4b",
    "musicgen-medium": "musicgen_medium",
    "mamba2-1.3b": "mamba2_1_3b",
    "hymba-1.5b": "hymba_1_5b",
}


def registry():
    return dict(_MODULES)


def _module(arch: str):
    import importlib

    if arch not in _MODULES:
        raise KeyError(
            f"unknown arch {arch!r}; available: {sorted(_MODULES)}"
        )
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()
