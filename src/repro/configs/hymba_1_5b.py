"""hymba-1.5b [hybrid] — 32L d=1600 25H (GQA kv=5) d_ff=5504, d_state=16.

Parallel attention + mamba heads within each layer (arXiv:2411.13676):
both paths read the same normed input; outputs are per-path RMSNormed,
scaled by learned β vectors, and mean-fused.  The SSM path mirrors the
attention width (d_inner = d_model = 1600 ⇒ 25 SSD heads × 64).
Attention is SWA(1024) except every 8th layer, which is global — carried
as per-layer scanned window data.  Hymba's 128 meta tokens are represented
by the frontend-prefix mechanism (learnable prompt prefix ≡ precomputed
embeddings; stubbed like the other frontends, noted in DESIGN.md §6).

``long_500k`` RUNS for this arch: SWA + constant SSM state keep decode
sub-quadratic.  [arXiv:2411.13676; hf]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        sliding_window=1024,
        global_attn_every=8,
        ssm_state=16,
        ssm_heads=25,
        ssm_head_dim=64,
        ssm_expand=1,               # SSM path mirrors attention width
        ssm_conv=4,
        ssm_chunk=128,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke",
        family="hybrid",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        sliding_window=16,
        global_attn_every=2,
        ssm_state=8,
        ssm_heads=4,
        ssm_head_dim=16,
        ssm_expand=1,
        ssm_conv=4,
        ssm_chunk=16,
        dtype="float32",
    )
