"""mamba2-1.3b [ssm] — 48L d=2048 attention-free, vocab=50280, d_state=128.

SSD (state-space duality, arXiv:2405.21060): d_inner = 2·d_model = 4096,
headdim = 64 ⇒ 64 SSD heads, ngroups = 1, conv4.  The chunked SSD scan is
the Pallas kernel in ``repro.kernels.ssd_scan``.

§Arch-applicability (DESIGN.md): the paper's RMQ-backed KV eviction is
INAPPLICABLE here — constant-size SSM state, no per-token cache, no
attention scores.  Implemented without the technique, as assigned.
``long_500k`` RUNS for this arch (O(1)-state decode).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50280,
        attention_type="none",
        ssm_state=128,
        ssm_heads=64,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv=4,
        ssm_chunk=128,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        num_layers=3,
        d_model=64,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=256,
        attention_type="none",
        ssm_state=16,
        ssm_heads=4,
        ssm_head_dim=32,
        ssm_expand=2,
        ssm_conv=4,
        ssm_chunk=32,
        tie_embeddings=True,
        dtype="float32",
    )
