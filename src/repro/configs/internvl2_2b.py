"""internvl2-2b [vlm] — InternLM2 trunk 24L d=2048 16H (GQA kv=8) d_ff=8192.

vocab = 92553.  The InternViT vision frontend is a STUB per the
assignment: ``input_specs()`` provides precomputed patch embeddings
(batch, 256, d_model) that the trunk consumes as a prefix (256 = 16×16
patch tokens after pixel-shuffle, InternVL2's per-tile budget).
[arXiv:2404.16821; hf]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        family="vlm",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=92553,
        rope_theta=1_000_000.0,
        frontend="vit_stub",
        frontend_tokens=256,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke",
        family="vlm",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        frontend="vit_stub",
        frontend_tokens=16,
        dtype="float32",
    )
