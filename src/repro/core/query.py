"""Batched hierarchical RMQ answering (paper §4.2–§4.4), pure-JAX reference.

This mirrors the paper's Listing 2 with JAX-compatible control flow: the
level walk is unrolled over the *static* number of levels from the
``HierarchyPlan``; the data-dependent early exit (``r - l <= 2c``) becomes a
``done`` predicate that masks later levels to no-ops.

Scans are fixed-size masked windows:

* boundary scans (levels we pass through) read one aligned ``c``-wide window
  on each side — exactly the paper's "random but cache-aligned chunk
  accesses";
* the stop-level scan reads a ``2c`` window starting at ``l`` (the paper
  guarantees ``r - l <= 2c`` there);
* the top level is scanned in full (``<= c*t`` entries), masked to
  ``[l, r)``.

This module is the *oracle* for the Pallas query kernel
(``repro.kernels.rmq_scan``) and is itself fast enough to serve as the
production path on non-TPU backends.

Query convention: ``(l, r)`` are **inclusive** bounds, ``0 <= l <= r < n``,
matching the paper's problem statement (§2.1).
"""

from __future__ import annotations

import functools
import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import bitpack
from repro.core.hierarchy import Hierarchy, pos_dtype_for
from repro.core.plan import HierarchyPlan

__all__ = [
    "rmq_value",
    "rmq_index",
    "rmq_value_batch",
    "rmq_index_batch",
    "check_query_args",
]

from repro.core.constants import POS_INF_I32 as _POS_INF_I32  # noqa: E402


def _debug_checks_enabled() -> bool:
    return os.environ.get("REPRO_RMQ_DEBUG", "0") not in ("", "0")


def check_query_args(ls, rs, n: int, debug: bool = None):
    """Validate a query batch against the convention ``0 <= l <= r < n``.

    Dtype and shape problems are always rejected (they are cheap, static
    checks).  The batched *value* check materializes the arrays, so it
    only runs in debug mode — ``debug=True`` or env ``REPRO_RMQ_DEBUG=1``
    — and only on concrete (non-traced) inputs.  Returns ``(ls, rs)`` as
    arrays.
    """
    ls, rs = jnp.asarray(ls), jnp.asarray(rs)
    for name, a in (("ls", ls), ("rs", rs)):
        if not jnp.issubdtype(a.dtype, jnp.integer):
            raise TypeError(
                f"query bounds {name} must be integers, got {a.dtype}"
            )
    if ls.shape != rs.shape:
        raise ValueError(
            f"query bounds must match in shape, got {ls.shape} vs {rs.shape}"
        )
    if debug is None:
        debug = _debug_checks_enabled()
    if debug and not (
        isinstance(ls, jax.core.Tracer) or isinstance(rs, jax.core.Tracer)
    ):
        import numpy as np

        l_np, r_np = np.asarray(ls), np.asarray(rs)
        bad = (l_np < 0) | (l_np > r_np) | (r_np >= n)
        if bad.any():
            i = int(np.argmax(bad))
            raise ValueError(
                f"query {i} = ({l_np.flat[i]}, {r_np.flat[i]}) violates "
                f"0 <= l <= r < n with n={n}"
            )
    return ls, rs


def _merge(m, p, m2, p2):
    """Combine two (min-value, leftmost-position) candidates."""
    take2 = (m2 < m) | ((m2 == m) & (p2 < p))
    return jnp.where(take2, m2, m), jnp.where(take2, p2, p)


def _masked_window_scan(
    arr, pos_arr, start, lo, hi, window, track_pos,
    coord=jnp.int32, exact_src=None,
):
    """min over ``arr[i]`` for ``i in [lo, hi) ∩ [start, start+window)``.

    ``start`` is clamped by ``dynamic_slice`` semantics; masking uses the
    *absolute* indices of the slice actually read, so clamping is safe.
    Returns ``(min_value, min_position)`` with +inf / INTmax identities;
    positions (and the scan coordinates) use dtype ``coord`` — int64 for
    capacities past 2^31 under x64.

    ``exact_src`` (the level-0 array) switches on bf16-summary recovery:
    the window min over ``arr`` is then quantized, so every candidate
    tied at the quantized min is re-read *exactly* from level 0 through
    its stored position, and the exact values pick the winner — the true
    minimum always survives into the tied set because bf16 rounding is
    monotone.
    """
    n = arr.shape[0]
    window = min(window, n)
    start = jnp.clip(start, 0, max(n - window, 0)).astype(coord)
    vals = jax.lax.dynamic_slice(arr, (start,), (window,))
    idx = start + jnp.arange(window, dtype=coord)
    mask = (idx >= lo) & (idx < hi)
    ident = jnp.array(jnp.iinfo(coord).max, dtype=coord)
    if exact_src is None:
        inf = jnp.array(jnp.inf, dtype=arr.dtype)
        masked = jnp.where(mask, vals, inf)
        m = jnp.min(masked)
        if track_pos:
            if pos_arr is None:
                pos = idx  # level 0: position is the index itself
            else:
                pos = jax.lax.dynamic_slice(pos_arr, (start,), (window,))
            cand = jnp.where(mask & (masked == m), pos, ident)
            p = jnp.min(cand).astype(coord)
        else:
            p = ident
        return m, p
    masked = jnp.where(mask, vals, jnp.array(jnp.inf, dtype=arr.dtype))
    mq = jnp.min(masked)  # quantized (bf16) window minimum
    pos = jax.lax.dynamic_slice(pos_arr, (start,), (window,))
    tied = mask & (masked == mq)
    safe = jnp.clip(pos, 0, exact_src.shape[0] - 1)
    exact_inf = jnp.array(jnp.inf, dtype=exact_src.dtype)
    ex = jnp.where(tied, exact_src[safe], exact_inf)
    m = jnp.min(ex)
    cand = jnp.where(tied & (ex == m), pos, ident)
    p = jnp.min(cand).astype(coord)
    return m, p


def _rmq_single(
    plan: HierarchyPlan,
    base: jax.Array,
    upper: jax.Array,
    upper_pos,
    l: jax.Array,
    r: jax.Array,
    track_pos: bool,
) -> Tuple[jax.Array, jax.Array]:
    """Answer a single RMQ; vmapped over the batch by the public API."""
    c = plan.c
    # All scan coordinates, merge identities, and returned positions use
    # the plan's position dtype — int32 everywhere except capacities past
    # 2^31 under x64 (int32 plans are byte-identical to the historical
    # hardcoded-int32 walk).
    coord = pos_dtype_for(plan.capacity, strict=False)
    ident = jnp.array(jnp.iinfo(coord).max, dtype=coord)
    # bf16 summaries: upper-level scans re-compare their quantized-tied
    # candidates against level 0 so results stay exact (positions are
    # required and tracked internally even for value-only queries).
    exact = upper.dtype != base.dtype and upper_pos is not None
    track = track_pos or exact
    inf = jnp.array(jnp.inf, dtype=base.dtype)
    m = inf
    p = ident
    l = l.astype(coord)
    r = (r + 1).astype(coord)  # make exclusive, as in Listing 2
    done = jnp.array(False)

    def level_arrays(level: int):
        if level == 0:
            return base, None, plan.n
        off, padded = plan.level_slice(level)
        vals = jax.lax.slice(upper, (off,), (off + padded,))
        pos = (
            None
            if upper_pos is None
            else jax.lax.slice(upper_pos, (off,), (off + padded,))
        )
        return vals, pos, plan.level_lens[level]

    for level in range(plan.num_levels):
        arr, pos_arr, _ = level_arrays(level)
        is_last = level == plan.num_levels - 1
        ex_src = base if (exact and level > 0) else None

        if is_last:
            stop_here = ~done
        else:
            stop_here = (~done) & ((r - l) <= 2 * c)

        # --- stop-level scan -------------------------------------------
        if is_last:
            # Scan the whole (small) top level, masked to [l, r).
            idx = jnp.arange(arr.shape[0], dtype=coord)
            mask = stop_here & (idx >= l) & (idx < r)
            masked = jnp.where(mask, arr, jnp.array(jnp.inf, arr.dtype))
            smq = jnp.min(masked)
            if ex_src is not None:
                tied = mask & (masked == smq)
                safe = jnp.clip(pos_arr, 0, ex_src.shape[0] - 1)
                ex = jnp.where(tied, ex_src[safe], inf)
                sm = jnp.min(ex)
                cand = jnp.where(tied & (ex == sm), pos_arr, ident)
                sp = jnp.min(cand).astype(coord)
            else:
                sm = smq
                if track:
                    if pos_arr is None:
                        pos = idx
                    else:
                        pos = pos_arr
                    cand = jnp.where(mask & (masked == sm), pos, ident)
                    sp = jnp.min(cand).astype(coord)
                else:
                    sp = ident
        else:
            # r - l <= 2c here, so a 2c window starting at l covers [l, r).
            sm, sp = _masked_window_scan(
                arr, pos_arr, l, l, jnp.where(stop_here, r, l), 2 * c,
                track, coord=coord, exact_src=ex_src,
            )
        m, p = _merge(m, p, jnp.where(stop_here, sm, inf),
                      jnp.where(stop_here, sp, ident))
        done = done | stop_here

        if is_last:
            break

        # --- boundary scans + ascend ------------------------------------
        advance = ~done
        next_l = ((l + c - 1) // c) * c  # next multiple of c >= l
        prev_r = (r // c) * c            # largest multiple of c <= r

        # Left partial chunk: [l, next_l) ⊂ [next_l - c, next_l).
        lm, lp = _masked_window_scan(
            arr, pos_arr, next_l - c, l, jnp.where(advance, next_l, l),
            c, track, coord=coord, exact_src=ex_src,
        )
        # Right partial chunk: [prev_r, r) ⊂ [prev_r, prev_r + c).
        rm, rp = _masked_window_scan(
            arr, pos_arr, prev_r, jnp.where(advance, prev_r, r), r,
            c, track, coord=coord, exact_src=ex_src,
        )
        m, p = _merge(m, p, jnp.where(advance, lm, inf),
                      jnp.where(advance, lp, ident))
        m, p = _merge(m, p, jnp.where(advance, rm, inf),
                      jnp.where(advance, rp, ident))

        l = jnp.where(advance, next_l // c, l)
        r = jnp.where(advance, prev_r // c, r)

    return m, p


def _rmq_batch_impl(plan, base, upper, upper_pos, ls, rs, track_pos: bool):
    """Un-jitted batch walk body (reused inside other jitted lowerings).

    Packed position planes are unpacked once per batch, outside the
    per-query vmap, so the transient absolute plane is shared by every
    lane of the launch.
    """
    upper_pos = bitpack.resolve_positions(upper_pos, plan)
    fn = functools.partial(_rmq_single, plan, base, upper, upper_pos,
                           track_pos=track_pos)
    return jax.vmap(lambda l, r: fn(l=l, r=r))(ls, rs)


@functools.partial(jax.jit, static_argnames=("plan", "track_pos"))
def _rmq_batch(plan, base, upper, upper_pos, ls, rs, track_pos: bool = True):
    return _rmq_batch_impl(plan, base, upper, upper_pos, ls, rs, track_pos)


def rmq_value_batch(h: Hierarchy, ls: jax.Array, rs: jax.Array) -> jax.Array:
    """``RMQ_value`` for a batch of inclusive ranges."""
    # bf16 summaries need the position plane even for value queries (the
    # exact re-compare reads level 0 through stored positions).
    pos = h.upper_pos if h.upper.dtype != h.base.dtype else None
    m, _ = _rmq_batch(h.plan, h.base, h.upper, pos, ls, rs, track_pos=False)
    return m


def rmq_index_batch(h: Hierarchy, ls: jax.Array, rs: jax.Array) -> jax.Array:
    """``RMQ_index`` (leftmost minimum position) for a batch of ranges."""
    if not h.with_positions:
        raise ValueError(
            "hierarchy was built without positions; "
            "use build_hierarchy(..., with_positions=True)"
        )
    _, p = _rmq_batch(h.plan, h.base, h.upper, h.upper_pos, ls, rs,
                      track_pos=True)
    return p


def rmq_value(h: Hierarchy, l, r) -> jax.Array:
    """Single-query convenience wrapper."""
    return rmq_value_batch(h, jnp.asarray([l]), jnp.asarray([r]))[0]


def rmq_index(h: Hierarchy, l, r) -> jax.Array:
    return rmq_index_batch(h, jnp.asarray([l]), jnp.asarray([r]))[0]
