"""The common index protocol every RMQ implementation speaks.

Four index implementations grew up around the paper's hierarchy —
:class:`repro.core.api.RMQ` (the facade), :class:`repro.streaming.StreamingRMQ`
(sliding windows), :class:`repro.core.hybrid.HybridRMQ` (O(1) sparse-table
top), and :class:`repro.core.distributed.DistributedRMQ` (segment-sharded
across a mesh) — each initially with its own private query/validation/
backend-selection plumbing.  This module is the contract that unifies them
so the layers above (``repro.qe``'s engine/service, ``repro.serve``) route
over *capabilities*, not concrete types:

* :class:`RMQIndex` — the read surface: static ``plan`` geometry, live
  ``length``, a monotonic ``generation`` counter (the cache-invalidation
  key), and the two batched query entry points
  ``query_value_batch`` / ``query_index_batch`` (aliases of the historical
  ``query`` / ``query_index`` names, which remain).
* :class:`MutableRMQIndex` — the optional mutation surface: batched point
  ``update`` and ``append`` into reserved capacity, both returning a
  *successor* index with ``generation + 1`` (every implementation is
  pure-functional).  Probe with :func:`supports_mutation`.
* shared helpers — the previously-duplicated plumbing, now in one place:
  backend resolution (:func:`resolve_backend` /
  :func:`runtime_backend`), input dtype coercion (:func:`coerce_values`),
  the single construction entry point every implementation builds through
  (:func:`build_hierarchy_with_backend`, backends ``'fused'`` /
  ``'pallas'`` / ``'jax'``, plus the vmapped :func:`build_many`),
  query/update backend dispatch (:func:`dispatch_query_value`,
  :func:`dispatch_query_index`, :func:`dispatch_update`,
  :func:`dispatch_append`) and batch validation
  (:func:`validate_update_batch`, :func:`validate_append_batch`).

Which implementation to pick (see README "Choosing an index"):

=================  ==========================================================
``RMQ``            default: build + query + incremental update/append.
``StreamingRMQ``   online arrays: adds sliding-window ``retire``.
``HybridRMQ``      long-span-heavy read-only workloads (O(1) top); usually
                   reached *through* the engine's long-span route instead.
``DistributedRMQ`` arrays past one device's memory: segment-sharded, same
                   protocol (including update/append), engine-routable.
=================  ==========================================================
"""

from __future__ import annotations

from typing import Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.hierarchy import (
    Hierarchy,
    build_hierarchy,
    build_many,
    finalize_compact,
)
from repro.core.plan import HierarchyPlan
from repro.core.query import _debug_checks_enabled
from repro.obs import trace

__all__ = [
    "RMQIndex",
    "MutableRMQIndex",
    "default_backend",
    "resolve_backend",
    "runtime_backend",
    "mutation_backend",
    "coerce_values",
    "build_hierarchy_with_backend",
    "build_many",
    "capacity_limit_message",
    "check_capacity_limit",
    "dispatch_query_value",
    "dispatch_query_index",
    "dispatch_update",
    "dispatch_append",
    "validate_update_batch",
    "validate_append_batch",
    "live_length",
    "is_distributed",
    "supports_mutation",
    "make_engine",
]

_VALUE_DTYPES = (jnp.float32, jnp.bfloat16, jnp.float64)


# ---------------------------------------------------------------------------
# the one capacity guard (previously four slightly-different copies)
# ---------------------------------------------------------------------------
def capacity_limit_message(capacity: int) -> str:
    """The canonical int32-capacity error text, shared by every guard site.

    Pinned byte-identical in ``test_protocol.py`` — the engine, the
    distributed build, and both Pallas kernel packages must all raise
    exactly this string (guard drift across those sites is how capacity
    bugs hid before the guard was centralized).
    """
    return (
        f"capacity {capacity} exceeds the int32 query index space; "
        "capacities >= 2**31 need jax x64 mode and the int64-coordinate "
        "jax path (DistributedRMQ or backend='jax' builds)"
    )


def check_capacity_limit(capacity: int, allow_x64: bool = False) -> None:
    """Reject capacities past the int32 query index space.

    ``allow_x64=True`` marks call sites that *can* serve int64
    coordinates (the jax walk, the distributed coordinate plane): they
    pass when x64 mode is enabled.  Strict sites (the Pallas kernels,
    the batched engine) always reject — their lowerings index in int32.
    """
    if capacity < 2**31:
        return
    if allow_x64 and jax.config.x64_enabled:
        return
    raise ValueError(capacity_limit_message(capacity))


# ---------------------------------------------------------------------------
# the protocol
# ---------------------------------------------------------------------------
@runtime_checkable
class RMQIndex(Protocol):
    """Read surface shared by every RMQ index implementation.

    ``plan`` is the static level geometry (for the sharded index: the
    *per-segment* plan — use ``capacity`` for the total addressable index
    space).  ``length`` is the live element count (may be ``None`` on
    implementations whose live length equals the build length; use
    :func:`live_length` to normalize).  ``generation`` increments on every
    mutation, keying engine result caches to the array version.
    """

    backend: str

    @property
    def plan(self) -> HierarchyPlan: ...

    @property
    def length(self) -> Optional[int]: ...

    @property
    def generation(self) -> int: ...

    @property
    def value_dtype(self): ...

    @property
    def capacity(self) -> int: ...

    @property
    def with_positions(self) -> bool: ...

    def query_value_batch(self, ls, rs) -> jax.Array: ...

    def query_index_batch(self, ls, rs) -> jax.Array: ...


@runtime_checkable
class MutableRMQIndex(RMQIndex, Protocol):
    """Optional mutation surface: pure-functional batched maintenance.

    Both mutators return a *successor* index sharing unmodified buffers,
    with ``generation`` bumped by one; the receiver is unchanged.  Cost is
    O(batch · log_c n) chunk re-reductions — never a rebuild.
    """

    def update(self, idxs, vals) -> "MutableRMQIndex": ...

    def append(self, vals) -> "MutableRMQIndex": ...


def supports_mutation(index) -> bool:
    """Does ``index`` expose the ``update``/``append`` capability?"""
    return isinstance(index, MutableRMQIndex)


def is_distributed(index) -> bool:
    """Is ``index`` a mesh-sharded implementation (no local hierarchy)?

    Distributed indices answer queries through sharded per-segment
    hierarchies; the engine routes them through the distributed executor
    (segment-local fast path + all-reduce for crossing spans) instead of
    the single-hierarchy span executors.
    """
    return bool(getattr(index, "distributed", False))


def live_length(index) -> int:
    """The live element count, normalized across implementations.

    ``RMQ`` permits ``length=None`` meaning "the build length" (on
    directly-constructed instances; ``RMQ.build`` always sets it), so a
    plain ``.length`` read is not universally an int — use this helper.
    """
    length = getattr(index, "length", None)
    if length is not None:
        return int(length)
    n = getattr(index, "n", None)
    if n is not None:
        return int(n)
    return int(index.plan.n)


# ---------------------------------------------------------------------------
# backend selection + input coercion (previously duplicated per facade)
# ---------------------------------------------------------------------------
def default_backend() -> str:
    """Pallas kernels on TPU, the pure-JAX reference elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "jax"


def resolve_backend(backend: str) -> str:
    """Normalize a user-facing backend name (``"auto"`` included).

    ``"fused"`` selects the single-launch pipelines on both phases:
    construction through ``kernels/hierarchy_fused`` (one launch per
    build) and queries through ``kernels/rmq_fused`` (one launch per
    batch, every span class, value and index ops alike).  Incremental
    updates/appends have no fused lowering and fall through to the
    platform default (:func:`mutation_backend`).
    """
    if backend == "auto":
        return default_backend()
    if backend not in ("jax", "pallas", "fused"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend


def runtime_backend(backend: str) -> str:
    """The query lowering behind a resolved backend name.

    ``"fused"`` is a *runtime* backend since the fused query kernel
    landed: batched queries on a fused index run through
    ``kernels/rmq_fused`` (the whole batch in one launch), so it passes
    through unchanged — as do ``"jax"``/``"pallas"``.  (Historically
    ``"fused"`` was construction-only and degraded to the platform
    default here.)  Mutations still degrade: see
    :func:`mutation_backend`.
    """
    return backend


def mutation_backend(backend: str) -> str:
    """The update/append lowering behind a resolved backend name.

    The fused pipelines cover construction and queries; incremental
    chunk re-reductions are per-touched-chunk work with no single-launch
    shape to exploit, so ``"fused"`` indexes mutate through the platform
    default (``hierarchy_update`` on TPU, pure JAX elsewhere) — the
    successor hierarchy is bit-identical either way.
    """
    if backend == "fused":
        return default_backend()
    return backend


def coerce_values(x) -> jax.Array:
    """The input array as a supported 1-D float dtype."""
    x = jnp.asarray(x)
    if x.ndim != 1:
        raise ValueError(f"input must be rank-1, got shape {x.shape}")
    if x.dtype not in _VALUE_DTYPES:
        x = x.astype(jnp.float32)
    return x


def build_hierarchy_with_backend(
    x: jax.Array,
    plan: HierarchyPlan,
    with_positions: bool,
    backend: str,
) -> Hierarchy:
    """The one construction entry point every index implementation uses.

    All three backends produce bit-identical hierarchies (values,
    leftmost-tie positions, and padding):

    * ``"fused"`` — ``kernels/hierarchy_fused``: every upper level in ONE
      Pallas launch, the ``upper`` buffer VMEM-resident throughout;
    * ``"pallas"`` — ``kernels/hierarchy_build``: one launch per level;
    * ``"jax"`` — the pure-JAX oracle (single fused pass into a
      preallocated buffer since the pipeline refactor).

    Compact plane layouts (``plan.packed_pos`` / ``plan.summary_dtype``)
    are applied uniformly: the jax oracle builds them natively; the
    Pallas backends build the classic layout and run through
    :func:`repro.core.hierarchy.finalize_compact`.
    """
    from repro.core.hierarchy import _check_compact_build

    _check_compact_build(plan, with_positions, x.dtype)
    if backend == "fused":
        from repro.kernels.hierarchy_fused import ops as fused_ops

        return finalize_compact(fused_ops.build_hierarchy_fused(
            x, plan, with_positions=with_positions
        ))
    if backend == "pallas":
        from repro.kernels.hierarchy_build import ops as build_ops

        return finalize_compact(build_ops.build_hierarchy_pallas(
            x, plan, with_positions=with_positions
        ))
    if backend == "jax":
        return build_hierarchy(x, plan, with_positions=with_positions)
    raise ValueError(f"unknown backend {backend!r}")




# ---------------------------------------------------------------------------
# query dispatch (previously duplicated in api.py / structure.py)
# ---------------------------------------------------------------------------
def _run_dispatch(kind: str, backend: str, fn, *args) -> jax.Array:
    # guarded span (not trace.span): dispatch helpers sit on the per-call
    # query path, so with tracing disabled this must stay one global load
    tr = trace.current()
    if tr is None:
        return fn(*args)
    sp = tr.begin("dispatch")
    out = fn(*args)
    tr.end(sp, kind=kind, backend=backend)
    return out


def dispatch_query_value(h: Hierarchy, ls, rs, backend: str) -> jax.Array:
    """Batched ``RMQ_value`` through the chosen backend."""
    backend = runtime_backend(backend)
    if backend == "fused":
        from repro.kernels.rmq_fused import ops as fused_ops

        fn = fused_ops.rmq_fused_value_batch
    elif backend == "pallas":
        from repro.kernels.rmq_scan import ops as scan_ops

        fn = scan_ops.rmq_value_batch_pallas
    else:
        from repro.core.query import rmq_value_batch

        fn = rmq_value_batch
    return _run_dispatch("query_value", backend, fn, h, ls, rs)


def dispatch_query_index(h: Hierarchy, ls, rs, backend: str) -> jax.Array:
    """Batched ``RMQ_index`` (leftmost minimum) through the chosen backend."""
    backend = runtime_backend(backend)
    if backend == "fused":
        from repro.kernels.rmq_fused import ops as fused_ops

        fn = fused_ops.rmq_fused_index_batch
    elif backend == "pallas":
        from repro.kernels.rmq_scan import ops as scan_ops

        fn = scan_ops.rmq_index_batch_pallas
    else:
        from repro.core.query import rmq_index_batch

        fn = rmq_index_batch
    return _run_dispatch("query_index", backend, fn, h, ls, rs)


# ---------------------------------------------------------------------------
# mutation dispatch + validation (shared by all mutable implementations)
# ---------------------------------------------------------------------------
def dispatch_update(h: Hierarchy, idxs, vals, backend: str) -> Hierarchy:
    """Backend dispatch for batched point updates."""
    backend = mutation_backend(backend)
    if backend == "pallas":
        from repro.kernels.hierarchy_update import ops as upd_ops

        fn = upd_ops.update_hierarchy_pallas
    else:
        from repro.streaming import updates as U

        fn = U.update_hierarchy
    return _run_dispatch("update", backend, fn, h, idxs, vals)


def dispatch_append(h: Hierarchy, vals, start, backend: str) -> Hierarchy:
    """Backend dispatch for appends at live offset ``start``."""
    backend = mutation_backend(backend)
    if backend == "pallas":
        from repro.kernels.hierarchy_update import ops as upd_ops

        fn = upd_ops.append_hierarchy_pallas
    else:
        from repro.streaming import updates as U

        fn = U.append_hierarchy
    return _run_dispatch("append", backend, fn, h, vals, start)


def validate_update_batch(idxs, vals, n: Optional[int] = None):
    """Shared idxs/vals checking for every ``update`` entry point.

    Out-of-range indices are dropped silently in normal operation (a
    jit-friendly contract); under ``REPRO_RMQ_DEBUG=1`` concrete batches
    are value-checked against the live length ``n`` so indexing bugs
    fail loudly instead of as stale minima — mirroring query validation.
    """
    idxs = jnp.asarray(idxs)
    vals = jnp.asarray(vals)
    if idxs.ndim != 1 or idxs.shape != vals.shape:
        raise ValueError(
            f"idxs/vals must be matching 1-D batches, got "
            f"{idxs.shape} vs {vals.shape}"
        )
    if not jnp.issubdtype(idxs.dtype, jnp.integer):
        raise TypeError(f"idxs must be integers, got {idxs.dtype}")
    if (
        n is not None
        and _debug_checks_enabled()
        and not isinstance(idxs, jax.core.Tracer)
    ):
        import numpy as np

        i_np = np.asarray(idxs)
        bad = (i_np < 0) | (i_np >= n)
        if bad.any():
            j = int(np.argmax(bad))
            raise ValueError(
                f"update index {j} = {i_np.flat[j]} out of range for "
                f"live length {n}"
            )
    return idxs, vals


def validate_append_batch(vals, length: int, capacity: int) -> jax.Array:
    """Shared vals checking for every ``append`` entry point.

    Rejects non-1-D batches and appends that would overflow the reserved
    capacity (the level geometry is capacity-derived, so growing past it
    would need a new plan — i.e. a rebuild, which ``append`` must never
    silently do).
    """
    vals = jnp.asarray(vals)
    if vals.ndim != 1:
        raise ValueError(f"vals must be 1-D, got shape {vals.shape}")
    b = int(vals.shape[0])
    if length + b > capacity:
        raise ValueError(
            f"append of {b} overflows capacity {capacity} (live length "
            f"{length}); build with a larger capacity reservation"
        )
    return vals


# ---------------------------------------------------------------------------
# engine hook (shared by every implementation's .engine())
# ---------------------------------------------------------------------------
def make_engine(index, **kwargs):
    """A span-routed :class:`repro.qe.QueryEngine` over ``index``.

    The engine classifies queries, executes each class on the cheapest
    applicable path (for distributed indices: segment-local answering
    without the all-reduce where possible), dedups duplicates, and caches
    results keyed by ``generation`` — re-attach (``engine.attach``) after
    any mutation, which returns a *successor* index.
    """
    from repro.qe import QueryEngine

    return QueryEngine.for_index(index, **kwargs)
