"""User-facing RMQ facade: backend selection (pure JAX vs. Pallas kernels).

``backend="auto"`` uses the Pallas query/build kernels when running on TPU
and the pure-JAX reference elsewhere (the kernels also run under
``interpret=True`` on CPU, which the test suite exercises; interpret mode is
a correctness tool, not a performance path, so "auto" avoids it at runtime).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.hierarchy import Hierarchy, build_hierarchy
from repro.core.plan import HierarchyPlan, make_plan
from repro.core.query import rmq_index_batch, rmq_value_batch

__all__ = ["RMQ"]


def _default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "jax"


@dataclasses.dataclass(frozen=True)
class RMQ:
    """A built range-minimum index over a static array (paper §4)."""

    hierarchy: Hierarchy
    backend: str

    # -- construction -----------------------------------------------------
    @staticmethod
    def build(
        x,
        c: int = 128,
        t: int = 64,
        with_positions: bool = False,
        backend: str = "auto",
        plan: Optional[HierarchyPlan] = None,
    ) -> "RMQ":
        x = jnp.asarray(x)
        if x.dtype not in (jnp.float32, jnp.bfloat16, jnp.float64):
            x = x.astype(jnp.float32)
        if plan is None:
            plan = make_plan(int(x.shape[0]), c=c, t=t)
        if backend == "auto":
            backend = _default_backend()
        if backend == "pallas":
            from repro.kernels.hierarchy_build import ops as build_ops

            h = build_ops.build_hierarchy_pallas(
                x, plan, with_positions=with_positions
            )
        elif backend == "jax":
            h = build_hierarchy(x, plan, with_positions=with_positions)
        else:
            raise ValueError(f"unknown backend {backend!r}")
        return RMQ(hierarchy=h, backend=backend)

    # -- queries ----------------------------------------------------------
    def query(self, ls, rs) -> jax.Array:
        """Batched ``RMQ_value`` over inclusive ranges."""
        ls, rs = jnp.asarray(ls), jnp.asarray(rs)
        if self.backend == "pallas":
            from repro.kernels.rmq_scan import ops as scan_ops

            return scan_ops.rmq_value_batch_pallas(self.hierarchy, ls, rs)
        return rmq_value_batch(self.hierarchy, ls, rs)

    def query_index(self, ls, rs) -> jax.Array:
        """Batched ``RMQ_index`` (leftmost minimum) over inclusive ranges."""
        ls, rs = jnp.asarray(ls), jnp.asarray(rs)
        if self.backend == "pallas":
            from repro.kernels.rmq_scan import ops as scan_ops

            return scan_ops.rmq_index_batch_pallas(self.hierarchy, ls, rs)
        return rmq_index_batch(self.hierarchy, ls, rs)

    # -- introspection ----------------------------------------------------
    @property
    def plan(self) -> HierarchyPlan:
        return self.hierarchy.plan

    def memory_bytes(self) -> int:
        return self.hierarchy.memory_bytes()

    def auxiliary_bytes(self) -> int:
        return self.hierarchy.auxiliary_bytes()
