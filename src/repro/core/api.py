"""User-facing RMQ facade: backend selection (pure JAX vs. Pallas kernels).

``backend="auto"`` uses the Pallas query/build kernels when running on TPU
and the pure-JAX reference elsewhere (the kernels also run under
``interpret=True`` on CPU, which the test suite exercises; interpret mode is
a correctness tool, not a performance path, so "auto" avoids it at runtime).

The index is not frozen at build time: ``update`` applies batched point
mutations and ``append`` grows the array into reserved capacity, both in
O(batch · log_c n) chunk re-reductions (see ``repro.streaming`` for the
full streaming structure with sliding-window retirement).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.hierarchy import Hierarchy, build_hierarchy
from repro.core.plan import HierarchyPlan, make_plan
from repro.core.query import (
    check_query_args,
    rmq_index_batch,
    rmq_value_batch,
)

__all__ = ["RMQ"]


def _default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "jax"


@dataclasses.dataclass(frozen=True)
class RMQ:
    """A built range-minimum index (paper §4) with incremental updates."""

    hierarchy: Hierarchy
    backend: str
    # Live length; None means "the build length" (plan.n).  Tracked
    # host-side so appends never invalidate jit specializations.
    length: Optional[int] = None
    # Monotonic mutation counter: every update/append returns a successor
    # with generation + 1.  Host-side metadata (never traced) used by the
    # query engine's result cache to invalidate entries that were computed
    # against an older version of the array.
    generation: int = 0

    # -- construction -----------------------------------------------------
    @staticmethod
    def build(
        x,
        c: int = 128,
        t: int = 64,
        with_positions: bool = False,
        backend: str = "auto",
        plan: Optional[HierarchyPlan] = None,
        capacity: Optional[int] = None,
    ) -> "RMQ":
        """Build over ``x``; pass ``capacity > len(x)`` to allow appends."""
        x = jnp.asarray(x)
        if x.dtype not in (jnp.float32, jnp.bfloat16, jnp.float64):
            x = x.astype(jnp.float32)
        if plan is not None and capacity is not None:
            raise ValueError(
                "pass capacity via make_plan(..., capacity=...) when "
                "supplying an explicit plan"
            )
        if plan is None:
            plan = make_plan(int(x.shape[0]), c=c, t=t, capacity=capacity)
        if backend == "auto":
            backend = _default_backend()
        if backend == "pallas":
            from repro.kernels.hierarchy_build import ops as build_ops

            h = build_ops.build_hierarchy_pallas(
                x, plan, with_positions=with_positions
            )
        elif backend == "jax":
            h = build_hierarchy(x, plan, with_positions=with_positions)
        else:
            raise ValueError(f"unknown backend {backend!r}")
        return RMQ(hierarchy=h, backend=backend, length=plan.n)

    # -- incremental maintenance ------------------------------------------
    def update(self, idxs, vals) -> "RMQ":
        """Batched point updates ``a[idxs] = vals`` (last wins on dups).

        Touches one chunk per level per distinct index — O(B log_c n) —
        instead of rebuilding.
        """
        from repro.streaming.structure import (
            dispatch_update,
            validate_update_batch,
        )

        idxs, vals = validate_update_batch(idxs, vals, n=self.n)
        if idxs.shape[0] == 0:
            return self
        h = dispatch_update(self.hierarchy, idxs, vals, self.backend)
        return dataclasses.replace(
            self, hierarchy=h, generation=self.generation + 1
        )

    def append(self, vals) -> "RMQ":
        """Grow the array with ``vals`` inside the reserved capacity."""
        from repro.streaming.structure import dispatch_append

        vals = jnp.asarray(vals)
        if vals.ndim != 1:
            raise ValueError(f"vals must be 1-D, got shape {vals.shape}")
        b = int(vals.shape[0])
        if b == 0:
            return self
        cap = self.plan.capacity
        if self.n + b > cap:
            raise ValueError(
                f"append of {b} overflows capacity {cap} (live length "
                f"{self.n}); build with RMQ.build(..., capacity=...)"
            )
        h = dispatch_append(
            self.hierarchy, vals, jnp.int32(self.n), self.backend
        )
        return dataclasses.replace(
            self,
            hierarchy=h,
            length=self.n + b,
            generation=self.generation + 1,
        )

    # -- queries ----------------------------------------------------------
    def query(self, ls, rs) -> jax.Array:
        """Batched ``RMQ_value`` over inclusive ranges."""
        ls, rs = check_query_args(ls, rs, self.n)
        if self.backend == "pallas":
            from repro.kernels.rmq_scan import ops as scan_ops

            return scan_ops.rmq_value_batch_pallas(self.hierarchy, ls, rs)
        return rmq_value_batch(self.hierarchy, ls, rs)

    def query_index(self, ls, rs) -> jax.Array:
        """Batched ``RMQ_index`` (leftmost minimum) over inclusive ranges."""
        ls, rs = check_query_args(ls, rs, self.n)
        if self.backend == "pallas":
            from repro.kernels.rmq_scan import ops as scan_ops

            return scan_ops.rmq_index_batch_pallas(self.hierarchy, ls, rs)
        return rmq_index_batch(self.hierarchy, ls, rs)

    # -- adaptive batched engine -------------------------------------------
    def engine(self, **kwargs) -> "object":
        """A span-routed :class:`repro.qe.QueryEngine` over this index.

        The engine classifies each query by span (short / mid / long),
        executes every class on the cheapest applicable path, dedups
        duplicate queries, and caches results keyed by ``generation`` —
        so it must be re-attached (``engine.attach(new_rmq)``) after
        ``update``/``append``, which return a *successor* index.  See
        ``repro.qe`` for knobs (``cache_size``, ``short_cutoff_chunks``,
        ``long_cutoff``...).
        """
        from repro.qe import QueryEngine

        return QueryEngine.for_index(self, **kwargs)

    # -- introspection ----------------------------------------------------
    @property
    def n(self) -> int:
        """Live array length (grows with ``append``)."""
        return self.plan.n if self.length is None else self.length

    @property
    def plan(self) -> HierarchyPlan:
        return self.hierarchy.plan

    def memory_bytes(self) -> int:
        return self.hierarchy.memory_bytes()

    def auxiliary_bytes(self) -> int:
        return self.hierarchy.auxiliary_bytes()
