"""User-facing RMQ facade: backend selection (pure JAX vs. Pallas kernels).

``backend="auto"`` uses the Pallas query/build kernels when running on TPU
and the pure-JAX reference elsewhere (the kernels also run under
``interpret=True`` on CPU, which the test suite exercises; interpret mode is
a correctness tool, not a performance path, so "auto" avoids it at runtime).
``backend="fused"`` selects the single-launch pipelines end to end:
construction in ONE kernel launch (``repro.kernels.hierarchy_fused``) and
batched queries in ONE launch per batch (``repro.kernels.rmq_fused`` —
every span class, value and index ops alike, no host-side class split).
Updates/appends have no fused lowering and run through the platform
default; results are bit-identical on every backend.

The index is not frozen at build time: ``update`` applies batched point
mutations and ``append`` grows the array into reserved capacity, both in
O(batch · log_c n) chunk re-reductions (see ``repro.streaming`` for the
full streaming structure with sliding-window retirement).

``RMQ`` implements the :class:`repro.core.protocol.RMQIndex` /
``MutableRMQIndex`` protocol — the common surface shared with
``StreamingRMQ``, ``HybridRMQ`` and ``DistributedRMQ`` that the batched
query engine routes over.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import protocol as px
from repro.core.hierarchy import Hierarchy
from repro.core.plan import HierarchyPlan, make_plan
from repro.core.query import check_query_args

__all__ = ["RMQ"]


@dataclasses.dataclass(frozen=True)
class RMQ:
    """A built range-minimum index (paper §4) with incremental updates."""

    hierarchy: Hierarchy
    backend: str
    # Live length; None means "the build length" (plan.n).  Tracked
    # host-side so appends never invalidate jit specializations.
    length: Optional[int] = None
    # Monotonic mutation counter: every update/append returns a successor
    # with generation + 1.  Host-side metadata (never traced) used by the
    # query engine's result cache to invalidate entries that were computed
    # against an older version of the array.
    generation: int = 0

    # -- construction -----------------------------------------------------
    @staticmethod
    def build(
        x,
        c=128,
        t: int = 64,
        with_positions: bool = False,
        backend: str = "auto",
        plan: Optional[HierarchyPlan] = None,
        capacity: Optional[int] = None,
        tuning=None,
        span_mix: str = "mixed",
        packed_pos: Optional[bool] = None,
        summary_dtype: Optional[str] = None,
    ) -> "RMQ":
        """Build over ``x``; pass ``capacity > len(x)`` to allow appends.

        ``c="auto"`` resolves geometry from the tuning cache (``tuning``
        — default: the committed ``repro.tune.default_cache()`` — keyed
        by platform × size bucket × ``span_mix``) and attaches the
        winner's ``LevelSplit`` to the plan; with ``backend="auto"`` the
        tuned *query* backend is adopted too (hierarchies are
        bit-identical across backends, so this only changes which
        lowering answers queries).  A cache miss falls back to today's
        defaults (``c=128, t=64``, platform backend) bit-identically.

        ``packed_pos`` / ``summary_dtype`` select the compact plane
        layouts (bit-packed chunk-local positions, bf16 value summaries
        with exact recovery — see ``make_plan``); ``None`` defers to the
        tuning cache, then the classic layout.
        """
        x = px.coerce_values(x)
        if plan is not None and capacity is not None:
            raise ValueError(
                "pass capacity via make_plan(..., capacity=...) when "
                "supplying an explicit plan"
            )
        tuned_cfg = None
        if plan is None and (c == "auto" or tuning is not None):
            from repro.tune import cache as _tc

            store = tuning if tuning is not None else _tc.default_cache()
            tuned_cfg = store.lookup(
                _tc.current_platform(), int(x.shape[0]), span_mix
            )
        if plan is None:
            if tuned_cfg is not None:
                if packed_pos is None:
                    packed_pos = getattr(tuned_cfg, "packed_pos", None)
                if summary_dtype is None:
                    summary_dtype = getattr(
                        tuned_cfg, "summary_dtype", None
                    )
                plan = make_plan(
                    int(x.shape[0]), c=tuned_cfg.c, t=tuned_cfg.t,
                    capacity=capacity,
                    level_split=tuned_cfg.level_split(),
                    packed_pos=packed_pos, summary_dtype=summary_dtype,
                )
            else:
                plan = make_plan(
                    int(x.shape[0]), c=128 if c == "auto" else c, t=t,
                    capacity=capacity,
                    packed_pos=packed_pos, summary_dtype=summary_dtype,
                )
        if backend == "auto" and tuned_cfg is not None:
            backend = tuned_cfg.backend
        backend = px.resolve_backend(backend)
        h = px.build_hierarchy_with_backend(
            x, plan, with_positions=with_positions, backend=backend
        )
        return RMQ(hierarchy=h, backend=backend, length=plan.n)

    @staticmethod
    def build_out_of_core(
        source,
        n: int,
        c: int = 128,
        t: int = 64,
        with_positions: bool = False,
        capacity: Optional[int] = None,
        segment_size: Optional[int] = None,
        packed_pos: Optional[bool] = None,
        summary_dtype: Optional[str] = None,
        backend: str = "jax",
    ) -> "RMQ":
        """Build by streaming fixed-size segments through the fused kernel.

        ``source`` is a sliceable array-like (numpy memmap, array) or a
        callable ``source(start, stop) -> values`` of logical length
        ``n`` — the input never has to exist as one device array during
        level-1 construction
        (:func:`repro.kernels.hierarchy_fused.ops.build_hierarchy_streamed`).
        Under jax x64 mode, position-tracking builds past ``2**31``
        elements store an int64 coordinate plane and queries route
        through the int64-aware pure-JAX walk; without x64 they refuse
        loudly.  Results are bit-identical to :meth:`build`.

        ``backend`` selects the *query* lowering of the returned index
        (default ``'jax'`` — the only walk that is coordinate-exact past
        ``2**31``).
        """
        plan = make_plan(
            n, c=c, t=t, capacity=capacity,
            packed_pos=packed_pos, summary_dtype=summary_dtype,
        )
        from repro.kernels.hierarchy_fused.ops import (
            build_hierarchy_streamed,
        )

        h = build_hierarchy_streamed(
            source, plan, with_positions=with_positions,
            segment_size=segment_size,
        )
        return RMQ(
            hierarchy=h, backend=px.resolve_backend(backend), length=n
        )

    # -- incremental maintenance ------------------------------------------
    def update(self, idxs, vals) -> "RMQ":
        """Batched point updates ``a[idxs] = vals`` (last wins on dups).

        Touches one chunk per level per distinct index — O(B log_c n) —
        instead of rebuilding.
        """
        idxs, vals = px.validate_update_batch(idxs, vals, n=self.n)
        if idxs.shape[0] == 0:
            return self
        h = px.dispatch_update(self.hierarchy, idxs, vals, self.backend)
        return dataclasses.replace(
            self, hierarchy=h, generation=self.generation + 1
        )

    def append(self, vals) -> "RMQ":
        """Grow the array with ``vals`` inside the reserved capacity."""
        vals = px.validate_append_batch(
            vals, length=self.n, capacity=self.plan.capacity
        )
        b = int(vals.shape[0])
        if b == 0:
            return self
        h = px.dispatch_append(
            self.hierarchy, vals, jnp.int32(self.n), self.backend
        )
        return dataclasses.replace(
            self,
            hierarchy=h,
            length=self.n + b,
            generation=self.generation + 1,
        )

    # -- queries ----------------------------------------------------------
    def query(self, ls, rs) -> jax.Array:
        """Batched ``RMQ_value`` over inclusive ranges."""
        ls, rs = check_query_args(ls, rs, self.n)
        return px.dispatch_query_value(self.hierarchy, ls, rs, self.backend)

    def query_index(self, ls, rs) -> jax.Array:
        """Batched ``RMQ_index`` (leftmost minimum) over inclusive ranges."""
        ls, rs = check_query_args(ls, rs, self.n)
        return px.dispatch_query_index(self.hierarchy, ls, rs, self.backend)

    # protocol spellings (RMQIndex): same entry points, canonical names
    query_value_batch = query
    query_index_batch = query_index

    # -- adaptive batched engine -------------------------------------------
    def engine(self, **kwargs) -> "object":
        """A span-routed :class:`repro.qe.QueryEngine` over this index.

        The engine classifies each query by span (short / mid / long),
        executes every class on the cheapest applicable path, dedups
        duplicate queries, and caches results keyed by ``generation`` —
        so it must be re-attached (``engine.attach(new_rmq)``) after
        ``update``/``append``, which return a *successor* index.  See
        ``repro.qe`` for knobs (``cache_size``, ``short_cutoff_chunks``,
        ``long_cutoff``...).
        """
        return px.make_engine(self, **kwargs)

    # -- introspection ----------------------------------------------------
    @property
    def n(self) -> int:
        """Live array length (grows with ``append``)."""
        return self.plan.n if self.length is None else self.length

    @property
    def plan(self) -> HierarchyPlan:
        return self.hierarchy.plan

    @property
    def capacity(self) -> int:
        return self.plan.capacity

    @property
    def with_positions(self) -> bool:
        return self.hierarchy.with_positions

    @property
    def value_dtype(self):
        return self.hierarchy.base.dtype

    def memory_bytes(self) -> int:
        return self.hierarchy.memory_bytes()

    def auxiliary_bytes(self) -> int:
        return self.hierarchy.auxiliary_bytes()
