"""Static geometry of a GPU-RMQ minima hierarchy (paper §4.1).

The hierarchy layout is fully determined by ``(n, c, t)`` — plus, for
streaming workloads, a reserved ``capacity``:

* ``n`` — logical input length at build time (level 0 is the input itself).
* ``c`` — chunk size: each level-(k+1) entry summarizes ``c`` adjacent
  level-k entries. Power of two, as in the paper.
* ``t`` — build cutoff: we stop adding levels once the topmost level holds
  at most ``c * t`` entries (i.e. at most ``t`` chunks), so the final scan
  touches at most ``c * t`` entries.
* ``capacity`` — storage length of level 0 (``>= n``).  Level geometry is
  derived from ``capacity``, so a ``StreamingRMQ`` can append into the
  reserved, ``+inf``-padded tail without changing the plan — keeping every
  jitted build/update/query specialization valid across appends.

Everything in this module is *static* Python metadata (hashable, usable as a
``jax.jit`` static argument).  Device arrays never appear here.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

__all__ = ["HierarchyPlan", "LevelSplit", "make_plan"]


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _round_up(a: int, b: int) -> int:
    return _ceil_div(a, b) * b


@dataclasses.dataclass(frozen=True)
class LevelSplit:
    """How hierarchy levels split across execution engines (paper "hybrid").

    The plan's geometry says *what* the levels are; a ``LevelSplit`` says
    *who answers them*.  It is static, hashable metadata carried on the
    plan (usually resolved from the tuning cache — see ``repro.tune``)
    that the query planner consumes instead of its analytic guesses:

    scan_chunks:  spans covering at most this many aligned ``c``-chunks
                  take the bottom scan route (the dedicated short-span
                  kernel).  1 or 2 only — the ``rmq_short`` kernel scans
                  at most two aligned chunks.
    sparse_top:   whether spans past ``long_cutoff`` route to the O(1)
                  sparse-table top (``HybridRMQ``) instead of walking
                  the hierarchy.
    long_cutoff:  the *measured* walk-vs-sparse-top crossover span;
                  ``None`` keeps the planner's analytic ``2c·c^(L-2)``
                  default.
    fused:        execute through the single-launch ``rmq_fused`` path
                  (no host-side class split) — the tuned winner for
                  workloads where one launch beats routing.
    """

    scan_chunks: int = 2
    sparse_top: bool = True
    long_cutoff: Optional[int] = None
    fused: bool = False

    def __post_init__(self):
        if self.scan_chunks not in (1, 2):
            raise ValueError(
                f"scan_chunks must be 1 or 2 (the short-span kernel scans "
                f"at most two aligned chunks), got {self.scan_chunks}")
        if self.long_cutoff is not None and self.long_cutoff < 1:
            raise ValueError(
                f"long_cutoff must be positive, got {self.long_cutoff}")


@dataclasses.dataclass(frozen=True)
class HierarchyPlan:
    """Immutable description of the level geometry.

    Attributes
    ----------
    n:            logical input length at build time (level 0).
    c:            chunk size (power of two).
    t:            build cutoff threshold (max chunks on the top level).
    capacity:     stored length of level 0 (``>= n``); the geometry below
                  is derived from it so appends up to ``capacity`` never
                  change the plan.
    level_lens:   length of every level, ``level_lens[0] == capacity``.
    padded_lens:  each upper level's stored length, rounded up to a
                  multiple of ``c`` (the base array is stored at
                  ``capacity`` length, +inf-padded past the live region).
    offsets:      start offset of each *upper* level (k >= 1) inside the
                  single contiguous ``upper`` buffer (paper: "we store all
                  precomputed layers in a single, contiguous buffer").
    level_split:  optional :class:`LevelSplit` routing levels across
                  execution engines (attached by the tuned build path);
                  ``None`` keeps every consumer's analytic defaults.
    packed_pos:   store ``upper_pos`` as bit-packed chunk-local offsets
                  (``log2(c)`` bits per entry in a uint32 word array —
                  see ``repro.core.bitpack``) instead of absolute int32/
                  int64 positions.  Bit-identical query results; the
                  position plane shrinks by ``32 / log2(c)`` (~4.6x at
                  ``c=128``).
    summary_dtype: value dtype of the upper levels: ``"float32"`` (exact
                  storage, the default) or ``"bfloat16"`` (half the value
                  bytes; queries re-compare bf16-tied candidates against
                  level 0 so results stay exact — requires a
                  position-tracking build over float32 input).
    """

    n: int
    c: int
    t: int
    level_lens: Tuple[int, ...]
    padded_lens: Tuple[int, ...]
    offsets: Tuple[int, ...]
    capacity: int = 0  # 0 means "== n" (plans predating streaming support)
    level_split: Optional[LevelSplit] = None
    packed_pos: bool = False
    summary_dtype: str = "float32"

    def __post_init__(self):
        if self.capacity == 0:
            object.__setattr__(self, "capacity", self.n)
        if self.summary_dtype not in ("float32", "bfloat16"):
            raise ValueError(
                f"summary_dtype must be 'float32' or 'bfloat16', "
                f"got {self.summary_dtype!r}")

    @property
    def num_levels(self) -> int:
        return len(self.level_lens)

    @property
    def num_upper_levels(self) -> int:
        return self.num_levels - 1

    @property
    def upper_size(self) -> int:
        """Total entries in the contiguous upper buffer."""
        if self.num_levels == 1:
            return 0
        return self.offsets[-1] + self.padded_lens[-1]

    @property
    def top_len(self) -> int:
        """Logical length of the topmost level."""
        return self.level_lens[-1]

    @property
    def top_padded_len(self) -> int:
        if self.num_levels == 1:
            return self.level_lens[0]
        return self.padded_lens[-1]

    def level_slice(self, level: int) -> Tuple[int, int]:
        """(offset, padded_len) of an upper level inside the upper buffer."""
        if level < 1 or level >= self.num_levels:
            raise ValueError(f"level {level} is not an upper level")
        return self.offsets[level - 1], self.padded_lens[level - 1]

    # -- paper §4.1 analytical bounds ------------------------------------
    def max_scanned_entries(self) -> int:
        """Worst-case scanned entries: ``c*t + 2c*log_c(n)`` (paper §4.1)."""
        return self.c * self.t + 2 * self.c * max(self.num_levels - 1, 0)

    def memory_bound_entries(self) -> float:
        """Upper bound on auxiliary entries: ``n / (c - 1)`` (paper §4.1)."""
        return self.n / (self.c - 1)

    def auxiliary_entries(self) -> int:
        """Actual auxiliary entries materialized (excludes the input)."""
        return self.upper_size

    def overhead_fraction(self) -> float:
        """Auxiliary memory as a fraction of the input array."""
        return self.auxiliary_entries() / max(self.n, 1)

    # -- byte accounting (paper §5.5 / Fig. 15 memory claims) ------------
    def pos_bits(self) -> int:
        """Bits per packed position entry (chunk-local offset < c)."""
        return max(1, (self.c - 1).bit_length())

    def input_bytes(self, value_itemsize: int = 4) -> int:
        """Bytes of the stored level-0 plane (padded to capacity)."""
        return self.capacity * value_itemsize

    def value_plane_bytes(self) -> int:
        """Bytes of the stored ``upper`` value plane under this plan."""
        itemsize = 2 if self.summary_dtype == "bfloat16" else 4
        return self.upper_size * itemsize

    def position_plane_bytes(self) -> int:
        """Bytes of the stored ``upper_pos`` plane for a
        position-tracking build: packed uint32 words under
        ``packed_pos``, else one absolute int32 (int64 past 2^31) per
        entry."""
        if self.upper_size == 0:
            return 0
        if self.packed_pos:
            return ((self.upper_size * self.pos_bits() + 31) // 32) * 4
        itemsize = 8 if self.capacity >= 2**31 else 4
        return self.upper_size * itemsize

    def auxiliary_bytes_planned(self, with_positions: bool = True) -> int:
        """Total auxiliary bytes (value plane + optional position plane)."""
        total = self.value_plane_bytes()
        if with_positions:
            total += self.position_plane_bytes()
        return total


def make_plan(
    n: int,
    c: Union[int, str] = 128,
    t: int = 64,
    capacity: Optional[int] = None,
    tuned: bool = False,
    span_mix: str = "mixed",
    tuning=None,
    platform: Optional[str] = None,
    level_split: Optional[LevelSplit] = None,
    packed_pos: Optional[bool] = None,
    summary_dtype: Optional[str] = None,
) -> HierarchyPlan:
    """Compute the level geometry for an input of length ``n``.

    Levels are added bottom-up until the topmost level holds at most
    ``c * t`` entries.  For ``n <= c * t`` the plan degenerates to a single
    level (pure scan), which is both correct and what the paper's cutoff
    implies.

    ``capacity`` (default ``n``) reserves room for streaming appends: the
    level geometry is computed as if the input were ``capacity`` long, and
    builds pad level 0 out to ``capacity`` with ``+inf``.  Because the
    geometry is capacity-derived, growing the live length up to
    ``capacity`` (``StreamingRMQ.append``) reuses every jit specialization.

    ``tuned=True`` (or ``c="auto"``) resolves geometry from the tuning
    cache (``tuning`` — default: the committed ``repro.tune.default_cache``
    — keyed by ``platform`` × size bucket × ``span_mix``) and attaches the
    winner's :class:`LevelSplit` to the plan.  A cache miss falls back to
    the numeric ``c``/``t`` passed here (i.e. today's defaults) with no
    split attached — tuning can never make a plan worse than untuned.

    ``packed_pos`` / ``summary_dtype`` select the compact plane layouts
    (see :class:`HierarchyPlan`); left at ``None`` they default to the
    classic layout (``False`` / ``"float32"``), except that the tuned
    path may adopt a cached winner's layout — an explicit value here
    always outranks the cache.
    """
    if n <= 0:
        raise ValueError(f"n must be positive, got {n}")
    if tuned or c == "auto":
        # Lazy import: the tuned path is the only jax-adjacent dependency
        # in this module, and only pays for itself when requested.
        from repro.tune import cache as _tc

        store = tuning if tuning is not None else _tc.default_cache()
        plat = platform or _tc.current_platform()
        cfg = store.lookup(plat, n, span_mix)
        if cfg is not None:
            c, t = cfg.c, cfg.t
            if level_split is None:
                level_split = cfg.level_split()
            if packed_pos is None:
                packed_pos = getattr(cfg, "packed_pos", None)
            if summary_dtype is None:
                summary_dtype = getattr(cfg, "summary_dtype", None)
        elif c == "auto":
            c = 128  # cache miss: today's default geometry
    if packed_pos is None:
        packed_pos = False
    if summary_dtype is None:
        summary_dtype = "float32"
    if c < 2 or (c & (c - 1)) != 0:
        raise ValueError(f"chunk size c must be a power of two >= 2, got {c}")
    if t < 1:
        raise ValueError(f"threshold t must be >= 1, got {t}")
    if capacity is None:
        capacity = n
    if capacity < n:
        raise ValueError(f"capacity {capacity} < n {n}")

    level_lens = [capacity]
    while level_lens[-1] > c * t:
        level_lens.append(_ceil_div(level_lens[-1], c))

    padded = [_round_up(m, c) for m in level_lens[1:]]
    offsets = []
    acc = 0
    for p in padded:
        offsets.append(acc)
        acc += p

    return HierarchyPlan(
        n=n,
        c=c,
        t=t,
        level_lens=tuple(level_lens),
        padded_lens=tuple(padded),
        offsets=tuple(offsets),
        capacity=capacity,
        level_split=level_split,
        packed_pos=packed_pos,
        summary_dtype=summary_dtype,
    )
