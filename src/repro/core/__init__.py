"""GPU-RMQ core: hierarchical range-minimum structure, TPU-adapted.

Public API:

    from repro.core import RMQ, make_plan, build_hierarchy

    rmq = RMQ.build(x, c="auto")              # geometry from the tuning
                                              # cache (c=128, t=64 on a
                                              # cache miss)
    vals = rmq.query(ls, rs)                  # batched RMQ_value
    rmq = RMQ.build(x, with_positions=True)
    pos  = rmq.query_index(ls, rs)            # batched RMQ_index (leftmost)

Explicit ``c``/``t`` still work everywhere; ``c="auto"`` resolves them
from ``results/tuning_cache.json`` (see ``repro.tune``) per platform,
input-size bucket, and span mix.
"""

from repro.core.api import RMQ
from repro.core.constants import PAD_POS, POS_INF_I32
from repro.core.hierarchy import (
    Hierarchy,
    build_hierarchy,
    build_many,
    pos_dtype_for,
)
from repro.core.plan import HierarchyPlan, LevelSplit, make_plan
from repro.core.protocol import (
    MutableRMQIndex,
    RMQIndex,
    is_distributed,
    live_length,
    supports_mutation,
)
from repro.core.query import (
    check_query_args,
    rmq_index,
    rmq_index_batch,
    rmq_value,
    rmq_value_batch,
)

__all__ = [
    "RMQ",
    "RMQIndex",
    "MutableRMQIndex",
    "is_distributed",
    "live_length",
    "supports_mutation",
    "Hierarchy",
    "HierarchyPlan",
    "LevelSplit",
    "PAD_POS",
    "POS_INF_I32",
    "build_hierarchy",
    "build_many",
    "make_plan",
    "pos_dtype_for",
    "check_query_args",
    "rmq_value",
    "rmq_value_batch",
    "rmq_index",
    "rmq_index_batch",
]
