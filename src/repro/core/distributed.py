"""Distributed RMQ: segment-sharded hierarchies + min all-reduce.

This is the piece that removes the paper's central limitation — the single
GPU's memory ceiling (LCA/RTXRMQ die at n = 2^28..2^29 on 24 GB; GPU-RMQ
itself is capped at n = 2^31 on a 4090, §5.5).  We shard the input array
into contiguous segments across a mesh axis (default ``"model"``); each
device owns one segment plus its private minima hierarchy (auxiliary
memory stays n_local/(c-1) per device).  A query batch is sharded across
the remaining axes (``"data"``, ``"pod"``) and *replicated* across the
segment axis; every device answers the intersection of each query with its
segment using the paper's algorithm, and a single ``pmin`` over the segment
axis combines per-segment minima.

Communication cost per batch: one all-reduce(min) of ``batch_local``
floats over the segment axis — independent of n.  Capacity scales linearly
with the number of devices: a 2×16×16 v5e mesh with the `model` axis as
segment axis holds 512 GB of f32 input (n = 2^37), 64× beyond the paper's
single-GPU ceiling.

The same code path runs on the production meshes via ``shard_map`` and on
a single CPU device (1×1 mesh) for tests.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.hierarchy import Hierarchy, build_hierarchy
from repro.core.plan import HierarchyPlan, make_plan
from repro.core.query import _rmq_batch

__all__ = ["DistributedRMQ"]

_POS_INF_I32 = jnp.iinfo(jnp.int32).max


def _num_segments(mesh: Mesh, axis: str) -> int:
    return mesh.shape[axis]


@dataclasses.dataclass(frozen=True)
class DistributedRMQ:
    """Segment-sharded RMQ index living on a device mesh."""

    base: jax.Array          # (n_padded,) sharded over segment axis
    upper: jax.Array         # (S * upper_local,) sharded over segment axis
    upper_pos: Optional[jax.Array]
    local_plan: HierarchyPlan
    mesh: Mesh
    segment_axis: str
    query_axes: Tuple[str, ...]
    n: int                   # logical (unpadded) length

    # -- construction -----------------------------------------------------
    @staticmethod
    def build(
        x,
        mesh: Mesh,
        segment_axis: str = "model",
        query_axes: Tuple[str, ...] = ("data",),
        c: int = 128,
        t: int = 64,
        with_positions: bool = False,
    ) -> "DistributedRMQ":
        x = jnp.asarray(x)
        n = int(x.shape[0])
        s = _num_segments(mesh, segment_axis)
        n_local = -(-n // s)
        n_padded = n_local * s
        if n_padded != n:
            x = jnp.pad(x, (0, n_padded - n), constant_values=jnp.inf)
        local_plan = make_plan(n_local, c=c, t=t)

        x = jax.device_put(x, NamedSharding(mesh, P(segment_axis)))

        @functools.partial(
            shard_map,
            mesh=mesh,
            in_specs=P(segment_axis),
            out_specs=(
                P(segment_axis),
                P(segment_axis),
                P(segment_axis) if with_positions else P(),
            ),
            check_vma=False,
        )
        def build_local(x_local):
            h = build_hierarchy(
                x_local, local_plan, with_positions=with_positions
            )
            pos = (
                h.upper_pos
                if with_positions
                else jnp.zeros((), dtype=jnp.int32)
            )
            return h.base, h.upper, pos

        base, upper, pos = jax.jit(build_local)(x)
        return DistributedRMQ(
            base=base,
            upper=upper,
            upper_pos=pos if with_positions else None,
            local_plan=local_plan,
            mesh=mesh,
            segment_axis=segment_axis,
            query_axes=tuple(query_axes),
            n=n,
        )

    # -- queries ----------------------------------------------------------
    def query(self, ls, rs) -> jax.Array:
        """Batched RMQ_value over global inclusive ranges."""
        return self._query(ls, rs, track_pos=False)[0]

    def query_index(self, ls, rs) -> jax.Array:
        if self.upper_pos is None:
            raise ValueError("built without positions")
        return self._query(ls, rs, track_pos=True)[1]

    def _query(self, ls, rs, track_pos: bool):
        mesh = self.mesh
        seg = self.segment_axis
        qspec = P(self.query_axes)
        ls = jnp.asarray(ls, dtype=jnp.int32)
        rs = jnp.asarray(rs, dtype=jnp.int32)
        ls = jax.device_put(ls, NamedSharding(mesh, qspec))
        rs = jax.device_put(rs, NamedSharding(mesh, qspec))
        n_local = self.local_plan.n
        plan = self.local_plan
        pos_in = (
            self.upper_pos
            if track_pos
            else jnp.zeros((0,), dtype=jnp.int32)
        )

        @functools.partial(
            shard_map,
            mesh=mesh,
            in_specs=(
                P(seg),
                P(seg),
                P(seg) if track_pos else P(),
                qspec,
                qspec,
            ),
            out_specs=(qspec, qspec),
            check_vma=False,
        )
        def go(base_l, upper_l, pos_l, ls_l, rs_l):
            seg_idx = jax.lax.axis_index(seg)
            seg_start = (seg_idx * n_local).astype(jnp.int32)
            # Intersect each global range with this segment.
            ll = jnp.clip(ls_l - seg_start, 0, n_local - 1)
            rr = jnp.clip(rs_l - seg_start, 0, n_local - 1)
            nonempty = (rs_l >= seg_start) & (ls_l < seg_start + n_local)
            m, p = _rmq_batch(
                plan, base_l, upper_l,
                pos_l if track_pos else None,
                ll, rr, track_pos=track_pos,
            )
            inf = jnp.array(jnp.inf, dtype=m.dtype)
            m = jnp.where(nonempty, m, inf)
            if track_pos:
                p = jnp.where(nonempty, p + seg_start, _POS_INF_I32)
                # Combine (value, pos) lexicographically across segments so
                # ties stay leftmost: min on value, then min pos among argmin.
                mins = jax.lax.pmin(m, seg)
                p = jnp.where(m == mins, p, _POS_INF_I32)
                p = jax.lax.pmin(p, seg)
                return mins, p
            return jax.lax.pmin(m, seg), jnp.zeros_like(ls_l)

        return jax.jit(go)(self.base, self.upper, pos_in, ls, rs)

    # -- introspection ------------------------------------------------------
    def memory_bytes_per_device(self) -> int:
        s = _num_segments(self.mesh, self.segment_axis)
        total = self.base.size * self.base.dtype.itemsize
        total += self.upper.size * self.upper.dtype.itemsize
        if self.upper_pos is not None:
            total += self.upper_pos.size * self.upper_pos.dtype.itemsize
        return total // s
