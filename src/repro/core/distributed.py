"""Distributed RMQ: segment-sharded hierarchies + min all-reduce.

This is the piece that removes the paper's central limitation — the single
GPU's memory ceiling (LCA/RTXRMQ die at n = 2^28..2^29 on 24 GB; GPU-RMQ
itself is capped at n = 2^31 on a 4090, §5.5).  We shard the input array
into contiguous segments across a mesh axis (default ``"model"``); each
device owns one segment plus its private minima hierarchy (auxiliary
memory stays n_local/(c-1) per device).  A query batch is sharded across
the remaining axes (``"data"``, ``"pod"``) and *replicated* across the
segment axis; every device answers the intersection of each query with its
segment using the paper's algorithm, and a single ``pmin`` over the segment
axis combines per-segment minima.

Communication cost per batch: one all-reduce(min) of ``batch_local``
floats over the segment axis — independent of n.  Per-device memory
scales down linearly with the number of segments, lifting the paper's
single-device ceiling up to this implementation's own int32 index-space
bound (total capacity < 2^31, enforced at build).

``DistributedRMQ`` implements the full
:class:`repro.core.protocol.MutableRMQIndex` protocol:

* **streaming mutation** — :meth:`update` and :meth:`append` route each
  batch to the owning segment under the same ``shard_map`` and re-reduce
  shard-locally through the ``repro.streaming`` update machinery
  (scatter + O(batch · log_c n_local) chunk re-reductions).  The batch is
  replicated over the segment axis and every non-owned index is dropped by
  the scatter's out-of-range semantics, so updates need **zero**
  cross-segment communication and never rebuild.  Mutators return a
  successor with ``generation + 1``.
* **engine routing** — ``repro.qe``'s engine accepts a ``DistributedRMQ``
  through the same ``attach()``/``register()`` surface as every other
  index; spans that fall entirely inside one segment are answered
  segment-locally (:meth:`_query_grouped` — no ``pmin`` at all), only
  segment-crossing spans pay the all-reduce.

Reserve headroom for appends with ``build(..., capacity=)``: each segment
reserves ``ceil(capacity / S)`` +inf-padded slots and element ``g`` lives
in segment ``g // segment_capacity`` — appends land on the tail segments.

The same code path runs on the production meshes via ``shard_map`` and on
a single CPU device (1×1 mesh) for tests.  Query/position arithmetic runs
in a *coordinate dtype* derived from the total capacity: int32 below
2**31 (bit-identical to the historical stack), int64 past it **when jax
x64 mode is on** — segment starts, globalized positions and combine
sentinels all widen together, so the paper's index-space ceiling lifts
with the memory ceiling.  Without x64, ``build`` refuses total
capacities at or past 2**31 (the same loud
``repro.core.protocol.check_capacity_limit`` contract the batched engine
enforces at ``attach``) rather than letting bounds wrap silently.

Compact layouts ride along: ``build(..., packed_pos=True)`` stores each
segment's position plane as log2(c)-bit packed words and
``summary_dtype='bfloat16'`` halves the upper value planes (the sharded
walks carry the position plane even for value-only batches then — exact
recovery re-reads level 0 through the stored positions).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import protocol as px
from repro.core.hierarchy import pos_dtype_for
from repro.core.plan import HierarchyPlan, make_plan
from repro.core.query import _rmq_batch, check_query_args

__all__ = ["DistributedRMQ"]


def _num_segments(mesh: Mesh, axis: str) -> int:
    return mesh.shape[axis]


# ---------------------------------------------------------------------------
# persistent jitted collectives, one per (mesh, geometry) — successor
# indices produced by update/append reuse the same compiled executables
# instead of retracing per call.
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=64)
def _build_fn(mesh: Mesh, seg: str, plan: HierarchyPlan,
              with_positions: bool, backend: str):
    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=P(seg),
        out_specs=(
            P(seg),
            P(seg),
            P(seg) if with_positions else P(),
        ),
        check_vma=False,
    )
    def build_local(x_local):
        # Shard-local construction through the shared pipeline: with
        # backend='fused' every device builds its whole segment hierarchy
        # in ONE kernel launch under the same shard_map.
        h = px.build_hierarchy_with_backend(
            x_local, plan, with_positions=with_positions, backend=backend
        )
        pos = (
            h.upper_pos
            if with_positions
            else jnp.zeros((), dtype=jnp.int32)
        )
        return h.base, h.upper, pos

    return jax.jit(build_local)


def _need_pos_plane(plan: HierarchyPlan, track: bool) -> bool:
    """Whether the sharded walk must carry the position plane.

    bf16 summaries need it even for value-only batches: exact recovery
    re-reads level 0 through the stored positions.
    """
    return track or plan.summary_dtype == "bfloat16"


def _local_rmq(plan: HierarchyPlan, base_l, upper_l, pos_l, ls, rs,
               track: bool, backend: str):
    """Shard-local batched RMQ behind the sharded walks.

    ``backend='fused'`` routes through ``kernels/rmq_fused`` — each
    device answers its whole (sub)batch in ONE fused dispatch (the
    engine's segment-contained fast path then costs one launch per
    device and still no collective); every other backend takes the
    pure-JAX walk.  Results are bit-identical either way.
    """
    need_pos = _need_pos_plane(plan, track)
    if backend == "fused":
        from repro.core.hierarchy import Hierarchy
        from repro.kernels.rmq_fused import ops as fused_ops

        h = Hierarchy(
            base=base_l,
            upper=upper_l,
            upper_pos=pos_l if need_pos else None,
            plan=plan,
        )
        m, p = fused_ops.rmq_fused_batch(h, ls, rs, track_pos=track)
        if not track:
            p = jnp.zeros_like(ls)
        return m, p
    return _rmq_batch(
        plan, base_l, upper_l, pos_l if need_pos else None, ls, rs,
        track_pos=track,
    )


@functools.lru_cache(maxsize=64)
def _allreduce_query_fn(mesh: Mesh, seg: str, qaxes: Tuple[str, ...],
                        plan: HierarchyPlan, track: bool, backend: str):
    """The monolithic query path: every segment answers its intersection,
    one ``pmin`` over the segment axis combines."""
    n_local = plan.capacity
    # Coordinate dtype of the GLOBAL index space: int64 past 2**31 under
    # x64, int32 (the historical arithmetic, bit-identical) below.
    coord = pos_dtype_for(n_local * mesh.shape[seg], strict=False)
    ident = jnp.iinfo(coord).max
    lcoord = pos_dtype_for(n_local, strict=False)
    need_pos = _need_pos_plane(plan, track)
    qspec = P(qaxes)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(
            P(seg),
            P(seg),
            P(seg) if need_pos else P(),
            qspec,
            qspec,
        ),
        out_specs=(qspec, qspec),
        check_vma=False,
    )
    def go(base_l, upper_l, pos_l, ls_l, rs_l):
        seg_idx = jax.lax.axis_index(seg)
        # Widen BEFORE the multiply: seg_idx * n_local wraps int32 past
        # 2**31 even when every operand fits individually.
        seg_start = seg_idx.astype(coord) * n_local
        ls_c = ls_l.astype(coord)
        rs_c = rs_l.astype(coord)
        # Intersect each global range with this segment; clip in the
        # global coordinate dtype, THEN narrow (a bare cast could wrap a
        # far-away bound back into local range).
        ll = jnp.clip(ls_c - seg_start, 0, n_local - 1).astype(lcoord)
        rr = jnp.clip(rs_c - seg_start, 0, n_local - 1).astype(lcoord)
        nonempty = (rs_c >= seg_start) & (ls_c < seg_start + n_local)
        m, p = _local_rmq(
            plan, base_l, upper_l, pos_l, ll, rr, track, backend
        )
        inf = jnp.array(jnp.inf, dtype=m.dtype)
        m = jnp.where(nonempty, m, inf)
        if track:
            p = jnp.where(nonempty, p.astype(coord) + seg_start, ident)
            # Combine (value, pos) lexicographically across segments so
            # ties stay leftmost: min on value, then min pos among argmin.
            mins = jax.lax.pmin(m, seg)
            p = jnp.where(m == mins, p, ident)
            p = jax.lax.pmin(p, seg)
            return mins, p
        return jax.lax.pmin(m, seg), jnp.zeros_like(ls_l)

    return jax.jit(go)


@functools.lru_cache(maxsize=64)
def _grouped_query_fn(mesh: Mesh, seg: str, plan: HierarchyPlan,
                      track: bool, backend: str):
    """Segment-local answering: the query batch arrives pre-grouped by
    owning segment as ``(S, k)`` *local* bounds sharded over the segment
    axis, each device answers only its own row, and no collective runs at
    all — this is the engine's fast path for spans contained in one
    segment."""
    n_local = plan.capacity
    coord = pos_dtype_for(n_local * mesh.shape[seg], strict=False)
    need_pos = _need_pos_plane(plan, track)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(
            P(seg),
            P(seg),
            P(seg) if need_pos else P(),
            P(seg),
            P(seg),
        ),
        out_specs=(P(seg), P(seg)),
        check_vma=False,
    )
    def go(base_l, upper_l, pos_l, ls_l, rs_l):
        seg_idx = jax.lax.axis_index(seg)
        seg_start = seg_idx.astype(coord) * n_local
        m, p = _local_rmq(
            plan, base_l, upper_l, pos_l, ls_l[0], rs_l[0], track, backend
        )
        if track:
            p = p.astype(coord) + seg_start  # globalize leftmost positions
        else:
            p = jnp.zeros_like(m, dtype=jnp.int32)
        return m[None, :], p[None, :]

    return jax.jit(go)


@functools.lru_cache(maxsize=64)
def _mutate_fn(mesh: Mesh, seg: str, plan: HierarchyPlan, track: bool):
    """Sharded batched point mutation: the (idxs, vals) batch is replicated
    over the segment axis; each device localizes the indices, the base
    scatter drops everything outside its segment, and the streaming
    machinery re-reduces only the touched shard-local chunks.  No
    collective — updates are communication-free."""
    from repro.streaming.updates import propagate_updates, scatter_base

    n_local = plan.capacity
    coord = pos_dtype_for(n_local * mesh.shape[seg], strict=False)
    lcoord = pos_dtype_for(n_local, strict=False)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(
            P(seg),
            P(seg),
            P(seg) if track else P(),
            P(),
            P(),
        ),
        out_specs=(
            P(seg),
            P(seg),
            P(seg) if track else P(),
        ),
        check_vma=False,
    )
    def go(base_l, upper_l, pos_l, idxs, vals):
        seg_idx = jax.lax.axis_index(seg)
        seg_start = seg_idx.astype(coord) * n_local
        # Localize in the global coordinate dtype, clamp out-of-segment
        # indices to the dropped sentinels BEFORE narrowing — a bare
        # int64->int32 cast could wrap a foreign index back into range.
        local = jnp.clip(
            idxs.astype(coord) - seg_start, -1, n_local
        ).astype(lcoord)
        # scatter_base drops local indices outside [0, n_local) — i.e.
        # every index another segment owns; propagate_updates routes their
        # chunk ids to an idempotent chunk-0 re-reduction, so each device
        # does identical-shape work on its own slice only.
        base2 = scatter_base(base_l, local, vals)
        upper2, pos2 = propagate_updates(
            plan, base2, upper_l, pos_l if track else None, local
        )
        if not track:
            pos2 = jnp.zeros((), dtype=jnp.int32)
        return base2, upper2, pos2

    return jax.jit(go)


@dataclasses.dataclass(frozen=True)
class DistributedRMQ:
    """Segment-sharded RMQ index living on a device mesh."""

    base: jax.Array          # (S * segment_capacity,) sharded over seg axis
    upper: jax.Array         # (S * upper_local,) sharded over seg axis
    upper_pos: Optional[jax.Array]
    local_plan: HierarchyPlan
    mesh: Mesh
    segment_axis: str
    query_axes: Tuple[str, ...]
    n: int                   # logical (unpadded) live length
    # Monotonic mutation counter (host-side, never traced): bumped by
    # update/append so engine result caches invalidate correctly.
    generation: int = 0
    # Runtime backend of the shard-local query walks: 'fused' answers
    # each device's (sub)batch in one rmq_fused dispatch, everything
    # else takes the pure-JAX walk under the same shard_map.  Mutations
    # are pure JAX on every backend.
    backend: str = "jax"

    # protocol marker: the engine routes distributed indices through the
    # segment-local/crossing executor instead of the span executors.
    distributed = True

    # -- construction -----------------------------------------------------
    @staticmethod
    def build(
        x,
        mesh: Mesh,
        segment_axis: str = "model",
        query_axes: Tuple[str, ...] = ("data",),
        c: int = 128,
        t: int = 64,
        with_positions: bool = False,
        capacity: Optional[int] = None,
        backend: str = "auto",
        packed_pos: Optional[bool] = None,
        summary_dtype: Optional[str] = None,
    ) -> "DistributedRMQ":
        """Build over ``x``; pass ``capacity > len(x)`` to allow appends.

        ``capacity`` is the *global* reservation: each segment reserves
        ``ceil(capacity / S)`` +inf-padded slots and the level geometry is
        derived from that, so appends up to ``capacity`` reuse every jit
        specialization (same contract as ``RMQ``/``StreamingRMQ``).

        ``backend`` selects the shard-local *construction* path (the
        shared ``'fused'``/``'pallas'``/``'jax'`` pipeline) and, for
        ``'fused'``, the shard-local *query* lowering too: each device
        answers its (sub)batch in one ``kernels/rmq_fused`` dispatch
        under the same ``shard_map``.  Updates/appends are pure JAX on
        every backend.

        ``packed_pos``/``summary_dtype`` select the compact per-segment
        layouts (log2(c)-bit packed position planes, bf16 value
        summaries with exact recovery) — same semantics as
        ``make_plan``; ``None`` defers to the tuning cache.
        """
        x = px.coerce_values(x)
        n = int(x.shape[0])
        s = _num_segments(mesh, segment_axis)
        if capacity is None:
            capacity = n
        if capacity < n:
            raise ValueError(f"capacity {capacity} < n {n}")
        cap_local = -(-capacity // s)
        cap_padded = cap_local * s
        # Bounds, positions and update indices flow through the
        # coordinate dtype — int32 below 2**31, int64 past it under x64.
        # Without x64 the shared guard refuses loudly rather than wrap
        # (mirrors the engine's attach-time contract).
        px.check_capacity_limit(cap_padded, allow_x64=True)
        if cap_padded != n:
            x = jnp.pad(x, (0, cap_padded - n), constant_values=jnp.inf)
        local_plan = make_plan(
            cap_local, c=c, t=t,
            packed_pos=packed_pos, summary_dtype=summary_dtype,
        )

        backend = px.resolve_backend(backend)
        x = jax.device_put(x, NamedSharding(mesh, P(segment_axis)))
        base, upper, pos = _build_fn(
            mesh, segment_axis, local_plan, with_positions, backend
        )(x)
        return DistributedRMQ(
            base=base,
            upper=upper,
            upper_pos=pos if with_positions else None,
            local_plan=local_plan,
            mesh=mesh,
            segment_axis=segment_axis,
            query_axes=tuple(query_axes),
            n=n,
            backend=backend,
        )

    # -- incremental maintenance ------------------------------------------
    def _mutate(self, idxs, vals) -> Tuple[jax.Array, ...]:
        """Run the sharded scatter + shard-local re-reduction."""
        track = self.with_positions
        repl = NamedSharding(self.mesh, P())
        coord = pos_dtype_for(self.capacity, strict=False)
        idxs = jax.device_put(jnp.asarray(idxs, coord), repl)
        vals = jax.device_put(jnp.asarray(vals), repl)
        pos_in = (
            self.upper_pos if track else jnp.zeros((), dtype=jnp.int32)
        )
        return _mutate_fn(
            self.mesh, self.segment_axis, self.local_plan, track
        )(self.base, self.upper, pos_in, idxs, vals)

    def update(self, idxs, vals) -> "DistributedRMQ":
        """Batched point updates ``a[idxs] = vals`` (last wins on dups).

        Global indices; each lands on its owning segment and re-reduces
        O(log_c n_local) shard-local chunks.  No cross-segment traffic.
        """
        idxs, vals = px.validate_update_batch(idxs, vals, n=self.n)
        if idxs.shape[0] == 0:
            return self
        base, upper, pos = self._mutate(idxs, vals)
        return dataclasses.replace(
            self,
            base=base,
            upper=upper,
            upper_pos=pos if self.with_positions else None,
            generation=self.generation + 1,
        )

    def append(self, vals) -> "DistributedRMQ":
        """Grow the array with ``vals`` inside the reserved capacity.

        Appends are point updates over the +inf-reserved tail: positions
        ``[n, n + B)`` are routed to their owning segment(s) — a batch may
        straddle a segment boundary — and repaired shard-locally.
        """
        vals = px.validate_append_batch(
            vals, length=self.n, capacity=self.capacity
        )
        b = int(vals.shape[0])
        if b == 0:
            return self
        coord = pos_dtype_for(self.capacity, strict=False)
        idxs = self.n + jnp.arange(b, dtype=coord)
        base, upper, pos = self._mutate(idxs, vals)
        return dataclasses.replace(
            self,
            base=base,
            upper=upper,
            upper_pos=pos if self.with_positions else None,
            n=self.n + b,
            generation=self.generation + 1,
        )

    # -- queries ----------------------------------------------------------
    def query(self, ls, rs) -> jax.Array:
        """Batched RMQ_value over global inclusive ranges."""
        return self._query(ls, rs, track_pos=False)[0]

    def query_index(self, ls, rs) -> jax.Array:
        if self.upper_pos is None:
            raise ValueError("built without positions")
        return self._query(ls, rs, track_pos=True)[1]

    # protocol spellings (RMQIndex): same entry points, canonical names
    query_value_batch = query
    query_index_batch = query_index

    def _query(self, ls, rs, track_pos: bool):
        ls, rs = check_query_args(ls, rs, self.n)
        mesh = self.mesh
        qspec = P(self.query_axes)
        coord = pos_dtype_for(self.capacity, strict=False)
        ls = jnp.asarray(ls, dtype=coord)
        rs = jnp.asarray(rs, dtype=coord)
        # The batch is sharded over the query axes, so its size must
        # divide evenly; pad with (0, 0) sentinels (valid on any
        # non-empty array) and slice the results back.
        m = int(ls.shape[0])
        q = 1
        for a in self.query_axes:
            q *= mesh.shape[a]
        pad = (-m) % q
        if pad:
            ls = jnp.pad(ls, (0, pad))
            rs = jnp.pad(rs, (0, pad))
        ls = jax.device_put(ls, NamedSharding(mesh, qspec))
        rs = jax.device_put(rs, NamedSharding(mesh, qspec))
        pos_in = (
            self.upper_pos
            if _need_pos_plane(self.local_plan, track_pos)
            else jnp.zeros((0,), dtype=jnp.int32)
        )
        fn = _allreduce_query_fn(
            mesh, self.segment_axis, self.query_axes, self.local_plan,
            track_pos, self.backend,
        )
        vals, poss = fn(self.base, self.upper, pos_in, ls, rs)
        if pad:
            vals, poss = vals[:m], poss[:m]
        return vals, poss

    def _query_grouped(self, ls_local, rs_local, track_pos: bool):
        """Answer pre-grouped segment-local queries without the all-reduce.

        ``ls_local``/``rs_local`` are ``(S, k)`` arrays of *segment-local*
        inclusive bounds — row ``i`` holds only queries whose global range
        falls entirely inside segment ``i`` (pad unused slots with
        ``(0, 0)``; their results are garbage to be dropped by the
        caller).  Returns ``(S, k)`` values and *global* leftmost
        positions.  This is the engine's fast path: zero cross-device
        communication.
        """
        if track_pos and self.upper_pos is None:
            raise ValueError("built without positions")
        mesh = self.mesh
        seg = self.segment_axis
        s = self.num_segments
        ls_local = jnp.asarray(ls_local, jnp.int32)
        rs_local = jnp.asarray(rs_local, jnp.int32)
        if ls_local.ndim != 2 or ls_local.shape[0] != s:
            raise ValueError(
                f"grouped bounds must be (num_segments={s}, k), got "
                f"{ls_local.shape}"
            )
        sh = NamedSharding(mesh, P(seg))
        ls_local = jax.device_put(ls_local, sh)
        rs_local = jax.device_put(rs_local, sh)
        pos_in = (
            self.upper_pos
            if _need_pos_plane(self.local_plan, track_pos)
            else jnp.zeros((0,), dtype=jnp.int32)
        )
        fn = _grouped_query_fn(
            mesh, seg, self.local_plan, track_pos, self.backend
        )
        return fn(self.base, self.upper, pos_in, ls_local, rs_local)

    # -- adaptive batched engine -------------------------------------------
    def engine(self, **kwargs):
        """A :class:`repro.qe.QueryEngine` routed over this sharded index.

        Spans contained in one segment are answered segment-locally (no
        all-reduce); crossing spans take the ``pmin`` path.  Results are
        bit-identical to :meth:`query`/:meth:`query_index`.  Re-attach
        after ``update``/``append`` (successors bump ``generation``).
        """
        return px.make_engine(self, **kwargs)

    # -- introspection ------------------------------------------------------
    @property
    def plan(self) -> HierarchyPlan:
        """The *per-segment* plan (see ``capacity`` for the global space)."""
        return self.local_plan

    @property
    def length(self) -> int:
        return self.n

    @property
    def num_segments(self) -> int:
        return _num_segments(self.mesh, self.segment_axis)

    @property
    def segment_capacity(self) -> int:
        """Slots per segment; element ``g`` lives in segment
        ``g // segment_capacity``."""
        return self.local_plan.capacity

    @property
    def capacity(self) -> int:
        """Total reserved (appendable) index space across segments."""
        return self.segment_capacity * self.num_segments

    @property
    def with_positions(self) -> bool:
        return self.upper_pos is not None

    @property
    def value_dtype(self):
        return self.base.dtype

    def memory_bytes_per_device(self) -> int:
        s = self.num_segments
        total = self.base.size * self.base.dtype.itemsize
        total += self.upper.size * self.upper.dtype.itemsize
        if self.upper_pos is not None:
            total += self.upper_pos.size * self.upper_pos.dtype.itemsize
        return total // s
