"""Analytical bounds from paper §4.1, used by property tests and benchmarks.

The paper derives two bounds for a hierarchy with chunk size ``c`` and
cutoff ``t`` over ``n`` elements:

* auxiliary entries ``E <= n / (c - 1)``  (geometric series bound), and
* scanned entries per query ``<= c*t + 2c*log_c(n)``  (top scan + two
  boundary scans per level).

``theoretical_scan_cost`` additionally gives the *expected* scanned entries
for a given range size, which the tuning benchmark uses for napkin math
before measuring.
"""

from __future__ import annotations

import math

from repro.core.plan import HierarchyPlan, make_plan

__all__ = [
    "aux_entries_bound",
    "max_scanned_entries",
    "expected_scanned_entries",
    "optimal_num_levels",
]


def aux_entries_bound(n: int, c: int) -> float:
    """Paper §4.1: E <= n / (c - 1).

    NOTE (reproduction finding): the paper's bound assumes each level is
    exactly n/c^i.  With ceil() at every level the exact bound is
    ``n/(c-1) + num_levels`` (one slack entry per level); for c = 2 and
    small n the actual count can exceed the paper's closed form (e.g.
    n=17, c=2: 19 logical auxiliary entries > 17).  Property tests check
    the ceil-corrected bound; the practical conclusion (overhead ~ 1/(c-1))
    is unaffected for the paper's c = 32 regime.
    """
    return n / (c - 1)


def aux_entries_bound_ceil(n: int, c: int, num_levels: int) -> float:
    """Ceil-corrected auxiliary entry bound (see aux_entries_bound note)."""
    return n / (c - 1) + num_levels


def max_scanned_entries(plan: HierarchyPlan) -> int:
    """Worst-case entries touched by one query."""
    return plan.max_scanned_entries()


def expected_scanned_entries(plan: HierarchyPlan, range_size: float) -> float:
    """Expected scanned entries for a query of ``range_size`` elements.

    The walk ascends until the remaining (level-local) range is <= 2c; each
    traversed level scans ~c entries per boundary on average (uniform
    offsets), then the stop level scans <= 2c.  Ranges that never cover a
    full top-level chunk stop early — this is the effect behind the paper's
    observation (Fig. 16) that throughput is almost range-size independent
    once upper levels are cache-resident.
    """
    c, s = plan.c, max(range_size, 1.0)
    levels_climbed = 0
    while s > 2 * c and levels_climbed < plan.num_levels - 1:
        s /= c
        levels_climbed += 1
    boundary = levels_climbed * 2 * (c / 2)  # avg half-chunk per side
    stop = min(s, 2 * c) if levels_climbed < plan.num_levels - 1 else min(
        s, plan.top_len
    )
    return boundary + stop


def optimal_num_levels(n: int, c: int, t: int) -> int:
    """Closed-form level count: smallest L with n / c^(L-1) <= c*t."""
    levels = 1
    m = n
    while m > c * t:
        m = math.ceil(m / c)
        levels += 1
    return levels
