"""Shared numeric sentinels of the minima hierarchy and its kernels.

Every build/update/query path agrees on one position sentinel so that
hierarchies produced by any backend are bit-identical (the test suites
assert exact equality of padding entries too).  Historically each module
redefined the value privately; this is the single home.

``PAD_POS``
    Position stored for padding entries (the +inf-padded tail of a level,
    chunks past ``capacity``).  Padding can never win a query because its
    value is ``+inf`` while real values are finite, so the concrete value
    only has to be *larger than every real position* — ``INT32_MAX``,
    since the whole query stack does int32 index math (capacity is
    enforced ``< 2**31`` wherever positions flow through kernels).

``POS_INF_I32``
    Identity element of the lexicographic ``(value, position)`` merge used
    by every query path to keep ties leftmost.  Numerically the same
    ``INT32_MAX`` as ``PAD_POS`` — kept as a distinct name because the two
    roles are distinct (a *stored* sentinel vs. a *merge* identity) and
    only coincide because both must dominate all real positions.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["PAD_POS", "POS_INF_I32"]

PAD_POS = jnp.iinfo(jnp.int32).max
POS_INF_I32 = PAD_POS
