"""Baseline RMQ methods the paper compares against (§5.2), in JAX.

The paper's GPU baselines (LCA, RTXRMQ) are CUDA/OptiX artifacts that do
not transfer to TPU mechanically (Euler-tour pointer chasing; RT-core BVH).
We implement baselines that occupy the *same design points* the paper uses
them to represent:

* ``FullScan``        — no preprocessing, O(range) per query
                        (== the paper's "Full GPU Scan").
* ``SparseTable``     — O(n log n) memory, O(1) per query: the classic
                        memory-heavy end of the space/time trade-off, the
                        profile the paper attributes to LCA (§2.1, Fig. 15).
* ``TwoLevelBlocks``  — 2n/c + n memory, O(c + n/c) query: the low-memory /
                        modest-throughput profile of CPU HRMQ-style block
                        decompositions (a GPU-RMQ hierarchy capped at two
                        levels, which is exactly Fischer–Heun's first stage).

All three share the batched ``(ls, rs) -> values`` interface of
``repro.core.query`` so the benchmark harness treats every method uniformly.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.hierarchy import build_hierarchy
from repro.core.plan import make_plan
from repro.core.query import rmq_value_batch

__all__ = ["FullScan", "SparseTable", "TwoLevelBlocks"]


# --------------------------------------------------------------------------
# Full scan
# --------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FullScan:
    """One masked min over the whole array per query (paper: Full GPU Scan)."""

    x: jax.Array

    @staticmethod
    def build(x: jax.Array) -> "FullScan":
        return FullScan(x=x)

    def memory_bytes(self) -> int:
        return self.x.size * self.x.dtype.itemsize

    def auxiliary_bytes(self) -> int:
        return 0

    def query_batch(self, ls: jax.Array, rs: jax.Array) -> jax.Array:
        return _full_scan_batch(self.x, ls, rs)


@jax.jit
def _full_scan_batch(x, ls, rs):
    n = x.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)

    def one(l, r):
        mask = (idx >= l) & (idx <= r)
        return jnp.min(jnp.where(mask, x, jnp.inf))

    # lax.map keeps peak memory at O(n) instead of O(batch * n).
    return jax.lax.map(lambda q: one(q[0], q[1]),
                       jnp.stack([ls, rs], axis=1),
                       batch_size=256)


# --------------------------------------------------------------------------
# Sparse table (memory-heavy / O(1) query — the LCA design point)
# --------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SparseTable:
    """``table[j, i] = min(x[i : i + 2^j])`` — O(n log n) memory, O(1) query.

    Mirrors the memory profile the paper criticizes in LCA/RTXRMQ: the
    auxiliary structure is a large multiple of the input (log2(n) times),
    which is what makes it infeasible for n >= 2^29 on a 24 GB GPU (Fig. 15).

    Optionally *index-tracking*: pass ``positions`` (the original-array
    position of each entry of ``x``) to also materialize
    ``pos[j, i] = argmin-position of x[i : i + 2^j]`` with leftmost-tie
    semantics, enabling O(1) ``RMQ_index`` lookups (used by the hybrid's
    top level so the query engine can route index queries long).
    """

    table: jax.Array  # (num_levels, n)
    pos: Optional[jax.Array]  # (num_levels, n) or None (value-only)
    n: int = dataclasses.field(metadata=dict(static=True))

    @staticmethod
    def build(
        x: jax.Array, positions: Optional[jax.Array] = None
    ) -> "SparseTable":
        n = int(x.shape[0])
        num_levels = max(1, n.bit_length())  # j = 0 .. floor(log2(n))
        rows = [x]
        track = positions is not None
        if track:
            positions = jnp.asarray(positions)
            pad_pos = jnp.iinfo(positions.dtype).max
            prows = [positions]
        for j in range(1, num_levels):
            prev = rows[-1]
            half = 1 << (j - 1)
            shifted = jnp.concatenate(
                [prev[half:], jnp.full((half,), jnp.inf, dtype=x.dtype)]
            )
            if track:
                pprev = prows[-1]
                pshift = jnp.concatenate(
                    [pprev[half:],
                     jnp.full((half,), pad_pos, dtype=positions.dtype)]
                )
                # lexicographic (value, position) min — leftmost on ties
                take2 = (shifted < prev) | (
                    (shifted == prev) & (pshift < pprev)
                )
                prows.append(jnp.where(take2, pshift, pprev))
            rows.append(jnp.minimum(prev, shifted))
        return SparseTable(
            table=jnp.stack(rows),
            pos=jnp.stack(prows) if track else None,
            n=n,
        )

    @property
    def with_positions(self) -> bool:
        return self.pos is not None

    def memory_bytes(self) -> int:
        total = self.table.size * self.table.dtype.itemsize
        if self.pos is not None:
            total += self.pos.size * self.pos.dtype.itemsize
        return total

    def auxiliary_bytes(self) -> int:
        return self.memory_bytes() - self.n * self.table.dtype.itemsize

    def query_batch(self, ls: jax.Array, rs: jax.Array) -> jax.Array:
        return _sparse_table_batch(self.table, ls, rs)

    def query_index_batch(self, ls: jax.Array, rs: jax.Array) -> jax.Array:
        """Leftmost-minimum positions (requires an index-tracking build)."""
        if self.pos is None:
            raise ValueError(
                "sparse table built value-only; "
                "use SparseTable.build(x, positions=...)"
            )
        return _sparse_table_index_batch(self.table, self.pos, ls, rs)


@jax.jit
def _sparse_table_batch(table, ls, rs):
    def one(l, r):
        span = r - l + 1
        # floor(log2(span)) without host math.
        j = (31 - jax.lax.clz(span.astype(jnp.int32))).astype(jnp.int32)
        left = table[j, l]
        right = table[j, r + 1 - (1 << j.astype(jnp.uint32)).astype(jnp.int32)]
        return jnp.minimum(left, right)

    return jax.vmap(one)(ls.astype(jnp.int32), rs.astype(jnp.int32))


@jax.jit
def _sparse_table_index_batch(table, pos, ls, rs):
    def one(l, r):
        span = r - l + 1
        j = (31 - jax.lax.clz(span.astype(jnp.int32))).astype(jnp.int32)
        r2 = r + 1 - (1 << j.astype(jnp.uint32)).astype(jnp.int32)
        vl, pl_ = table[j, l], pos[j, l]
        vr, pr_ = table[j, r2], pos[j, r2]
        take_r = (vr < vl) | ((vr == vl) & (pr_ < pl_))
        return jnp.where(take_r, pr_, pl_)

    return jax.vmap(one)(ls.astype(jnp.int32), rs.astype(jnp.int32))


# --------------------------------------------------------------------------
# Two-level block decomposition (the HRMQ design point)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TwoLevelBlocks:
    """GPU-RMQ hierarchy capped at exactly two levels.

    With block size c, a query scans two partial blocks (O(c)) plus the
    block-minima array (O(n/c)) — the sqrt-decomposition design point of
    CPU block-based RMQ structures.
    """

    hierarchy: object

    @staticmethod
    def build(x: jax.Array, c: int = 256) -> "TwoLevelBlocks":
        n = int(x.shape[0])
        # Force at most two levels: pick t so the first reduction already
        # satisfies the cutoff ceil(n/c) <= c*t.
        t = max(1, math.ceil(math.ceil(n / c) / c))
        plan = make_plan(n, c=c, t=t)
        assert plan.num_levels <= 2
        h = build_hierarchy(x, plan)
        return TwoLevelBlocks(hierarchy=h)

    def memory_bytes(self) -> int:
        return self.hierarchy.memory_bytes()

    def auxiliary_bytes(self) -> int:
        return self.hierarchy.auxiliary_bytes()

    def query_batch(self, ls: jax.Array, rs: jax.Array) -> jax.Array:
        return rmq_value_batch(self.hierarchy, ls, rs)
