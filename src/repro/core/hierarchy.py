"""Construction of the GPU-RMQ minima hierarchy (paper §4.1, §4.4).

Construction is a bottom-up sequence of chunked min-reductions.  On the
GPU the paper assigns a warp group to each chunk and reduces with warp
shuffles; on TPU each level build is a dense ``(m, c) -> (m,)`` reduction
that XLA maps onto the VPU.  :func:`build_hierarchy` below is the pure-JAX
oracle: one end-to-end-jitted pass that reduces each level directly into
its ``plan.offsets`` slot of a *preallocated* contiguous ``upper`` buffer
— no per-level intermediate arrays, no concatenate.  The Pallas
realizations are validated bit-identical against it:
``kernels/hierarchy_fused`` (all levels in ONE launch, the default
construction kernel) and ``kernels/hierarchy_build`` (the historical
one-launch-per-level tiling).

All upper levels live in one contiguous buffer (paper: "To further reduce
allocation complexity, we store all precomputed layers in a single,
contiguous buffer").

The structure is *not* build-once: point mutations, appends into reserved
capacity (``make_plan(..., capacity=...)``), and sliding-window retirement
are maintained incrementally — O(log_c n) chunk re-reductions per touched
element — by ``repro.streaming`` (pure JAX) and
``repro.kernels.hierarchy_update`` (Pallas).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.constants import PAD_POS
from repro.core.plan import HierarchyPlan, make_plan

__all__ = [
    "Hierarchy",
    "build_hierarchy",
    "build_many",
    "finalize_compact",
    "make_plan",
    "pos_dtype_for",
]

# Back-compat alias; the shared home is repro.core.constants.
_PAD_POS = PAD_POS


def pos_dtype_for(n: int, strict: bool = True) -> jnp.dtype:
    """Position dtype for an array of length ``n``.

    int32 covers n < 2**31; larger arrays need int64, which JAX silently
    downcasts to int32 unless x64 mode is enabled.  ``strict`` (the
    default, for build paths about to materialize positions) raises
    loudly instead of returning positions that wrap; ``strict=False``
    (for dtype *selection* at dispatch/trace time) returns int64 only
    when x64 is actually on and otherwise falls back to int32 — the
    build-side strict guard has already ruled out wrapping structures.
    """
    if n < 2**31:
        return jnp.int32
    if not jax.config.x64_enabled:
        if strict:
            raise ValueError(
                f"n={n} needs int64 positions, but jax x64 mode is disabled "
                "(int64 would silently downcast to int32 and wrap); enable "
                'it with jax.config.update("jax_enable_x64", True)'
            )
        return jnp.int32
    return jnp.int64


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Hierarchy:
    """Device-resident minima hierarchy.

    ``base`` is the input array (level 0), stored padded to
    ``plan.capacity`` with ``+inf`` (no padding in the common
    ``capacity == n`` case).  ``upper`` holds levels 1..L-1 concatenated,
    each padded to a multiple of ``c`` with ``+inf``.  ``upper_pos``
    (optional, for RMQ_index) stores for each summary entry the position
    *in the original array* of its minimum, leftmost on ties.
    """

    base: jax.Array
    upper: jax.Array
    upper_pos: Optional[jax.Array]
    plan: HierarchyPlan = dataclasses.field(
        metadata=dict(static=True)
    )

    @property
    def with_positions(self) -> bool:
        return self.upper_pos is not None

    def memory_bytes(self) -> int:
        """Total bytes of the structure (input + auxiliary)."""
        total = self.base.size * self.base.dtype.itemsize
        total += self.upper.size * self.upper.dtype.itemsize
        if self.upper_pos is not None:
            total += self.upper_pos.size * self.upper_pos.dtype.itemsize
        return total

    def auxiliary_bytes(self) -> int:
        total = self.upper.size * self.upper.dtype.itemsize
        if self.upper_pos is not None:
            total += self.upper_pos.size * self.upper_pos.dtype.itemsize
        return total


def _pad_to(x: jax.Array, length: int, fill) -> jax.Array:
    pad = length - x.shape[0]
    if pad == 0:
        return x
    return jnp.pad(x, (0, pad), constant_values=fill)


def _check_compact_build(plan: HierarchyPlan, with_positions: bool, dtype):
    """Static validation of the compact-layout knobs before building."""
    if plan.summary_dtype == "bfloat16":
        if not with_positions:
            raise ValueError(
                "summary_dtype='bfloat16' requires with_positions=True: "
                "exact queries re-compare bf16-tied candidates on level 0 "
                "through the stored positions")
        if dtype != jnp.float32:
            raise ValueError(
                "summary_dtype='bfloat16' supports float32 inputs only, "
                f"got {jnp.dtype(dtype).name}")


def finalize_compact(h: Hierarchy) -> Hierarchy:
    """Apply the plan's compact layouts to a freshly built hierarchy.

    Converts an absolute position plane to packed words when
    ``plan.packed_pos`` (no-op if already uint32-packed) and casts the
    value plane to bf16 when ``plan.summary_dtype == "bfloat16"``.  Safe
    to call inside a jitted program; the Pallas/fused backends build in
    the classic layout and run through here.
    """
    plan = h.plan
    if (
        plan.packed_pos
        and h.upper_pos is not None
        and h.upper_pos.dtype != jnp.uint32
    ):
        from repro.core import bitpack

        h = dataclasses.replace(
            h, upper_pos=bitpack.pack_plane_from_absolute(h.upper_pos, plan)
        )
    if plan.summary_dtype == "bfloat16" and h.upper.dtype != jnp.bfloat16:
        h = dataclasses.replace(h, upper=h.upper.astype(jnp.bfloat16))
    return h


@functools.partial(jax.jit, static_argnames=("plan", "with_positions"))
def build_hierarchy(
    x: jax.Array,
    plan: HierarchyPlan,
    with_positions: bool = False,
) -> Hierarchy:
    """Build the hierarchy for input ``x`` according to ``plan``.

    Pure-JAX reference construction, single fused pass: the ``upper``
    buffer is preallocated at ``plan.upper_size`` (+inf / ``PAD_POS``
    filled, which *is* each level's padding) and every level's chunk
    minima are reduced straight into its ``plan.offsets`` slot.  Peak
    auxiliary memory is the output buffer itself — the historical
    per-level path kept every level alive twice (once standalone, once in
    the final concatenate).

    The Pallas builds in ``repro.kernels.hierarchy_fused`` (one launch)
    and ``repro.kernels.hierarchy_build`` (one launch per level) are
    validated bit-identical against this function.
    """
    if x.ndim != 1:
        raise ValueError(f"input must be rank-1, got shape {x.shape}")
    if x.shape[0] != plan.n:
        raise ValueError(f"plan is for n={plan.n}, input has n={x.shape[0]}")
    _check_compact_build(plan, with_positions, x.dtype)

    c = plan.c
    cap = plan.capacity
    inf = jnp.array(jnp.inf, dtype=x.dtype)
    # Only position-tracking builds materialize indices, so only they
    # need the int64/x64 guard.  Packed builds store log2(c)-bit offsets,
    # but queries still reconstruct absolute positions — the guard
    # applies either way.
    pos_dtype = pos_dtype_for(cap) if with_positions else None
    packed = with_positions and plan.packed_pos
    if packed:
        from repro.core import bitpack

    # Level 0 is stored at full capacity; the reserved tail is +inf so it
    # can never win a query and appends just overwrite it.
    x = _pad_to(x, cap, inf)

    # The whole contiguous upper buffer, preallocated: the fill values
    # double as every level's padding (entries past a level's live length
    # are never written below).
    upper = jnp.full((plan.upper_size,), jnp.inf, dtype=x.dtype)
    if packed:
        # Chunk-local offsets, packed at the end.  Each level's argmin
        # *is* the local offset; no absolute chain is ever materialized.
        upper_loc = jnp.zeros((plan.upper_size,), jnp.int32)
        upper_pos = None
    else:
        upper_loc = None
        upper_pos = (
            jnp.full((plan.upper_size,), PAD_POS, dtype=pos_dtype)
            if with_positions
            else None
        )

    cur_v = x
    cur_p = (
        jnp.arange(cap, dtype=pos_dtype)
        if with_positions and not packed
        else None
    )
    for k in range(1, plan.num_levels):
        # The reduction consumes ceil(len/c)*c entries; pad the current
        # level out to exactly c * next-level-len before reshaping.
        want = plan.level_lens[k] * c
        v = _pad_to(cur_v, want, inf).reshape(-1, c)
        idx = jnp.argmin(v, axis=1)
        nxt_v = jnp.take_along_axis(v, idx[:, None], axis=1)[:, 0]
        off = plan.offsets[k - 1]
        upper = jax.lax.dynamic_update_slice(upper, nxt_v, (off,))
        if packed:
            upper_loc = jax.lax.dynamic_update_slice(
                upper_loc, idx.astype(jnp.int32), (off,)
            )
        elif with_positions:
            p = _pad_to(cur_p, want, jnp.array(PAD_POS, pos_dtype))
            p = p.reshape(-1, c)
            nxt_p = jnp.take_along_axis(p, idx[:, None], axis=1)[:, 0]
            upper_pos = jax.lax.dynamic_update_slice(
                upper_pos, nxt_p, (off,)
            )
            cur_p = nxt_p
        cur_v = nxt_v

    if packed:
        upper_pos = bitpack.pack_offsets(upper_loc, bitpack.pos_bits(c))
    if plan.summary_dtype == "bfloat16":
        upper = upper.astype(jnp.bfloat16)

    return Hierarchy(base=x, upper=upper, upper_pos=upper_pos, plan=plan)


@functools.partial(jax.jit, static_argnames=("plan", "with_positions"))
def build_many(
    xs: jax.Array,
    plan: HierarchyPlan,
    with_positions: bool = False,
) -> Hierarchy:
    """Batched construction: ``(B, n)`` inputs -> one batched Hierarchy.

    One vmapped, end-to-end-jitted build indexes all ``B`` arrays in a
    single launch — every plane of the returned :class:`Hierarchy`
    carries a leading batch axis (``base`` is ``(B, capacity)``,
    ``upper`` is ``(B, upper_size)``); row ``i`` is bit-identical to
    ``build_hierarchy(xs[i], plan, with_positions)``.  This is what
    ``QueryService.register_many`` uses to index many equal-length
    arrays without paying per-array dispatch.
    """
    if xs.ndim != 2:
        raise ValueError(f"inputs must be rank-2 (B, n), got {xs.shape}")
    return jax.vmap(
        lambda row: build_hierarchy(row, plan, with_positions=with_positions)
    )(xs)
