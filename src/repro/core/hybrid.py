"""Hybrid RMQ: hierarchy lower levels + O(1) sparse-table top (paper §4.5).

The paper's §4.5 replaces the top-level linear scan with a different index
engine (an RT-core triangle scene).  The portable version of that design
question is: *does a constant-time index over the top level beat scanning
it?*  This module implements the hybrid faithfully with a sparse table:

* levels 0..L-2: the standard boundary-chunk walk (identical cost);
* top level: one O(1) sparse-table lookup instead of an O(c·t) scan.

Trade-off surface (mirrors the paper's Fig. 13 analysis):
* extra memory: the top level has T <= c·t entries ⇒ table is
  T·log2(T) entries — tiny in absolute terms but up to log2(T)× the top
  level itself;
* extra build: one log2(T)-pass table build after the hierarchy build;
* query win: replaces the ct-entry masked scan with 2 loads — only pays
  off when c·t is large (exactly the paper's conclusion: with a small,
  cache/VMEM-resident top level there is little to win back, which is why
  RT cores lost; with a LARGE t — which the hybrid enables, paper §4.5
  implication (1) — the hybrid frontier shifts).

The paper's hybrid is value-only (RTXRMQ triangles encode values).  Ours
goes past that: built ``with_positions=True`` (or from a
position-tracking hierarchy via :meth:`from_hierarchy`), the sparse
table also tracks leftmost-minimum *positions*, so ``query_index``
gets the same O(1) top — this is what lets the batched query engine
(``repro.qe``) route long-span ``RMQ_index`` queries here instead of
falling back to the full walk.

:meth:`from_hierarchy` wraps an *existing* hierarchy without rebuilding
it — the engine uses this to add a hybrid top to a live index for the
cost of one tiny (<= c·t entries) table build.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.baselines import SparseTable
from repro.core.constants import POS_INF_I32 as _POS_INF_I32
from repro.core.hierarchy import Hierarchy
from repro.core.plan import HierarchyPlan, make_plan

__all__ = ["HybridRMQ"]


@dataclasses.dataclass(frozen=True)
class HybridRMQ:
    """Minima hierarchy with a sparse-table top level."""

    hierarchy: Hierarchy
    top_table: SparseTable

    @staticmethod
    def build(
        x,
        c: int = 128,
        t: int = 1024,
        with_positions: bool = False,
        backend: str = "auto",
        packed_pos: Optional[bool] = None,
        summary_dtype: Optional[str] = None,
    ) -> "HybridRMQ":
        """Note the default t is 16x the scan version's: the O(1) top
        makes large tops free at query time (paper §4.5 implication (1)),
        which in turn removes one hierarchy level.

        ``backend`` selects the hierarchy construction path (the shared
        ``'fused'``/``'pallas'``/``'jax'`` pipeline); the hybrid walk
        itself is pure JAX regardless.  ``packed_pos`` selects the
        bit-packed position plane (the table top reads it through the
        shared unpack helpers); ``summary_dtype='bfloat16'`` is refused
        — the sparse-table top would compare quantized values.
        """
        from repro.core import protocol as px

        x = px.coerce_values(x)
        plan = make_plan(int(x.shape[0]), c=c, t=t,
                         packed_pos=packed_pos,
                         summary_dtype=summary_dtype)
        h = px.build_hierarchy_with_backend(
            x, plan, with_positions=with_positions,
            backend=px.resolve_backend(backend),
        )
        return HybridRMQ.from_hierarchy(h)

    @staticmethod
    def from_hierarchy(h: Hierarchy) -> "HybridRMQ":
        """Add a sparse-table top to an existing hierarchy (no rebuild).

        Position tracking follows the hierarchy: a ``with_positions``
        build gets an index-tracking table, a value-only build gets a
        value-only table (and ``query_index`` raises).
        """
        plan = h.plan
        if h.upper.dtype != h.base.dtype:
            raise ValueError(
                "HybridRMQ does not support bf16 summaries: the sparse-"
                "table top would compare quantized values; query bf16 "
                "indexes through the exact-recovery walk/fused paths"
            )
        if plan.num_levels == 1:
            top = h.base
            top_pos = (
                jnp.arange(h.base.shape[0], dtype=jnp.int32)
                if h.with_positions
                else None
            )
        else:
            off, _ = plan.level_slice(plan.num_levels - 1)
            top = h.upper[off : off + plan.top_len]
            if not h.with_positions:
                top_pos = None
            elif plan.packed_pos:
                # The packed plane has no sliceable absolute view; walk
                # the top entries' offset chains down to level 0.
                top_pos = _packed_top_positions(h.upper_pos, plan)
            else:
                top_pos = h.upper_pos[off : off + plan.top_len]
        return HybridRMQ(
            hierarchy=h, top_table=SparseTable.build(top, positions=top_pos)
        )

    # -- protocol surface (repro.core.protocol.RMQIndex) -------------------
    # The hybrid is read-only (no update/append): a point update could move
    # the top level's minima, invalidating sparse-table rows wholesale.
    # Mutating workloads should hold a mutable index and let the engine
    # re-derive the hybrid top per generation (LongSpanExecutor does).
    backend = "jax"  # the hybrid walk is pure JAX on every backend
    generation = 0

    @property
    def plan(self) -> HierarchyPlan:
        return self.hierarchy.plan

    @property
    def length(self) -> int:
        return self.plan.n

    @property
    def capacity(self) -> int:
        return self.plan.capacity

    @property
    def value_dtype(self):
        return self.hierarchy.base.dtype

    def engine(self, **kwargs):
        """A span-routed :class:`repro.qe.QueryEngine` over this index."""
        from repro.core.protocol import make_engine

        return make_engine(self, **kwargs)

    @property
    def with_positions(self) -> bool:
        return self.top_table.with_positions

    def auxiliary_bytes(self) -> int:
        return (
            self.hierarchy.auxiliary_bytes()
            + self.top_table.auxiliary_bytes()
        )

    def query(self, ls, rs) -> jax.Array:
        ls = jnp.asarray(ls, jnp.int32)
        rs = jnp.asarray(rs, jnp.int32)
        m, _ = _hybrid_batch(
            self.plan, self.hierarchy.base, self.hierarchy.upper, None,
            self.top_table.table, None, ls, rs, track_pos=False,
        )
        return m

    def query_index(self, ls, rs) -> jax.Array:
        """Leftmost-minimum positions with the O(1) sparse-table top."""
        if not self.with_positions:
            raise ValueError(
                "hybrid built value-only; build with with_positions=True "
                "(or from a position-tracking hierarchy)"
            )
        ls = jnp.asarray(ls, jnp.int32)
        rs = jnp.asarray(rs, jnp.int32)
        _, p = _hybrid_batch(
            self.plan, self.hierarchy.base, self.hierarchy.upper,
            self.hierarchy.upper_pos, self.top_table.table,
            self.top_table.pos, ls, rs, track_pos=True,
        )
        return p

    # protocol spellings (RMQIndex): same entry points, canonical names
    query_value_batch = query
    query_index_batch = query_index


@functools.partial(jax.jit, static_argnames=("plan",))
def _packed_top_positions(words, plan):
    """Absolute level-0 positions of the top level's live entries."""
    from repro.core import bitpack
    from repro.core.hierarchy import pos_dtype_for

    coord = pos_dtype_for(plan.capacity, strict=False)
    ids = jnp.arange(plan.top_len, dtype=jnp.int32)
    return bitpack.gather_absolute(
        words, plan, plan.num_levels - 1, ids, coord
    )


@functools.partial(jax.jit, static_argnames=("plan", "track_pos"))
def _hybrid_batch(plan, base, upper, upper_pos, top_table, top_pos, ls, rs,
                  track_pos):
    from repro.core import bitpack

    upper_pos = bitpack.resolve_positions(upper_pos, plan)
    return jax.vmap(
        lambda l, r: _hybrid_single(
            plan, base, upper, upper_pos, top_table, top_pos, l, r,
            track_pos,
        )
    )(ls, rs)


def _hybrid_single(plan: HierarchyPlan, base, upper, upper_pos, top_table,
                   top_pos, l, r, track_pos):
    """Branch-free walk for levels 0..L-2 + O(1) table lookup at the top."""
    # shared lexicographic (value, leftmost-position) merge: the engine's
    # parity contract needs identical tie-breaking across all paths
    from repro.kernels.rmq_scan.ref import _merge, _window

    c = plan.c
    l = l.astype(jnp.int32)
    r = (r + 1).astype(jnp.int32)
    m = jnp.float32(jnp.inf)
    p = jnp.int32(_POS_INF_I32)

    for level in range(plan.num_levels - 1):
        if level == 0:
            arr, pos_arr = base, None  # level-0 positions are the indices
        else:
            off, padded = plan.level_slice(level)
            arr = jax.lax.slice(upper, (off,), (off + padded,))
            pos_arr = (
                jax.lax.slice(upper_pos, (off,), (off + padded,))
                if track_pos
                else None
            )
        next_l = ((l + c - 1) // c) * c
        prev_r = (r // c) * c
        m2, p2 = _window(arr, pos_arr, (l // c) * c, l,
                         jnp.minimum(next_l, r), c, track_pos)
        m, p = _merge(m, p, m2, p2)
        m2, p2 = _window(arr, pos_arr, prev_r, jnp.maximum(prev_r, l), r, c,
                         track_pos)
        m, p = _merge(m, p, m2, p2)
        l = (l + c - 1) // c
        r = r // c

    # --- O(1) top: sparse table on [l, r) (empty range -> +inf) ---------
    nonempty = r > l
    rr = jnp.maximum(r - 1, l)          # inclusive, clamped
    span = rr - l + 1
    j = (31 - jax.lax.clz(span.astype(jnp.int32))).astype(jnp.int32)
    r2 = rr + 1 - (1 << j.astype(jnp.uint32)).astype(jnp.int32)
    vl = top_table[j, l]
    vr = top_table[j, r2]
    if track_pos:
        pl_ = top_pos[j, l]
        pr_ = top_pos[j, r2]
        tm, tp = _merge(vl, pl_, vr, pr_)
    else:
        tm, tp = jnp.minimum(vl, vr), jnp.int32(_POS_INF_I32)
    tm = jnp.where(nonempty, tm, jnp.inf)
    tp = jnp.where(nonempty, tp, _POS_INF_I32)
    return _merge(m, p, tm, tp)
