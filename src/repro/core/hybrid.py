"""Hybrid RMQ: hierarchy lower levels + O(1) sparse-table top (paper §4.5).

The paper's §4.5 replaces the top-level linear scan with a different index
engine (an RT-core triangle scene).  The portable version of that design
question is: *does a constant-time index over the top level beat scanning
it?*  This module implements the hybrid faithfully with a sparse table:

* levels 0..L-2: the standard boundary-chunk walk (identical cost);
* top level: one O(1) sparse-table lookup instead of an O(c·t) scan.

Trade-off surface (mirrors the paper's Fig. 13 analysis):
* extra memory: the top level has T <= c·t entries ⇒ table is
  T·log2(T) entries — tiny in absolute terms but up to log2(T)× the top
  level itself;
* extra build: one log2(T)-pass table build after the hierarchy build;
* query win: replaces the ct-entry masked scan with 2 loads — only pays
  off when c·t is large (exactly the paper's conclusion: with a small,
  cache/VMEM-resident top level there is little to win back, which is why
  RT cores lost; with a LARGE t — which the hybrid enables, paper §4.5
  implication (1) — the hybrid frontier shifts).

``HybridRMQ`` supports RMQ_value (the paper's hybrid is value-only too:
RTXRMQ triangles encode values).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.baselines import SparseTable
from repro.core.hierarchy import Hierarchy, build_hierarchy
from repro.core.plan import HierarchyPlan, make_plan

__all__ = ["HybridRMQ"]


@dataclasses.dataclass(frozen=True)
class HybridRMQ:
    """Minima hierarchy with a sparse-table top level."""

    hierarchy: Hierarchy
    top_table: SparseTable

    @staticmethod
    def build(x, c: int = 128, t: int = 1024) -> "HybridRMQ":
        """Note the default t is 16x the scan version's: the O(1) top
        makes large tops free at query time (paper §4.5 implication (1)),
        which in turn removes one hierarchy level."""
        x = jnp.asarray(x, jnp.float32)
        plan = make_plan(int(x.shape[0]), c=c, t=t)
        h = build_hierarchy(x, plan)
        if plan.num_levels == 1:
            top = x
        else:
            off, padded = plan.level_slice(plan.num_levels - 1)
            top = h.upper[off : off + plan.top_len]
        return HybridRMQ(hierarchy=h, top_table=SparseTable.build(top))

    @property
    def plan(self) -> HierarchyPlan:
        return self.hierarchy.plan

    def auxiliary_bytes(self) -> int:
        return (
            self.hierarchy.auxiliary_bytes()
            + self.top_table.auxiliary_bytes()
        )

    def query(self, ls, rs) -> jax.Array:
        ls = jnp.asarray(ls, jnp.int32)
        rs = jnp.asarray(rs, jnp.int32)
        return _hybrid_batch(
            self.plan, self.hierarchy.base, self.hierarchy.upper,
            self.top_table.table, ls, rs,
        )


@functools.partial(jax.jit, static_argnames=("plan",))
def _hybrid_batch(plan, base, upper, top_table, ls, rs):
    return jax.vmap(
        lambda l, r: _hybrid_single(plan, base, upper, top_table, l, r)
    )(ls, rs)


def _hybrid_single(plan: HierarchyPlan, base, upper, top_table, l, r):
    """Branch-free walk for levels 0..L-2 + O(1) table lookup at the top."""
    from repro.kernels.rmq_scan.ref import _window

    c = plan.c
    l = l.astype(jnp.int32)
    r = (r + 1).astype(jnp.int32)
    m = jnp.float32(jnp.inf)

    for level in range(plan.num_levels - 1):
        if level == 0:
            arr = base
        else:
            off, padded = plan.level_slice(level)
            arr = jax.lax.slice(upper, (off,), (off + padded,))
        next_l = ((l + c - 1) // c) * c
        prev_r = (r // c) * c
        m2, _ = _window(arr, None, (l // c) * c, l,
                        jnp.minimum(next_l, r), c, False)
        m = jnp.minimum(m, m2)
        m2, _ = _window(arr, None, prev_r, jnp.maximum(prev_r, l), r, c,
                        False)
        m = jnp.minimum(m, m2)
        l = (l + c - 1) // c
        r = r // c

    # --- O(1) top: sparse table on [l, r) (empty range -> +inf) ---------
    nonempty = r > l
    rr = jnp.maximum(r - 1, l)          # inclusive, clamped
    span = rr - l + 1
    j = (31 - jax.lax.clz(span.astype(jnp.int32))).astype(jnp.int32)
    left = top_table[j, l]
    right = top_table[j, rr + 1 - (1 << j.astype(jnp.uint32)).astype(
        jnp.int32)]
    top_min = jnp.minimum(left, right)
    return jnp.where(nonempty, jnp.minimum(m, top_min), m)
