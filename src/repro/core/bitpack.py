"""Bit-packed chunk-relative position planes (the memory-footprint push).

A position-tracking hierarchy historically stored ``upper_pos`` as one
absolute int32 (int64 past 2^31) per summary entry — as many auxiliary
bytes again as the value plane itself.  But an entry's minimum always
comes from one of the ``c`` children it summarizes, so the *chunk-local
offset* — ``log2(c)`` bits — determines the absolute position once the
level below is known:

* level 1: ``abs(e) = e*c + local(e)`` (children are level-0 indices);
* level k: ``abs(e) = abs_{k-1}[e*c + local(e)]`` — resolved bottom-up.

This module packs those offsets tightly into a uint32 word array (entry
``e`` occupies bits ``[e*bits, (e+1)*bits)`` of the stream, little-endian
within each word): at ``c = 128`` the position plane shrinks from 32 to
7 bits per entry.  The packed words live directly in
``Hierarchy.upper_pos`` when ``plan.packed_pos`` is set — the pytree
shape is unchanged, and every query lowering unpacks on the fly inside
its jitted program (:func:`resolve_positions`), reconstructing a plane
bit-identical to the unpacked oracle's (leftmost ties and ``PAD_POS``
padding included — the differential harness gates exactly that).

Incremental updates rewrite fields in place with a wrapping-delta
scatter-add (:func:`scatter_offsets`): a field's bits hold exactly its
old value, so adding ``(new - old) << shift`` (mod 2^32, split across
the at most two words a field straddles) replaces the field without
carries escaping into neighbours — exact even when several entries
share a word, because scatter-add accumulates and modular addition
commutes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.constants import PAD_POS

__all__ = [
    "pos_bits",
    "packed_words",
    "pack_offsets",
    "unpack_offsets",
    "gather_offsets",
    "scatter_offsets",
    "gather_absolute",
    "pack_plane_from_absolute",
    "unpack_to_absolute",
    "resolve_positions",
]

_WORD = 32


def pos_bits(c: int) -> int:
    """Bits per packed entry: a chunk-local offset in ``[0, c)``."""
    return max(1, (c - 1).bit_length())


def packed_words(n_entries: int, bits: int) -> int:
    """uint32 words needed for ``n_entries`` fields of ``bits`` each."""
    return (n_entries * bits + _WORD - 1) // _WORD


def _field_coords(entry_ids, bits: int):
    """(word index, in-word shift) of each entry's field start.

    Bit offsets are computed in uint32 — exact while the plane holds
    fewer than ``2**32 / bits`` entries (tens of billions of elements at
    c = 128), far past any capacity the stack admits.
    """
    bitpos = entry_ids.astype(jnp.uint32) * jnp.uint32(bits)
    w0 = (bitpos >> 5).astype(jnp.int32)
    sh = bitpos & jnp.uint32(_WORD - 1)
    return w0, sh


def _split_contrib(value_u32, sh, bits: int):
    """A field value as its (low word, straddling high word) contributions."""
    lo = value_u32 << sh
    # sh == 0 would shift by 32 (undefined); the straddle is empty there.
    hi = jnp.where(
        sh == 0,
        jnp.uint32(0),
        value_u32 >> (jnp.uint32(_WORD) - jnp.maximum(sh, jnp.uint32(1))),
    )
    return lo, hi


def pack_offsets(local: jax.Array, bits: int) -> jax.Array:
    """Pack per-entry chunk-local offsets (< 2**bits) into uint32 words.

    Fields of distinct entries are disjoint bit ranges, so the
    scatter-add over shared words is exactly a scatter-or.
    """
    n = local.shape[0]
    e = jnp.arange(n, dtype=jnp.int32)
    v = local.astype(jnp.uint32) & jnp.uint32((1 << bits) - 1)
    w0, sh = _field_coords(e, bits)
    lo, hi = _split_contrib(v, sh, bits)
    words = jnp.zeros((packed_words(n, bits),), jnp.uint32)
    words = words.at[w0].add(lo, mode="drop")
    words = words.at[w0 + 1].add(hi, mode="drop")
    return words


def gather_offsets(words: jax.Array, entry_ids, bits: int) -> jax.Array:
    """Read the packed fields at ``entry_ids`` (any shape) as int32."""
    nwords = words.shape[0]
    w0, sh = _field_coords(entry_ids, bits)
    lo = words[w0] >> sh
    hi = jnp.where(
        sh == 0,
        jnp.uint32(0),
        words[jnp.minimum(w0 + 1, nwords - 1)]
        << (jnp.uint32(_WORD) - jnp.maximum(sh, jnp.uint32(1))),
    )
    return ((lo | hi) & jnp.uint32((1 << bits) - 1)).astype(jnp.int32)


def unpack_offsets(words: jax.Array, n_entries: int, bits: int) -> jax.Array:
    """All ``n_entries`` packed fields, in entry order, as int32."""
    return gather_offsets(
        words, jnp.arange(n_entries, dtype=jnp.int32), bits
    )


def scatter_offsets(
    words: jax.Array,
    entry_ids: jax.Array,
    new_local: jax.Array,
    bits: int,
    live=None,
) -> jax.Array:
    """Overwrite the fields at ``entry_ids`` with ``new_local``.

    ``live`` (optional bool mask) turns lanes into no-ops — required for
    the duplicate entry ids ``touched_chunk_ids``'s static-size dedupe
    can emit, whose deltas would otherwise apply twice.  Within one call
    distinct live entries may share words freely: each delta only moves
    its own field's bits (see module docstring), and scatter-add
    accumulates shared-word deltas exactly under mod-2^32 arithmetic.
    """
    mask = jnp.uint32((1 << bits) - 1)
    old = gather_offsets(words, entry_ids, bits).astype(jnp.uint32)
    new = new_local.astype(jnp.uint32) & mask
    if live is not None:
        new = jnp.where(live, new, old)
    w0, sh = _field_coords(entry_ids, bits)
    new_lo, new_hi = _split_contrib(new, sh, bits)
    old_lo, old_hi = _split_contrib(old, sh, bits)
    words = words.at[w0].add(new_lo - old_lo, mode="drop")
    words = words.at[w0 + 1].add(new_hi - old_hi, mode="drop")
    return words


def gather_absolute(
    words: jax.Array, plan, level: int, entry_ids: jax.Array, pos_dtype
) -> jax.Array:
    """Absolute level-0 positions of ``entry_ids`` within ``level``.

    Descends one gather per level: an entry's field names the child
    holding its minimum, the child's field names the grandchild, down to
    the level-0 index.  Caller masks padding entries (their chains read
    zero-filled fields and return in-range garbage).
    """
    bits = pos_bits(plan.c)
    e = entry_ids.astype(pos_dtype)
    for lvl in range(level, 0, -1):
        off = plan.offsets[lvl - 1]
        loc = gather_offsets(words, off + e, bits)
        e = e * plan.c + loc.astype(pos_dtype)
    return e


def _plane_dtype(plan):
    from repro.core.hierarchy import pos_dtype_for

    return pos_dtype_for(plan.capacity, strict=False)


@functools.partial(jax.jit, static_argnames=("plan",))
def unpack_to_absolute(words: jax.Array, plan) -> jax.Array:
    """The full absolute-position plane from a packed word array.

    Bit-identical to the plane an unpacked build stores: live entries
    reconstruct level by level (the selected child of a live entry is
    itself live, so the chains never touch padding), padding entries are
    forced to ``PAD_POS``.
    """
    c = plan.c
    bits = pos_bits(c)
    dtype = _plane_dtype(plan)
    pad = jnp.array(PAD_POS, dtype)
    out = jnp.full((plan.upper_size,), PAD_POS, dtype=dtype)
    prev = None
    for k in range(1, plan.num_levels):
        off, padded = plan.level_slice(k)
        loc = gather_offsets(
            words, off + jnp.arange(padded, dtype=jnp.int32), bits
        )
        e = jnp.arange(padded, dtype=dtype)
        child = e * c + loc.astype(dtype)
        if k == 1:
            abs_k = child
        else:
            abs_k = prev[jnp.minimum(child, prev.shape[0] - 1)]
        abs_k = jnp.where(e < plan.level_lens[k], abs_k, pad)
        out = jax.lax.dynamic_update_slice(out, abs_k, (off,))
        prev = abs_k
    return out


@functools.partial(jax.jit, static_argnames=("plan",))
def pack_plane_from_absolute(abs_plane: jax.Array, plan) -> jax.Array:
    """Packed words from an absolute-position plane (any backend's build).

    Level 1 offsets are ``abs - e*c``; at level k the selected child is
    the unique child whose absolute position equals the parent's (chunk
    minima summarize disjoint ranges, so positions are distinct among a
    parent's live children).  Padding entries pack as zero — they are
    masked back to ``PAD_POS`` on unpack.
    """
    c = plan.c
    bits = pos_bits(c)
    locals_ = jnp.zeros((plan.upper_size,), jnp.int32)
    for k in range(1, plan.num_levels):
        off, padded = plan.level_slice(k)
        cur = jax.lax.slice(abs_plane, (off,), (off + padded,))
        e = jnp.arange(padded, dtype=jnp.int32)
        if k == 1:
            loc = (cur - e.astype(cur.dtype) * c).astype(jnp.int32)
        else:
            poff, ppadded = plan.level_slice(k - 1)
            child = jax.lax.slice(abs_plane, (poff,), (poff + ppadded,))
            win = child[
                jnp.minimum(
                    e[:, None] * c + jnp.arange(c, dtype=jnp.int32)[None, :],
                    ppadded - 1,
                )
            ]
            loc = jnp.argmax(win == cur[:, None], axis=1).astype(jnp.int32)
        loc = jnp.where(e < plan.level_lens[k], loc, 0)
        locals_ = jax.lax.dynamic_update_slice(locals_, loc, (off,))
    return pack_offsets(locals_, bits)


def resolve_positions(upper_pos, plan):
    """The absolute-position plane a query lowering should consume.

    Pass-through for unpacked planes and position-less builds; unpacks
    packed planes on the fly (call from inside a jitted program — the
    transient absolute plane then lives only for the launch).  Idempotent:
    packed word arrays are uint32, absolute planes are signed, so an
    already-resolved plane passes through unchanged.
    """
    if (
        upper_pos is not None
        and getattr(plan, "packed_pos", False)
        and upper_pos.dtype == jnp.uint32
    ):
        return unpack_to_absolute(upper_pos, plan)
    return upper_pos
