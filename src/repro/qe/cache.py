"""Result cache for the batched query engine.

Two layers, both host-side (results are scalars — a float or an int —
so the cache never pins device memory):

* **within-batch dedup** lives in the engine (``np.unique`` over the
  ``(l, r)`` pairs); this module only sees deduplicated queries;
* **cross-batch LRU** keyed by ``(op, generation, l, r)``.  The
  generation is the index's monotonic mutation counter —
  ``RMQ.update``/``append`` (and the streaming mutators) return a
  successor with ``generation + 1``, so entries computed against an
  older array version can never be returned for the new one.  Stale
  generations age out of the LRU naturally.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Hashable, Optional, Tuple

__all__ = ["ResultCache"]


class ResultCache:
    """Bounded LRU mapping ``(op, generation, l, r) -> scalar result``."""

    def __init__(self, capacity: int):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.capacity = capacity
        self._d: "OrderedDict[Tuple[Hashable, ...], object]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._d)

    def get(self, op: str, generation: int, l: int, r: int):
        """The cached result, or None on miss (results are never None)."""
        if self.capacity == 0:
            self.misses += 1
            return None
        key = (op, generation, l, r)
        val = self._d.get(key)
        if val is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return val

    def put(self, op: str, generation: int, l: int, r: int, value) -> None:
        if self.capacity == 0:
            return
        key = (op, generation, l, r)
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        self._d.clear()

    def stats(self) -> dict:
        return {
            "capacity": self.capacity,
            "entries": len(self._d),
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
        }
