"""Result cache for the batched query engine.

Two layers, both host-side (results are scalars — a float or an int —
so the cache never pins device memory):

* **within-batch dedup** lives in the engine (``np.unique`` over the
  ``(l, r)`` pairs); this module only sees deduplicated queries;
* **cross-batch LRU** keyed by ``(op, generation, l, r)``.  The
  generation is the index's monotonic mutation counter —
  ``RMQ.update``/``append`` (and the streaming mutators) return a
  successor with ``generation + 1``, so entries computed against an
  older array version can never be returned for the new one.  Stale
  generations age out of the LRU naturally.

The cache is shared between the serving tier's flusher thread and any
caller thread that queries an engine directly, so every operation —
including the hit/miss bookkeeping, where ``x += 1`` is not atomic under
the GIL — runs under one lock.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Hashable, Optional, Tuple

__all__ = ["ResultCache"]


class ResultCache:
    """Bounded LRU mapping ``(op, generation, l, r) -> scalar result``.

    Thread-safe: one lock covers the OrderedDict and the hit/miss/
    eviction counters, so ``stats()`` is always a consistent snapshot.
    """

    def __init__(self, capacity: int):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.capacity = capacity
        self._lock = threading.Lock()
        self._d: "OrderedDict[Tuple[Hashable, ...], object]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._d)

    def get(self, op: str, generation: int, l: int, r: int):
        """The cached result, or None on miss (results are never None)."""
        with self._lock:
            if self.capacity == 0:
                self.misses += 1
                return None
            key = (op, generation, l, r)
            val = self._d.get(key)
            if val is None:
                self.misses += 1
                return None
            self._d.move_to_end(key)
            self.hits += 1
            return val

    def put(self, op: str, generation: int, l: int, r: int, value) -> None:
        if self.capacity == 0:
            return
        key = (op, generation, l, r)
        with self._lock:
            self._d[key] = value
            self._d.move_to_end(key)
            while len(self._d) > self.capacity:
                self._d.popitem(last=False)
                self.evictions += 1

    def clear(self) -> None:
        with self._lock:
            self._d.clear()

    def hit_rate(self) -> float:
        """Hits / lookups over the cache's lifetime (0.0 when untouched)."""
        with self._lock:
            total = self.hits + self.misses
            return self.hits / total if total else 0.0

    def stats(self) -> dict:
        with self._lock:
            return {
                "capacity": self.capacity,
                "entries": len(self._d),
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
            }
