"""``QueryService`` — multi-index registry + micro-batching admission.

Serving-shaped frontend for the query engine: many named indices, many
small callers.  Small requests are the enemy of batched RMQ throughput
(every dispatch pays fixed planner/launch cost), so the service holds an
admission queue: ``submit`` enqueues a request and returns a ticket;
``flush`` coalesces everything pending for the same (index, op) pair
into one engine execution — one dedup pass, one set of padded buckets —
then scatters each request's slice back to its ticket.  On fused-backend
engines the two op groups of an index merge further into one *mixed*
execution (``QueryEngine.query_mixed``: value and index results from
the same single-launch buckets).  ``submit``
auto-flushes once the pending query count crosses ``max_pending``, which
bounds queue memory and gives an admission-control backstop.

The serving tier (``repro.serving``) drives flushes *externally* on
deadline/size triggers; the hooks it uses are public surface: pass
``auto_flush=False`` so ``submit`` never flushes behind the scheduler's
back, call ``flush(names=...)`` to flush one tenant's requests without
coupling other tenants' latency to it, ``validate_request`` for
admission-time checks without enqueueing, ``snapshot(name)`` for the
immutable index handle currently serving a name, and
``on_dropped_result`` to observe unclaimed-result evictions.

The registry is generation-aware: ``attach(name, successor)`` follows a
mutation (the engine's result cache invalidates by generation key).
``register_many`` admits a whole batch of equal-length arrays through one
vmapped construction launch (``repro.core.build_many``) instead of
per-array builds.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.obs import trace
from repro.obs.metrics import Metrics
from repro.qe.engine import QueryEngine
from repro.qe.executors import INDEX, VALUE

__all__ = ["QueryService"]


@dataclasses.dataclass(frozen=True)
class _Request:
    ticket: int
    name: str
    op: str
    ls: np.ndarray
    rs: np.ndarray


class QueryService:
    """Named engines + a coalescing admission queue."""

    def __init__(
        self,
        max_pending: int = 4096,
        max_unclaimed: int = 4096,
        auto_flush: bool = True,
        on_dropped_result: Optional[Callable[[str, int], None]] = None,
        metrics: Optional[Metrics] = None,
        tuning=None,
        **engine_defaults,
    ):
        # A TuningCache here flows into every engine the service
        # constructs: per-tenant engines self-configure their geometry
        # knobs (backend, long_cutoff, scan chunks) from measured
        # winners.  Explicit per-engine kwargs still win.
        if tuning is not None:
            engine_defaults.setdefault("tuning", tuning)
        self.max_pending = max_pending
        # Results stay claimable via take() after a flush, but a caller
        # that only reads flush()'s return value never claims — so the
        # buffer is bounded (FIFO eviction of the oldest unclaimed),
        # or a long-running service would leak one result per request.
        # The bound is PER INDEX: one tenant's unclaimed flood must not
        # evict another tenant's still-claimable results.
        self.max_unclaimed = max_unclaimed
        # auto_flush=False hands flush timing to an external scheduler
        # (the serving tier's deadline batcher); the scheduler then owns
        # bounding the queue — submit never flushes on max_pending.
        self.auto_flush = auto_flush
        # called as on_dropped_result(name, ticket) for every unclaimed
        # result FIFO-evicted past max_unclaimed — a warning hook, not a
        # veto (the result is gone either way)
        self.on_dropped_result = on_dropped_result
        self._engine_defaults = engine_defaults
        self._engines: Dict[str, QueryEngine] = {}
        self._pending: List[_Request] = []
        self._pending_queries = 0
        self._results: Dict[str, "OrderedDict[int, jnp.ndarray]"] = {}
        self._result_name: Dict[int, str] = {}
        self._next_ticket = 0
        self.flushes = 0
        self.coalesced_batches = 0
        self.mixed_retries = 0
        self.requests = 0
        self.dropped_results = 0
        # Optional obs registry: service counters export as read-through
        # gauges (no double bookkeeping on the hot path) and each
        # registered engine gets a child scope that renders as an
        # {index="..."} label in the Prometheus exposition.
        self.metrics = metrics
        self._engine_metrics: Optional[Metrics] = None
        if metrics is not None:
            self._engine_metrics = metrics.scope(
                "engines", child_label="index")
            metrics.gauge("requests", fn=lambda: self.requests)
            metrics.gauge("flushes", fn=lambda: self.flushes)
            metrics.gauge("coalesced_batches",
                          fn=lambda: self.coalesced_batches)
            metrics.gauge("mixed_retries", fn=lambda: self.mixed_retries)
            metrics.gauge("pending_queries",
                          fn=lambda: self._pending_queries)
            metrics.gauge("unclaimed_results",
                          fn=lambda: len(self._result_name))
            metrics.gauge("dropped_results",
                          fn=lambda: self.dropped_results)

    # -- registry ---------------------------------------------------------
    def register(self, name: str, index, **engine_kwargs) -> QueryEngine:
        """Create (or replace) the engine serving ``name``.

        Replacing a name whose queue still holds requests would answer
        those tickets against the wrong index — flush first (same
        contract as :meth:`unregister`; use :meth:`attach` to follow a
        mutation of the *same* logical index).
        """
        if any(r.name == name for r in self._pending):
            raise ValueError(
                f"index {name!r} has pending requests; flush first"
            )
        kwargs = {**self._engine_defaults, **engine_kwargs}
        if self._engine_metrics is not None and "metrics" not in kwargs:
            kwargs["metrics"] = self._engine_metrics.scope(name)
        engine = QueryEngine.for_index(index, **kwargs)
        self._engines[name] = engine
        return engine

    def register_many(
        self,
        arrays: Dict[str, object],
        c: int = 128,
        t: int = 64,
        with_positions: bool = False,
        backend: str = "auto",
        capacity: int = None,
        **engine_kwargs,
    ) -> Dict[str, QueryEngine]:
        """Index many equal-length arrays in ONE batched build launch.

        All arrays share one plan (same ``n``/``c``/``t``/``capacity``)
        and are stacked into a ``(B, n)`` batch for the vmapped
        :func:`repro.core.build_many` — a single end-to-end-jitted build
        instead of ``B`` dispatches.  Each row is then registered under
        its dict key as a normal :class:`repro.core.RMQ` (bit-identical
        to a solo ``RMQ.build`` of that array).

        The batched *construction* always runs the vmapped pure-JAX
        fused pass (every build backend is bit-identical, so there is
        nothing to choose); ``backend`` selects only the query/update
        lowering of the resulting indexes.  Stacking promotes mixed
        input dtypes to a common one; pass same-dtype arrays for exact
        per-array dtype control.
        """
        from repro.core import protocol as px
        from repro.core.api import RMQ
        from repro.core.hierarchy import Hierarchy, build_many
        from repro.core.plan import make_plan

        names = list(arrays)
        if not names:
            return {}
        # All-or-nothing: fail before any engine is replaced, not midway
        # through the loop (same pending-tickets contract as register).
        blocked = sorted(
            {r.name for r in self._pending} & set(names)
        )
        if blocked:
            raise ValueError(
                f"index(es) {blocked} have pending requests; flush first"
            )
        vals = [px.coerce_values(arrays[name]) for name in names]
        n = int(vals[0].shape[0])
        for name, v in zip(names, vals):
            if int(v.shape[0]) != n:
                raise ValueError(
                    f"register_many requires equal lengths; {names[0]!r} "
                    f"has {n}, {name!r} has {int(v.shape[0])} — register "
                    "differing geometries individually"
                )
        plan = make_plan(n, c=c, t=t, capacity=capacity)
        backend = px.resolve_backend(backend)
        batched = build_many(
            jnp.stack(vals), plan, with_positions=with_positions
        )
        engines: Dict[str, QueryEngine] = {}
        for i, name in enumerate(names):
            h = Hierarchy(
                base=batched.base[i],
                upper=batched.upper[i],
                upper_pos=(
                    batched.upper_pos[i] if with_positions else None
                ),
                plan=plan,
            )
            engines[name] = self.register(
                name,
                RMQ(hierarchy=h, backend=backend, length=n),
                **engine_kwargs,
            )
        return engines

    def attach(self, name: str, index, **kwargs) -> None:
        """Re-bind ``name`` to a successor index after a mutation."""
        self._engine(name).attach(index, **kwargs)

    def unregister(self, name: str) -> None:
        if any(r.name == name for r in self._pending):
            raise ValueError(
                f"index {name!r} has pending requests; flush first"
            )
        del self._engines[name]

    def engine(self, name: str) -> QueryEngine:
        return self._engine(name)

    def _engine(self, name: str) -> QueryEngine:
        if name not in self._engines:
            raise KeyError(
                f"no index registered as {name!r}; "
                f"have {sorted(self._engines)}"
            )
        return self._engines[name]

    # -- admission queue --------------------------------------------------
    def validate_request(
        self, name: str, ls, rs, op: str = VALUE
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Admission-time checks without enqueueing; returns coerced
        1-D ``(ls, rs)``.  Shared by :meth:`submit` and the serving
        tier, so a rejected request fails in the caller's hands, not at
        flush time where the error would be detached from it."""
        engine = self._engine(name)  # fail fast on unknown names
        if op not in (VALUE, INDEX):
            raise ValueError(f"op must be 'value' or 'index', got {op!r}")
        if op == INDEX and not engine.index.with_positions:
            raise ValueError(
                f"index {name!r} was built without positions; "
                "op='index' needs with_positions=True"
            )
        ls = np.atleast_1d(np.asarray(ls))
        rs = np.atleast_1d(np.asarray(rs))
        if ls.shape != rs.shape or ls.ndim != 1:
            raise ValueError(
                f"bounds must be matching 1-D batches, got "
                f"{ls.shape} vs {rs.shape}"
            )
        return ls, rs

    def submit(self, name: str, ls, rs, op: str = VALUE) -> int:
        """Enqueue a request; returns a ticket for :meth:`flush` results."""
        ls, rs = self.validate_request(name, ls, rs, op)
        ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append(_Request(ticket, name, op, ls, rs))
        self._pending_queries += ls.shape[0]
        self.requests += 1
        if self.auto_flush and self._pending_queries >= self.max_pending:
            self.flush()
        return ticket

    def submit_bulk(self, name: str, ls, rs, op: str = VALUE) -> int:
        """Execute a bulk-analytics batch immediately; returns its ticket.

        The offline counterpart of :meth:`submit`: admission checks are
        shared (:meth:`validate_request`), but the request bypasses the
        micro-batching queue entirely — coalescing exists to amortize
        launch cost across *small* callers, and a 10^7-query batch IS
        the launch.  Execution goes straight through
        :meth:`QueryEngine.query_bulk` (endpoint-sorted coalesced sweep,
        no per-query LRU or dedup above the crossover; small batches
        still fall back to the fused path inside the engine).  The
        result is stored immediately, so :meth:`take` can claim the
        ticket without any :meth:`flush` — pending micro-batched
        requests are untouched.
        """
        ls, rs = self.validate_request(name, ls, rs, op)
        ticket = self._next_ticket
        self._next_ticket += 1
        self.requests += 1
        res = self._engine(name).query_bulk(ls, rs, op)
        self._store_result(name, ticket, res)
        return ticket

    def snapshot(self, name: str):
        """The immutable index object currently serving ``name``.

        Pure-functional indexes make this a stable read handle: whatever
        mutations follow, the returned object keeps answering with its
        own generation's values (the serving tier's snapshot slots are
        built on exactly this property)."""
        return self._engine(name).index

    def flush(
        self, names: Optional[Iterable[str]] = None
    ) -> Dict[int, jnp.ndarray]:
        """Execute everything pending, coalesced per (index, op).

        ``names`` restricts the flush to those indexes' requests,
        leaving the rest queued — the serving tier flushes one tenant on
        *its* deadline without dragging other tenants' batches (and
        their latency accounting) along.

        Returns {ticket: results}; results also stay claimable via
        :meth:`take` until collected or until ``max_unclaimed`` newer
        results push them out (oldest-first).

        On an engine whose backend supports mixed execution (the fused
        runtime backend), an index's value AND index groups merge into
        one :meth:`QueryEngine.query_mixed` call — one dedup pass, one
        fused launch per bucket for the whole op mix — instead of one
        execution per op.

        Failures stay isolated per (index, op) group: a group that
        raises (e.g. out-of-range bounds for one index) does not lose
        other groups' results — when a *merged* mixed execution fails,
        the two op groups are retried separately so a bad index request
        can never take down the index's healthy value requests.  Stored
        results stay claimable as usual, and the first error re-raises
        after the loop with the failed groups' tickets in the message.
        """
        tr = trace.current()
        sp = tr.begin("service_flush") if tr is not None else None
        if names is None:
            pending, self._pending = self._pending, []
            self._pending_queries = 0
        else:
            picked = set(names)
            pending = [r for r in self._pending if r.name in picked]
            self._pending = [
                r for r in self._pending if r.name not in picked
            ]
            self._pending_queries = sum(
                r.ls.shape[0] for r in self._pending
            )
        if pending:
            self.flushes += 1
        groups: Dict[Tuple[str, str], List[_Request]] = {}
        for req in pending:
            groups.setdefault((req.name, req.op), []).append(req)
        out: Dict[int, jnp.ndarray] = {}
        out_name: Dict[int, str] = {}
        failures: List[Tuple[str, str, List[int], Exception]] = []

        def run_group(name, op, reqs, count_coalesced=True):
            """One per-op engine execution with its own failure unit.

            Returns True when results landed in ``out``.  The merged
            mixed path suppresses ``count_coalesced`` on its per-op
            retries and counts the admission-coalesced group itself —
            once — so the same workload reports the same stats whether
            the merged execution succeeded or fell back.
            """
            engine = self._engines[name]
            ls = np.concatenate([r.ls for r in reqs])
            rs = np.concatenate([r.rs for r in reqs])
            try:
                res = (
                    engine.query(ls, rs) if op == VALUE
                    else engine.query_index(ls, rs)
                )
            except Exception as e:
                failures.append((name, op, [r.ticket for r in reqs], e))
                return False
            if count_coalesced and len(reqs) > 1:
                self.coalesced_batches += 1
            off = 0
            for r in reqs:
                out[r.ticket] = res[off : off + r.ls.shape[0]]
                out_name[r.ticket] = r.name
                off += r.ls.shape[0]
            return True

        handled = set()
        for (name, op), reqs in groups.items():
            if (name, op) in handled:
                continue
            engine = self._engines[name]
            other = (name, INDEX if op == VALUE else VALUE)
            if other in groups and engine.supports_mixed:
                # merge both ops into one mixed execution (one launch
                # per bucket on the fused backend)
                reqs = groups[(name, VALUE)] + groups[(name, INDEX)]
                handled.add((name, VALUE))
                handled.add((name, INDEX))
                ls = np.concatenate([r.ls for r in reqs])
                rs = np.concatenate([r.rs for r in reqs])
                flags = np.concatenate([
                    np.full((r.ls.shape[0],), r.op == INDEX, bool)
                    for r in reqs
                ])
                try:
                    vals, poss = engine.query_mixed(ls, rs, flags)
                except Exception:
                    # keep the per-(index, op) failure-isolation
                    # contract: retry each op group separately so one
                    # bad op group cannot take the other down with it.
                    # Coalescing stats are counted HERE, not inside the
                    # retries: the admission coalesced these requests
                    # once, and that count must not depend on which
                    # execution path answered them (the retries used to
                    # double-increment when both op groups were multi-
                    # request and report zero when both were singletons).
                    self.mixed_retries += 1
                    ok_v = run_group(name, VALUE, groups[(name, VALUE)],
                                     count_coalesced=False)
                    ok_i = run_group(name, INDEX, groups[(name, INDEX)],
                                     count_coalesced=False)
                    if (ok_v or ok_i) and len(reqs) > 1:
                        self.coalesced_batches += 1
                    continue
                if len(reqs) > 1:
                    self.coalesced_batches += 1
                # per-ticket scatter, picking each request's plane —
                # mirrors run_group's offset bookkeeping; changes to
                # either scatter must land in both
                off = 0
                for r in reqs:
                    cnt = r.ls.shape[0]
                    plane = poss if r.op == INDEX else vals
                    out[r.ticket] = jnp.asarray(plane[off : off + cnt])
                    out_name[r.ticket] = r.name
                    off += cnt
                continue
            run_group(name, op, reqs)
        for ticket, res in out.items():
            self._store_result(out_name[ticket], ticket, res)
        if tr is not None:
            tr.end(sp, requests=len(pending), groups=len(groups),
                   failed=len(failures))
        if failures:
            name, op, tickets, err = failures[0]
            raise RuntimeError(
                f"flush failed for {len(failures)} group(s); first: "
                f"index {name!r} op {op!r} tickets {tickets}: {err} "
                "(other groups' results were stored and are claimable)"
            ) from err
        return out

    def _store_result(self, name: str, ticket: int, res) -> None:
        """Stash a flushed result, FIFO-bounding unclaimed per index."""
        bucket = self._results.setdefault(name, OrderedDict())
        bucket[ticket] = res
        self._result_name[ticket] = name
        while len(bucket) > self.max_unclaimed:
            old, _ = bucket.popitem(last=False)
            del self._result_name[old]
            self.dropped_results += 1
            if self.on_dropped_result is not None:
                self.on_dropped_result(name, old)

    def take(self, ticket: int) -> jnp.ndarray:
        """Claim (and remove) a flushed result by ticket.

        Raises ``KeyError`` for tickets never flushed *and* for results
        evicted past ``max_unclaimed`` (bounded per index) — claim
        promptly after flushing.
        """
        name = self._result_name.pop(ticket, None)
        if name is None:
            raise KeyError(
                f"ticket {ticket} has no result; flush() it first "
                "(or it aged out of the unclaimed-results buffer)"
            )
        bucket = self._results[name]
        res = bucket.pop(ticket)
        if not bucket:
            del self._results[name]
        return res

    # -- synchronous conveniences -----------------------------------------
    def _query_sync(self, name: str, ls, rs, op: str) -> jnp.ndarray:
        ticket = self.submit(name, ls, rs, op)
        try:
            self.flush()
        except RuntimeError:
            # flush failures are per-(index, op) group: if OUR group
            # executed, its result is stored and claimable — an unrelated
            # group's bad request must not lose this caller's answer.
            if ticket not in self._result_name:
                raise
        return self.take(ticket)

    def query(self, name: str, ls, rs) -> jnp.ndarray:
        """Submit + flush + take in one call (still coalesces any queue)."""
        return self._query_sync(name, ls, rs, VALUE)

    def query_index(self, name: str, ls, rs) -> jnp.ndarray:
        return self._query_sync(name, ls, rs, INDEX)

    # -- introspection ----------------------------------------------------
    def stats(self) -> dict:
        return {
            "requests": self.requests,
            "flushes": self.flushes,
            "coalesced_batches": self.coalesced_batches,
            "mixed_retries": self.mixed_retries,
            "pending_requests": len(self._pending),
            "pending_queries": self._pending_queries,
            "unclaimed_results": len(self._result_name),
            "dropped_results": self.dropped_results,
            "engines": {
                name: eng.stats() for name, eng in self._engines.items()
            },
        }
