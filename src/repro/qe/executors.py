"""Per-span-class executors holding persistent jitted callables.

Each executor owns the dispatch for one planner class and keeps a table
of bound callables keyed by ``(op, bucket shape)`` — the underlying
functions are module-level ``jax.jit`` specializations (static plan +
shape), so a (plan, shape, op) triple traces exactly once and every
later bucket with the same shape reuses the compiled executable.  The
table doubles as the retrace ledger surfaced in engine stats.

Backend dispatch mirrors the facade: ``backend="pallas"`` routes short
spans to the ``rmq_short`` kernel and mid spans to the ``rmq_scan``
kernel; ``backend="jax"`` uses the pure-JAX paths.  The long executor's
hybrid walk is pure JAX on either backend (its win is algorithmic — an
O(1) top — not a lowering).

``backend="fused"`` replaces the whole per-class trio with
:class:`FusedExecutor`: one ``kernels/rmq_fused`` dispatch answers the
entire bucket — every span class, and (via :meth:`FusedExecutor.run_mixed`)
value and index ops in the same launch.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hierarchy import Hierarchy
from repro.kernels.profiling import timed_dispatch
from repro.obs import trace

__all__ = [
    "ShortSpanExecutor",
    "MidSpanExecutor",
    "LongSpanExecutor",
    "FusedExecutor",
    "BulkExecutor",
]

VALUE = "value"
INDEX = "index"
MIXED = "mixed"


class _ExecutorBase:
    """Shared bookkeeping: the (op, shape) -> callable table and stats."""

    # dispatch-site label for the launch registry's opt-in wall timer
    label = "executor"

    def __init__(self):
        self._compiled: Dict[Tuple[str, int], Callable] = {}
        self.calls = 0
        self.queries = 0

    def _bind(self, op: str, shape: int, make: Callable) -> Callable:
        key = (op, shape)
        fn = self._compiled.get(key)
        if fn is None:
            fn = make()
            self._compiled[key] = fn
        return fn

    def run(self, h: Hierarchy, ls, rs, op: str) -> jax.Array:
        self.calls += 1
        self.queries += int(ls.shape[0])
        fn = self._bind(op, int(ls.shape[0]), lambda: self._make(h, op))
        return timed_dispatch(f"{self.label}:{op}", fn, h, ls, rs)

    def stats(self) -> dict:
        return {
            "calls": self.calls,
            "queries": self.queries,
            "specializations": len(self._compiled),
        }

    def invalidate(self) -> None:
        """Drop state tied to a particular index version (default: none)."""


class ShortSpanExecutor(_ExecutorBase):
    """Two-chunk level-0 scan; never touches the hierarchy."""

    label = "short"

    def __init__(self, backend: str, interpret: Optional[bool] = None):
        super().__init__()
        self.backend = backend
        self.interpret = interpret

    def _make(self, h: Hierarchy, op: str) -> Callable:
        from repro.kernels.rmq_short import ops as short_ops

        if self.backend == "pallas":
            if op == VALUE:
                return lambda h, ls, rs: short_ops.rmq_short_value_batch_pallas(
                    h, ls, rs, interpret=self.interpret
                )
            return lambda h, ls, rs: short_ops.rmq_short_index_batch_pallas(
                h, ls, rs, interpret=self.interpret
            )
        if op == VALUE:
            return short_ops.rmq_short_value_batch
        return short_ops.rmq_short_index_batch


class MidSpanExecutor(_ExecutorBase):
    """The standard full hierarchy walk (the previous monolithic path)."""

    label = "mid"

    def __init__(self, backend: str, interpret: Optional[bool] = None):
        super().__init__()
        self.backend = backend
        self.interpret = interpret

    def _make(self, h: Hierarchy, op: str) -> Callable:
        if self.backend == "pallas":
            from repro.kernels.rmq_scan import ops as scan_ops

            if op == VALUE:
                return lambda h, ls, rs: scan_ops.rmq_value_batch_pallas(
                    h, ls, rs, interpret=self.interpret
                )
            return lambda h, ls, rs: scan_ops.rmq_index_batch_pallas(
                h, ls, rs, interpret=self.interpret
            )
        from repro.core.query import rmq_index_batch, rmq_value_batch

        return rmq_value_batch if op == VALUE else rmq_index_batch


class LongSpanExecutor(_ExecutorBase):
    """Hybrid sparse-table top: O(1) instead of the c·t top scan.

    The hybrid wraps the engine's *live* hierarchy
    (``HybridRMQ.from_hierarchy`` — no rebuild; one <= c·t-entry table
    build), so it must be re-derived when the index mutates: the engine
    calls :meth:`invalidate` on every attach.
    """

    label = "long"

    def __init__(self):
        super().__init__()
        self._hybrid = None

    def invalidate(self) -> None:
        self._hybrid = None

    def _hybrid_for(self, h: Hierarchy):
        if self._hybrid is None or self._hybrid.hierarchy is not h:
            from repro.core.hybrid import HybridRMQ

            self._hybrid = HybridRMQ.from_hierarchy(h)
        return self._hybrid

    def _make(self, h: Hierarchy, op: str) -> Callable:
        if op == VALUE:
            return lambda h, ls, rs: self._hybrid_for(h).query(ls, rs)
        return lambda h, ls, rs: self._hybrid_for(h).query_index(ls, rs)


class FusedExecutor(_ExecutorBase):
    """The whole span mix in one ``rmq_fused`` dispatch per bucket.

    No class routing: the kernel decomposes each span internally
    (prefix-chunk scan + offset-table level lookups + suffix-chunk scan;
    short spans resolve entirely on its level-0 path).  ``run`` serves
    the engine's per-op path; :meth:`run_mixed` returns *both* output
    planes from one launch, which is how a batch mixing value and index
    ops avoids a second dispatch.
    """

    label = "fused"

    def __init__(self, interpret: Optional[bool] = None):
        super().__init__()
        self.interpret = interpret

    def _make(self, h: Hierarchy, op: str) -> Callable:
        from repro.kernels.rmq_fused import ops as fused_ops

        if op == MIXED:
            # one launch, both planes (positions imply track_pos)
            return lambda h, ls, rs: fused_ops.rmq_fused_batch(
                h, ls, rs, track_pos=True, interpret=self.interpret
            )
        if op == VALUE:
            return lambda h, ls, rs: fused_ops.rmq_fused_value_batch(
                h, ls, rs, interpret=self.interpret
            )
        return lambda h, ls, rs: fused_ops.rmq_fused_index_batch(
            h, ls, rs, interpret=self.interpret
        )

    def run_mixed(self, h: Hierarchy, ls, rs):
        """``(values, positions)`` for the whole bucket, one launch."""
        self.calls += 1
        self.queries += int(ls.shape[0])
        fn = self._bind(MIXED, int(ls.shape[0]),
                        lambda: self._make(h, MIXED))
        return timed_dispatch(f"{self.label}:{MIXED}", fn, h, ls, rs)


def _next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


class BulkExecutor(_ExecutorBase):
    """Offline bulk-analytics sweep: sort, bucket, one launch per bucket.

    The executor owns the host-side choreography of the
    ``kernels/rmq_bulk`` pass: the whole ``(ls, rs)`` batch is sorted by
    ``(chunk(l), chunk(r))`` so queries sharing boundary chunks become
    adjacent, split into buckets of at most ``max_bucket`` (pow2-padded
    with ``(0, 0)`` sentinel queries, so bucket shapes — and therefore
    traces — come from a tiny set), each bucket answered by a single
    level-0-coalesced dispatch, and the results inverse-permuted back to
    submission order.  One ``rmq_bulk`` launch per bucket is the
    CI-gated contract.

    No dedup and no LRU interplay here — at the 10^6+ batch sizes where
    bulk beats fused, per-query caching is pure overhead; the engine's
    ``query_bulk`` routes small batches to the fused path instead.

    ``max_bucket`` is deliberately large (default 2^20): the jnp
    lowering rebuilds the shared chunk ladder per dispatch, so bigger
    buckets amortize it further; the kernel path has no per-dispatch
    setup worth splitting for.
    """

    label = "bulk"

    def __init__(
        self,
        interpret: Optional[bool] = None,
        max_bucket: int = 1 << 20,
        min_bucket: int = 16,
    ):
        super().__init__()
        if max_bucket < min_bucket or min_bucket < 1:
            raise ValueError(
                f"need max_bucket >= min_bucket >= 1, got "
                f"{max_bucket}, {min_bucket}"
            )
        self.interpret = interpret
        self.max_bucket = int(max_bucket)
        self.min_bucket = int(min_bucket)

    def _make(self, h: Hierarchy, op: str) -> Callable:
        from repro.kernels.rmq_bulk import ops as bulk_ops

        if op == VALUE:
            return lambda h, ls, rs: bulk_ops.rmq_bulk_value_batch(
                h, ls, rs, interpret=self.interpret
            )
        return lambda h, ls, rs: bulk_ops.rmq_bulk_index_batch(
            h, ls, rs, interpret=self.interpret
        )

    def run(self, h: Hierarchy, ls, rs, op: str) -> np.ndarray:
        """Answer the whole batch; returns results in submission order."""
        ls = np.asarray(ls, np.int32).ravel()
        rs = np.asarray(rs, np.int32).ravel()
        m = ls.shape[0]
        out_dtype = np.int32 if op == INDEX else np.dtype(h.base.dtype)
        if m == 0:
            return np.zeros((0,), out_dtype)
        c = h.plan.c
        self.queries += m

        tr = trace.current()
        sp = tr.begin("plan") if tr is not None else None
        # last lexsort key is primary: chunk(l) major, chunk(r) minor
        order = np.lexsort((rs // c, ls // c))
        sls, srs = ls[order], rs[order]
        n_buckets = -(-m // self.max_bucket)
        if tr is not None:
            tr.end(sp, queries=m, buckets=n_buckets, op=op,
                   strategy="bulk")

        sorted_res = np.empty((m,), out_dtype)
        for start in range(0, m, self.max_bucket):
            stop = min(start + self.max_bucket, m)
            count = stop - start
            k = max(_next_pow2(count), self.min_bucket)
            bl = np.zeros((k,), np.int32)
            br = np.zeros((k,), np.int32)
            bl[:count] = sls[start:stop]
            br[:count] = srs[start:stop]
            self.calls += 1
            fn = self._bind(op, k, lambda: self._make(h, op))
            sp = tr.begin("execute") if tr is not None else None
            res = timed_dispatch(
                f"{self.label}:{op}", fn, h, jnp.asarray(bl),
                jnp.asarray(br),
            )
            sorted_res[start:stop] = np.asarray(res)[:count].astype(
                out_dtype, copy=False
            )
            if tr is not None:
                tr.end(sp, cls="bulk", count=count, shape=k, op=op)

        sp = tr.begin("scatter") if tr is not None else None
        out = np.empty((m,), out_dtype)
        out[order] = sorted_res
        if tr is not None:
            tr.end(sp, queries=m, unique=m, op=op)
        return out
