"""``QueryEngine`` — span-routed, deduped, cached batched RMQ execution.

One engine serves one index — anything implementing the
:class:`repro.core.protocol.RMQIndex` protocol: ``RMQ``, ``StreamingRMQ``,
``HybridRMQ``, or the mesh-sharded ``DistributedRMQ``.  The engine is a
*host-side* orchestration layer: classification, packing, dedup and cache
bookkeeping run in numpy; only the packed buckets touch the device,
through persistent jitted callables (see :mod:`repro.qe.executors`).

Execution pipeline per batch::

    validate -> dedup (np.unique) -> LRU lookup -> planner buckets
             -> per-class executors -> scatter-back -> LRU insert

For single-hierarchy indices the miss classes are short / mid / long span
buckets; for distributed indices the planner is replaced by the
segment-aware :class:`repro.qe.distributed.DistributedExecutor`
(segment-contained spans answered shard-locally with no all-reduce,
crossing spans through the ``pmin`` path).

With the **fused** runtime backend the engine prefers the
:class:`repro.qe.executors.FusedExecutor`: the planner degrades to a
single bucket class (``kernels/rmq_fused`` decomposes spans in-kernel,
so the short/mid/long split buys nothing) and each bucket is one
launch; :meth:`QueryEngine.query_mixed` additionally serves a batch
mixing value and index ops from that same single launch (both output
planes come out of one kernel call).  Dedup, the LRU result cache, and
the service's coalescing all operate unchanged on top.

Results are bit-identical — values *and* leftmost-tie positions — to
the index's monolithic oracles (``rmq_value_batch``/``rmq_index_batch``,
or ``DistributedRMQ.query``/``query_index``): every routed path computes
the exact lexicographic (value, position) minimum over the same range,
just over a cheaper decomposition.

Mutation protocol: the index is pure-functional, so ``update``/
``append`` return a *successor* with ``generation + 1``.  Call
:meth:`attach` with the successor; cached results keyed to older
generations can then never be served (and age out of the LRU).
Attaching an index that is not a successor of the current one (its
generation did not strictly increase, or its plan differs) clears the
cache outright.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.protocol import (
    check_capacity_limit,
    is_distributed,
    live_length,
    runtime_backend,
)
from repro.core.query import check_query_args
from repro.kernels.profiling import record_config
from repro.obs import trace
from repro.obs.metrics import SIZE_BUCKETS, Metrics
from repro.qe.cache import ResultCache
from repro.qe.distributed import DistributedExecutor
from repro.qe.executors import (
    INDEX,
    VALUE,
    BulkExecutor,
    FusedExecutor,
    LongSpanExecutor,
    MidSpanExecutor,
    ShortSpanExecutor,
)
from repro.qe.planner import FUSED, LONG, MID, SHORT, QueryPlanner

__all__ = ["QueryEngine"]


def _quantized(index) -> bool:
    """Does ``index`` store bf16 value summaries (exact-recovery walks)?"""
    return (
        getattr(index.plan, "summary_dtype", "float32") == "bfloat16"
    )


class QueryEngine:
    """Adaptive batched execution over one RMQ index."""

    def __init__(
        self,
        index,
        cache_size: int = 8192,
        long_enabled: bool = True,
        long_cutoff: Optional[int] = None,
        min_bucket: int = 16,
        max_bucket: int = 4096,
        backend: Optional[str] = None,
        interpret: Optional[bool] = None,
        metrics: Optional[Metrics] = None,
        tuning=None,
        span_mix: str = "mixed",
        bulk_crossover: Optional[int] = None,
    ):
        # Config precedence (most- to least-specific), resolved per
        # attach by _resolve_config:
        #   explicit ctor kwargs > ``tuning`` cache lookup
        #   > plan.level_split (baked at build) > analytic defaults.
        self._tuning = tuning
        self._span_mix = span_mix
        self._explicit_backend = backend
        self._long_enabled = long_enabled
        self._long_cutoff = long_cutoff
        self._min_bucket = min_bucket
        self._max_bucket = max_bucket
        self._interpret = interpret
        self._bulk_crossover = bulk_crossover
        if bulk_crossover is not None and bulk_crossover < 1:
            raise ValueError(
                f"bulk_crossover must be >= 1, got {bulk_crossover}"
            )
        self.bulk_crossover: int = 1  # resolved per attach
        self._bulk = BulkExecutor(interpret=interpret)
        self.cache = ResultCache(cache_size)
        self.tuned: Optional[dict] = None  # resolved config provenance
        self.backend = self._resolve_backend(index)
        self._configure_executors(self.backend)
        self.batches = 0
        self.queries_in = 0
        self.dedup_saved = 0
        self.class_counts = {SHORT: 0, MID: 0, LONG: 0, FUSED: 0}
        self._index = None
        self.planner: Optional[QueryPlanner] = None
        self.distributed: Optional[DistributedExecutor] = None
        self.metrics: Optional[Metrics] = None
        self._m_padding = None
        self._m_padded_lanes = None
        self._m_live_lanes = None
        self._m_tuned = None
        if metrics is not None:
            self._register_metrics(metrics)
        self.attach(index)

    # -- tuned-config resolution ------------------------------------------
    def _tuned_lookup(self, index):
        """The tuning-cache entry for this index, or ``None``."""
        if self._tuning is None or is_distributed(index):
            return None
        from repro.tune.cache import current_platform

        return self._tuning.lookup(
            current_platform(), live_length(index), self._span_mix
        )

    def _resolve_backend(self, index) -> str:
        """Query lowering per the precedence ladder (hierarchies are
        bit-identical across backends, so adopting a tuned backend over
        any build only changes which lowering answers)."""
        if self._explicit_backend is not None:
            return runtime_backend(self._explicit_backend)
        cfg = self._tuned_lookup(index)
        if cfg is not None:
            return runtime_backend(cfg.backend)
        split = getattr(index.plan, "level_split", None)
        if split is not None and split.fused:
            return "fused"
        return runtime_backend(index.backend)

    def _resolve_config(self, index) -> dict:
        """Planner knobs + provenance for ``index`` (non-distributed)."""
        cfg = self._tuned_lookup(index)
        split = getattr(index.plan, "level_split", None)
        source = "default"
        long_cutoff = self._long_cutoff
        scan_chunks = 2
        sparse_top = True
        if split is not None:
            source = "plan"
            scan_chunks = split.scan_chunks
            sparse_top = split.sparse_top
            if long_cutoff is None:
                long_cutoff = split.long_cutoff
        if cfg is not None:
            source = "cache"
            scan_chunks = cfg.scan_chunks
            sparse_top = cfg.sparse_top
            if self._long_cutoff is None:
                long_cutoff = cfg.long_cutoff
        if self._long_cutoff is not None:
            long_cutoff = self._long_cutoff
            if source != "default":
                source += "+override"
        # bf16 summaries: the long-span hybrid's sparse-table top would
        # compare quantized values (HybridRMQ refuses to build one);
        # long spans route through the exact mid-span walk instead.
        long_ok = not _quantized(index)
        return {
            "backend": self.backend,
            "planner": "fused" if self.backend == "fused" else "routed",
            "long_cutoff": long_cutoff,
            "scan_chunks": scan_chunks,
            "long_enabled": self._long_enabled and sparse_top and long_ok,
            "source": source,
        }

    def _resolve_bulk_crossover(self, index) -> int:
        """Batch size at which :meth:`query_bulk` leaves the fused path.

        Same precedence as the rest of the config: explicit ctor kwarg >
        tuned cache (``bulk_crossover`` measured by the Autotuner) >
        analytic model.  The analytic fallback charges the bulk pass its
        fixed per-dispatch cost — the shared chunk ladder is ~log2(c)
        full passes over the ``capacity/c`` chunk grid, worth paying
        once the batch is of the same order — and floors at 1024 so tiny
        indexes never bulk-route micro-batches.
        """
        if self._bulk_crossover is not None:
            return self._bulk_crossover
        cfg = self._tuned_lookup(index)
        if cfg is not None and getattr(cfg, "bulk_crossover", None):
            return int(cfg.bulk_crossover)
        plan = index.plan
        rows = max(index.capacity // plan.c, 1)
        return max(1024, rows * max(plan.c.bit_length() - 1, 1))

    def _configure_executors(self, backend: str) -> None:
        """(Re)build the executor table for ``backend`` — called at
        construction and when an attach adopts a different tuned
        backend (dropping the old backend's compiled tables)."""
        self.executors = {
            SHORT: ShortSpanExecutor(backend, interpret=self._interpret),
            MID: MidSpanExecutor(backend, interpret=self._interpret),
            LONG: LongSpanExecutor(),
        }
        if backend == "fused":
            # the whole span mix in one launch per bucket — the per-class
            # executors above never run (the planner emits FUSED only)
            self.executors[FUSED] = FusedExecutor(interpret=self._interpret)

    def _register_metrics(self, metrics: Metrics) -> None:
        """Export engine state into ``metrics``.

        Hot-path counters stay plain attributes — the gauges read them
        through callbacks at export time, so enabling metrics adds no
        per-query locking.  The only per-bucket write is the
        padding-waste histogram (one lock per *bucket*, not per query).
        """
        self.metrics = metrics
        cache = self.cache
        metrics.gauge("cache_hits", fn=lambda: cache.hits)
        metrics.gauge("cache_misses", fn=lambda: cache.misses)
        metrics.gauge("cache_hit_rate", fn=cache.hit_rate)
        metrics.gauge("cache_entries", fn=cache.__len__)
        metrics.gauge("cache_evictions", fn=lambda: cache.evictions)
        metrics.gauge("batches", fn=lambda: self.batches)
        metrics.gauge("queries", fn=lambda: self.queries_in)
        metrics.gauge("dedup_saved", fn=lambda: self.dedup_saved)
        for cls in (SHORT, MID, LONG, FUSED):
            metrics.gauge(f"span_class_{cls}",
                          fn=lambda c=cls: self.class_counts[c])
        self._m_padding = metrics.histogram(
            "bucket_padding_waste", SIZE_BUCKETS)
        self._m_padded_lanes = metrics.counter("padded_lanes")
        self._m_live_lanes = metrics.counter("live_lanes")
        self._m_tuned = metrics.info("tuned_config")
        if self.tuned is not None:
            self._m_tuned.set({k: str(v) for k, v in self.tuned.items()})

    def _note_bucket(self, bucket) -> None:
        """Per-bucket accounting shared by both execution paths."""
        self.class_counts[bucket.cls] += bucket.count
        if self._m_padding is not None:
            self._m_padding.record(bucket.padding)
            self._m_padded_lanes.inc(bucket.padding)
            self._m_live_lanes.inc(bucket.count)

    @classmethod
    def for_index(cls, index, **kwargs) -> "QueryEngine":
        return cls(index, **kwargs)

    # -- index binding ----------------------------------------------------
    @property
    def index(self):
        return self._index

    @property
    def generation(self) -> int:
        return getattr(self._index, "generation", 0)

    def attach(self, index, reset_cache: Optional[bool] = None) -> None:
        """Bind a (successor) index.

        ``reset_cache=None`` keeps cached results only when ``index``
        looks like a successor of the current binding: same plan and a
        strictly larger generation (old entries are then unreachable by
        key).  Pass ``True``/``False`` to override.
        """
        prev = self._index
        if reset_cache is None:
            reset_cache = not (
                prev is not None
                and index.plan == prev.plan
                and getattr(index, "generation", 0)
                > getattr(prev, "generation", 0)
            )
        if reset_cache:
            self.cache.clear()
        plan = index.plan
        # Query bounds/positions flow through int32 index space (planner
        # packing, the short kernel's iota, and the bucket packing's
        # numpy arithmetic alike — x64 does not lift this path).  Refuse
        # loudly rather than wrap.  ``capacity`` is the total addressable
        # space — for sharded indices that is segments * per-segment
        # capacity, not the (per-segment) plan's.
        check_capacity_limit(index.capacity)
        if is_distributed(index):
            # Sharded index: routing is by segment containment, not span
            # class — the planner and span executors never run.
            self.planner = None
            self.tuned = None
            self.bulk_crossover = self._resolve_bulk_crossover(index)
            if self.distributed is None:
                self.distributed = DistributedExecutor(
                    min_bucket=self._min_bucket,
                    max_bucket=self._max_bucket,
                )
        else:
            self.distributed = None
            # Re-resolve the tuned config against the new binding: a
            # successor index may carry a different plan (and cache
            # lookups key on the live length).  Adopting a different
            # tuned backend rebuilds the executor table.
            backend = self._resolve_backend(index)
            if backend != self.backend:
                self.backend = backend
                self._configure_executors(backend)
            resolved = self._resolve_config(index)
            self.bulk_crossover = self._resolve_bulk_crossover(index)
            resolved["bulk_crossover"] = self.bulk_crossover
            planner = QueryPlanner(
                c=plan.c,
                num_levels=plan.num_levels,
                long_cutoff=resolved["long_cutoff"],
                long_enabled=resolved["long_enabled"],
                min_bucket=self._min_bucket,
                max_bucket=self._max_bucket,
                fused=self.backend == "fused",
                scan_chunks=resolved["scan_chunks"],
            )
            if planner != self.planner:
                self.planner = planner
            self._record_tuned(index, resolved)
        self._index = index
        self.executors[LONG].invalidate()

    def _record_tuned(self, index, resolved: dict) -> None:
        """Expose the chosen config: ``stats()["tuned"]``, the launch
        registry (``engine_tuned_config`` records), and the metrics tree
        (``repro_..._tuned_config`` info gauge labels)."""
        plan = index.plan
        tuned = {
            "c": plan.c,
            "t": plan.t,
            "n": live_length(index),
            **{k: resolved[k] for k in
               ("backend", "planner", "long_cutoff", "scan_chunks",
                "long_enabled", "bulk_crossover", "source")},
        }
        if tuned == self.tuned:
            return
        self.tuned = tuned
        record_config("engine_tuned_config", **tuned)
        if self._m_tuned is not None:
            self._m_tuned.set(
                {k: str(v) for k, v in tuned.items()}
            )

    # -- public query surface ---------------------------------------------
    def query(self, ls, rs) -> jnp.ndarray:
        """Batched ``RMQ_value``; bit-identical to the index's oracle."""
        return self._execute(ls, rs, VALUE)

    def query_index(self, ls, rs) -> jnp.ndarray:
        """Batched ``RMQ_index``; bit-identical to the index's oracle."""
        if not self._index.with_positions:
            raise ValueError(
                "index was built without positions; rebuild it with "
                "with_positions=True to serve RMQ_index queries"
            )
        return self._execute(ls, rs, INDEX)

    def query_bulk(self, ls, rs, op: str = VALUE) -> jnp.ndarray:
        """Offline bulk-analytics batch (``op`` = ``"value"``/``"index"``).

        The execution strategy for the 10^6+-query regime: the batch is
        sorted by ``(chunk(l), chunk(r))`` and answered in single
        level-0-coalesced ``kernels/rmq_bulk`` dispatches that share
        chunk reads across queries (:class:`BulkExecutor`), results
        inverse-permuted back to submission order.  Bit-identical to
        :meth:`query` / :meth:`query_index` — values and leftmost-tie
        positions — at any batch size.

        Batches below :attr:`bulk_crossover` (explicit kwarg > autotuned
        cache > analytic model) take the standard fused path instead:
        below the crossover the bulk pass's fixed ladder cost loses, and
        dedup + the LRU still pay for themselves.  At and above it both
        are skipped — per-query caching is pure overhead at bulk scale.
        On a distributed index the endpoint sort also groups queries by
        owning segment, so segment-contained spans run shard-locally
        with zero collectives
        (:meth:`~repro.qe.distributed.DistributedExecutor.run_bulk`).
        """
        if op not in (VALUE, INDEX):
            raise ValueError(
                f"op must be {VALUE!r} or {INDEX!r}, got {op!r}"
            )
        index = self._index
        if op == INDEX and not index.with_positions:
            raise ValueError(
                "index was built without positions; rebuild it with "
                "with_positions=True to serve RMQ_index queries"
            )
        n = live_length(index)
        ls, rs = check_query_args(ls, rs, n)
        ls = np.asarray(ls, np.int32).ravel()
        rs = np.asarray(rs, np.int32).ravel()
        if ls.shape[0] < self.bulk_crossover or (
            self.distributed is None and _quantized(index)
        ):
            # bf16 summaries: the coalesced bulk sweep compares quantized
            # level-1 values with no exact-recovery pass, so bf16 indexes
            # always take the routed path (whose walks re-read level 0).
            return self._execute(ls, rs, op)
        self.batches += 1
        self.queries_in += ls.shape[0]
        if self.distributed is not None:
            res = self.distributed.run_bulk(index, ls, rs, op)
        else:
            res = self._bulk.run(index.hierarchy, ls, rs, op)
        out_dtype = (
            np.int32 if op == INDEX else np.dtype(index.value_dtype)
        )
        return jnp.asarray(np.asarray(res).astype(out_dtype, copy=False))

    @property
    def supports_mixed(self) -> bool:
        """Can a value+index mix execute as ONE launch per bucket?

        True on fused-backend engines over a single hierarchy (the
        kernel emits both output planes); the service uses this to
        coalesce a registered index's value and index groups into one
        execution instead of two.
        """
        return FUSED in self.executors and self.distributed is None

    def query_mixed(self, ls, rs, is_index) -> tuple:
        """Answer a batch mixing ``RMQ_value`` and ``RMQ_index`` ops.

        ``is_index[i]`` selects row ``i``'s op.  Returns ``(values,
        positions)`` numpy arrays of the batch length; only the plane
        selected by ``is_index`` is meaningful per row (the other
        plane's entry is unspecified).  On a fused engine the whole
        deduped miss batch executes through :class:`FusedExecutor` with
        both planes from the same launch; elsewhere it falls back to one
        standard execution per op.  Results are bit-identical to
        :meth:`query` / :meth:`query_index` row-wise.
        """
        index = self._index
        is_index = np.asarray(is_index, bool).ravel()
        if is_index.any() and not index.with_positions:
            raise ValueError(
                "index was built without positions; rebuild it with "
                "with_positions=True to serve RMQ_index queries"
            )
        n = live_length(index)
        ls, rs = check_query_args(ls, rs, n)
        ls = np.asarray(ls, np.int32).ravel()
        rs = np.asarray(rs, np.int32).ravel()
        if ls.shape != is_index.shape:
            raise ValueError(
                f"is_index must match the batch, got {is_index.shape} "
                f"vs {ls.shape}"
            )
        m = ls.shape[0]
        val_dtype = np.dtype(index.value_dtype)
        vals_out = np.zeros((m,), val_dtype)
        pos_out = np.zeros((m,), np.int32)
        if m == 0:
            return vals_out, pos_out

        single_op = is_index.all() or not is_index.any()
        if not self.supports_mixed or single_op:
            # per-op path: also taken by genuinely single-op batches on
            # fused engines — the dual-plane launch would waste the
            # unused plane (and track positions value-only builds lack)
            vi = np.nonzero(~is_index)[0]
            ii = np.nonzero(is_index)[0]
            if vi.shape[0]:
                vals_out[vi] = np.asarray(
                    self._execute(ls[vi], rs[vi], VALUE)
                )
            if ii.shape[0]:
                pos_out[ii] = np.asarray(
                    self._execute(ls[ii], rs[ii], INDEX)
                )
            return vals_out, pos_out

        self.batches += 1
        self.queries_in += m

        # Dedup on (l, r) pairs — the fused launch computes both planes
        # for every query anyway, so value and index requests for the
        # same range share one execution.
        uniq, inverse = np.unique(
            np.stack([ls, rs]), axis=1, return_inverse=True
        )
        uls, urs = uniq[0], uniq[1]
        k = uls.shape[0]
        self.dedup_saved += m - k
        inverse = inverse.ravel()
        uv = np.zeros((k,), val_dtype)
        up = np.zeros((k,), np.int32)
        need_val = np.zeros((k,), bool)
        need_pos = np.zeros((k,), bool)
        need_val[inverse[~is_index]] = True
        need_pos[inverse[is_index]] = True

        gen = self.generation
        if self.cache.capacity > 0:
            missing = np.zeros((k,), bool)
            for i in range(k):
                l, r = int(uls[i]), int(urs[i])
                if need_val[i]:
                    hit = self.cache.get(VALUE, gen, l, r)
                    if hit is None:
                        missing[i] = True
                    else:
                        uv[i] = hit
                if need_pos[i]:
                    hit = self.cache.get(INDEX, gen, l, r)
                    if hit is None:
                        missing[i] = True
                    else:
                        up[i] = hit
            miss_idx = np.nonzero(missing)[0]
        else:
            miss_idx = np.arange(k)

        tr = trace.current()
        if miss_idx.shape[0]:
            h = index.hierarchy
            fused = self.executors[FUSED]
            mls, mrs = uls[miss_idx], urs[miss_idx]
            sp = tr.begin("plan") if tr is not None else None
            buckets = self.planner.plan(mls, mrs)
            if tr is not None:
                tr.end(sp, misses=int(miss_idx.shape[0]),
                       buckets=len(buckets), op="mixed")
            for bucket in buckets:
                if bucket.count == 0:
                    continue
                self._note_bucket(bucket)
                sp = tr.begin("execute") if tr is not None else None
                bv, bp = fused.run_mixed(
                    h, jnp.asarray(bucket.ls), jnp.asarray(bucket.rs)
                )
                rows = miss_idx[bucket.idxs]
                uv[rows] = np.asarray(bv)[: bucket.count].astype(
                    val_dtype, copy=False
                )
                up[rows] = np.asarray(bp)[: bucket.count]
                if tr is not None:
                    tr.end(sp, cls=bucket.cls, count=bucket.count,
                           shape=bucket.shape, op="mixed")
            if self.cache.capacity > 0:
                for i in miss_idx:
                    l, r = int(uls[i]), int(urs[i])
                    if need_val[i]:
                        self.cache.put(VALUE, gen, l, r, uv[i].item())
                    if need_pos[i]:
                        self.cache.put(INDEX, gen, l, r, int(up[i]))

        sp = tr.begin("scatter") if tr is not None else None
        out = uv[inverse], up[inverse]
        if tr is not None:
            tr.end(sp, queries=m, unique=k, op="mixed")
        return out

    # -- execution --------------------------------------------------------
    # NOTE: query_mixed above carries a dual-plane variant of this
    # dedup -> LRU -> bucket-execute -> cache-writeback pipeline (its
    # cache entries are per-op, its execution per-(l,r) pair); cache or
    # dedup semantics changed here must change there too.
    def _execute(self, ls, rs, op: str) -> jnp.ndarray:
        index = self._index
        n = live_length(index)
        ls, rs = check_query_args(ls, rs, n)
        ls = np.asarray(ls, np.int32).ravel()
        rs = np.asarray(rs, np.int32).ravel()
        m = ls.shape[0]
        out_dtype = (
            np.int32 if op == INDEX else np.dtype(index.value_dtype)
        )
        if m == 0:
            return jnp.zeros((0,), out_dtype)

        self.batches += 1
        self.queries_in += m

        # -- within-batch dedup -------------------------------------------
        uniq, inverse = np.unique(
            np.stack([ls, rs]), axis=1, return_inverse=True
        )
        uls, urs = uniq[0], uniq[1]
        k = uls.shape[0]
        self.dedup_saved += m - k
        uniq_res = np.empty((k,), out_dtype)

        # -- LRU lookup ---------------------------------------------------
        gen = self.generation
        if self.cache.capacity > 0:
            missing = np.ones((k,), bool)
            for i in range(k):
                hit = self.cache.get(op, gen, int(uls[i]), int(urs[i]))
                if hit is not None:
                    uniq_res[i] = hit
                    missing[i] = False
            miss_idx = np.nonzero(missing)[0]
        else:
            miss_idx = np.arange(k)

        # -- plan + execute the misses ------------------------------------
        tr = trace.current()
        if miss_idx.shape[0]:
            mls, mrs = uls[miss_idx], urs[miss_idx]
            if self.distributed is not None:
                res = self.distributed.run(index, mls, mrs, op)
                uniq_res[miss_idx] = res.astype(out_dtype, copy=False)
            else:
                h = index.hierarchy
                sp = tr.begin("plan") if tr is not None else None
                buckets = self.planner.plan(mls, mrs)
                if tr is not None:
                    tr.end(sp, misses=int(miss_idx.shape[0]),
                           buckets=len(buckets), op=op)
                for bucket in buckets:
                    if bucket.count == 0:
                        continue
                    self._note_bucket(bucket)
                    sp = tr.begin("execute") if tr is not None else None
                    res = self.executors[bucket.cls].run(
                        h, jnp.asarray(bucket.ls), jnp.asarray(bucket.rs),
                        op,
                    )
                    res = np.asarray(res)[: bucket.count].astype(
                        out_dtype, copy=False
                    )
                    if tr is not None:
                        tr.end(sp, cls=bucket.cls, count=bucket.count,
                               shape=bucket.shape, op=op)
                    uniq_res[miss_idx[bucket.idxs]] = res
            if self.cache.capacity > 0:
                for i in miss_idx:
                    self.cache.put(
                        op, gen, int(uls[i]), int(urs[i]),
                        uniq_res[i].item(),
                    )

        sp = tr.begin("scatter") if tr is not None else None
        out = jnp.asarray(uniq_res[inverse.ravel()])
        if tr is not None:
            tr.end(sp, queries=m, unique=k, op=op)
        return out

    # -- introspection ----------------------------------------------------
    def stats(self) -> dict:
        counts = dict(self.class_counts)
        executors = {
            cls: ex.stats() for cls, ex in self.executors.items()
        }
        if self.distributed is not None:
            counts = dict(self.distributed.class_counts)
            executors = {"distributed": self.distributed.stats()}
        if self._bulk.calls:
            executors["bulk"] = self._bulk.stats()
        return {
            "backend": self.backend,
            "generation": self.generation,
            "batches": self.batches,
            "queries": self.queries_in,
            "dedup_saved": self.dedup_saved,
            "class_counts": counts,
            "cache": self.cache.stats(),
            "executors": executors,
            "tuned": dict(self.tuned) if self.tuned else None,
        }
