"""Adaptive batched query engine (the system layer above the kernels).

The paper's throughput data is *non-uniform in query span* (Fig. 16
reports per-range-class throughput; §4.5's hybrid exists because long
queries want a different engine than short ones).  This package turns
that observation into an execution layer:

* :class:`QueryPlanner` — classifies each query by span into
  short / mid / long and packs each class into fixed padded bucket
  shapes (bounded set of shapes ⇒ bounded jit retraces as batch
  composition shifts);
* executors (:mod:`repro.qe.executors`) — one per class, holding
  persistent jitted callables: short spans skip the hierarchy via the
  ``rmq_short`` two-chunk kernel, mid spans take the standard walk,
  long spans use the :class:`~repro.core.hybrid.HybridRMQ` O(1)
  sparse-table top; with the fused runtime backend the per-class trio
  is replaced by the :class:`~repro.qe.executors.FusedExecutor` — the
  whole span mix (and both value/index output planes) in ONE
  ``kernels/rmq_fused`` launch per bucket, the planner degrading to a
  single ``FUSED`` class;
* :class:`ResultCache` — within-batch duplicate dedup plus an LRU keyed
  by ``(op, index generation, l, r)``; ``RMQ.update``/``append`` bump
  the generation so streaming mutations invalidate correctly;
* :class:`QueryEngine` — ties the three together for one index
  (``RMQ.engine()`` on the facade); any
  :class:`repro.core.protocol.RMQIndex` attaches, including the
  mesh-sharded ``DistributedRMQ``, whose batches route through
  :class:`DistributedExecutor` instead (segment-contained spans answered
  shard-locally with no all-reduce, crossing spans via ``pmin``);
* :class:`QueryService` — a multi-index registry with a micro-batching
  admission queue that coalesces small requests into one padded
  execution with per-request scatter-back;
* :class:`BulkExecutor` — the offline analytics path
  (``QueryEngine.query_bulk`` / ``QueryService.submit_bulk``): the
  whole batch endpoint-sorted by ``(chunk(l), chunk(r))`` and answered
  in single level-0-coalesced ``kernels/rmq_bulk`` dispatches that
  share chunk reads across queries, with an autotuned size crossover
  back to the fused path for small batches.
"""

from repro.qe.cache import ResultCache
from repro.qe.distributed import CROSSING, SEG_LOCAL, DistributedExecutor
from repro.qe.engine import QueryEngine
from repro.qe.executors import BulkExecutor, FusedExecutor
from repro.qe.planner import FUSED, LONG, MID, SHORT, Bucket, QueryPlanner
from repro.qe.service import QueryService

__all__ = [
    "Bucket",
    "BulkExecutor",
    "CROSSING",
    "DistributedExecutor",
    "FUSED",
    "FusedExecutor",
    "LONG",
    "MID",
    "SEG_LOCAL",
    "SHORT",
    "QueryEngine",
    "QueryPlanner",
    "QueryService",
    "ResultCache",
]
