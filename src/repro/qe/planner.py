"""Span classification and bucket packing for the batched query engine.

Routing predicates (host-side numpy — the planner runs before any device
dispatch):

* **short** — the query's level-0 footprint spans at most two aligned
  ``c``-chunks (``r // c - l // c <= 1``).  Answered by the
  ``rmq_short`` direct scan; the hierarchy is never touched.
* **long** — ``span >= long_cutoff``, where the default cutoff
  ``2c · c^(L-2)`` is the smallest span that *must* ascend all the way
  to the top level (the walk's early exit fires once the remaining
  range is ``<= 2c``, and each ascent divides the span by ``c``).
  Routed to the hybrid's O(1) sparse-table top, which replaces the
  ``c·t``-entry top scan with two loads.
* **mid** — everything else: the standard hierarchy walk.

With the **fused** runtime backend (``kernels/rmq_fused``) the class
split is unnecessary — the kernel decomposes spans internally, so the
planner *degrades to a single bucket class* (``fused=True``): every
query lands in ``FUSED`` buckets and the engine executes the whole mix
through one executor, one launch per bucket.

Each class is packed into *fixed padded bucket shapes*: full buckets of
``max_bucket`` queries plus one tail padded up to a power of two (at
least ``min_bucket``).  The set of distinct shapes the executors ever
see is therefore ``O(log(max_bucket))`` per class — jit specializations
are bounded no matter how batch composition shifts between calls.
Padding queries are ``(0, 0)`` (valid on any non-empty array); their
results are dropped at scatter-back.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

__all__ = ["SHORT", "MID", "LONG", "FUSED", "Bucket", "QueryPlanner"]

SHORT = "short"
MID = "mid"
LONG = "long"
FUSED = "fused"


def _next_pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length() if x > 1 else 1


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One padded execution unit: ``idxs`` maps rows back to the batch."""

    cls: str           # SHORT | MID | LONG
    idxs: np.ndarray   # (k,) positions in the planned batch
    ls: np.ndarray     # (shape,) int32, padded with 0
    rs: np.ndarray     # (shape,) int32, padded with 0

    @property
    def shape(self) -> int:
        return int(self.ls.shape[0])

    @property
    def count(self) -> int:
        return int(self.idxs.shape[0])

    @property
    def padding(self) -> int:
        """Dead lanes: padded pow2 shape minus live queries."""
        return self.shape - self.count


@dataclasses.dataclass(frozen=True)
class QueryPlanner:
    """Static routing policy for one hierarchy geometry."""

    c: int
    num_levels: int
    long_cutoff: Optional[int] = None   # None -> 2c * c^(L-2) default
    long_enabled: bool = True
    min_bucket: int = 16
    max_bucket: int = 4096
    # fused runtime backend: no class split — the kernel decomposes
    # spans internally, so everything packs into FUSED buckets.
    fused: bool = False
    # bottom-scan threshold in aligned c-chunks (1 or 2): spans touching
    # at most this many chunks take the rmq_short route.  Tuned via
    # LevelSplit.scan_chunks; 2 is the kernel's maximum.
    scan_chunks: int = 2

    def effective_long_cutoff(self) -> int:
        if self.long_cutoff is not None:
            return self.long_cutoff
        return 2 * self.c ** max(self.num_levels - 1, 1)

    def classify(self, ls: np.ndarray, rs: np.ndarray) -> np.ndarray:
        """Class label per query (vectorized; '<U5' array)."""
        if self.fused:
            return np.full(ls.shape, FUSED, dtype="<U5")
        c = self.c
        out = np.full(ls.shape, MID, dtype="<U5")
        short = (rs // c) - (ls // c) <= self.scan_chunks - 1
        out[short] = SHORT
        if self.long_enabled and self.num_levels >= 2:
            span = rs.astype(np.int64) - ls + 1
            out[~short & (span >= self.effective_long_cutoff())] = LONG
        return out

    def plan(self, ls: np.ndarray, rs: np.ndarray) -> List[Bucket]:
        """Pack a batch into per-class padded buckets."""
        ls = np.asarray(ls, np.int32)
        rs = np.asarray(rs, np.int32)
        labels = self.classify(ls, rs)
        buckets: List[Bucket] = []
        classes = (FUSED,) if self.fused else (SHORT, MID, LONG)
        for cls in classes:
            idxs = np.nonzero(labels == cls)[0]
            for lo in range(0, idxs.shape[0], self.max_bucket):
                part = idxs[lo : lo + self.max_bucket]
                buckets.append(self._pack(cls, part, ls, rs))
        return buckets

    def _pack(self, cls: str, idxs: np.ndarray, ls, rs) -> Bucket:
        shape = min(
            max(_next_pow2(idxs.shape[0]), self.min_bucket),
            self.max_bucket,
        )
        pl = np.zeros((shape,), np.int32)
        pr = np.zeros((shape,), np.int32)
        pl[: idxs.shape[0]] = ls[idxs]
        pr[: idxs.shape[0]] = rs[idxs]
        return Bucket(cls=cls, idxs=idxs, ls=pl, rs=pr)
