"""Distributed executor: segment-aware routing for sharded indices.

A :class:`repro.core.distributed.DistributedRMQ` has no single local
hierarchy, so the span executors (short/mid/long) don't apply.  What *does*
transfer is the engine's core observation — different queries want
different execution — with a sharding-native routing predicate:

* **seg_local** — the span falls entirely inside one segment
  (``l // segment_capacity == r // segment_capacity``).  The batch is
  grouped by owning segment on the host, localized, packed into one
  ``(S, k)`` array sharded over the segment axis, and each device answers
  only its own row — **no all-reduce at all** (zero cross-device
  communication, vs. one ``pmin`` per batch on the monolithic path).
  Short and mid spans land here with probability ``≈ 1 - span/seg_cap``.
* **crossing** — the span straddles a segment boundary; routed to the
  monolithic all-reduce path (``DistributedRMQ._query``), which is the
  engine's oracle.

Both paths produce values and leftmost-tie positions bit-identical to
``DistributedRMQ.query``/``query_index``.  Shapes are padded to powers of
two (``(0, 0)`` sentinel queries, dropped at scatter-back) so the set of
jit specializations stays bounded as batch composition shifts — the same
discipline as the planner's buckets.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.obs import trace
from repro.qe.executors import INDEX
from repro.qe.planner import _next_pow2

__all__ = ["SEG_LOCAL", "CROSSING", "DistributedExecutor"]

SEG_LOCAL = "seg_local"
CROSSING = "crossing"


class DistributedExecutor:
    """Routes one deduped miss batch over a segment-sharded index."""

    def __init__(self, min_bucket: int = 16, max_bucket: int = 4096):
        self.min_bucket = min_bucket
        self.max_bucket = max_bucket
        self.calls = 0
        self.queries = 0
        self.class_counts: Dict[str, int] = {SEG_LOCAL: 0, CROSSING: 0}

    def run(self, index, ls: np.ndarray, rs: np.ndarray,
            op: str) -> np.ndarray:
        """Answer ``(ls, rs)`` (np.int32, deduped) against ``index``."""
        self.calls += 1
        m = ls.shape[0]
        self.queries += m
        cap = index.segment_capacity
        out_dtype = np.int32 if op == INDEX else np.dtype(index.value_dtype)
        out = np.empty((m,), out_dtype)
        owner = ls // cap
        local = owner == (rs // cap)
        self.class_counts[SEG_LOCAL] += int(local.sum())
        self.class_counts[CROSSING] += int(m - local.sum())

        tr = trace.current()
        cross_idx = np.nonzero(~local)[0]
        if cross_idx.shape[0]:
            sp = tr.begin("execute") if tr is not None else None
            out[cross_idx] = self._run_crossing(
                index, ls[cross_idx], rs[cross_idx], op, out_dtype
            )
            if tr is not None:
                tr.end(sp, cls=CROSSING, count=int(cross_idx.shape[0]),
                       op=op)
        local_idx = np.nonzero(local)[0]
        if local_idx.shape[0]:
            sp = tr.begin("execute") if tr is not None else None
            out[local_idx] = self._run_seg_local(
                index, ls[local_idx], rs[local_idx], owner[local_idx], op,
                out_dtype,
            )
            if tr is not None:
                tr.end(sp, cls=SEG_LOCAL, count=int(local_idx.shape[0]),
                       op=op)
        return out

    def run_bulk(self, index, ls: np.ndarray, rs: np.ndarray,
                 op: str) -> np.ndarray:
        """Bulk-analytics route: the endpoint sort groups by owner too.

        Same routing predicate as :meth:`run`, but segment-contained
        queries are pre-sorted by ``(owner segment, chunk(l), chunk(r))``
        in segment-local coordinates before the grouped shard-local
        execution — the one sort simultaneously (a) packs each segment's
        queries contiguously so ``_run_seg_local``'s stable owner sort
        is an identity pass, and (b) makes every shard's row
        endpoint-sorted, the locality the bulk regime is after.  The
        grouped path runs with **zero collectives**; only
        boundary-crossing spans (a ``span/segment_capacity`` fraction of
        a uniform batch) pay the ``pmin`` oracle.  No dedup, no LRU —
        bulk-scale batches bypass both by design.
        """
        self.calls += 1
        m = ls.shape[0]
        self.queries += m
        cap = index.segment_capacity
        c = index.plan.c
        out_dtype = np.int32 if op == INDEX else np.dtype(index.value_dtype)
        out = np.empty((m,), out_dtype)

        tr = trace.current()
        sp = tr.begin("plan") if tr is not None else None
        owner = ls // cap
        local = owner == (rs // cap)
        n_local = int(local.sum())
        self.class_counts[SEG_LOCAL] += n_local
        self.class_counts[CROSSING] += m - n_local
        local_idx = np.nonzero(local)[0]
        lsub, rsub = ls[local_idx], rs[local_idx]
        osub = owner[local_idx]
        lloc = lsub - osub.astype(np.int32) * cap
        rloc = rsub - osub.astype(np.int32) * cap
        sort = np.lexsort((rloc // c, lloc // c, osub))
        if tr is not None:
            tr.end(sp, queries=m, seg_local=n_local,
                   crossing=m - n_local, op=op, strategy="bulk")

        cross_idx = np.nonzero(~local)[0]
        if cross_idx.shape[0]:
            sp = tr.begin("execute") if tr is not None else None
            out[cross_idx] = self._run_crossing(
                index, ls[cross_idx], rs[cross_idx], op, out_dtype
            )
            if tr is not None:
                tr.end(sp, cls=CROSSING, count=int(cross_idx.shape[0]),
                       op=op)
        if local_idx.shape[0]:
            sp = tr.begin("execute") if tr is not None else None
            res = self._run_seg_local(
                index, lsub[sort], rsub[sort], osub[sort], op, out_dtype
            )
            if tr is not None:
                tr.end(sp, cls=SEG_LOCAL, count=int(local_idx.shape[0]),
                       op=op)
            sp = tr.begin("scatter") if tr is not None else None
            out[local_idx[sort]] = res
            if tr is not None:
                tr.end(sp, queries=m, unique=m, op=op)
        return out

    # -- crossing spans: the pmin oracle, padded to bounded shapes --------
    def _run_crossing(self, index, ls, rs, op, out_dtype) -> np.ndarray:
        k = ls.shape[0]
        shape = min(
            max(_next_pow2(k), self.min_bucket), self.max_bucket
        )
        res = np.empty((k,), out_dtype)
        for lo in range(0, k, shape):
            cnt = min(shape, k - lo)
            pl = np.zeros((shape,), np.int32)
            pr = np.zeros((shape,), np.int32)
            pl[:cnt] = ls[lo : lo + cnt]
            pr[:cnt] = rs[lo : lo + cnt]
            r = index.query_index(pl, pr) if op == INDEX \
                else index.query(pl, pr)
            res[lo : lo + cnt] = np.asarray(r)[:cnt]
        return res

    # -- contained spans: grouped per owner, answered without collectives -
    def _run_seg_local(self, index, ls, rs, owner, op,
                       out_dtype) -> np.ndarray:
        cap = index.segment_capacity
        s = index.num_segments
        # stable sort by owner -> contiguous per-segment runs; row_pos is
        # each query's slot inside its segment's row
        order = np.argsort(owner, kind="stable")
        so = owner[order]
        counts = np.bincount(so, minlength=s)
        starts = np.cumsum(counts) - counts
        row_pos = np.arange(so.shape[0]) - starts[so]
        lloc = ls[order] - so.astype(np.int32) * cap
        rloc = rs[order] - so.astype(np.int32) * cap
        picked = np.empty((so.shape[0],), out_dtype)
        # row width is bounded at max_bucket (same discipline as the
        # planner's buckets): a skewed batch runs in several rounds of
        # already-compiled shapes instead of tracing one giant one
        for lo in range(0, int(counts.max()), self.max_bucket):
            sel = (row_pos >= lo) & (row_pos < lo + self.max_bucket)
            rp = row_pos[sel] - lo
            k = max(_next_pow2(int(rp.max()) + 1), self.min_bucket)
            gl = np.zeros((s, k), np.int32)
            gr = np.zeros((s, k), np.int32)
            gl[so[sel], rp] = lloc[sel]
            gr[so[sel], rp] = rloc[sel]
            vals, poss = index._query_grouped(
                gl, gr, track_pos=(op == INDEX)
            )
            picked[sel] = np.asarray(
                poss if op == INDEX else vals
            )[so[sel], rp].astype(out_dtype, copy=False)
        res = np.empty((ls.shape[0],), out_dtype)
        res[order] = picked
        return res

    def stats(self) -> dict:
        return {
            "calls": self.calls,
            "queries": self.queries,
            "class_counts": dict(self.class_counts),
        }

    def invalidate(self) -> None:
        """No per-index state (the sharded fns are cached by geometry)."""
