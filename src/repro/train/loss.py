"""Next-token cross-entropy with optional z-loss, frontend-prefix aware."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


def next_token_loss(
    logits: jax.Array,          # (B, S_total, V) f32
    tokens: jax.Array,          # (B, S) — the token (non-prefix) part
    prefix_len: int = 0,
    z_loss_coef: float = 1e-4,
) -> jax.Array:
    """Mean NLL of tokens[:, 1:] given positions predicting them.

    With a frontend prefix of length F, logits[:, F + i] predicts
    tokens[:, i + 1].
    """
    s = tokens.shape[1]
    pred = logits[:, prefix_len : prefix_len + s - 1]       # (B, S-1, V)
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(pred, axis=-1)
    gold = jnp.take_along_axis(pred, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    if z_loss_coef:
        nll = nll + z_loss_coef * jnp.square(logz).mean()
    return nll


def chunked_next_token_loss(
    cfg,
    params,
    hidden: jax.Array,          # (B, S_total, D) final-normed states
    tokens: jax.Array,          # (B, S)
    prefix_len: int = 0,
    chunk: int = 512,
    z_loss_coef: float = 1e-4,
    sharder=None,
) -> jax.Array:
    """Cross-entropy computed per sequence chunk: the (B, S, V) f32 logits
    never materialize (0.5 GiB live instead of 8.4 GiB at command-r scale,
    fwd+bwd — §Perf H2 iter 8).  jax.checkpoint on the chunk body makes
    the backward recompute chunk logits instead of saving them."""
    from jax.ad_checkpoint import checkpoint_name

    from repro.models import layers as L

    s = tokens.shape[1]
    head_w = (
        params["embed"]["w"].T
        if cfg.tie_embeddings
        else params["lm_head"]["w"]
    )
    head_w = L.cast(head_w, cfg)
    if sharder is not None:
        # materialize the gathered head ONCE before the chunk loop: SPMD
        # otherwise re-gathers the (D, V) matrix at every chunk's use site
        # in fwd and bwd (8 x 2 x 6.3 GiB at command-r scale, §Perf H2)
        head_w = sharder(head_w, "loss_head_w")
    head_w = checkpoint_name(head_w, "loss_head_w")
    pred_h = hidden[:, prefix_len : prefix_len + s - 1]
    targets = tokens[:, 1:]
    n = pred_h.shape[1]
    pad = (-n) % chunk
    if pad:
        pred_h = jnp.pad(pred_h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    nc = pred_h.shape[1] // chunk
    hc = pred_h.reshape(pred_h.shape[0], nc, chunk, -1).transpose(1, 0, 2, 3)
    tc_ = targets.reshape(targets.shape[0], nc, chunk).transpose(1, 0, 2)
    valid = (
        jnp.arange(nc * chunk).reshape(nc, chunk) < n
    ).astype(jnp.float32)                                  # (nc, chunk)

    @functools.partial(
        jax.checkpoint,
        policy=jax.checkpoint_policies.save_only_these_names("loss_head_w"),
    )
    def one(args):
        h_i, t_i, v_i = args
        logits = (h_i @ head_w).astype(jnp.float32)        # (B, chunk, V)
        if cfg.logit_softcap:
            logits = cfg.logit_softcap * jnp.tanh(
                logits / cfg.logit_softcap)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t_i[..., None], axis=-1)[..., 0]
        nll = ((logz - gold) * v_i[None]).sum()
        zl = (jnp.square(logz) * v_i[None]).sum()
        return nll, zl

    # Python loop (not lax.map): the chunk count is small (S/chunk <= 8-64)
    # and an unrolled loop keeps XLA cost analysis exact — a while-loop
    # body would be FLOP-counted once (same pitfall as the layer scan,
    # launch/cells.py calibration docstring).
    nll = jnp.float32(0.0)
    zl = jnp.float32(0.0)
    for i in range(nc):
        a, b = one((hc[i], tc_[i], valid[i]))
        nll += a
        zl += b
    denom = hidden.shape[0] * n
    return nll / denom + z_loss_coef * zl / denom
