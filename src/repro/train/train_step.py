"""Train-step builder: remat, microbatched grad accumulation, AdamW.

The returned function is pure (state, batch) -> (state, metrics) and is
jit/pjit'd by the caller (``launch/train.py`` supplies shardings; smoke
tests call it on CPU directly).

Distributed-optimization knobs (DESIGN.md §5):
* ``remat_policy``  — none | minimal (matmul outputs saveable) | full
* ``microbatches``  — grad accumulation via lax.scan; gradients are
  accumulated in ``grad_allreduce_dtype`` (bf16 by default), so the
  cross-data-shard reduction XLA inserts runs on compressed gradients
  while the AdamW update stays f32 (error is bounded by the accumulator
  width, not the update width).
* compute/comm overlap — with microbatches > 1 XLA can overlap each
  microbatch's gradient reduce-scatter with the next microbatch's
  backward pass; the §Perf log verifies collective placement in the HLO.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models.lm import forward
from repro.train.loss import chunked_next_token_loss, next_token_loss
from repro.train.optimizer import AdamWState, adamw_init, adamw_update


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState
    step: jax.Array


def init_train_state(cfg: ModelConfig, tc: TrainConfig,
                     key: jax.Array) -> TrainState:
    from repro.models.lm import init_params

    params = init_params(cfg, key)
    return TrainState(
        params=params,
        opt=adamw_init(params, tc.optimizer_state_dtype),
        step=jnp.zeros((), jnp.int32),
    )


def make_remat(policy: str) -> Optional[Callable]:
    if policy == "none":
        return None
    if policy == "minimal":
        return functools.partial(
            jax.checkpoint,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=False,
        )
    if policy == "full":
        return functools.partial(
            jax.checkpoint,
            policy=jax.checkpoint_policies.nothing_saveable,
            prevent_cse=False,
        )
    if policy == "names":
        # save exactly the per-block attention/FFN/SSM outputs tagged with
        # checkpoint_name in models/lm.py — recompute everything else.
        # Sits between "full" (recompute-everything: 2x fwd HBM traffic)
        # and "minimal" (saves every contraction: OOM at 100B scale).
        return functools.partial(
            jax.checkpoint,
            policy=jax.checkpoint_policies.save_only_these_names(
                "blk_attn", "blk_ffn", "blk_ssm"
            ),
            prevent_cse=False,
        )
    raise ValueError(f"unknown remat policy {policy!r}")


def build_train_step(
    cfg: ModelConfig,
    tc: TrainConfig,
    sharder=None,
    attn_impl: str = "auto",
    unroll: bool = False,
    grad_shardings=None,
):
    """Returns train_step(state, batch) -> (state, metrics).

    ``batch``: {"tokens": (B, S) int32, optional "prefix": (B, F, D)}.
    """
    remat = make_remat(tc.remat_policy)
    prefix_len = cfg.frontend_tokens if cfg.frontend else 0
    shard = sharder if sharder is not None else (lambda x, n: x)
    acc_dtype = jnp.dtype(tc.grad_allreduce_dtype)

    compute_dtype = jnp.dtype(cfg.dtype)

    def cast_params(params):
        # pre-cast 2-D+ weights to the compute dtype ONCE, pinned to their
        # (ZeRO) shardings: the per-use FSDP all-gathers then move bf16,
        # not f32 — this halved weight-gather bytes on command-r (§Perf H2
        # iter 9).  1-D params (norm scales, biases) stay f32.
        def one(p, sh):
            if p.ndim < 2 or p.dtype != jnp.float32:
                return p
            pc = p.astype(compute_dtype)
            if sh is not None:
                pc = jax.lax.with_sharding_constraint(pc, sh)
            return pc
        if grad_shardings is None:
            return jax.tree.map(lambda p: one(p, None), params)
        return jax.tree.map(one, params, grad_shardings)

    def loss_fn(params, tokens, prefix):
        params = cast_params(params)
        out, aux = forward(
            cfg, params, tokens,
            prefix_embeddings=prefix,
            sharder=shard,
            remat=remat,
            attn_impl=attn_impl,
            unroll=unroll,
            return_hidden=tc.loss_chunk > 0,
        )
        if tc.loss_chunk > 0:
            loss = chunked_next_token_loss(
                cfg, params, out, tokens,
                prefix_len=prefix_len, chunk=tc.loss_chunk,
                sharder=shard if sharder is not None else None,
            )
        else:
            loss = next_token_loss(out, tokens, prefix_len=prefix_len)
        return loss + aux, (loss, aux)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single_micro(params, tokens, prefix):
        (total, (loss, aux)), grads = grad_fn(params, tokens, prefix)
        return grads, loss, aux

    def constrain_grads(grads):
        # pin gradient shardings to the (ZeRO) param shardings and cast to
        # the compressed reduction dtype: XLA then emits bf16
        # reduce-scatters instead of replicated f32 all-reduces (918 GiB ->
        # 208 GiB per step on command-r-plus; §Perf H2 iter 6)
        grads = jax.tree.map(lambda g: g.astype(acc_dtype), grads)
        if grad_shardings is not None:
            grads = jax.tree.map(
                lambda g, sh: jax.lax.with_sharding_constraint(g, sh),
                grads, grad_shardings,
            )
        return grads

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        tokens = batch["tokens"]
        prefix = batch.get("prefix")
        k = tc.microbatches
        if k == 1:
            grads, loss, aux = single_micro(state.params, tokens, prefix)
            grads = constrain_grads(grads)
        else:
            b = tokens.shape[0]
            assert b % k == 0, (b, k)
            mb_tokens = tokens.reshape(k, b // k, *tokens.shape[1:])
            mb_prefix = (
                prefix.reshape(k, b // k, *prefix.shape[1:])
                if prefix is not None
                else None
            )

            def acc_body(carry, idx):
                acc, loss_acc, aux_acc = carry
                t = mb_tokens[idx]
                p = mb_prefix[idx] if mb_prefix is not None else None
                g, loss, aux = single_micro(state.params, t, p)
                g = constrain_grads(g)
                acc = jax.tree.map(lambda a, gg: a + gg, acc, g)
                return (acc, loss_acc + loss, aux_acc + aux), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), state.params
            )
            (grads, loss, aux), _ = jax.lax.scan(
                acc_body,
                (zeros, jnp.float32(0.0), jnp.float32(0.0)),
                jnp.arange(k),
            )
            grads = jax.tree.map(lambda g: (g / k), grads)
            loss = loss / k
            aux = aux / k

        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, tc
        )
        metrics = {
            "loss": loss,
            "aux_loss": aux,
            "step": state.step + 1,
            **opt_metrics,
        }
        return (
            TrainState(params=new_params, opt=new_opt, step=state.step + 1),
            metrics,
        )

    return train_step
