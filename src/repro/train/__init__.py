from repro.train.optimizer import (
    AdamWState,
    adamw_init,
    adamw_update,
    cosine_schedule,
    global_norm,
)
from repro.train.train_step import TrainState, build_train_step, init_train_state

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "global_norm",
    "TrainState",
    "build_train_step",
    "init_train_state",
]
