"""AdamW with ZeRO-friendly sharding and dtype-configurable state.

No optax dependency (offline build).  Features used by the framework:

* ``state_dtype="bfloat16"`` stores m/v in bf16 — halves optimizer HBM,
  required to fit llama4-400b on a single v5e pod (EXPERIMENTS.md
  §Dry-run); master params stay f32.
* optimizer state inherits the parameters' shardings (ZeRO-3 profile):
  the train-step builder simply puts the same PartitionSpec on m/v as on
  the corresponding param.
* global-norm clipping and a cosine-with-warmup schedule, both pure jnp.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    m: Any
    v: Any
    count: jax.Array


def adamw_init(params, state_dtype: str = "float32") -> AdamWState:
    dt = jnp.dtype(state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def cosine_schedule(tc: TrainConfig):
    def lr_at(step):
        step = step.astype(jnp.float32)
        warm = tc.learning_rate * step / max(tc.warmup_steps, 1)
        prog = jnp.clip(
            (step - tc.warmup_steps)
            / max(tc.total_steps - tc.warmup_steps, 1),
            0.0, 1.0,
        )
        cos = 0.1 * tc.learning_rate + 0.9 * tc.learning_rate * 0.5 * (
            1.0 + jnp.cos(jnp.pi * prog)
        )
        return jnp.where(step < tc.warmup_steps, warm, cos)

    return lr_at


def adamw_update(
    grads,
    state: AdamWState,
    params,
    tc: TrainConfig,
) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    count = state.count + 1
    lr = cosine_schedule(tc)(count)

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, tc.grad_clip / (gnorm + 1e-9))
    b1, b2 = tc.beta1, tc.beta2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        update = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + tc.eps)
        # decoupled weight decay (skip 1-D params: norms, biases)
        wd = tc.weight_decay if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (update + wd * p.astype(
            jnp.float32))
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(m=new_m, v=new_v, count=count), metrics
