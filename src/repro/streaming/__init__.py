"""Streaming RMQ: incremental hierarchy maintenance for online arrays.

    from repro.streaming import StreamingRMQ

    s = StreamingRMQ.from_array(x, c=128, t=64, capacity=2 * len(x),
                                with_positions=True)
    s = s.update(idxs, vals)     # batched point updates, O(B log_c n)
    s = s.append(new_tail)       # grow into reserved capacity
    s = s.retire(1024)           # slide the window (ring workloads)
    pos = s.query_index(ls, rs)  # same query surface as repro.core.RMQ
"""

from repro.streaming.structure import StreamingRMQ
from repro.streaming.updates import append_hierarchy, update_hierarchy

__all__ = ["StreamingRMQ", "update_hierarchy", "append_hierarchy"]
