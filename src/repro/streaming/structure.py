"""``StreamingRMQ`` — a minima hierarchy that tracks a mutating array.

Wraps :class:`repro.core.hierarchy.Hierarchy` with three online
operations, each maintained in O(batch · log_c capacity) chunk
re-reductions instead of a rebuild:

* :meth:`update` — batched point updates (duplicate indices: last wins);
* :meth:`append` — extend the live region into pre-reserved,
  ``+inf``-padded capacity (``make_plan(..., capacity=...)`` keeps the
  level geometry static under jit across appends);
* :meth:`retire` — slide the window start forward for ring-buffer
  workloads by writing ``+inf`` over the oldest entries, so they can never
  win a query again.

The structure is pure-functional: every mutator returns a new
``StreamingRMQ`` sharing unmodified buffers.  ``backend="pallas"`` routes
chunk re-reductions through ``repro.kernels.hierarchy_update``; both
backends are bit-identical to a fresh build of the mutated array.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.api import _default_backend
from repro.core.hierarchy import Hierarchy, build_hierarchy
from repro.core.plan import HierarchyPlan, make_plan
from repro.core.query import (
    _debug_checks_enabled,
    check_query_args,
    rmq_index_batch,
    rmq_value_batch,
)
from repro.streaming import updates as U

__all__ = [
    "StreamingRMQ",
    "validate_update_batch",
    "dispatch_update",
    "dispatch_append",
]


def validate_update_batch(idxs, vals, n: Optional[int] = None):
    """Shared idxs/vals checking for ``update`` entry points.

    Out-of-range indices are dropped silently in normal operation (a
    jit-friendly contract); under ``REPRO_RMQ_DEBUG=1`` concrete batches
    are value-checked against the live length ``n`` so indexing bugs
    fail loudly instead of as stale minima — mirroring query validation.
    """
    idxs = jnp.asarray(idxs)
    vals = jnp.asarray(vals)
    if idxs.ndim != 1 or idxs.shape != vals.shape:
        raise ValueError(
            f"idxs/vals must be matching 1-D batches, got "
            f"{idxs.shape} vs {vals.shape}"
        )
    if not jnp.issubdtype(idxs.dtype, jnp.integer):
        raise TypeError(f"idxs must be integers, got {idxs.dtype}")
    if (
        n is not None
        and _debug_checks_enabled()
        and not isinstance(idxs, jax.core.Tracer)
    ):
        import numpy as np

        i_np = np.asarray(idxs)
        bad = (i_np < 0) | (i_np >= n)
        if bad.any():
            j = int(np.argmax(bad))
            raise ValueError(
                f"update index {j} = {i_np.flat[j]} out of range for "
                f"live length {n}"
            )
    return idxs, vals


def dispatch_update(h: Hierarchy, idxs, vals, backend: str) -> Hierarchy:
    """Backend dispatch for batched point updates (used by RMQ too)."""
    if backend == "pallas":
        from repro.kernels.hierarchy_update import ops as upd_ops

        return upd_ops.update_hierarchy_pallas(h, idxs, vals)
    return U.update_hierarchy(h, idxs, vals)


def dispatch_append(h: Hierarchy, vals, start, backend: str) -> Hierarchy:
    """Backend dispatch for appends at live offset ``start``."""
    if backend == "pallas":
        from repro.kernels.hierarchy_update import ops as upd_ops

        return upd_ops.append_hierarchy_pallas(h, vals, start)
    return U.append_hierarchy(h, vals, start)


@dataclasses.dataclass(frozen=True)
class StreamingRMQ:
    """A range-minimum index over an online array (paper §4 + streaming).

    ``length`` / ``start`` delimit the live window ``[start, length)`` and
    live host-side, outside the jitted plan — growing them never triggers
    retracing.
    """

    hierarchy: Hierarchy
    backend: str
    length: int
    start: int = 0
    # Monotonic mutation counter (host-side, never traced): bumped by
    # update/append/retire so the query engine's result cache can key
    # entries to the array version they were computed against.
    generation: int = 0

    # -- construction -----------------------------------------------------
    @staticmethod
    def from_array(
        x,
        c: int = 128,
        t: int = 64,
        capacity: Optional[int] = None,
        with_positions: bool = False,
        backend: str = "auto",
        plan: Optional[HierarchyPlan] = None,
    ) -> "StreamingRMQ":
        """Build over ``x``, reserving ``capacity`` slots for appends."""
        x = jnp.asarray(x)
        if x.dtype not in (jnp.float32, jnp.bfloat16, jnp.float64):
            x = x.astype(jnp.float32)
        n = int(x.shape[0])
        if plan is not None and capacity is not None:
            raise ValueError(
                "pass capacity via make_plan(..., capacity=...) when "
                "supplying an explicit plan"
            )
        if plan is None:
            plan = make_plan(n, c=c, t=t, capacity=capacity)
        if backend == "auto":
            backend = _default_backend()
        if backend == "pallas":
            from repro.kernels.hierarchy_build import ops as build_ops

            h = build_ops.build_hierarchy_pallas(
                x, plan, with_positions=with_positions
            )
        elif backend == "jax":
            h = build_hierarchy(x, plan, with_positions=with_positions)
        else:
            raise ValueError(f"unknown backend {backend!r}")
        return StreamingRMQ(hierarchy=h, backend=backend, length=n)

    # -- mutation ---------------------------------------------------------
    def update(self, idxs, vals) -> "StreamingRMQ":
        """Batched point updates ``a[idxs] = vals`` (last wins on dups)."""
        idxs, vals = validate_update_batch(idxs, vals, n=self.length)
        if idxs.shape[0] == 0:
            return self
        return dataclasses.replace(
            self,
            hierarchy=dispatch_update(
                self.hierarchy, idxs, vals, self.backend
            ),
            generation=self.generation + 1,
        )

    def append(self, vals) -> "StreamingRMQ":
        """Extend the array with ``vals``; fails when capacity is spent."""
        vals = jnp.asarray(vals)
        if vals.ndim != 1:
            raise ValueError(f"vals must be 1-D, got shape {vals.shape}")
        b = int(vals.shape[0])
        if b == 0:
            return self
        if self.length + b > self.capacity:
            raise ValueError(
                f"append of {b} overflows capacity {self.capacity} "
                f"(live length {self.length}); build with a larger "
                "make_plan(..., capacity=...) reservation"
            )
        h = dispatch_append(
            self.hierarchy, vals, jnp.int32(self.length), self.backend
        )
        return dataclasses.replace(
            self,
            hierarchy=h,
            length=self.length + b,
            generation=self.generation + 1,
        )

    def retire(self, count: int) -> "StreamingRMQ":
        """Slide the window: drop the ``count`` oldest live entries.

        Retired slots are overwritten with ``+inf`` (one batched update),
        so queries that straddle them still answer correctly for the live
        window.  Capacity is not reclaimed — provision ``capacity`` for
        the stream length, or rebuild with ``from_array`` when exhausted.
        """
        count = min(int(count), self.length - self.start)
        if count <= 0:
            return self
        idxs = self.start + jnp.arange(count, dtype=jnp.int32)
        vals = jnp.full((count,), jnp.inf, self.hierarchy.base.dtype)
        return dataclasses.replace(
            self,
            hierarchy=dispatch_update(
                self.hierarchy, idxs, vals, self.backend
            ),
            start=self.start + count,
            generation=self.generation + 1,
        )

    # -- queries ----------------------------------------------------------
    def query(self, ls, rs) -> jax.Array:
        """Batched ``RMQ_value`` over inclusive ranges in the live window."""
        ls, rs = check_query_args(ls, rs, self.length)
        if self.backend == "pallas":
            from repro.kernels.rmq_scan import ops as scan_ops

            return scan_ops.rmq_value_batch_pallas(self.hierarchy, ls, rs)
        return rmq_value_batch(self.hierarchy, ls, rs)

    def query_index(self, ls, rs) -> jax.Array:
        """Batched ``RMQ_index`` (leftmost minimum) over inclusive ranges."""
        ls, rs = check_query_args(ls, rs, self.length)
        if self.backend == "pallas":
            from repro.kernels.rmq_scan import ops as scan_ops

            return scan_ops.rmq_index_batch_pallas(self.hierarchy, ls, rs)
        return rmq_index_batch(self.hierarchy, ls, rs)

    # -- adaptive batched engine -------------------------------------------
    def engine(self, **kwargs):
        """A span-routed :class:`repro.qe.QueryEngine` over this index.

        Re-attach (``engine.attach``) after any mutation — update/append/
        retire return successor indices with a bumped ``generation``.
        """
        from repro.qe import QueryEngine

        return QueryEngine.for_index(self, **kwargs)

    # -- introspection ----------------------------------------------------
    @property
    def plan(self) -> HierarchyPlan:
        return self.hierarchy.plan

    @property
    def capacity(self) -> int:
        return self.plan.capacity

    @property
    def with_positions(self) -> bool:
        return self.hierarchy.with_positions

    def memory_bytes(self) -> int:
        return self.hierarchy.memory_bytes()
