"""``StreamingRMQ`` — a minima hierarchy that tracks a mutating array.

Wraps :class:`repro.core.hierarchy.Hierarchy` with three online
operations, each maintained in O(batch · log_c capacity) chunk
re-reductions instead of a rebuild:

* :meth:`update` — batched point updates (duplicate indices: last wins);
* :meth:`append` — extend the live region into pre-reserved,
  ``+inf``-padded capacity (``make_plan(..., capacity=)`` keeps the
  level geometry static under jit across appends);
* :meth:`retire` — slide the window start forward for ring-buffer
  workloads by writing ``+inf`` over the oldest entries, so they can never
  win a query again.

The structure is pure-functional: every mutator returns a new
``StreamingRMQ`` sharing unmodified buffers.  ``backend="pallas"`` routes
chunk re-reductions through ``repro.kernels.hierarchy_update``;
``backend="fused"`` builds the initial hierarchy in one kernel launch
(``repro.kernels.hierarchy_fused``), answers query batches in one launch
(``repro.kernels.rmq_fused``), and mutates through the platform
default.  Every backend is bit-identical to a fresh build of the mutated
array.

Implements :class:`repro.core.protocol.MutableRMQIndex`; the shared
validation/dispatch plumbing lives in :mod:`repro.core.protocol` (the
names below are re-exported for back-compat).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import protocol as px
from repro.core.hierarchy import Hierarchy
from repro.core.plan import HierarchyPlan, make_plan
from repro.core.protocol import (  # noqa: F401  (re-exported for back-compat)
    dispatch_append,
    dispatch_update,
    validate_update_batch,
)
from repro.core.query import check_query_args

__all__ = [
    "StreamingRMQ",
    "validate_update_batch",
    "dispatch_update",
    "dispatch_append",
]


@dataclasses.dataclass(frozen=True)
class StreamingRMQ:
    """A range-minimum index over an online array (paper §4 + streaming).

    ``length`` / ``start`` delimit the live window ``[start, length)`` and
    live host-side, outside the jitted plan — growing them never triggers
    retracing.
    """

    hierarchy: Hierarchy
    backend: str
    length: int
    start: int = 0
    # Monotonic mutation counter (host-side, never traced): bumped by
    # update/append/retire so the query engine's result cache can key
    # entries to the array version they were computed against.
    generation: int = 0

    # -- construction -----------------------------------------------------
    @staticmethod
    def from_array(
        x,
        c: int = 128,
        t: int = 64,
        capacity: Optional[int] = None,
        with_positions: bool = False,
        backend: str = "auto",
        plan: Optional[HierarchyPlan] = None,
        packed_pos: Optional[bool] = None,
        summary_dtype: Optional[str] = None,
    ) -> "StreamingRMQ":
        """Build over ``x``, reserving ``capacity`` slots for appends.

        Construction goes through the shared pipeline
        (``protocol.build_hierarchy_with_backend``): ``backend='fused'``
        builds the whole hierarchy in one kernel launch.

        ``packed_pos`` / ``summary_dtype`` select the compact plane
        layouts (see ``make_plan``); incremental updates/appends/retires
        maintain both bit-identically to a fresh build.
        """
        x = px.coerce_values(x)
        n = int(x.shape[0])
        if plan is not None and capacity is not None:
            raise ValueError(
                "pass capacity via make_plan(..., capacity=...) when "
                "supplying an explicit plan"
            )
        if plan is None:
            plan = make_plan(
                n, c=c, t=t, capacity=capacity,
                packed_pos=packed_pos, summary_dtype=summary_dtype,
            )
        backend = px.resolve_backend(backend)
        h = px.build_hierarchy_with_backend(
            x, plan, with_positions=with_positions, backend=backend
        )
        return StreamingRMQ(hierarchy=h, backend=backend, length=n)

    # -- mutation ---------------------------------------------------------
    def update(self, idxs, vals) -> "StreamingRMQ":
        """Batched point updates ``a[idxs] = vals`` (last wins on dups)."""
        idxs, vals = px.validate_update_batch(idxs, vals, n=self.length)
        if idxs.shape[0] == 0:
            return self
        return dataclasses.replace(
            self,
            hierarchy=px.dispatch_update(
                self.hierarchy, idxs, vals, self.backend
            ),
            generation=self.generation + 1,
        )

    def append(self, vals) -> "StreamingRMQ":
        """Extend the array with ``vals``; fails when capacity is spent."""
        vals = px.validate_append_batch(
            vals, length=self.length, capacity=self.capacity
        )
        b = int(vals.shape[0])
        if b == 0:
            return self
        h = px.dispatch_append(
            self.hierarchy, vals, jnp.int32(self.length), self.backend
        )
        return dataclasses.replace(
            self,
            hierarchy=h,
            length=self.length + b,
            generation=self.generation + 1,
        )

    def retire(self, count: int) -> "StreamingRMQ":
        """Slide the window: drop the ``count`` oldest live entries.

        Retired slots are overwritten with ``+inf`` (one batched update),
        so queries that straddle them still answer correctly for the live
        window.  Capacity is not reclaimed — provision ``capacity`` for
        the stream length, or rebuild with ``from_array`` when exhausted.
        """
        count = min(int(count), self.length - self.start)
        if count <= 0:
            return self
        idxs = self.start + jnp.arange(count, dtype=jnp.int32)
        vals = jnp.full((count,), jnp.inf, self.hierarchy.base.dtype)
        return dataclasses.replace(
            self,
            hierarchy=px.dispatch_update(
                self.hierarchy, idxs, vals, self.backend
            ),
            start=self.start + count,
            generation=self.generation + 1,
        )

    # -- queries ----------------------------------------------------------
    def query(self, ls, rs) -> jax.Array:
        """Batched ``RMQ_value`` over inclusive ranges in the live window."""
        ls, rs = check_query_args(ls, rs, self.length)
        return px.dispatch_query_value(self.hierarchy, ls, rs, self.backend)

    def query_index(self, ls, rs) -> jax.Array:
        """Batched ``RMQ_index`` (leftmost minimum) over inclusive ranges."""
        ls, rs = check_query_args(ls, rs, self.length)
        return px.dispatch_query_index(self.hierarchy, ls, rs, self.backend)

    # protocol spellings (RMQIndex): same entry points, canonical names
    query_value_batch = query
    query_index_batch = query_index

    # -- adaptive batched engine -------------------------------------------
    def engine(self, **kwargs):
        """A span-routed :class:`repro.qe.QueryEngine` over this index.

        Re-attach (``engine.attach``) after any mutation — update/append/
        retire return successor indices with a bumped ``generation``.
        """
        return px.make_engine(self, **kwargs)

    # -- introspection ----------------------------------------------------
    @property
    def plan(self) -> HierarchyPlan:
        return self.hierarchy.plan

    @property
    def capacity(self) -> int:
        return self.plan.capacity

    @property
    def with_positions(self) -> bool:
        return self.hierarchy.with_positions

    @property
    def value_dtype(self):
        return self.hierarchy.base.dtype

    def memory_bytes(self) -> int:
        return self.hierarchy.memory_bytes()
