"""Incremental maintenance of the minima hierarchy — pure-JAX reference.

A point update at index ``i`` invalidates exactly one ``c``-wide chunk per
upper level: chunk ``i // c**k`` at level ``k``.  A batch of ``B`` updates
therefore needs at most ``min(B, m_k)`` chunk re-reductions at level ``k``
— O(B log_c n) work against the O(n/c) full rebuild, which is the whole
point of streaming support: the paper's construction is a few chunked
reductions, and an update replays only the chunks on the touched
root-to-leaf paths.

Algorithm per batch:

1. scatter the new values into level 0 with deterministic last-wins
   semantics for duplicate indices (a scatter-max of the batch order
   decides the winner; losers are dropped);
2. for each upper level, dedupe the touched chunk ids (``jnp.unique`` with
   a static size bound so the whole batch stays jit-compatible), gather
   each chunk's ``c`` source entries from the level below, min/argmin
   re-reduce, and scatter the summaries back into the contiguous ``upper``
   buffer;
3. divide the chunk ids by ``c`` and ascend.

Results are bit-identical to a fresh ``build_hierarchy`` of the mutated
array (values and leftmost-tie positions) — the streaming property tests
assert exactly that.  The Pallas realization of step 2 lives in
``repro.kernels.hierarchy_update`` and is validated against this module.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bitpack
from repro.core.constants import PAD_POS as _PAD_POS
from repro.core.hierarchy import Hierarchy, pos_dtype_for
from repro.core.plan import HierarchyPlan

__all__ = ["update_hierarchy", "append_hierarchy", "index_dtype_for"]


def index_dtype_for(capacity: int) -> jnp.dtype:
    """Dtype able to address every element index below ``capacity``.

    Delegates to the canonical :func:`repro.core.hierarchy.pos_dtype_for`
    in its non-strict mode: int64 only helps when x64 is enabled; without
    it an int64 request would silently downcast, so stay on int32
    (indices >= 2**31 cannot be represented by the caller in that mode
    anyway).
    """
    return pos_dtype_for(capacity, strict=False)


def scatter_base(
    base: jax.Array, idxs: jax.Array, vals: jax.Array
) -> jax.Array:
    """Scatter ``vals`` into ``base`` with last-wins duplicate semantics.

    XLA scatter leaves the winner among duplicate indices unspecified; we
    make it deterministic (the *latest* batch entry wins, matching
    sequential application) by scatter-maxing the batch order and dropping
    every non-winner out of range.  Indices outside ``[0, len(base))``
    are dropped entirely — including negative ones, which ``.at[]`` would
    otherwise wrap NumPy-style.
    """
    cap = base.shape[0]
    b = idxs.shape[0]
    order = jnp.arange(b, dtype=jnp.int32)
    valid = (idxs >= 0) & (idxs < cap)
    target = jnp.where(valid, idxs, cap)  # cap is dropped by mode="drop"
    stamp = jnp.full((cap,), -1, jnp.int32).at[target].max(
        order, mode="drop"
    )
    win = valid & (stamp[jnp.where(valid, idxs, 0)] == order)
    safe = jnp.where(win, idxs, cap)
    return base.at[safe].set(vals.astype(base.dtype), mode="drop")


def _level_sources(
    plan: HierarchyPlan,
    base: jax.Array,
    upper: jax.Array,
    upper_pos: Optional[jax.Array],
    level: int,
    ids: jax.Array,
):
    """Gather the ``(B, c)`` source windows feeding chunks ``ids`` of an
    upper ``level`` — values and (if tracked) original-array positions."""
    c = plan.c
    cap = plan.capacity
    track = upper_pos is not None
    gather = ids[:, None] * c + jnp.arange(c, dtype=ids.dtype)[None, :]
    if level == 1:
        # Level 0 may not be c-aligned: out-of-range reads become +inf
        # (value) / _PAD_POS (position), the build's padding convention.
        v = jnp.take(base, gather, mode="fill", fill_value=float("inf"))
        p = None
        if track:
            pos_dtype = pos_dtype_for(cap)
            p = jnp.where(gather < cap, gather, _PAD_POS).astype(pos_dtype)
    else:
        off, _padded = plan.level_slice(level - 1)
        # Upper levels are stored padded to a multiple of c, so the gather
        # stays in range by construction.
        v = jnp.take(upper, off + gather)
        p = jnp.take(upper_pos, off + gather) if track else None
    return v, p


def _reduce_windows(v: jax.Array, p: Optional[jax.Array]):
    """Min + leftmost-tie position over each row of ``(B, c)`` windows."""
    am = jnp.argmin(v, axis=1)
    nv = jnp.take_along_axis(v, am[:, None], axis=1)[:, 0]
    np_ = (
        jnp.take_along_axis(p, am[:, None], axis=1)[:, 0]
        if p is not None
        else None
    )
    return nv, np_


def touched_chunk_ids(
    ids: jax.Array, num_chunks: int
) -> jax.Array:
    """Dedupe touched chunk ids with a static output size.

    ``jnp.unique`` pads with ``fill_value=0``: chunk 0 may be re-reduced
    redundantly, which is idempotent (same inputs, same summary), so
    correctness is unaffected while shapes stay static under jit.

    Dense fast path: a batch at least as large as the level covers every
    chunk id it could touch, so re-reducing all chunks (a superset,
    idempotent) replaces the O(B log B) sort inside ``unique`` — this is
    the shape the serve engine's full-score sync hits every round.
    """
    if ids.shape[0] >= num_chunks:
        return jnp.arange(num_chunks, dtype=ids.dtype)
    return jnp.unique(ids, size=ids.shape[0], fill_value=0)


def _exact_recompare(v: jax.Array, p_abs: jax.Array, live: jax.Array,
                     base: jax.Array):
    """Row-wise winner over quantized ``(B, c)`` windows, decided exactly.

    ``v`` holds bf16 summaries, so its row argmin can pick the wrong
    leftmost entry.  Every *live* lane tied at the quantized row min is
    re-read exactly from level 0 through its absolute position; the exact
    values (with position as tie-break, and lanes ascend in position)
    pick the true leftmost minimum.  Returns ``(row_min_quantized,
    winner_position, winner_lane)``.
    """
    inf_q = jnp.array(jnp.inf, dtype=v.dtype)
    mq = jnp.min(jnp.where(live, v, inf_q), axis=1, keepdims=True)
    tied = live & (v == mq)
    safe = jnp.clip(p_abs, 0, base.shape[0] - 1)
    ex = jnp.where(tied, base[safe], jnp.array(jnp.inf, dtype=base.dtype))
    m = jnp.min(ex, axis=1, keepdims=True)
    win = tied & (ex == m)
    am = jnp.argmax(win, axis=1).astype(jnp.int32)  # leftmost winner
    nv = mq[:, 0]
    np_ = jnp.take_along_axis(p_abs, am[:, None].astype(p_abs.dtype),
                              axis=1)[:, 0]
    return nv, np_, am


def propagate_updates(
    plan: HierarchyPlan,
    base: jax.Array,
    upper: jax.Array,
    upper_pos: Optional[jax.Array],
    idxs: jax.Array,
) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Re-reduce every chunk on the root-to-leaf paths of ``idxs``.

    ``base`` must already hold the new level-0 values.  Handles all four
    plane layouts: classic (absolute positions, exact values), packed
    positions (the per-level argmin *is* the chunk-local offset, written
    back with a wrapping-delta field scatter), bf16 summaries (winners at
    levels >= 2 are re-decided exactly against level 0 — see
    :func:`_exact_recompare`), and both at once.
    """
    c = plan.c
    idxs = idxs.astype(index_dtype_for(plan.capacity))
    # Out-of-range indices were dropped by the base scatter; route their
    # chunk ids to chunk 0, whose re-reduction of unchanged data is an
    # idempotent no-op (an unsanitized id would clamp-scatter into a
    # *different* level's region of the contiguous upper buffer).
    idxs = jnp.where((idxs >= 0) & (idxs < plan.capacity), idxs, 0)
    ids = idxs // c
    packed = upper_pos is not None and plan.packed_pos
    quantized = upper.dtype != base.dtype
    if not packed and not quantized:
        for level in range(1, plan.num_levels):
            ids = touched_chunk_ids(ids, plan.level_lens[level])
            v, p = _level_sources(plan, base, upper, upper_pos, level, ids)
            nv, np_ = _reduce_windows(v, p)
            off = plan.offsets[level - 1]
            # ids are unique (apart from idempotent fill duplicates), so
            # the scatter is conflict-free.
            upper = upper.at[off + ids].set(nv)
            if upper_pos is not None:
                upper_pos = upper_pos.at[off + ids].set(np_)
            ids = ids // c
        return upper, upper_pos

    bits = bitpack.pos_bits(c)
    coord = pos_dtype_for(plan.capacity, strict=False)
    lane_off = jnp.arange(c, dtype=jnp.int32)[None, :]
    for level in range(1, plan.num_levels):
        ids = touched_chunk_ids(ids, plan.level_lens[level])
        off = plan.offsets[level - 1]
        # Fill duplicates from the static-size dedupe are idempotent for
        # plain value/position scatters but NOT for the packed delta
        # scatter — mask every repeat of chunk 0 past lane 0 (a genuine 0
        # sorts first in `jnp.unique`'s output; the dense arange fast
        # path keeps its single 0 at lane 0).
        lanes = jnp.arange(ids.shape[0], dtype=ids.dtype)
        first = (ids != 0) | (lanes == 0)
        gather = ids[:, None] * c + lane_off.astype(ids.dtype)
        if level == 1:
            # Level 0 is exact regardless of summary dtype.
            v = jnp.take(base, gather, mode="fill", fill_value=float("inf"))
            am = jnp.argmin(v, axis=1).astype(jnp.int32)
            nv = jnp.take_along_axis(v, am[:, None], axis=1)[:, 0]
            sel = jnp.take_along_axis(gather, am[:, None].astype(ids.dtype),
                                      axis=1)[:, 0]
            np_ = jnp.where(sel < plan.capacity, sel,
                            _PAD_POS).astype(coord)
        elif not quantized:
            # Packed, exact values: argmin over exact summaries is the
            # new chunk-local offset — no child positions needed.
            v = jnp.take(upper, plan.offsets[level - 2] + gather)
            am = jnp.argmin(v, axis=1).astype(jnp.int32)
            nv = jnp.take_along_axis(v, am[:, None], axis=1)[:, 0]
            np_ = None
        else:
            # bf16 summaries: re-decide the winner exactly.
            poff = plan.offsets[level - 2]
            v = jnp.take(upper, poff + gather)
            live = gather < plan.level_lens[level - 1]
            if packed:
                p_abs = bitpack.gather_absolute(
                    upper_pos, plan, level - 1, gather, coord
                )
            else:
                p_abs = jnp.take(upper_pos, poff + gather)
            nv, np_, am = _exact_recompare(v, p_abs, live, base)
        upper = upper.at[off + ids].set(nv.astype(upper.dtype))
        if packed:
            upper_pos = bitpack.scatter_offsets(
                upper_pos, off + ids, am, bits, live=first
            )
        else:
            upper_pos = upper_pos.at[off + ids].set(np_.astype(coord))
        ids = ids // c
    return upper, upper_pos


@jax.jit
def update_hierarchy(
    h: Hierarchy, idxs: jax.Array, vals: jax.Array
) -> Hierarchy:
    """Apply a batch of point updates ``a[idxs] = vals`` to the hierarchy.

    Duplicate indices resolve last-wins.  Cost: one O(B) scatter plus
    O(min(B, m_k)) chunk re-reductions per upper level.
    """
    idxs = idxs.astype(index_dtype_for(h.plan.capacity))
    base = scatter_base(h.base, idxs, vals)
    upper, upper_pos = propagate_updates(
        h.plan, base, h.upper, h.upper_pos, idxs
    )
    return Hierarchy(base=base, upper=upper, upper_pos=upper_pos,
                     plan=h.plan)


@jax.jit
def append_hierarchy(
    h: Hierarchy, vals: jax.Array, start: jax.Array
) -> Hierarchy:
    """Write ``vals`` at positions ``[start, start + B)`` of level 0 and
    repair the upper levels.

    ``start`` is a traced scalar (the live length), so consecutive appends
    of the same batch shape reuse one jit specialization.  The caller
    guarantees ``start + B <= plan.capacity``.
    """
    idx_dtype = index_dtype_for(h.plan.capacity)
    vals = vals.astype(h.base.dtype)
    start = jnp.asarray(start, idx_dtype)
    base = jax.lax.dynamic_update_slice(h.base, vals, (start,))
    idxs = start + jnp.arange(vals.shape[0], dtype=idx_dtype)
    upper, upper_pos = propagate_updates(
        h.plan, base, h.upper, h.upper_pos, idxs
    )
    return Hierarchy(base=base, upper=upper, upper_pos=upper_pos,
                     plan=h.plan)
