"""Sharded pytree checkpointing with async writes and mesh-elastic restore.

Format: one directory per step containing

* ``manifest.json``  — treedef (path strings), shapes, dtypes, and the
  *logical* PartitionSpec of every leaf (never physical device ids);
* ``<leaf-hash>.npy`` — one file per leaf (host-gathered).

Because only logical shardings are stored, a checkpoint written on a
(2, 16, 16) mesh restores onto any mesh whose axes divide the logical
axes — the elastic re-mesh path (DESIGN.md §5) restores a 512-chip
checkpoint onto 256 chips by re-device_put-ing with the surviving mesh.

Async mode hands the host-side write to a daemon thread; ``wait()``
blocks until all pending writes are durable (the train loop calls it
before declaring a step checkpointed).  Writes go to a temp dir that is
atomically renamed, so a crash mid-write never corrupts the latest
complete checkpoint.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def _leaf_file(path_str: str) -> str:
    return hashlib.sha1(path_str.encode()).hexdigest()[:16] + ".npy"


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[Dict] = None) -> str:
    """Synchronous save. Returns the checkpoint path."""
    ckpt_dir = os.path.join(directory, f"step_{step:08d}")
    tmp_dir = ckpt_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)

    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for path, leaf in leaves:
        ps = _path_str(path)
        arr = np.asarray(jax.device_get(leaf))
        fname = _leaf_file(ps)
        np.save(os.path.join(tmp_dir, fname), arr)
        manifest["leaves"].append(
            {"path": ps, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(ckpt_dir):
        shutil.rmtree(ckpt_dir)
    os.rename(tmp_dir, ckpt_dir)   # atomic publish
    return ckpt_dir


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name,
                                           "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like: Any,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``like``.

    ``shardings``: optional pytree of NamedShardings (same structure) —
    the elastic-restore path device_puts each leaf with the *current*
    mesh's sharding, regardless of the mesh that wrote the checkpoint.
    """
    ckpt_dir = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(ckpt_dir, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(shardings)[0]

    out = []
    for i, (path, leaf) in enumerate(flat):
        ps = _path_str(path)
        if ps not in by_path:
            raise KeyError(f"checkpoint missing leaf {ps}")
        arr = np.load(os.path.join(ckpt_dir, by_path[ps]["file"]))
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(
                f"shape mismatch for {ps}: ckpt {arr.shape} vs "
                f"expected {leaf.shape}"
            )
        arr = arr.astype(leaf.dtype)
        if shard_flat is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Async checkpoint writer with bounded queue + crash-safe publish."""

    def __init__(self, directory: str, keep: int = 3,
                 async_mode: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_mode = async_mode
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._errors: list = []
        self._thread = None
        if async_mode:
            self._thread = threading.Thread(target=self._worker,
                                            daemon=True)
            self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, tree, extra = item
            try:
                save_checkpoint(self.directory, step, tree, extra)
                self._gc()
            except Exception as e:  # surfaced on wait()
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"),
                ignore_errors=True,
            )

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        if self.async_mode:
            # device_get on the main thread (jax arrays are not
            # thread-safe to fetch concurrently with compute dispatch)
            host_tree = jax.tree.map(
                lambda x: np.asarray(jax.device_get(x)), tree
            )
            self._q.put((step, host_tree, extra))
        else:
            save_checkpoint(self.directory, step, tree, extra)
            self._gc()

    def wait(self):
        if self.async_mode:
            self._q.join()
        if self._errors:
            raise self._errors[0]

    def close(self):
        if self.async_mode and self._thread is not None:
            self._q.put(None)
            self._thread.join(timeout=60)
            self._thread = None

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)
