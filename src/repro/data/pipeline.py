"""Deterministic, shardable, restart-safe synthetic token pipeline.

Every batch is a pure function of ``(seed, step, shard_id)`` — no iterator
state exists anywhere, so:

* **restart safety**: after a crash, resuming at step k reproduces exactly
  the batches k, k+1, ... that the lost run would have seen (the
  checkpoint only needs to record the step);
* **sharding**: each data shard draws its disjoint slice of the global
  batch by folding ``shard_id`` into the counter-based RNG (numpy Philox),
  so hosts never communicate for data;
* **elasticity**: re-sharding after a mesh change is just re-partitioning
  the ``global_batch`` range — batches are defined globally, shards only
  select rows.

A real deployment would swap this for a tokenized corpus reader with the
same (step, shard) → batch contract; everything downstream (train loop,
checkpoint/restart, elastic re-mesh) only relies on the contract.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class SyntheticTokenDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_shards: int = 1
    shard_id: int = 0
    prefix_tokens: int = 0       # frontend prefix positions (embeddings)
    d_model: int = 0

    def __post_init__(self):
        assert self.global_batch % self.num_shards == 0
        assert 0 <= self.shard_id < self.num_shards

    @property
    def shard_batch(self) -> int:
        return self.global_batch // self.num_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for ``step`` — pure function of (seed, step, shard_id)."""
        rng = np.random.Generator(
            np.random.Philox(key=self.seed, counter=[0, 0, step,
                                                     self.shard_id])
        )
        tokens = rng.integers(
            0, self.vocab_size,
            (self.shard_batch, self.seq_len), dtype=np.int32,
        )
        out = {"tokens": tokens}
        if self.prefix_tokens:
            out["prefix"] = rng.standard_normal(
                (self.shard_batch, self.prefix_tokens, self.d_model)
            ).astype(np.float32) * 0.02
        return out

    def reshard(self, num_shards: int, shard_id: int
                ) -> "SyntheticTokenDataset":
        """Elastic re-mesh: same global batches, different shard slices."""
        return dataclasses.replace(
            self, num_shards=num_shards, shard_id=shard_id
        )


def make_batch_specs(cfg: ModelConfig, seq_len: int, global_batch: int):
    """ShapeDtypeStructs of a global batch (used by dryrun input_specs)."""
    import jax
    import jax.numpy as jnp

    specs = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
    if cfg.frontend:
        specs["prefix"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    return specs
