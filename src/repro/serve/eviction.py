"""RMQ-backed KV-cache eviction — the paper's data structure as a serving
feature (DESIGN.md §4).

During long-context decode each sequence accumulates per-token importance
scores (attention probability mass, H2O-style).  When the live token count
exceeds the budget, the manager must find the *least-important* tokens —
range-minimum queries over the score array.  This is exactly the paper's
workload shape:

* eviction scans are batched: one RMQ per candidate window per sequence —
  thousands of queries per round at production batch sizes;
* the score array mutates between rounds, which is the streaming case:
  the hierarchy is maintained by **batched incremental updates**
  (``repro.streaming.StreamingRMQ``) instead of being rebuilt — no
  re-planning, no reallocation, and no fresh jit trace per round, where
  the old rebuild path re-specialized on every distinct live length.

Strategy per round: split the evictable region [0, n - protected_window)
into ``evict_count`` equal windows and take ``RMQ_index`` in each — this
keeps evictions spread across the context (a known failure mode of global
top-k eviction is clustering; windowed argmin enforces coverage) and makes
every query an independent member of one RMQ batch.

Two entry points:

* :meth:`plan_evictions` — one-shot: builds a throwaway index over the
  given scores (kept for offline/batch callers and as the reference the
  streaming path is tested against);
* :meth:`make_index` + :meth:`plan_evictions_streaming` — serving hot
  path: one index for the whole generation, synced each round with a
  single fixed-shape batched update (chunk-granular re-reductions), then
  queried.  ``ServeEngine`` uses this path exclusively.

Queries go through the adaptive batched engine (``repro.qe``) rather
than the monolithic walk: eviction windows are ``evictable /
evict_count`` wide, so under memory pressure (many victims per round)
most of the batch lands in the engine's *short* class and skips the
hierarchy entirely via the two-chunk kernel.  The streaming path keeps
one engine for the generation and re-attaches it each round (the score
update bumps the index generation, invalidating the engine's result
cache by key); the result cache itself is disabled — scores change
every round, so cross-round hits are impossible by construction.

With :meth:`attach_serving` the manager becomes a *tenant* of the async
serving tier (``repro.serving``) instead of owning a private engine:
each round stages the synced index as a snapshot replacement and submits
the window batch under the tenant's latency SLO, so eviction scans from
many sequences/replicas coalesce with everything else the tier serves —
the production shape, and the tier's first in-repo tenant.

The manager is pure-functional: planners return indices (plus the updated
index for the streaming path); ``apply_evictions`` compacts cache +
scores.  Engine code owns the arrays.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.api import RMQ
from repro.streaming import StreamingRMQ

__all__ = ["RMQEvictionManager"]


@dataclasses.dataclass(frozen=True)
class RMQEvictionManager:
    budget: int                 # max live tokens per sequence
    protected_window: int = 256  # never evict the most recent tokens
    c: int = 128
    t: int = 16
    backend: str = "jax"

    def needs_eviction(self, live_tokens: int) -> bool:
        return live_tokens > self.budget

    # -- shared window geometry -------------------------------------------
    def _plan_round(self, live_tokens: int):
        """(evictable, evict_count) for a round, or None if nothing to do."""
        evict_count = live_tokens - self.budget
        if evict_count <= 0:
            return None
        evictable = live_tokens - self.protected_window
        if evictable <= 0:
            return None
        return evictable, min(evict_count, evictable)

    @staticmethod
    def _windows(evictable: int, evict_count: int):
        """One RMQ window per victim — disjoint, covering [0, evictable)."""
        bounds = jnp.linspace(0, evictable, evict_count + 1).astype(jnp.int32)
        ls = bounds[:-1]
        rs = jnp.maximum(bounds[1:] - 1, ls)
        return ls, rs

    # -- one-shot path (offline / reference) ------------------------------
    def plan_evictions(
        self,
        scores: jax.Array,       # (S_live,) importance of each live token
        live_tokens: int,
    ) -> jax.Array:
        """Indices (ascending, unique) of tokens to evict this round."""
        round_ = self._plan_round(live_tokens)
        if round_ is None:
            return jnp.zeros((0,), jnp.int32)
        evictable, evict_count = round_

        # one RMQ_index per window — a batch of (l, r) pairs, the paper's
        # exact query interface.  The chunk size must stay a power of
        # two even when the evictable region is smaller than self.c
        # (e.g. a protected window covering almost the whole context).
        c_fit = min(self.c, max(2, evictable))
        c_fit = 1 << (c_fit.bit_length() - 1)   # largest pow2 <= c_fit
        rmq = RMQ.build(
            scores[:evictable], c=c_fit,
            t=self.t, with_positions=True, backend=self.backend,
        )
        ls, rs = self._windows(evictable, evict_count)
        # Span-routed argmin: a throwaway index gets a throwaway engine
        # (no result cache — every build is a fresh generation anyway).
        victims = rmq.engine(cache_size=0).query_index(ls, rs)
        # windows are disjoint and each argmin lies in its window => unique
        return jnp.sort(victims).astype(jnp.int32)

    # -- streaming path (serving hot loop) --------------------------------
    def make_index(self, capacity: int) -> StreamingRMQ:
        """One-time index over ``capacity`` score slots (all ``+inf``)."""
        return StreamingRMQ.from_array(
            jnp.full((capacity,), jnp.inf, jnp.float32),
            c=self.c, t=self.t, with_positions=True, backend=self.backend,
        )

    def attach_serving(
        self,
        tier,
        tenant: str = "kv-eviction",
        *,
        slo_ms: float = 2.0,
    ) -> None:
        """Route streaming eviction queries through a serving-tier tenant.

        The tenant registers lazily on the first round (the tier needs an
        index to register); every later round stages the freshly-synced
        index as a snapshot replacement and submits the window batch
        with the given SLO.  The manager dataclass is frozen config —
        like ``_engine``, the tier binding is runtime state parked on
        the instance dict.
        """
        object.__setattr__(self, "_tier", tier)
        object.__setattr__(self, "_tenant", tenant)
        object.__setattr__(self, "_tenant_slo_ms", float(slo_ms))

    def _victims_via_tier(self, index: StreamingRMQ, ls, rs):
        """One serving-tier round: stage the synced index, submit windows.

        The staged replacement swaps in at the flush that answers this
        round's batch (mutations apply before reads in a flush cycle),
        so the windows are answered against exactly this round's scores
        — same snapshot discipline as every other tenant.
        """
        from repro.qe.executors import INDEX

        tier = self.__dict__["_tier"]
        tenant = self.__dict__["_tenant"]
        try:
            tier.tenant_config(tenant)
        except KeyError:
            # cross-round result caching is impossible by construction
            # (scores change every round) — same reasoning as _engine_for
            tier.register_tenant(
                tenant, index, slo_ms=self.__dict__["_tenant_slo_ms"],
                cache_size=0,
            )
        else:
            tier.replace_index(tenant, index)
        return tier.query(tenant, ls, rs, op=INDEX, timeout=60.0)

    def _engine_for(self, index: StreamingRMQ):
        """One persistent query engine per manager, re-attached each round.

        The manager dataclass is frozen (it is config, hashable); the
        engine is runtime state, parked on the instance dict so jitted
        bucket callables and planner stats persist across rounds.
        """
        eng = self.__dict__.get("_engine")
        if eng is None:
            eng = index.engine(cache_size=0)
            object.__setattr__(self, "_engine", eng)
        else:
            eng.attach(index)
        return eng

    def plan_evictions_streaming(
        self,
        index: StreamingRMQ,
        slot_scores: jax.Array,  # (capacity,) live scores, +inf beyond live
        live_tokens: int,
    ) -> Tuple[StreamingRMQ, jax.Array]:
        """Sync the index with this round's scores and pick victims.

        Decode adds attention mass to *every* live score each step, so
        the exact sync here is dense: one fixed-shape batched update that
        re-reduces every chunk in place.  That is rebuild-equivalent
        reduction FLOPs (plus the update path's O(capacity) dedupe
        bookkeeping) — the win over the old rebuild-per-round path is
        structural, not FLOPs: no reallocation, no re-planning, and one
        jit specialization for all rounds, where the old path built a
        fresh ``make_plan(evictable)`` and re-traced for every distinct
        live length.  Callers whose scores change sparsely between
        rounds get the real O(B log_c n) asymptotics by calling
        ``index.update(changed_idxs, changed_vals)`` themselves and
        skipping this dense sync.
        """
        round_ = self._plan_round(live_tokens)
        if round_ is None:
            return index, jnp.zeros((0,), jnp.int32)
        evictable, evict_count = round_

        index = index.update(
            jnp.arange(index.capacity, dtype=jnp.int32), slot_scores
        )
        ls, rs = self._windows(evictable, evict_count)
        if self.__dict__.get("_tier") is not None:
            victims = self._victims_via_tier(index, ls, rs)
        else:
            victims = self._engine_for(index).query_index(ls, rs)
        return index, jnp.sort(victims).astype(jnp.int32)

    def apply_evictions(
        self,
        victims: jax.Array,      # (E,) ascending indices
        scores: jax.Array,       # (S_live,)
        live_tokens: int,
        *cache_arrays: jax.Array,   # arrays with a length-S_live token axis
        token_axis: int = 0,
    ) -> Tuple[jax.Array, Tuple[jax.Array, ...], int]:
        """Compact scores and cache arrays by deleting ``victims`` rows."""
        e = int(victims.shape[0])
        if e == 0:
            return scores, cache_arrays, live_tokens
        keep_mask = jnp.ones((live_tokens,), bool).at[victims].set(False)
        keep_idx = jnp.nonzero(keep_mask, size=live_tokens - e)[0]
        new_scores = jnp.take(scores, keep_idx, axis=0)
        new_caches = tuple(
            jnp.take(a, keep_idx, axis=token_axis) for a in cache_arrays
        )
        return new_scores, new_caches, live_tokens - e
