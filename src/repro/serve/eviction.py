"""RMQ-backed KV-cache eviction — the paper's data structure as a serving
feature (DESIGN.md §4).

During long-context decode each sequence accumulates per-token importance
scores (attention probability mass, H2O-style).  When the live token count
exceeds the budget, the manager must find the *least-important* tokens —
range-minimum queries over the score array.  This is exactly the paper's
workload shape:

* the score array is static between eviction rounds (scores only grow by
  += on recent positions; eviction happens in bursts);
* eviction scans are batched: one RMQ per candidate window per sequence —
  thousands of queries per round at production batch sizes;
* after a burst the hierarchy is rebuilt in O(n/c) — the operation the
  paper shows is 50–2400× cheaper than competing structures' builds.

Strategy per round: split the evictable region [0, n - protected_window)
into ``evict_count`` equal windows and take ``RMQ_index`` in each — this
keeps evictions spread across the context (a known failure mode of global
top-k eviction is clustering; windowed argmin enforces coverage) and makes
every query an independent member of one RMQ batch.

The manager is pure-functional: ``plan_evictions`` returns indices;
``apply_evictions`` compacts cache + scores.  Engine code owns the arrays.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.api import RMQ

__all__ = ["RMQEvictionManager"]


@dataclasses.dataclass(frozen=True)
class RMQEvictionManager:
    budget: int                 # max live tokens per sequence
    protected_window: int = 256  # never evict the most recent tokens
    c: int = 128
    t: int = 16
    backend: str = "jax"

    def needs_eviction(self, live_tokens: int) -> bool:
        return live_tokens > self.budget

    def plan_evictions(
        self,
        scores: jax.Array,       # (S_live,) importance of each live token
        live_tokens: int,
    ) -> jax.Array:
        """Indices (ascending, unique) of tokens to evict this round."""
        evict_count = live_tokens - self.budget
        if evict_count <= 0:
            return jnp.zeros((0,), jnp.int32)
        evictable = live_tokens - self.protected_window
        evict_count = min(evict_count, evictable)
        if evictable <= 0:
            return jnp.zeros((0,), jnp.int32)

        # one RMQ_index per window — a batch of (l, r) pairs, the paper's
        # exact query interface
        rmq = RMQ.build(
            scores[:evictable], c=min(self.c, max(2, evictable)),
            t=self.t, with_positions=True, backend=self.backend,
        )
        bounds = jnp.linspace(0, evictable, evict_count + 1).astype(jnp.int32)
        ls = bounds[:-1]
        rs = jnp.maximum(bounds[1:] - 1, ls)
        victims = rmq.query_index(ls, rs)
        # windows are disjoint and each argmin lies in its window => unique
        return jnp.sort(victims).astype(jnp.int32)

    def apply_evictions(
        self,
        victims: jax.Array,      # (E,) ascending indices
        scores: jax.Array,       # (S_live,)
        live_tokens: int,
        *cache_arrays: jax.Array,   # arrays with a length-S_live token axis
        token_axis: int = 0,
    ) -> Tuple[jax.Array, Tuple[jax.Array, ...], int]:
        """Compact scores and cache arrays by deleting ``victims`` rows."""
        e = int(victims.shape[0])
        if e == 0:
            return scores, cache_arrays, live_tokens
        keep_mask = jnp.ones((live_tokens,), bool).at[victims].set(False)
        keep_idx = jnp.nonzero(keep_mask, size=live_tokens - e)[0]
        new_scores = jnp.take(scores, keep_idx, axis=0)
        new_caches = tuple(
            jnp.take(a, keep_idx, axis=token_axis) for a in cache_arrays
        )
        return new_scores, new_caches, live_tokens - e
