from repro.serve.engine import ServeEngine
from repro.serve.eviction import RMQEvictionManager

__all__ = ["ServeEngine", "RMQEvictionManager"]
