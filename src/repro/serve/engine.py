"""Batched serving engine: prefill + decode loop with RMQ eviction hooks.

A deliberately small engine (the framework's serving deliverable is the
``serve_step`` lowered in the dry-run; this class is the host-side driver
used by examples/tests): greedy decoding over a fixed batch, optional
RMQ-backed eviction when the per-sequence importance scores outgrow the
budget.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.models.lm import decode_step, make_decode_cache, prefill
from repro.serve.eviction import RMQEvictionManager


class ServeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        sc: ServeConfig,
        serving_tier: Optional[Any] = None,
    ):
        self.cfg = cfg
        self.params = params
        self.sc = sc
        self.eviction = (
            RMQEvictionManager(
                budget=sc.eviction_budget,
                protected_window=sc.eviction_window,
                c=sc.rmq_chunk,
                t=sc.rmq_threshold,
            )
            if sc.eviction_enabled
            else None
        )
        # eviction scans become a tenant of the async serving tier:
        # window batches coalesce under the tenant's SLO with whatever
        # else the tier serves, instead of a private per-engine flush
        if self.eviction is not None and serving_tier is not None:
            self.eviction.attach_serving(serving_tier)
        cache_dtype = jnp.dtype(sc.kv_cache_dtype)
        self._prefill = jax.jit(
            functools.partial(
                prefill, cfg, cache_len=sc.seq_len, cache_dtype=cache_dtype
            ),
            static_argnames=(),
        )
        self._decode = jax.jit(
            functools.partial(
                decode_step, cfg,
                return_attn_mass=sc.eviction_enabled,
            )
        )

    def generate(
        self,
        prompt_tokens: jax.Array,            # (B, S_prompt)
        max_new_tokens: int,
        prefix_embeddings: Optional[jax.Array] = None,
    ) -> Dict[str, Any]:
        cfg = self.cfg
        b, s_prompt = prompt_tokens.shape
        f = cfg.frontend_tokens if cfg.frontend else 0
        logits, cache = self._prefill(
            self.params, prompt_tokens,
            prefix_embeddings=prefix_embeddings,
        )
        pos = f + s_prompt
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [token]
        scores = jnp.zeros((b, self.sc.seq_len), jnp.float32)
        evictions = 0
        # Streaming score index: built once, then kept in sync by batched
        # incremental updates — eviction rounds never rebuild the
        # hierarchy (and never re-trace: the plan is fixed at seq_len
        # capacity, while the old rebuild path re-specialized per length).
        score_index = None

        for _ in range(max_new_tokens - 1):
            logits, cache, mass = self._decode(
                self.params, token, cache, pos=pos
            )
            if mass is not None:
                scores = scores + mass
            pos += 1
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(token)

            if (
                self.eviction is not None
                and self.eviction.needs_eviction(pos)
            ):
                # Evict per-sequence on the mean score (batch-shared cache
                # layout keeps positions aligned across sequences); dead
                # slots sync as +inf so they can never be picked.
                mean_scores = jnp.where(
                    jnp.arange(self.sc.seq_len) < pos,
                    scores.mean(axis=0),
                    jnp.inf,
                )
                if score_index is None:
                    score_index = self.eviction.make_index(self.sc.seq_len)
                score_index, victims = (
                    self.eviction.plan_evictions_streaming(
                        score_index, mean_scores, pos
                    )
                )
                if victims.shape[0]:
                    cache, scores, pos = self._evict(
                        cache, scores, victims, pos
                    )
                    evictions += int(victims.shape[0])

        return {
            "tokens": jnp.stack(out, axis=1),
            "final_pos": pos,
            "evicted": evictions,
        }

    def _evict(self, cache, scores, victims, live):
        """Compact live tokens along the cache S axis, shapes static.

        Permutation [kept live rows | old tail | victim rows]: victims are
        parked past the live region, where every slot is overwritten by a
        future ``dynamic_update_slice`` before it can be attended
        (decode writes position ``pos`` before reading ``col <= pos``).
        """
        vict = np.asarray(victims)
        keep_mask = np.ones((self.sc.seq_len,), bool)
        keep_mask[vict] = False
        keep_idx = np.concatenate(
            [np.nonzero(keep_mask[:live])[0],
             np.arange(live, self.sc.seq_len),
             vict]
        )
        assert keep_idx.shape[0] == self.sc.seq_len
        keep_idx = jnp.asarray(keep_idx, jnp.int32)
        new_live = live - int(vict.shape[0])

        new_cache = dict(cache)
        for key in ("k", "v"):
            if key in cache:
                new_cache[key] = jnp.take(cache[key], keep_idx, axis=3)
        for key in ("latent", "rope"):
            if key in cache:
                new_cache[key] = jnp.take(cache[key], keep_idx, axis=2)
        new_scores = jnp.take(scores, keep_idx, axis=1)
        # stale rows past the live region must not carry scores
        new_scores = jnp.where(
            jnp.arange(self.sc.seq_len)[None, :] < new_live, new_scores, 0.0
        )
        return new_cache, new_scores, new_live
