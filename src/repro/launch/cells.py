"""Dry-run cells: (architecture × input shape × mesh) → lower/compile/analyse.

One *cell* = one entry of the assigned 40-cell grid.  For each cell this
module builds:

* the step function (``train_step`` for train shapes, ``prefill`` /
  ``serve_step`` for inference shapes),
* fully-sharded ``jax.ShapeDtypeStruct`` stand-ins for every input
  (weights, optimizer state, batches, KV caches — no allocation ever),
* the lower→compile pipeline, returning roofline raw numbers:
  per-device HLO FLOPs / bytes (``cost_analysis``), per-device memory
  (``memory_analysis``) and per-collective operand bytes parsed from the
  partitioned HLO.

Shape grid (assignment):
  train_4k     seq 4096   global_batch 256   -> train_step
  prefill_32k  seq 32768  global_batch 32    -> prefill
  decode_32k   seq 32768  global_batch 128   -> serve_step (1 new token)
  long_500k    seq 524288 global_batch 1     -> serve_step, SSM/hybrid only
"""

from __future__ import annotations

import dataclasses
import functools
import re
from collections import defaultdict
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import cost_analysis_dict
from repro.configs.base import (
    ARCH_IDS,
    ModelConfig,
    TrainConfig,
    get_config,
)
from repro.distributed.shardings import (
    batch_shardings,
    cache_shardings,
    make_sharder,
    param_shardings,
    train_state_shardings,
)
from repro.models.lm import decode_step, init_params, make_decode_cache, prefill
from repro.train.train_step import build_train_step, init_train_state

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

# long_500k requires sub-quadratic attention: runs only for SSM/hybrid.
LONG_CONTEXT_ARCHS = ("mamba2-1.3b", "hymba-1.5b")

# Per-arch best configuration found by the §Perf hillclimb (EXPERIMENTS.md).
# layout: "fsdp" (pure ZeRO-3) wins for big dense models at ~1 seq/device;
# "tp_sp" (tensor parallel + Megatron-SP + shard_map MoE dispatch) wins
# for MoE and small/mid dense models.
BEST_CONFIG = {
    # fsdp (pure ZeRO-3) needs batch >= chips: right for command-r TRAIN
    # (256 seqs / 256 chips), wrong for its 32-seq prefill — layouts are
    # per (arch, shape-kind).
    ("command-r-plus-104b", "train"): dict(layout="fsdp", remat="full"),
}
DEFAULT_BEST = dict(layout="tp_sp", remat="full")


def best_config(arch: str, shape: Optional[str] = None,
                num_chips: int = 256):
    kind = SHAPES[shape]["kind"] if shape in SHAPES else None
    bc = BEST_CONFIG.get((arch, kind), DEFAULT_BEST)
    if bc["layout"] == "fsdp" and shape in SHAPES             and SHAPES[shape]["global_batch"] < num_chips:
        # pure ZeRO-3 needs batch >= chips; below that the model axis
        # would recompute every token redundantly — fall back to tp_sp
        return DEFAULT_BEST
    return bc


def cell_is_skipped(arch: str, shape: str) -> Optional[str]:
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return (
            "long_500k needs sub-quadratic attention; "
            f"{arch} is a full-attention arch (DESIGN.md §6)"
        )
    return None


def all_cells():
    for arch in ARCH_IDS:
        for shape in SHAPES:
            yield arch, shape


# ---------------------------------------------------------------------------
# ShapeDtypeStruct builders (weak-type-correct, shardable, no allocation)
# ---------------------------------------------------------------------------
def _with_shardings(struct_tree, sharding_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        struct_tree,
        sharding_tree,
    )


def train_cell(
    cfg: ModelConfig,
    mesh,
    seq_len: int,
    global_batch: int,
    tc: Optional[TrainConfig] = None,
    sequence_sharding: bool = True,
    unroll: bool = False,
    layout: str = "tp_sp",
):
    """Returns (fn, example_args) ready for jit(...).lower(*args)."""
    tc = tc or TrainConfig(
        seq_len=seq_len,
        global_batch=global_batch,
        remat_policy="minimal",
        optimizer_state_dtype=(
            "bfloat16" if cfg.num_params() > 2e11 else "float32"
        ),
        loss_chunk=(512 if (cfg.padded_vocab >= 65536
                    and cfg.num_params() > 5e10) else 0),
    )
    sharder = make_sharder(mesh, sequence_sharding=sequence_sharding,
                           layout=layout)
    state_struct = jax.eval_shape(
        lambda: init_train_state(cfg, tc, jax.random.PRNGKey(0))
    )
    state_sh = train_state_shardings(mesh, state_struct, layout)
    step_fn = build_train_step(cfg, tc, sharder=sharder, unroll=unroll,
                               grad_shardings=state_sh.params)
    state = _with_shardings(state_struct, state_sh)

    batch_struct = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
    if cfg.frontend:
        batch_struct["prefix"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    batch = _with_shardings(
        batch_struct, batch_shardings(mesh, batch_struct, layout)
    )
    return step_fn, (state, batch), tc


def params_struct_sharded(cfg: ModelConfig, mesh):
    p_struct = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0))
    )
    return _with_shardings(p_struct, param_shardings(mesh, p_struct))


def prefill_cell(cfg: ModelConfig, mesh, seq_len: int, global_batch: int,
                 unroll: bool = False):
    sharder = make_sharder(mesh)
    cache_dtype = jnp.bfloat16

    def fn(params, tokens, prefix=None):
        return prefill(
            cfg, params, tokens,
            cache_len=seq_len,
            prefix_embeddings=prefix,
            cache_dtype=cache_dtype,
            sharder=sharder,
            unroll=unroll,
        )

    params = params_struct_sharded(cfg, mesh)
    tok_struct = {
        "tokens": jax.ShapeDtypeStruct(
            (global_batch, seq_len - (cfg.frontend_tokens if cfg.frontend
                                      else 0)),
            jnp.int32,
        )
    }
    if cfg.frontend:
        tok_struct["prefix"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    tok_sh = batch_shardings(mesh, tok_struct)
    tok = _with_shardings(tok_struct, tok_sh)
    args = (params, tok["tokens"])
    if cfg.frontend:
        args = args + (tok["prefix"],)
    return fn, args


def decode_cell(cfg: ModelConfig, mesh, seq_len: int, global_batch: int,
                unroll: bool = False):
    sharder = make_sharder(mesh)

    def fn(params, token, cache, pos):
        return decode_step(cfg, params, token, cache, pos, sharder=sharder,
                           unroll=unroll)

    params = params_struct_sharded(cfg, mesh)
    cache_struct = jax.eval_shape(
        lambda: make_decode_cache(cfg, global_batch, seq_len, jnp.bfloat16)
    )
    cache = _with_shardings(cache_struct, cache_shardings(mesh, cache_struct))
    token = jax.ShapeDtypeStruct((global_batch,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return fn, (params, token, cache, pos)


# ---------------------------------------------------------------------------
# Lower + compile + analyse
# ---------------------------------------------------------------------------
# result type may be a tuple "(f32[..], f32[..])" for variadic collectives
_COLLECTIVE_RE = re.compile(
    r"(\S+)\s*=\s*(\(?[a-z0-9\[\]{},/_\s]*?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in partitioned HLO.

    Uses the *result* shape of each collective as the per-device payload
    proxy (for all-reduce this is the operand size; for all-gather the
    gathered size; ring-transfer factors are applied by the roofline
    model, not here).  Ops inside while-loop bodies are counted once per
    occurrence; the roofline model multiplies by trip counts where known
    (layer-scan collectives dominate and scale with num_layers — see
    benchmarks/roofline.py).
    """
    totals: Dict[str, float] = defaultdict(float)
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async pair: count the -start only
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        # result type(s) sit between '=' and the op name
        eq = line.find("=")
        if eq < 0:
            continue
        rhs = line[eq + 1 : m.start(3)]
        shapes = _SHAPE_RE.findall(rhs)
        nbytes = 0.0
        for dt, dims in shapes:
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        totals[kind] += nbytes
    return dict(totals)


def while_trip_counts(hlo_text: str) -> int:
    """Best-effort count of while ops (layer scans) in the module."""
    return hlo_text.count(" while(")


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh_desc: str
    flops_per_device: float
    bytes_per_device: float
    argument_bytes: float
    output_bytes: float
    temp_bytes: float
    collective_bytes: Dict[str, float]
    num_while_loops: int
    scan_length: int
    compile_seconds: float
    skipped: Optional[str] = None

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def run_cell(arch: str, shape: str, mesh, mesh_desc: str,
             sequence_sharding: bool = True,
             remat_policy: str = "minimal",
             microbatches: int = 1,
             layers_override: Optional[int] = None,
             unroll: bool = False,
             layout: str = "tp_sp") -> CellResult:
    import time

    skip = cell_is_skipped(arch, shape)
    if skip:
        return CellResult(
            arch=arch, shape=shape, mesh_desc=mesh_desc,
            flops_per_device=0, bytes_per_device=0, argument_bytes=0,
            output_bytes=0, temp_bytes=0, collective_bytes={},
            num_while_loops=0, scan_length=0, compile_seconds=0,
            skipped=skip,
        )

    cfg = get_config(arch)
    if layers_override is not None:
        cfg = dataclasses.replace(cfg, num_layers=layers_override)
    spec = SHAPES[shape]
    from repro.models.lm import _num_scan_steps

    if spec["kind"] == "train":
        tc = TrainConfig(
            seq_len=spec["seq_len"], global_batch=spec["global_batch"],
            remat_policy=remat_policy, microbatches=microbatches,
            optimizer_state_dtype=(
                "bfloat16" if cfg.num_params() > 2e11 else "float32"
            ),
            loss_chunk=(512 if (cfg.padded_vocab >= 65536
                    and cfg.num_params() > 5e10) else 0),
        )
        fn, args, _ = train_cell(
            cfg, mesh, spec["seq_len"], spec["global_batch"], tc=tc,
            sequence_sharding=sequence_sharding, unroll=unroll,
            layout=layout,
        )
        donate = (0,)   # donate TrainState: params/opt buffers reused
    elif spec["kind"] == "prefill":
        fn, args = prefill_cell(cfg, mesh, spec["seq_len"],
                                spec["global_batch"], unroll=unroll)
        donate = ()
    else:
        fn, args = decode_cell(cfg, mesh, spec["seq_len"],
                               spec["global_batch"], unroll=unroll)
        donate = (2,)   # donate the cache: decode updates in place

    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
    dt = time.time() - t0

    ca = cost_analysis_dict(compiled)
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    return CellResult(
        arch=arch,
        shape=shape,
        mesh_desc=mesh_desc,
        flops_per_device=float(ca.get("flops", 0.0)),
        bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        argument_bytes=float(getattr(ma, "argument_size_in_bytes", 0)),
        output_bytes=float(getattr(ma, "output_size_in_bytes", 0)),
        temp_bytes=float(getattr(ma, "temp_size_in_bytes", 0)),
        collective_bytes=collective_bytes_from_hlo(hlo),
        num_while_loops=while_trip_counts(hlo),
        scan_length=_num_scan_steps(cfg),
        compile_seconds=dt,
    )


# ---------------------------------------------------------------------------
# Calibration: exact per-layer FLOPs/bytes/collectives via unrolled compiles
# ---------------------------------------------------------------------------
def calibrate_cell(arch: str, shape: str, mesh, mesh_desc: str,
                   sequence_sharding: bool = True,
                   remat_policy: str = "minimal",
                   microbatches: int = 1,
                   layout: str = "tp_sp") -> Dict[str, Any]:
    """XLA cost analysis counts while-loop (layer scan) bodies ONCE.

    Fix: compile the same cell with 2 and 4 layers, *unrolled* (no while),
    solve  F(L) = once + L * per_layer  exactly, and extrapolate to the
    production depth.  Layer bodies are depth-independent (same shapes), so
    the extrapolation is exact for FLOPs/bytes/collectives.  Memory numbers
    always come from the production compile (run_cell), never from here.
    """
    cfg = get_config(arch)
    period = 2 if (cfg.uses_moe and cfg.moe_layer_period == 2) else 1
    l_small, l_big = 2 * period, 4 * period

    res = {}
    for lo in (l_small, l_big):
        res[lo] = run_cell(
            arch, shape, mesh, mesh_desc,
            sequence_sharding=sequence_sharding,
            remat_policy=remat_policy,
            microbatches=microbatches,
            layers_override=lo, unroll=True,
            layout=layout,
        )

    dl = l_big - l_small
    per_layer_flops = (res[l_big].flops_per_device
                       - res[l_small].flops_per_device) / dl
    per_layer_bytes = (res[l_big].bytes_per_device
                       - res[l_small].bytes_per_device) / dl
    once_flops = res[l_small].flops_per_device - l_small * per_layer_flops
    once_bytes = res[l_small].bytes_per_device - l_small * per_layer_bytes

    coll_kinds = set(res[l_small].collective_bytes) | set(
        res[l_big].collective_bytes)
    per_layer_coll, once_coll = {}, {}
    for kind in coll_kinds:
        a = res[l_small].collective_bytes.get(kind, 0.0)
        b = res[l_big].collective_bytes.get(kind, 0.0)
        per_layer_coll[kind] = (b - a) / dl
        once_coll[kind] = a - l_small * per_layer_coll[kind]

    L = cfg.num_layers
    return {
        "arch": arch,
        "shape": shape,
        "mesh_desc": mesh_desc,
        "num_layers": L,
        "flops_per_device": once_flops + L * per_layer_flops,
        "bytes_per_device": once_bytes + L * per_layer_bytes,
        "collective_bytes": {
            k: once_coll[k] + L * per_layer_coll[k] for k in coll_kinds
        },
        "per_layer_flops": per_layer_flops,
        "once_flops": once_flops,
        "per_layer_bytes": per_layer_bytes,
        "once_bytes": once_bytes,
        "per_layer_collectives": per_layer_coll,
    }
