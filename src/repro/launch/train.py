"""Production training driver with fault tolerance + elastic re-mesh.

Composes the substrate: sharded train step (pjit), deterministic resumable
data pipeline, async checkpointing, heartbeat/straggler monitoring, and an
elastic restart loop that survives (simulated) node failures by
re-planning the mesh and restoring the latest checkpoint with the new
mesh's shardings.

CPU usage (CI / laptop):
  python -m repro.launch.train --arch qwen1.5-0.5b --smoke --steps 20
Cluster usage (per-host, TPU): identical entrypoint; jax.distributed
initialization is gated on JAX_COORDINATOR being set.

Failure drill (exercised by tests/test_fault_tolerance.py):
  --inject-failure-at N kills the "host" at step N; the driver re-meshes
  to the next ladder entry, restores, re-shards the data pipeline, and
  continues — the loss curve continues from the checkpointed step.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time
from typing import Optional

import numpy as np


class SimulatedFailure(RuntimeError):
    pass


def build_objects(cfg, tc, mesh, sequence_sharding=False):
    import jax
    import jax.numpy as jnp

    from repro.distributed.shardings import (
        batch_shardings,
        make_sharder,
        train_state_shardings,
    )
    from repro.train.train_step import build_train_step, init_train_state

    sharder = make_sharder(mesh, sequence_sharding=sequence_sharding)
    step_fn = build_train_step(cfg, tc, sharder=sharder)

    state_struct = jax.eval_shape(
        lambda: init_train_state(cfg, tc, jax.random.PRNGKey(tc.seed))
    )
    state_sh = train_state_shardings(mesh, state_struct)

    with mesh:
        init = jax.jit(
            lambda: init_train_state(cfg, tc, jax.random.PRNGKey(tc.seed)),
            out_shardings=state_sh,
        )
        step = jax.jit(step_fn, donate_argnums=(0,))
    return init, step, state_sh


def train_loop(args) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.checkpoint import CheckpointManager, restore_checkpoint
    from repro.configs.base import TrainConfig, get_config, get_smoke_config
    from repro.data.pipeline import SyntheticTokenDataset
    from repro.distributed.fault_tolerance import (
        HeartbeatMonitor,
        plan_remesh,
    )
    from repro.launch.mesh import make_test_mesh
    from repro.train.train_step import init_train_state

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    tc = TrainConfig(
        total_steps=args.steps,
        warmup_steps=max(args.steps // 10, 1),
        seq_len=args.seq_len,
        global_batch=args.global_batch,
        microbatches=args.microbatches,
        remat_policy=args.remat,
        checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir,
    )

    # mesh: degrade gracefully to whatever devices exist
    n_dev = jax.device_count()
    model_ax = min(args.model_parallel, n_dev)
    data_ax = n_dev // model_ax
    mesh = make_test_mesh((data_ax, model_ax), ("data", "model"))

    ckpt = CheckpointManager(tc.checkpoint_dir, async_mode=tc.async_checkpoint)
    monitor = HeartbeatMonitor(num_hosts=max(jax.process_count(), 1))
    dataset = SyntheticTokenDataset(
        vocab_size=cfg.vocab_size,
        seq_len=tc.seq_len,
        global_batch=tc.global_batch,
        seed=tc.seed,
        prefix_tokens=cfg.frontend_tokens if cfg.frontend else 0,
        d_model=cfg.d_model,
    )

    init, step, state_sh = build_objects(cfg, tc, mesh)

    # restore-or-init (restart safety)
    start_step = ckpt.latest_step()
    if start_step is not None:
        template = jax.eval_shape(
            lambda: init_train_state(cfg, tc, jax.random.PRNGKey(tc.seed))
        )
        state = restore_checkpoint(
            tc.checkpoint_dir, start_step, template, shardings=state_sh
        )
        print(f"[train] restored checkpoint @ step {start_step}")
    else:
        state = init()
        start_step = 0

    losses = []
    t_last = time.time()
    try:
        for i in range(start_step, tc.total_steps):
            if args.inject_failure_at is not None \
                    and i == args.inject_failure_at:
                raise SimulatedFailure(
                    f"injected node failure at step {i}"
                )
            batch_np = dataset.batch_at(i)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            state, metrics = step(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            monitor.report(0, i)
            if (i + 1) % tc.checkpoint_every == 0 or i + 1 == tc.total_steps:
                ckpt.save(i + 1, state)
            if (i + 1) % args.log_every == 0:
                dt = time.time() - t_last
                t_last = time.time()
                print(
                    f"[train] step {i+1}/{tc.total_steps} "
                    f"loss={loss:.4f} lr={float(metrics['lr']):.2e} "
                    f"gnorm={float(metrics['grad_norm']):.2f} "
                    f"({dt/args.log_every:.2f}s/step)"
                )
    finally:
        # Drain in-flight async checkpoint writes on EVERY exit — normal
        # completion, the injected drill failure, or a real crash —
        # before any restart machinery scans for the latest durable
        # step.  Otherwise a save enqueued just before the failure
        # silently loses the race and the restart restores a stale step.
        # Must be read BEFORE the inner except (inside an except clause
        # sys.exc_info() would report the writer error itself).
        unwinding = sys.exc_info()[0] is not None
        try:
            try:
                ckpt.wait()
            except Exception as werr:  # noqa: BLE001
                # While unwinding another exception, a buffered writer
                # error must not mask it (the restart loop keys on the
                # original); on a normal exit it IS the failure and must
                # propagate.
                if not unwinding:
                    raise
                print(f"[train] checkpoint writer error during teardown: "
                      f"{werr}")
        finally:
            # The async checkpointer must always be shut down — including
            # when wait() raised a writer error on a normal exit — or its
            # executor threads outlive the (restarted) loop.
            ckpt.close()
    return {"losses": losses, "final_step": tc.total_steps}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="minimal")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--max-restarts", type=int, default=2)
    args = ap.parse_args()

    restarts = 0
    while True:
        try:
            out = train_loop(args)
            print(f"[train] done: final loss {out['losses'][-1]:.4f}")
            return 0
        except SimulatedFailure as e:
            restarts += 1
            print(f"[train] FAILURE: {e} — restart {restarts}")
            if restarts > args.max_restarts:
                print("[train] restart budget exhausted")
                return 1
            # the injected failure fires once; clear it and resume from
            # the latest checkpoint (elastic path: a real deployment would
            # also call plan_remesh with the surviving host count here)
            args.inject_failure_at = None


if __name__ == "__main__":
    raise SystemExit(main())
