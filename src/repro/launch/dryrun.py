import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

Proves the distribution config is coherent without hardware: for every
(architecture × input shape) cell, ``jit(step).lower(**specs).compile()``
must succeed on BOTH the single-pod 16×16 mesh and the 2×16×16 multi-pod
mesh, and we record ``memory_analysis()`` (fits?) + ``cost_analysis()``
(FLOPs/bytes for §Roofline) + the HLO collective schedule.

The two lines above MUST stay the first statements in this file — jax
locks the device count at first init, and every other repro module is
imported only afterwards.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
  python -m repro.launch.dryrun --all --mesh single --out results.jsonl
  python -m repro.launch.dryrun --all --mesh multi  --out results.jsonl
Each cell runs in-process; use --subprocess to isolate cells (slower,
survives per-cell OOM/compile crashes during sweeps).
"""

import argparse
import json
import subprocess
import sys
import traceback


def _run_one(arch: str, shape: str, mesh_name: str, args) -> dict:
    import jax  # first jax import happens under the XLA_FLAGS above

    from repro.launch.cells import best_config, run_cell
    from repro.launch.mesh import make_production_mesh

    if args.best:
        bc = best_config(arch, shape,
                         num_chips=512 if mesh_name == "multi" else 256)
        args.layout = bc["layout"]
        args.remat = bc["remat"]
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    res = run_cell(
        arch, shape, mesh,
        mesh_desc=mesh_name,
        sequence_sharding=not args.no_sequence_sharding,
        remat_policy=args.remat,
        microbatches=args.microbatches,
        layout=args.layout,
    )
    out = res.to_json()
    if args.calibrate and not res.skipped:
        from repro.launch.cells import calibrate_cell

        out["calibrated"] = calibrate_cell(
            arch, shape, mesh, mesh_name,
            sequence_sharding=not args.no_sequence_sharding,
            remat_policy=args.remat,
            microbatches=args.microbatches,
            layout=args.layout,
        )
    out["ok"] = True
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--subprocess", action="store_true")
    ap.add_argument("--no-sequence-sharding", action="store_true")
    ap.add_argument("--remat", default="minimal",
                    choices=["none", "minimal", "full", "names"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--layout", default="tp_sp",
                    choices=["tp_sp", "fsdp"])
    ap.add_argument("--best", action="store_true",
                    help="use the per-arch hillclimbed layout/remat "
                         "(launch.cells.BEST_CONFIG)")
    ap.add_argument("--calibrate", action="store_true",
                    help="also run 2/4-layer unrolled compiles for exact "
                         "per-layer FLOPs/bytes/collectives (§Roofline)")
    args = ap.parse_args()

    from repro.configs.base import ARCH_IDS
    from repro.launch.cells import SHAPES, cell_is_skipped

    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    rc = 0
    sink = open(args.out, "a") if args.out else None
    for arch, shape in cells:
        skip = cell_is_skipped(arch, shape)
        if skip:
            rec = {"arch": arch, "shape": shape, "mesh_desc": args.mesh,
                   "skipped": skip, "ok": True}
            print(f"[SKIP] {arch} × {shape}: {skip}")
        elif args.subprocess:
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape, "--mesh", args.mesh,
                "--remat", args.remat,
                "--microbatches", str(args.microbatches),
            ]
            if args.no_sequence_sharding:
                cmd.append("--no-sequence-sharding")
            if args.out:
                cmd += ["--out", args.out]
            r = subprocess.run(cmd)
            if r.returncode != 0:
                rc = 1
            continue
        else:
            try:
                rec = _run_one(arch, shape, args.mesh, args)
                print(
                    f"[OK]   {arch} × {shape} × {args.mesh}: "
                    f"flops/dev={rec['flops_per_device']:.3e} "
                    f"bytes/dev={rec['bytes_per_device']:.3e} "
                    f"args={rec['argument_bytes']/2**30:.2f}GiB "
                    f"temp={rec['temp_bytes']/2**30:.2f}GiB "
                    f"compile={rec['compile_seconds']:.0f}s"
                )
                colls = rec.get("collective_bytes", {})
                if colls:
                    summary = ", ".join(
                        f"{k}={v/2**20:.1f}MiB" for k, v in
                        sorted(colls.items())
                    )
                    print(f"       collectives(per-iter): {summary}")
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "mesh_desc": args.mesh,
                       "ok": False, "error": f"{type(e).__name__}: {e}"}
                print(f"[FAIL] {arch} × {shape} × {args.mesh}: {e}")
                traceback.print_exc()
                rc = 1
        if sink:
            sink.write(json.dumps(rec) + "\n")
            sink.flush()
    if sink:
        sink.close()
    return rc


if __name__ == "__main__":
    sys.exit(main())
