"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state — required because smoke
tests and benchmarks must see exactly one CPU device, while
``launch/dryrun.py`` sets the 512-placeholder-device XLA flag before its
first jax import and then calls this.

Axes:
* single pod:  (16, 16)      ("data", "model")  — 256 chips (one v5e pod)
* multi-pod:   (2, 16, 16)   ("pod", "data", "model") — 512 chips

``pod`` and ``data`` carry data parallelism + FSDP weight sharding;
``model`` carries tensor / sequence / expert parallelism.  At >2 pods the
same function takes ``num_pods``; the mesh ladder for degraded (elastic)
configurations lives in ``repro.distributed.fault_tolerance``.
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax.sharding.AxisType (explicit-sharding API) only exists on newer
    # JAX; Auto is the default there and the only behavior on older JAX.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False, num_pods: int = 2):
    shape = (num_pods, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU fake-device tests (same axis semantics)."""
    return _make_mesh(shape, axes)
