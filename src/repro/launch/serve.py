"""Serving driver: batched generation with optional RMQ-backed eviction.

CPU usage:
  python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
      --prompt-len 32 --max-new 32 --batch 4
  python -m repro.launch.serve --arch llama3.2-3b --smoke --evict \
      --budget 48 --max-new 64
"""

from __future__ import annotations

import argparse
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=None)
    ap.add_argument("--evict", action="store_true")
    ap.add_argument("--budget", type=int, default=0)
    ap.add_argument("--protected", type=int, default=16)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs.base import ServeConfig, get_config, get_smoke_config
    from repro.models.frontends import synthetic_frontend_embeddings
    from repro.models.lm import init_params
    from repro.serve.engine import ServeEngine

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    f = cfg.frontend_tokens if cfg.frontend else 0
    cache_len = args.cache_len or (
        f + args.prompt_len + args.max_new + 8
    )
    sc = ServeConfig(
        seq_len=cache_len,
        batch=args.batch,
        kv_cache_dtype="float32" if args.smoke else "bfloat16",
        eviction_enabled=args.evict,
        eviction_budget=args.budget or (cache_len * 3 // 4),
        eviction_window=args.protected,
        rmq_chunk=16,
        rmq_threshold=4,
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, sc)

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size,
    )
    prefix = synthetic_frontend_embeddings(cfg, args.batch)
    t0 = time.time()
    out = engine.generate(prompts, args.max_new, prefix_embeddings=prefix)
    dt = time.time() - t0
    toks = int(out["tokens"].shape[0] * out["tokens"].shape[1])
    print(
        f"[serve] {args.arch}: generated {toks} tokens in {dt:.2f}s "
        f"({toks/dt:.1f} tok/s), evicted={out['evicted']}, "
        f"final_pos={out['final_pos']}"
    )
    print(f"[serve] sample: {out['tokens'][0, :16].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
