"""Deadline-driven serving tier over :class:`repro.qe.QueryService`.

``QueryService`` micro-batches, but its flushes are caller-driven: every
client either pays a flush per request (tiny launches, the fused path's
worst case) or some caller must volunteer to flush for everyone.  This
module adds the production front end the ROADMAP's millions-of-users
story needs:

* **deadline scheduler** — each tenant carries a latency SLO; a flush
  fires
  when the oldest queued request's deadline arrives *or* the queue
  reaches the fused bucket capacity, whichever comes first.  One
  flusher (a background thread via :meth:`ServingTier.start`, an asyncio
  pump via :mod:`repro.serving.aio`, or manual :meth:`ServingTier.step`
  calls with an injected clock for tests) drives all tenants;
* **snapshot-isolated reads** — each tenant's index lives in a
  :class:`repro.serving.snapshot.SnapshotSlot`: mutations stage onto the
  back log in O(1) (admitting while reads drain) and swap in *between*
  flushes, so every request in a flush is answered by one pinned
  generation and a half-applied update batch is unobservable;
* **admission control** — bounded per-tenant queues and token-bucket
  quotas reject with :class:`Backpressure` (carrying ``retry_after``)
  instead of growing without bound;
* **telemetry** — per-tenant counters and histograms
  (:mod:`repro.serving.metrics`), exported as a plain dict by
  :meth:`ServingTier.stats`.

Requests return :class:`Ticket`\\ s (``concurrent.futures``-backed):
``submit`` is non-blocking, ``Ticket.result`` blocks until the deadline
flush resolves it.  Under the hood each flush funnels the tenant's whole
queue through ``QueryService`` coalescing — on a fused-backend engine
that is ONE ``rmq_fused`` launch per flush for the entire mixed batch.
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.obs import trace
from repro.obs.metrics import LATENCY_BUCKETS, Metrics, SIZE_BUCKETS
from repro.qe.executors import INDEX, VALUE
from repro.qe.service import QueryService
from repro.serving.snapshot import SnapshotSlot

__all__ = [
    "Backpressure",
    "FlushEvent",
    "ServingTier",
    "TenantConfig",
    "Ticket",
]


class Backpressure(RuntimeError):
    """Admission rejected; retry after ``retry_after`` seconds.

    ``reason`` is ``"queue_full"`` (bounded per-tenant queue at
    capacity) or ``"quota"`` (token-bucket QPS quota exhausted).  The
    tier never buffers beyond the configured bounds — callers own the
    retry, which is what keeps overload from turning into unbounded
    memory growth and collapsed tail latency.
    """

    def __init__(self, tenant: str, reason: str, retry_after: float):
        self.tenant = tenant
        self.reason = reason
        self.retry_after = float(retry_after)
        super().__init__(
            f"tenant {tenant!r} rejected ({reason}); "
            f"retry after {self.retry_after:.4f}s"
        )


@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """Per-tenant SLO + admission knobs.

    ``slo_ms`` — flush-by deadline for a queued request (the client-side
    latency is roughly ``slo_ms`` + one flush's execution time);
    ``max_queue`` — bound on queued *queries* (not requests) before
    :class:`Backpressure`; ``max_batch`` — queue size that triggers an
    early size-based flush (defaults to the fused bucket capacity so a
    full flush is still one launch); ``quota_qps`` — optional sustained
    queries/second token bucket with burst ``quota_burst``.
    """

    slo_ms: float = 5.0
    max_queue: int = 8192
    max_batch: int = 4096
    quota_qps: Optional[float] = None
    quota_burst: Optional[float] = None

    def __post_init__(self):
        if self.slo_ms <= 0:
            raise ValueError(f"slo_ms must be > 0, got {self.slo_ms}")
        if self.max_batch <= 0 or self.max_queue < self.max_batch:
            raise ValueError(
                f"need 0 < max_batch <= max_queue, got "
                f"max_batch={self.max_batch} max_queue={self.max_queue}"
            )
        if self.quota_qps is not None and self.quota_qps <= 0:
            raise ValueError(f"quota_qps must be > 0, got {self.quota_qps}")


class Ticket:
    """Future-style handle for one submitted request.

    ``result(timeout)`` blocks until the deadline/size flush resolves
    it (or re-raises the flush failure).  After resolution,
    ``generation`` records the snapshot the answers came from and
    ``completed_at`` the tier-clock completion time.
    """

    __slots__ = ("tenant", "op", "count", "submitted_at", "deadline",
                 "generation", "completed_at", "_future")

    def __init__(self, tenant, op, count, submitted_at, deadline):
        self.tenant = tenant
        self.op = op
        self.count = count
        self.submitted_at = submitted_at
        self.deadline = deadline
        self.generation: Optional[int] = None
        self.completed_at: Optional[float] = None
        self._future: "concurrent.futures.Future" = (
            concurrent.futures.Future()
        )

    def result(self, timeout: Optional[float] = None):
        return self._future.result(timeout)

    def exception(self, timeout: Optional[float] = None):
        return self._future.exception(timeout)

    def done(self) -> bool:
        return self._future.done()

    @property
    def future(self) -> "concurrent.futures.Future":
        """The underlying future (asyncio front ends wrap this)."""
        return self._future


@dataclasses.dataclass(frozen=True)
class FlushEvent:
    """Passed to the ``on_flush`` hook after the snapshot is pinned and
    staged mutations swapped, *before* the read batch executes — the
    seam where 'mutation admitted mid-flush' semantics are observable
    (and tested)."""

    tenant: str
    generation: int
    reason: str
    requests: int
    applied_mutations: int


@dataclasses.dataclass
class _Queued:
    ticket: Ticket
    ls: np.ndarray
    rs: np.ndarray


class _Tenant:
    """Queue + slot + quota-bucket + metrics for one registered index."""

    def __init__(self, name: str, cfg: TenantConfig, slot: SnapshotSlot,
                 metrics: Metrics):
        self.name = name
        self.cfg = cfg
        self.slot = slot
        self.lock = threading.Lock()          # queue + quota state
        self.flush_lock = threading.Lock()    # one flush at a time
        self.queue: Deque[_Queued] = deque()
        self.queued_queries = 0
        self.mutation_deadline: Optional[float] = None
        self.tokens = float(cfg.quota_burst or cfg.quota_qps or 0.0)
        self.last_refill: Optional[float] = None
        m = metrics
        self.m_submits = m.counter("submits")
        self.m_submitted_queries = m.counter("submitted_queries")
        self.m_rejected_queue = m.counter("rejected_queue_full")
        self.m_rejected_quota = m.counter("rejected_quota")
        self.m_flushes = m.counter("flushes")
        self.m_flush_deadline = m.counter("flushes_deadline")
        self.m_flush_size = m.counter("flushes_size")
        self.m_flush_mutation = m.counter("flushes_mutation")
        self.m_flush_forced = m.counter("flushes_forced")
        self.m_bulk = m.counter("bulk_routed")
        self.m_failed = m.counter("failed_requests")
        self.m_mut_staged = m.counter("mutations_staged")
        self.m_mut_applied = m.counter("mutations_applied")
        self.m_swaps = m.counter("snapshot_swaps")
        self.m_dropped = m.counter("dropped_results")
        self.m_deadline_miss = m.counter("deadline_misses")
        self.m_latency = m.histogram("latency_s", LATENCY_BUCKETS)
        self.m_batch = m.histogram("flush_queries", SIZE_BUCKETS)
        self.m_depth = m.histogram("queue_depth", SIZE_BUCKETS)


class ServingTier:
    """Multi-tenant deadline batcher over one :class:`QueryService`.

    Drive it one of three ways:

    * ``tier.start()`` — background flusher thread (production shape;
      pair with the default ``time.monotonic`` clock);
    * :class:`repro.serving.aio.AsyncServingTier` — asyncio pump, no
      thread;
    * ``tier.step(now)`` / ``tier.drain(name)`` — manual, with an
      injectable ``clock`` for deterministic tests.
    """

    def __init__(
        self,
        service: Optional[QueryService] = None,
        *,
        clock: Callable[[], float] = time.monotonic,
        metrics: Optional[Metrics] = None,
        idle_tick: float = 0.05,
        on_flush: Optional[Callable[[FlushEvent], None]] = None,
        tuning=None,
    ):
        self.metrics = metrics if metrics is not None else Metrics()
        if service is None:
            # the tier owns flush timing; the service must never flush
            # behind its back on a max_pending crossing.  A tier-owned
            # service also joins the tier's metrics tree (engine scopes
            # included) so one to_prometheus() covers the whole stack.
            # A TuningCache passed here reaches every per-tenant engine
            # the service constructs (self-configured geometry knobs).
            service = QueryService(auto_flush=False,
                                   metrics=self.metrics.scope("service"),
                                   tuning=tuning)
        elif tuning is not None:
            raise ValueError(
                "pass tuning via the QueryService when supplying an "
                "explicit service")
        self._service = service
        self._service_lock = threading.Lock()
        self._clock = clock
        self._idle_tick = float(idle_tick)
        self._on_flush = on_flush
        self._tenant_metrics = self.metrics.scope(
            "tenants", child_label="tenant")
        self._m_steps = self.metrics.counter("steps")
        self._m_errors = self.metrics.counter("flusher_errors")
        self._tenants: Dict[str, _Tenant] = {}
        self._tenants_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        self._wake = threading.Event()
        service.on_dropped_result = self._count_drop

    # -- registry ---------------------------------------------------------
    def register_tenant(
        self,
        name: str,
        index,
        *,
        slo_ms: float = 5.0,
        max_queue: int = 8192,
        max_batch: int = 4096,
        quota_qps: Optional[float] = None,
        quota_burst: Optional[float] = None,
        **engine_kwargs,
    ):
        """Register ``index`` under ``name`` with its serving SLO.

        Returns the tenant's :class:`~repro.qe.engine.QueryEngine` (the
        same object ``QueryService.register`` creates).
        """
        cfg = TenantConfig(slo_ms=slo_ms, max_queue=max_queue,
                           max_batch=max_batch, quota_qps=quota_qps,
                           quota_burst=quota_burst)
        with self._tenants_lock:
            if name in self._tenants:
                raise ValueError(f"tenant {name!r} already registered")
            with self._service_lock:
                engine = self._service.register(name, index,
                                                **engine_kwargs)
            self._tenants[name] = _Tenant(
                name, cfg, SnapshotSlot(index),
                self._tenant_metrics.scope(name),
            )
        return engine

    def unregister_tenant(self, name: str) -> None:
        tenant = self._tenant(name)
        self.drain(name)
        with self._tenants_lock:
            with self._service_lock:
                self._service.unregister(name)
            del self._tenants[name]
        for q in tenant.queue:     # post-drain submits lose their home
            q.ticket._future.set_exception(
                KeyError(f"tenant {name!r} unregistered")
            )

    def tenant_config(self, name: str) -> TenantConfig:
        return self._tenant(name).cfg

    def _tenant(self, name: str) -> _Tenant:
        t = self._tenants.get(name)
        if t is None:
            raise KeyError(
                f"no tenant registered as {name!r}; "
                f"have {sorted(self._tenants)}"
            )
        return t

    @property
    def service(self) -> QueryService:
        """The underlying service.  While the tier is running, mutate it
        only through tier methods (the flusher owns its lock)."""
        return self._service

    # -- admission --------------------------------------------------------
    def submit(self, name: str, ls, rs, op: str = VALUE,
               slo_ms: Optional[float] = None) -> Ticket:
        """Enqueue a read; non-blocking.  Raises :class:`Backpressure`
        when the tenant's queue bound or quota rejects it.

        Oversized read-only submissions — more queries than the
        tenant's ``max_batch`` — cannot ride the deadline queue: they
        would either be unadmittable forever (``m > max_queue``) or
        monopolise a flush the SLO sized for interactive traffic.
        They route to the engine's offline bulk path instead
        (:meth:`QueryService.submit_bulk` →
        :meth:`~repro.qe.engine.QueryEngine.query_bulk`): no
        micro-batching, no LRU, one coalesced pass per sorted bucket.
        The returned :class:`Ticket` is already resolved when this
        returns (the bulk sweep runs inline on the caller's thread),
        answered against the tenant's *current* front generation —
        staged mutations keep waiting for the next flush, exactly as a
        queued read admitted before the swap would.  Quota admission
        still applies; only the queue bound is bypassed.
        """
        tenant = self._tenant(name)
        tr = trace.current()
        sp = tr.begin("submit") if tr is not None else None
        admitted = False
        try:
            with self._service_lock:
                ls, rs = self._service.validate_request(name, ls, rs, op)
            m = int(ls.shape[0])
            now = self._clock()
            cfg = tenant.cfg
            bulk = m > cfg.max_batch
            asp = tr.begin("admission") if tr is not None else None
            try:
                with tenant.lock:
                    if cfg.quota_qps is not None:
                        if tenant.last_refill is None:
                            tenant.last_refill = now
                        tenant.tokens = min(
                            float(cfg.quota_burst or cfg.quota_qps),
                            tenant.tokens
                            + (now - tenant.last_refill) * cfg.quota_qps,
                        )
                        tenant.last_refill = now
                        if tenant.tokens < m:
                            tenant.m_rejected_quota.inc()
                            raise Backpressure(
                                name, "quota",
                                (m - tenant.tokens) / cfg.quota_qps,
                            )
                        tenant.tokens -= m
                    if not bulk:
                        if tenant.queued_queries + m > cfg.max_queue:
                            tenant.m_rejected_queue.inc()
                            head = tenant.queue[0].ticket.deadline \
                                if tenant.queue else now + cfg.slo_ms / 1e3
                            raise Backpressure(
                                name, "queue_full",
                                max(head - now, 0.0) + 1e-4,
                            )
                    deadline = now + (slo_ms if slo_ms is not None
                                      else cfg.slo_ms) / 1e3
                    ticket = Ticket(name, op, m, now, deadline)
                    if not bulk:
                        tenant.queue.append(_Queued(ticket, ls, rs))
                        tenant.queued_queries += m
                    depth = tenant.queued_queries
                admitted = True
            finally:
                if tr is not None:
                    tr.end(asp, tenant=name, queries=m,
                           admitted=admitted, bulk=bulk)
            tenant.m_submits.inc()
            tenant.m_submitted_queries.inc(m)
            tenant.m_depth.record(depth)
            if bulk:
                self._execute_bulk(tenant, ticket, ls, rs)
            else:
                self._wake.set()
            return ticket
        finally:
            if tr is not None:
                tr.end(sp, tenant=name, op=op, admitted=admitted)

    def _execute_bulk(self, tenant: _Tenant, ticket: Ticket,
                      ls: np.ndarray, rs: np.ndarray) -> None:
        """Resolve one oversized read inline via the bulk path.

        Bypasses the deadline queue and the flush cycle entirely.  The
        snapshot pin brackets the service call so a concurrent flush's
        generation swap cannot retire the index mid-read; the recorded
        ``generation`` is the front the service is attached to —
        ``flush_lock`` excludes the window inside a flush where the
        slot has swapped but the service has not re-attached yet (same
        lock order as :meth:`_flush_tenant`: flush, then service)."""
        tenant.m_bulk.inc()
        with tenant.flush_lock:
            snap = tenant.slot.pin()
            try:
                with self._service_lock:
                    st = self._service.submit_bulk(
                        tenant.name, ls, rs, ticket.op)
                    res = self._service.take(st)
            except Exception as e:
                ticket._future.set_exception(e)
                tenant.m_failed.inc()
                return
            finally:
                snap.release()
        now = self._clock()
        ticket.generation = snap.generation
        ticket.completed_at = now
        tenant.m_latency.record(now - ticket.submitted_at)
        ticket._future.set_result(res)

    # -- mutation staging -------------------------------------------------
    def update(self, name: str, idxs, vals) -> None:
        """Stage a batched point update; O(1), never blocks on reads."""
        self._stage(name, "update", (idxs, vals))

    def append(self, name: str, vals) -> None:
        self._stage(name, "append", (vals,))

    def replace_index(self, name: str, index) -> None:
        """Stage a wholesale successor index (supersedes earlier staged
        ops; see :meth:`SnapshotSlot.stage_replace`)."""
        self._stage(name, "replace", (index,))

    def _stage(self, name, kind, args) -> None:
        tenant = self._tenant(name)
        slot = tenant.slot
        if kind == "update":
            slot.stage_update(*args)
        elif kind == "append":
            slot.stage_append(*args)
        else:
            slot.stage_replace(*args)
        tenant.m_mut_staged.inc()
        now = self._clock()
        with tenant.lock:
            d = now + tenant.cfg.slo_ms / 1e3
            if tenant.mutation_deadline is None \
                    or d < tenant.mutation_deadline:
                tenant.mutation_deadline = d
        self._wake.set()

    # -- the scheduler ----------------------------------------------------
    def step(self, now: Optional[float] = None) -> Optional[float]:
        """Flush every tenant that is due; return the earliest pending
        deadline (None when fully idle).  This is the whole scheduler —
        the thread/asyncio drivers just call it in a loop."""
        now = self._clock() if now is None else now
        self._m_steps.inc()
        nxt: Optional[float] = None
        with self._tenants_lock:
            tenants = list(self._tenants.values())
        for tenant in tenants:
            reason = self._due_reason(tenant, now)
            if reason is not None:
                self._flush_tenant(tenant, reason)
            d = self._next_deadline(tenant)
            if d is not None:
                nxt = d if nxt is None else min(nxt, d)
        return nxt

    @staticmethod
    def _due_reason(tenant: _Tenant, now: float) -> Optional[str]:
        with tenant.lock:
            if tenant.queued_queries >= tenant.cfg.max_batch:
                return "size"
            if tenant.queue and tenant.queue[0].ticket.deadline <= now:
                return "deadline"
            if tenant.mutation_deadline is not None \
                    and tenant.mutation_deadline <= now:
                return "mutation"
        return None

    @staticmethod
    def _next_deadline(tenant: _Tenant) -> Optional[float]:
        with tenant.lock:
            ds = []
            if tenant.queue:
                ds.append(tenant.queue[0].ticket.deadline)
            if tenant.mutation_deadline is not None:
                ds.append(tenant.mutation_deadline)
        return min(ds) if ds else None

    def drain(self, name: str) -> int:
        """Force an immediate flush of one tenant (sync callers, tests).
        Returns the number of requests resolved."""
        tenant = self._tenant(name)
        return self._flush_tenant(tenant, "forced")

    def flush_all(self) -> int:
        with self._tenants_lock:
            tenants = list(self._tenants.values())
        return sum(self._flush_tenant(t, "forced") for t in tenants)

    # -- one flush cycle --------------------------------------------------
    def _flush_tenant(self, tenant: _Tenant, reason: str) -> int:
        tr = trace.current()
        with tenant.flush_lock:
            sp = tr.begin("flush") if tr is not None else None
            batch: List[_Queued] = []
            applied = 0
            generation = -1
            try:
                with tenant.lock:
                    batch = list(tenant.queue)
                    tenant.queue.clear()
                    tenant.queued_queries = 0
                    tenant.mutation_deadline = None
                if tr is not None:
                    # the queue wait is a cross-thread edge (submitted on
                    # a caller thread, drained here) — record it
                    # retroactively from the ticket's own timestamps
                    drained = self._clock()
                    for q in batch:
                        tr.record("queue", q.ticket.submitted_at, drained,
                                  parent=sp, tenant=tenant.name,
                                  queries=q.ticket.count)
                # 1. generation swap: staged mutations fold into the
                #    successor and publish BEFORE any read executes — a
                #    flush never observes a half-applied batch, and
                #    mutations staged from here on wait for the next
                #    cycle.
                ssp = tr.begin("snapshot_swap") if tr is not None else None
                front, applied = tenant.slot.swap()
                if applied:
                    with self._service_lock:
                        self._service.attach(tenant.name, front)
                    tenant.m_swaps.inc()
                    tenant.m_mut_applied.inc(applied)
                if tr is not None:
                    tr.end(ssp, applied=applied)
                if not batch and not applied and reason == "forced":
                    return 0
                # 2. pin the snapshot every request in this flush answers
                #    against (concurrent staging cannot move it).
                snap = tenant.slot.pin()
                generation = snap.generation
                try:
                    if self._on_flush is not None:
                        self._on_flush(FlushEvent(
                            tenant.name, snap.generation, reason,
                            len(batch), applied,
                        ))
                    if batch:
                        self._execute(tenant, batch, snap.generation)
                finally:
                    snap.release()
                tenant.m_flushes.inc()
                {
                    "deadline": tenant.m_flush_deadline,
                    "size": tenant.m_flush_size,
                    "mutation": tenant.m_flush_mutation,
                    "forced": tenant.m_flush_forced,
                }[reason].inc()
                tenant.m_batch.record(sum(q.ticket.count for q in batch))
                return len(batch)
            finally:
                if tr is not None:
                    tr.end(sp, tenant=tenant.name, reason=reason,
                           requests=len(batch), applied=applied,
                           generation=generation)

    def _execute(self, tenant: _Tenant, batch: List[_Queued],
                 generation: int) -> None:
        """Funnel the drained queue through one service flush and
        scatter results/failures back to tickets."""
        svc = self._service
        with self._service_lock:
            stickets: List[Optional[int]] = []
            for q in batch:
                try:
                    stickets.append(
                        svc.submit(tenant.name, q.ls, q.rs, q.ticket.op)
                    )
                except Exception as e:   # late validation (e.g. swap
                    stickets.append(None)             # dropped positions)
                    q.ticket._future.set_exception(e)
                    tenant.m_failed.inc()
            flush_err: Optional[Exception] = None
            try:
                svc.flush(names=(tenant.name,))
            except RuntimeError as e:
                # per-(index, op)-group isolation: healthy groups'
                # results are stored and claimed below
                flush_err = e
            now = self._clock()
            for q, st in zip(batch, stickets):
                if st is None:
                    continue
                try:
                    res = svc.take(st)
                except KeyError:
                    q.ticket._future.set_exception(
                        flush_err if flush_err is not None else
                        RuntimeError(
                            f"flush produced no result for ticket {st}"
                        )
                    )
                    tenant.m_failed.inc()
                    continue
                q.ticket.generation = generation
                q.ticket.completed_at = now
                lat = now - q.ticket.submitted_at
                tenant.m_latency.record(lat)
                if now > q.ticket.deadline \
                        + tenant.cfg.slo_ms / 1e3:
                    tenant.m_deadline_miss.inc()
                q.ticket._future.set_result(res)

    # -- sync convenience -------------------------------------------------
    def query(self, name: str, ls, rs, op: str = VALUE,
              timeout: Optional[float] = None):
        """submit + wait.  Without a running flusher the tier drains the
        tenant inline (callers in a synchronous loop — e.g. the KV-cache
        eviction tenant — still get one coalesced flush)."""
        ticket = self.submit(name, ls, rs, op)
        if not self.running:
            self.drain(name)
        return ticket.result(timeout)

    # -- drivers ----------------------------------------------------------
    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "ServingTier":
        """Run the deadline flusher on a background daemon thread."""
        if self.running:
            raise RuntimeError("serving tier is already running")
        self._stop_evt.clear()
        self._thread = threading.Thread(
            target=self._run, name="serving-tier-flusher", daemon=True
        )
        self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        if self._thread is not None:
            self._stop_evt.set()
            self._wake.set()
            self._thread.join()
            self._thread = None
        if drain:
            self.flush_all()

    def __enter__(self) -> "ServingTier":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _run(self) -> None:
        while not self._stop_evt.is_set():
            try:
                trace.instant("pump_wakeup", driver="thread")
                nxt = self.step()
            except Exception:
                # a tenant's flush failure resolves its tickets with the
                # exception; the scheduler itself must keep breathing
                self._m_errors.inc()
                nxt = None
            now = self._clock()
            timeout = self._idle_tick if nxt is None else \
                min(max(nxt - now, 0.0), self._idle_tick)
            if self._wake.wait(timeout):
                self._wake.clear()

    # -- telemetry --------------------------------------------------------
    def _count_drop(self, name: str, ticket: int) -> None:
        tenant = self._tenants.get(name)
        if tenant is not None:
            tenant.m_dropped.inc()

    def stats(self) -> dict:
        """Plain-dict telemetry: tier metrics + per-tenant snapshot/slot
        state + the underlying service's own counters."""
        out = self.metrics.as_dict()
        for name, tenant in self._tenants.items():
            out["tenants"].setdefault(name, {})["snapshot"] = \
                tenant.slot.stats()
            out["tenants"][name]["queued_queries"] = \
                tenant.queued_queries
        out["service"] = self._service.stats()
        return out
