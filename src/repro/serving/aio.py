"""Asyncio front end for :class:`repro.serving.tier.ServingTier`.

Two pieces, composable:

* :class:`AsyncServingTier` — ``await``-able submit/query wrappers.
  Tickets are ``concurrent.futures``-backed, so ``asyncio.wrap_future``
  bridges them onto the running loop with zero polling;
* :meth:`AsyncServingTier.pump` — the deadline scheduler as a coroutine:
  the same :meth:`ServingTier.step` loop the thread driver runs, but on
  the event loop via ``asyncio.sleep`` — a pure-asyncio application
  needs no background thread at all.

Use either the pump *or* ``tier.start()``'s thread, not both.
"""

from __future__ import annotations

import asyncio
from typing import Optional

from repro.obs import trace
from repro.qe.executors import VALUE
from repro.serving.tier import ServingTier, Ticket

__all__ = ["AsyncServingTier"]


class AsyncServingTier:
    """Awaitable facade over a (shared) :class:`ServingTier`."""

    def __init__(self, tier: ServingTier, min_sleep: float = 1e-4):
        self._tier = tier
        self._min_sleep = float(min_sleep)
        self._pumping = False

    @property
    def tier(self) -> ServingTier:
        return self._tier

    # -- awaitable request surface ----------------------------------------
    def submit(self, name: str, ls, rs, op: str = VALUE,
               slo_ms: Optional[float] = None) -> Ticket:
        """Synchronous enqueue (admission control may raise
        :class:`~repro.serving.tier.Backpressure`); await the result via
        :meth:`wait` or :meth:`query`."""
        return self._tier.submit(name, ls, rs, op, slo_ms=slo_ms)

    async def wait(self, ticket: Ticket):
        return await asyncio.wrap_future(ticket.future)

    async def query(self, name: str, ls, rs, op: str = VALUE,
                    slo_ms: Optional[float] = None):
        """submit + await — resolves when the deadline batcher flushes
        the tenant (run :meth:`pump` or ``tier.start()`` so it does)."""
        return await self.wait(self.submit(name, ls, rs, op,
                                           slo_ms=slo_ms))

    # -- mutation passthrough (already non-blocking) ----------------------
    def update(self, name: str, idxs, vals) -> None:
        self._tier.update(name, idxs, vals)

    def append(self, name: str, vals) -> None:
        self._tier.append(name, vals)

    def replace_index(self, name: str, index) -> None:
        self._tier.replace_index(name, index)

    # -- the event-loop driver --------------------------------------------
    async def pump(self, stop: Optional[asyncio.Event] = None) -> None:
        """Drive the deadline scheduler on the event loop.

        Sleeps until the earliest pending deadline (capped at the tier's
        idle tick so new submits are picked up promptly), flushing due
        tenants each wakeup.  Cancel the task or set ``stop`` to end it;
        queued work is drained on the way out so no ticket is left
        hanging.
        """
        if self._tier.running:
            raise RuntimeError(
                "tier already has a thread driver; use one driver only"
            )
        if self._pumping:
            raise RuntimeError("pump() is already running")
        self._pumping = True
        try:
            while stop is None or not stop.is_set():
                trace.instant("pump_wakeup", driver="asyncio")
                nxt = self._tier.step()
                now = self._tier._clock()
                delay = self._tier._idle_tick if nxt is None else \
                    min(max(nxt - now, self._min_sleep),
                        self._tier._idle_tick)
                await asyncio.sleep(delay)
        finally:
            self._pumping = False
            self._tier.flush_all()
