"""Async serving tier: deadline micro-batching, snapshot-isolated reads,
admission control and telemetry over the fused query path.

The layer cake, bottom-up:

* ``repro.qe.QueryService`` — multi-index registry + coalescing (one
  fused launch per flushed mixed batch), flush timing caller-driven;
* :class:`~repro.serving.snapshot.SnapshotSlot` — double-buffered index
  per tenant: immutable *front* serves reads, mutations stage onto the
  *back* log and swap in atomically between flushes;
* :class:`~repro.serving.tier.ServingTier` — the deadline scheduler:
  per-tenant latency SLOs and size triggers decide when to flush,
  bounded queues + token-bucket quotas reject with
  :class:`~repro.serving.tier.Backpressure` instead of growing, and
  every submit returns a Future-style
  :class:`~repro.serving.tier.Ticket`;
* :class:`~repro.serving.aio.AsyncServingTier` — the same tier behind
  ``await``, with an event-loop pump replacing the flusher thread;
* :mod:`repro.obs.metrics` (re-exported here as
  ``repro.serving.metrics``) — counters/gauges/histograms for submits,
  flushes, batch sizes, queue depth, rejections and snapshot swaps,
  exported as one plain dict (:meth:`ServingTier.stats`) or Prometheus
  text (``tier.metrics.to_prometheus()``); request-lifecycle tracing
  comes from :mod:`repro.obs.trace` (install a tracer with
  ``trace.use_tracer`` and export ``tracer.to_chrome_trace()``).
"""

from repro.serving.metrics import Counter, Gauge, Histogram, Metrics
from repro.serving.snapshot import Snapshot, SnapshotSlot
from repro.serving.tier import (
    Backpressure,
    FlushEvent,
    ServingTier,
    TenantConfig,
    Ticket,
)

__all__ = [
    "AsyncServingTier",
    "Backpressure",
    "Counter",
    "FlushEvent",
    "Gauge",
    "Histogram",
    "Metrics",
    "ServingTier",
    "Snapshot",
    "SnapshotSlot",
    "TenantConfig",
    "Ticket",
]


def __getattr__(name):
    # asyncio front end imported lazily: the tier itself stays importable
    # in stripped-down environments without the asyncio machinery loaded
    if name == "AsyncServingTier":
        from repro.serving.aio import AsyncServingTier

        return AsyncServingTier
    raise AttributeError(name)
