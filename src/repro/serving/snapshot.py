"""Double-buffered index snapshots: readers pin a generation, mutations
stage onto a log that swaps in between flushes.

The RMQ indexes are pure-functional (``update``/``append`` return a
*successor* with ``generation + 1``), which makes snapshot isolation
cheap — but the query service alone doesn't provide it: a caller that
attaches a successor mid-flush changes what later groups in the same
flush observe.  :class:`SnapshotSlot` closes that hole with the classic
double-buffer discipline:

* the **front** buffer is the currently-served index.  It is immutable;
  a reader that pinned it keeps bit-stable answers no matter what
  happens concurrently;
* the **back** buffer is a staged-mutation log (``update`` / ``append``
  / ``replace`` records).  Staging is O(1) and never blocks on reads —
  mutations admit while a long flush drains;
* :meth:`swap` folds the staged log into a successor chain and publishes
  it as the new front in one atomic reference move.  A half-applied
  batch is unobservable by construction: readers see the old front until
  the *entire* log has been applied.

``swap`` is written for a single swapper (the serving tier's flusher
owns it); concurrent *staging* and *pinning* from any number of threads
is supported.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Deque, Tuple

__all__ = ["Snapshot", "SnapshotSlot"]

_UPDATE, _APPEND, _REPLACE = "update", "append", "replace"


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """A pinned read view: one index object, one generation, forever."""

    index: object
    generation: int
    _slot: "SnapshotSlot" = dataclasses.field(repr=False)

    def release(self) -> None:
        self._slot._release()


class SnapshotSlot:
    """Front/back double buffer over one pure-functional RMQ index."""

    def __init__(self, index):
        self._lock = threading.Lock()
        self._front = index
        self._staged: Deque[Tuple[str, tuple]] = deque()
        self._pins = 0
        self.swaps = 0
        self.staged_total = 0

    # -- read side --------------------------------------------------------
    @property
    def front(self):
        return self._front

    @property
    def generation(self) -> int:
        return getattr(self._front, "generation", 0)

    @property
    def pins(self) -> int:
        """Readers currently draining against a pinned snapshot."""
        return self._pins

    def pin(self) -> Snapshot:
        with self._lock:
            self._pins += 1
            return Snapshot(self._front, self.generation, self)

    def _release(self) -> None:
        with self._lock:
            if self._pins <= 0:
                raise RuntimeError("release() without a matching pin()")
            self._pins -= 1

    # -- write side -------------------------------------------------------
    def stage_update(self, idxs, vals) -> None:
        self._stage(_UPDATE, (idxs, vals))

    def stage_append(self, vals) -> None:
        self._stage(_APPEND, (vals,))

    def stage_replace(self, index) -> None:
        """Stage a wholesale successor (e.g. a caller-built new index).

        Replaces stack with the earlier staged ops: ops staged *before*
        it are superseded (the replacement index is the caller's own
        fold of whatever state it wanted), ops staged after apply on
        top.
        """
        with self._lock:
            self._staged.clear()
            self._staged.append((_REPLACE, (index,)))
            self.staged_total += 1

    def _stage(self, kind, args) -> None:
        with self._lock:
            self._staged.append((kind, args))
            self.staged_total += 1

    @property
    def staged(self) -> int:
        return len(self._staged)

    # -- the swap ---------------------------------------------------------
    def swap(self) -> Tuple[object, int]:
        """Apply the staged log, publish the successor, return it.

        Returns ``(front, n_applied)``; ``n_applied == 0`` means nothing
        was staged and the front is unchanged.  Single-swapper contract:
        only one thread (the tier's flusher) may call this — staging and
        pinning stay safe from any thread throughout.
        """
        with self._lock:
            staged = list(self._staged)
            self._staged.clear()
            front = self._front
        if not staged:
            return front, 0
        # Fold outside the lock: successor construction runs real device
        # work, and staging/pinning must not block behind it.  Readers
        # keep the old front until the publish below.
        for kind, args in staged:
            if kind == _UPDATE:
                front = front.update(*args)
            elif kind == _APPEND:
                front = front.append(*args)
            else:
                front = args[0]
        with self._lock:
            self._front = front
            self.swaps += 1
        return front, len(staged)

    def stats(self) -> dict:
        return {
            "generation": self.generation,
            "pins": self._pins,
            "staged": len(self._staged),
            "staged_total": self.staged_total,
            "swaps": self.swaps,
        }
