"""Back-compat shim: this module moved to :mod:`repro.obs.metrics`.

The serving tier was the first metrics consumer, but the engine and
query service now share the same registry tree, so the implementation
lives in the cross-cutting ``repro.obs`` package.  Existing imports
(``from repro.serving.metrics import Metrics``) keep working via this
re-export.
"""

from repro.obs.metrics import (  # noqa: F401
    LATENCY_BUCKETS,
    SIZE_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    Metrics,
)

__all__ = ["Counter", "Gauge", "Histogram", "Metrics", "LATENCY_BUCKETS",
           "SIZE_BUCKETS"]
