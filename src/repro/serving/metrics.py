"""Serving-tier telemetry: counters + latency histograms, dict export.

Deliberately dependency-free (no prometheus client in the container):
monotonic :class:`Counter`\\ s and fixed-bucket :class:`Histogram`\\ s
collected in a :class:`Metrics` registry whose :meth:`Metrics.as_dict`
emits a plain nested dict — the exchange format tests, benchmarks and
examples consume directly.  Everything is lock-protected: the tier's
flusher thread and caller threads record concurrently (``x += 1`` on an
attribute is NOT atomic under the GIL).

Registries nest: ``metrics.scope("tenants").scope("search")`` gives each
tenant its own namespace inside one exported tree.  Metric objects are
created lazily on first touch and are stable thereafter, so hot paths
can hold a reference (``self._submits = scope.counter("submits")``)
instead of re-resolving names per call.
"""

from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence, Union

__all__ = ["Counter", "Histogram", "Metrics", "LATENCY_BUCKETS",
           "SIZE_BUCKETS"]

# Log-spaced seconds from 10us to ~10s — spans a sub-millisecond SLO and
# a pathological multi-second stall in the same histogram.
LATENCY_BUCKETS = tuple(1e-5 * (10 ** (i / 3.0)) for i in range(19))

# Pow2 batch/queue-depth buckets up to the fused bucket ceiling.
SIZE_BUCKETS = tuple(float(1 << i) for i in range(15))


class Counter:
    """Monotonic counter (thread-safe)."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value

    def as_dict(self) -> int:
        return self._value


class Histogram:
    """Fixed-bucket histogram with count/sum/min/max + bucket percentiles.

    ``bounds`` are bucket *upper* edges; an implicit +inf bucket catches
    the overflow.  :meth:`percentile` answers from bucket edges (clamped
    to the observed max), so it is a bounded-error estimate — callers
    needing exact tail latencies keep their own sample list and use this
    for the exported summary.
    """

    __slots__ = ("_lock", "bounds", "counts", "count", "total",
                 "vmin", "vmax")

    def __init__(self, bounds: Sequence[float] = LATENCY_BUCKETS):
        self._lock = threading.Lock()
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError("histogram bounds must be strictly increasing")
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")

    def record(self, value: float) -> None:
        value = float(value)
        lo, hi = 0, len(self.bounds)
        while lo < hi:                      # first bucket with bound >= value
            mid = (lo + hi) // 2
            if value <= self.bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        with self._lock:
            self.counts[lo] += 1
            self.count += 1
            self.total += value
            self.vmin = min(self.vmin, value)
            self.vmax = max(self.vmax, value)

    def percentile(self, q: float) -> float:
        """Upper-edge estimate of the ``q``-quantile (q in [0, 1])."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        with self._lock:
            if self.count == 0:
                return 0.0
            rank = q * self.count
            seen = 0
            for i, c in enumerate(self.counts):
                seen += c
                if seen >= rank and c:
                    edge = (self.bounds[i] if i < len(self.bounds)
                            else self.vmax)
                    return min(edge, self.vmax)
            return self.vmax

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def as_dict(self) -> dict:
        with self._lock:
            if self.count == 0:
                return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                        "mean": 0.0, "p50": 0.0, "p99": 0.0}
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.vmin,
            "max": self.vmax,
            "mean": self.mean,
            "p50": self.percentile(0.50),
            "p99": self.percentile(0.99),
        }


class Metrics:
    """Lazy registry of named counters/histograms + nested scopes."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Union[Counter, Histogram]] = {}
        self._scopes: Dict[str, "Metrics"] = {}

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, ())

    def histogram(
        self, name: str, bounds: Optional[Sequence[float]] = None
    ) -> Histogram:
        return self._get(name, Histogram,
                         (bounds if bounds is not None else LATENCY_BUCKETS,))

    def scope(self, name: str) -> "Metrics":
        with self._lock:
            if name in self._metrics:
                raise ValueError(f"{name!r} is already a metric here")
            scope = self._scopes.get(name)
            if scope is None:
                scope = self._scopes[name] = Metrics()
            return scope

    def _get(self, name, cls, args):
        with self._lock:
            if name in self._scopes:
                raise ValueError(f"{name!r} is already a scope here")
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(*args)
            elif not isinstance(m, cls):
                raise ValueError(
                    f"{name!r} is a {type(m).__name__}, not {cls.__name__}"
                )
            return m

    def as_dict(self) -> dict:
        with self._lock:
            metrics = dict(self._metrics)
            scopes = dict(self._scopes)
        out = {name: m.as_dict() for name, m in metrics.items()}
        for name, scope in scopes.items():
            out[name] = scope.as_dict()
        return out
