"""Version compatibility shims for the JAX APIs this repo straddles.

The codebase targets the modern ``jax.shard_map`` entry point (with its
``check_vma`` argument); older jaxlibs only ship
``jax.experimental.shard_map.shard_map`` (whose equivalent knob is spelled
``check_rep``).  ``shard_map`` below presents the modern keyword surface on
both.

The replication-check keyword is detected by *keyword support*
(``inspect.signature``), never by which module the function lives in:
mid-band JAX versions promoted ``shard_map`` to ``jax.shard_map`` while it
still only accepted ``check_rep``, so probing by attribute location would
pass the wrong keyword there.
"""

from __future__ import annotations

import functools
import inspect

import jax

__all__ = ["shard_map", "cost_analysis_dict"]


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a dict on every JAX version.

    Newer JAX returns the flat dict directly; older versions return a
    one-element list of per-computation dicts.
    """
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def _resolve_shard_map():
    """The installed shard_map entry point (modern location preferred)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map
    from jax.experimental.shard_map import shard_map as _legacy

    return _legacy


@functools.lru_cache(maxsize=8)
def _replication_check_kwarg(fn) -> str:
    """The replication-check keyword ``fn`` actually accepts.

    Decided by signature, NOT by where the function lives: the modern
    ``jax.shard_map`` spelling pre-dates the ``check_vma`` rename in some
    releases (they accept only ``check_rep``), so the two properties are
    independent.  Falls back to ``check_vma`` when the signature is not
    introspectable (builtins/wrappers) — the modern default.
    """
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return "check_vma"
    if "check_vma" in params:
        return "check_vma"
    if "check_rep" in params:
        return "check_rep"
    if any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()
    ):
        return "check_vma"  # **kwargs: pass the modern spelling through
    return ""  # accepts neither: omit the knob entirely


def shard_map(f=None, *, mesh, in_specs, out_specs, check_vma=True):
    """``jax.shard_map`` on new JAX, ``jax.experimental.shard_map`` on old.

    Usable both as a direct call and inside ``functools.partial`` the way
    ``jax.shard_map`` is (``f`` first, keywords after).  The replication
    check is forwarded under whichever keyword the installed version
    supports (``check_vma`` or the older ``check_rep``).
    """
    impl = _resolve_shard_map()
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    kw = _replication_check_kwarg(impl)
    if kw:
        kwargs[kw] = check_vma
    return impl(f, **kwargs)
