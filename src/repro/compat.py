"""Version compatibility shims for the JAX APIs this repo straddles.

The codebase targets the modern ``jax.shard_map`` entry point (with its
``check_vma`` argument); older jaxlibs only ship
``jax.experimental.shard_map.shard_map`` (whose equivalent knob is spelled
``check_rep``).  ``shard_map`` below presents the modern keyword surface on
both.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map", "cost_analysis_dict"]


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a dict on every JAX version.

    Newer JAX returns the flat dict directly; older versions return a
    one-element list of per-computation dicts.
    """
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def shard_map(f=None, *, mesh, in_specs, out_specs, check_vma=True):
    """``jax.shard_map`` on new JAX, ``jax.experimental.shard_map`` on old.

    Usable both as a direct call and inside ``functools.partial`` the way
    ``jax.shard_map`` is (``f`` first, keywords after).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _legacy

    return _legacy(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )
