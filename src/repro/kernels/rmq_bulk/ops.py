"""Jitted wrappers for the bulk (endpoint-sorted, coalesced) query pass.

One call = one device dispatch for an entire bucket of the sorted batch:

* **TPU** — the ``kernel.py`` ``pallas_call``: the fused query kernel
  with *conditional* level-0 DMA, so runs of queries sharing a boundary
  chunk copy it HBM→VMEM once instead of once per query.
* **elsewhere** — a single end-to-end-jitted jnp program realizing the
  same traffic contract: level 0 is read ONCE into a shared per-chunk
  sparse **ladder** (``ladder[j][row, i] = min`` over ``2^j`` in-chunk
  entries), built per dispatch and amortized over the whole bucket.
  Each query's prefix/suffix chunk pieces then cost two O(1) ladder
  lookups instead of two ``c``-wide masked window scans — the CPU
  analogue of the kernel's chunk reuse (every query sharing a chunk
  reads the same ladder rows).  Mid/long interiors are resolved through
  the *existing hierarchy*: the standard boundary walk over levels
  ``1..L-2`` plus an in-program sparse table over the hierarchy's own
  top level (exactly ``rmq_fused``'s top treatment, <= c·t entries).

Results are bit-identical to ``rmq_fused`` — values and leftmost-tie
positions.  The decompositions differ at chunk-aligned endpoints (bulk
covers a boundary chunk via the ladder where the walk covers it at
level 1), but both cover each query's range exactly with exact pieces
and merge lexicographically, so the (min, leftmost-pos) result is
identical; float min has no rounding, making overlap harmless.

Launch accounting: both lowerings call
:func:`repro.kernels.profiling.record_launch` (``"rmq_bulk"``) from
inside their traced bodies — one recorded launch per bucket is the
contract the CI smoke asserts via ``count_launches()``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import bitpack
from repro.core.baselines import SparseTable
from repro.core.constants import POS_INF_I32 as _POS_INF_I32
from repro.core.hierarchy import Hierarchy
from repro.core.plan import HierarchyPlan
from repro.kernels import profiling
from repro.kernels.rmq_bulk import kernel as K
from repro.kernels.rmq_scan.ref import _merge, _window

__all__ = [
    "rmq_bulk_batch",
    "rmq_bulk_value_batch",
    "rmq_bulk_index_batch",
]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _kernel_applicable(plan: HierarchyPlan) -> bool:
    return plan.num_levels >= 2 and plan.capacity >= plan.c


@functools.partial(jax.jit, static_argnames=("plan", "track_pos"))
def _bulk_jnp(base, upper, upper_pos, ls, rs, plan, track_pos):
    """One-dispatch jnp lowering: shared chunk ladder + hierarchy interior."""
    c = plan.c
    rows = -(-plan.capacity // c)
    profiling.record_launch(
        "rmq_bulk",
        lowering="jnp",
        queries=int(ls.shape[0]),
        levels=plan.num_levels,
        chunk=int(c),
        chunk_rows=int(rows),
        track_pos=bool(track_pos),
        operand_bytes=profiling.operand_bytes(
            base, upper, upper_pos, ls, rs),
    )
    num_levels = plan.num_levels
    logc = c.bit_length() - 1  # c is a power of two
    inf = jnp.array(jnp.inf, dtype=base.dtype)
    pos_inf = jnp.int32(_POS_INF_I32)
    # Packed planes unpack to absolute positions inside this same program.
    upper_pos = bitpack.resolve_positions(upper_pos, plan)

    # -- the shared per-chunk sparse ladder (the one level-0 read) --------
    # ladder[j][row, i] = min(chunk_row[i : i + 2^j]) clipped to the chunk
    # (the +inf shift-fill truncates at the chunk edge); positions carry
    # absolute level-0 indices so leftmost ties survive the merges.
    pad = rows * c - plan.capacity
    basep = (
        jnp.concatenate([base, jnp.full((pad,), inf, base.dtype)])
        if pad
        else base
    )
    chunks = basep.reshape(rows, c)
    lad = [chunks]
    plad = None
    if track_pos:
        abs_idx = (
            jax.lax.broadcasted_iota(jnp.int32, (rows, c), 0) * c
            + jax.lax.broadcasted_iota(jnp.int32, (rows, c), 1)
        )
        plad = [abs_idx]
    for j in range(1, logc + 1):
        half = 1 << (j - 1)
        prev = lad[-1]
        shifted = jnp.concatenate(
            [prev[:, half:], jnp.full((rows, half), inf, base.dtype)],
            axis=1,
        )
        if track_pos:
            pprev = plad[-1]
            pshift = jnp.concatenate(
                [pprev[:, half:],
                 jnp.full((rows, half), pos_inf, jnp.int32)],
                axis=1,
            )
            take2 = (shifted < prev) | ((shifted == prev) & (pshift < pprev))
            plad.append(jnp.where(take2, pshift, pprev))
        lad.append(jnp.minimum(prev, shifted))
    ladder = jnp.stack(lad)                     # (logc+1, rows, c)
    pladder = jnp.stack(plad) if track_pos else None

    # -- interior top: the hierarchy's own top level as a sparse table ----
    # (same in-program table as _fused_jnp; for a degenerate single-level
    # plan the "hierarchy top" for chunk-granular interiors is the chunk
    # minima, which the finished ladder already holds in column 0)
    if num_levels == 1:
        top = ladder[logc, :, 0]
        top_pos = pladder[logc, :, 0] if track_pos else None
    else:
        off, _ = plan.level_slice(num_levels - 1)
        top = jax.lax.slice(upper, (off,), (off + plan.top_len,))
        top_pos = (
            jax.lax.slice(upper_pos, (off,), (off + plan.top_len,))
            if track_pos
            else None
        )
    tbl = SparseTable.build(top, positions=top_pos)

    def chunk_lookup(chunk, lo, hi):
        """Exact (min, pos) over absolute ``[lo, hi)`` inside ``chunk``.

        Caller guarantees the range is nonempty and chunk-contained, so
        both pow2 lookups stay fully inside the chunk: two O(1) gathers
        replace a ``c``-wide masked window scan.
        """
        a = lo - chunk * c
        b = hi - 1 - chunk * c
        span = b - a + 1
        k = (31 - jax.lax.clz(span)).astype(jnp.int32)
        i2 = b + 1 - (1 << k.astype(jnp.uint32)).astype(jnp.int32)
        v1 = ladder[k, chunk, a]
        v2 = ladder[k, chunk, i2]
        if track_pos:
            return _merge(v1, pladder[k, chunk, a], v2, pladder[k, chunk, i2])
        return jnp.minimum(v1, v2), pos_inf

    def one(l, r):
        l = l.astype(jnp.int32)
        re = (r + 1).astype(jnp.int32)  # exclusive
        cla = l // c
        clb = (re - 1) // c
        # prefix / suffix pieces (always nonempty; same-chunk queries
        # cover the whole range twice — overlap is exact, so harmless)
        m, p = chunk_lookup(cla, l, jnp.minimum((cla + 1) * c, re))
        m2, p2 = chunk_lookup(clb, jnp.maximum(clb * c, l), re)
        m, p = _merge(m, p, m2, p2)

        # interior chunks [cla+1, clb) at level-1 coordinates, resolved
        # via the existing hierarchy: the boundary walk for levels
        # 1..L-2 (masks empty when the interior is), then the O(1) top
        li = cla + 1
        ri = clb
        for level in range(1, num_levels - 1):
            off, padded = plan.level_slice(level)
            arr = jax.lax.slice(upper, (off,), (off + padded,))
            pos_arr = (
                jax.lax.slice(upper_pos, (off,), (off + padded,))
                if track_pos
                else None
            )
            next_l = ((li + c - 1) // c) * c
            prev_r = (ri // c) * c
            m2, p2 = _window(arr, pos_arr, (li // c) * c, li,
                             jnp.minimum(next_l, ri), c, track_pos)
            m, p = _merge(m, p, m2, p2)
            m2, p2 = _window(arr, pos_arr, prev_r, jnp.maximum(prev_r, li),
                             ri, c, track_pos)
            m, p = _merge(m, p, m2, p2)
            li = (li + c - 1) // c
            ri = ri // c

        # O(1) sparse top over [li, ri) (empty range -> +inf, like hybrid)
        nonempty = ri > li
        rr = jnp.maximum(ri - 1, li)
        span = rr - li + 1
        j = (31 - jax.lax.clz(span)).astype(jnp.int32)
        r2 = rr + 1 - (1 << j.astype(jnp.uint32)).astype(jnp.int32)
        vl = tbl.table[j, li]
        vr = tbl.table[j, r2]
        if track_pos:
            tm, tp = _merge(vl, tbl.pos[j, li], vr, tbl.pos[j, r2])
        else:
            tm, tp = jnp.minimum(vl, vr), pos_inf
        tm = jnp.where(nonempty, tm, inf)
        tp = jnp.where(nonempty, tp, pos_inf)
        return _merge(m, p, tm, tp)

    vals, poss = jax.vmap(one)(ls, rs)
    if track_pos:
        return vals, poss
    return vals, None


@functools.partial(
    jax.jit, static_argnames=("plan", "qb", "track_pos", "interpret")
)
def _run_kernel(base, upper, upper_pos, ls, rs, plan, qb, track_pos,
                interpret):
    m = ls.shape[0]
    m_pad = -(-m // qb) * qb
    profiling.record_launch(
        "rmq_bulk",
        lowering="pallas",
        queries=int(m),
        grid=int(m_pad // qb),
        levels=plan.num_levels,
        chunk=int(plan.c),
        track_pos=bool(track_pos),
        operand_bytes=profiling.operand_bytes(
            base, upper, upper_pos, ls, rs),
    )
    if m_pad != m:
        ls = jnp.pad(ls, (0, m_pad - m))
        rs = jnp.pad(rs, (0, m_pad - m))
    upper_pos = bitpack.resolve_positions(upper_pos, plan)
    upper2d = upper.reshape(-1, plan.c)
    upos2d = upper_pos.reshape(-1, plan.c) if track_pos else None
    offs = jnp.asarray(plan.offsets, jnp.int32)
    vals, pos = K.rmq_bulk_pallas(
        base,
        upper2d,
        upos2d,
        offs,
        ls.astype(jnp.int32),
        rs.astype(jnp.int32),
        plan,
        qb=qb,
        track_pos=track_pos,
        interpret=interpret,
    )
    if track_pos:
        return vals[:m], pos[:m]
    return vals[:m], None


def rmq_bulk_batch(
    h: Hierarchy,
    ls: jax.Array,
    rs: jax.Array,
    track_pos: bool = False,
    qb: int = K.DEFAULT_QUERY_BLOCK,
    interpret: bool | None = None,
):
    """``(values, positions)`` for one bucket, one device dispatch.

    ``positions`` is ``None`` unless ``track_pos``.  ``interpret=None``
    picks the production lowering (kernel on TPU, the jnp ladder program
    elsewhere); ``interpret=True`` forces the kernel in interpreter mode
    (the correctness tool the test suite uses off-TPU).  Best throughput
    when ``(ls, rs)`` is sorted by ``(chunk(l), chunk(r))`` — the
    ``BulkExecutor`` owns that sort; unsorted input stays correct.
    """
    ls = jnp.asarray(ls, jnp.int32)
    rs = jnp.asarray(rs, jnp.int32)
    if track_pos and not h.with_positions:
        raise ValueError(
            "hierarchy was built without positions; "
            "use build_hierarchy(..., with_positions=True)"
        )
    if h.upper.dtype != h.base.dtype:
        raise ValueError(
            "the bulk path does not support bf16 summaries; route bf16 "
            "indexes through the engine's walk/fused paths instead"
        )
    plan = h.plan
    use_kernel = _kernel_applicable(plan) and (
        _on_tpu() if interpret is None else bool(interpret) or _on_tpu()
    )
    if use_kernel:
        itp = False if interpret is None else bool(interpret)
        return _run_kernel(
            h.base, h.upper, h.upper_pos if track_pos else None,
            ls, rs, plan, qb, track_pos, itp,
        )
    return _bulk_jnp(
        h.base, h.upper, h.upper_pos if track_pos else None,
        ls, rs, plan, track_pos,
    )


def rmq_bulk_value_batch(
    h: Hierarchy, ls, rs, qb: int = K.DEFAULT_QUERY_BLOCK,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched ``RMQ_value`` through the bulk coalesced path."""
    vals, _ = rmq_bulk_batch(
        h, ls, rs, track_pos=False, qb=qb, interpret=interpret
    )
    return vals


def rmq_bulk_index_batch(
    h: Hierarchy, ls, rs, qb: int = K.DEFAULT_QUERY_BLOCK,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched ``RMQ_index`` (leftmost minimum) through the bulk path."""
    _, pos = rmq_bulk_batch(
        h, ls, rs, track_pos=True, qb=qb, interpret=interpret
    )
    return pos
