"""Pallas TPU kernel: level-0-coalesced sweep over an endpoint-sorted batch.

``rmq_fused`` answers an arbitrary mixed batch in one launch, but pays
two level-0 chunk DMAs per query — for the offline bulk regime
(10^7+ queries, Grabowski & Kowalski's "Faster batched range minimum
queries") that re-reads the same chunks over and over, because a sorted
batch's consecutive queries overwhelmingly share boundary chunks.  This
kernel is the fused kernel with the level-0 traffic made *conditional*:

* **chunk-reuse DMA.**  The query loop carries the previous query's
  aligned window anchors; a boundary chunk is copied HBM→VMEM only when
  its anchor *changes* (``pl.when(a_start != prev_a)``).  On a batch
  sorted by ``(chunk(l), chunk(r))`` — the ``BulkExecutor`` contract —
  runs of queries sharing a chunk pay ONE copy for the run, so level-0
  bytes scale with the number of *distinct* chunks touched, not with the
  query count.  The window buffer is single-slot per side: prefetching
  ahead would be wrong exactly when reuse fires (the next query usually
  wants the chunk already resident).
* **everything above level 0 is the fused walk.**  Upper levels stay
  VMEM-resident for the launch and are merged with the same
  offset-table lookups as ``rmq_fused`` — sorting buys nothing there
  (the upper buffer is already on-chip), so the code is kept identical
  to preserve the bit-for-bit parity contract.

An *unsorted* batch stays correct — anchors then rarely repeat and every
query pays its two copies, degenerating to fused-kernel traffic — so
sortedness is a performance contract, not a safety precondition.

Tie-breaking and padding follow the shared contract: lexicographic
``(value, leftmost position)`` merges, +inf / ``PAD_POS`` tails that can
never win.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.constants import POS_INF_I32 as _POS_INF_I32
from repro.core.plan import HierarchyPlan
from repro.kernels.rmq_fused.kernel import (
    DEFAULT_QUERY_BLOCK,
    _masked_min_2d,
    _merge,
)

__all__ = ["DEFAULT_QUERY_BLOCK", "rmq_bulk_pallas"]


def _rmq_bulk_kernel(
    # scalar prefetch
    offs_ref,       # SMEM (L-1,) i32: plan.offsets (entry units)
    # inputs
    l_ref,          # SMEM (qb,) i32 — sorted by (chunk(l), chunk(r))
    r_ref,          # SMEM (qb,) i32
    base_hbm,       # ANY  (capacity,) level 0, stays in HBM
    upper_ref,      # VMEM (rows, c): all upper levels, one chunk per row
    upper_pos_ref,  # VMEM (rows, c) i32 or None (closure decides)
    # outputs
    out_ref,        # SMEM (qb,) values
    out_pos_ref,    # SMEM (qb,) i32 or None
    # scratch
    win_ref,        # VMEM (2, c) resident boundary windows [side][c]
    sems,           # DMA semaphores (2,)
    *,
    plan: HierarchyPlan,
    qb: int,
    track_pos: bool,
):
    c = plan.c
    n = plan.capacity
    num_levels = plan.num_levels

    lane = jax.lax.broadcasted_iota(jnp.int32, (1, c), 1)

    def copy(start, side):
        return pltpu.make_async_copy(
            base_hbm.at[pl.ds(start, c)], win_ref.at[side],
            sems.at[side],
        )

    def body(i, carry):
        prev_a, prev_b = carry
        l = l_ref[i]
        r = r_ref[i] + 1  # exclusive
        # same anchor formulas as the fused kernel (exclusive-r b_start),
        # so sorted runs sharing a chunk pair produce identical anchors
        a_start = jnp.clip((l // c) * c, 0, max(n - c, 0))
        b_start = jnp.clip((r // c) * c, 0, max(n - c, 0))

        # level-0 chunk reuse: only a changed anchor moves any bytes.
        # The copies are synchronous (start+wait inside the guard) — a
        # single-slot window cannot overlap copy with the previous
        # query's reads, and on a sorted batch most iterations skip the
        # copy entirely, which is the win being harvested.
        @pl.when(a_start != prev_a)
        def _load_a():
            cp = copy(a_start, 0)
            cp.start()
            cp.wait()

        @pl.when(b_start != prev_b)
        def _load_b():
            cp = copy(b_start, 1)
            cp.start()
            cp.wait()

        # ---- level 0: prefix / suffix scans over the resident windows ---
        next_l = ((l + c - 1) // c) * c
        prev_r = (r // c) * c
        idx_a = a_start + lane
        idx_b = b_start + lane
        pos_a = idx_a if track_pos else None
        pos_b = idx_b if track_pos else None
        m, p = _masked_min_2d(
            win_ref[0].reshape(1, c), idx_a, l,
            jnp.minimum(next_l, r), pos_a,
        )
        m2, p2 = _masked_min_2d(
            win_ref[1].reshape(1, c), idx_b,
            jnp.maximum(prev_r, l), r, pos_b,
        )
        m, p = _merge(m, p, m2, p2)

        l_k = (l + c - 1) // c   # ceil
        r_k = r // c             # floor

        # ---- upper levels: identical to the fused kernel ----------------
        for level in range(1, num_levels):
            off_rows = offs_ref[level - 1] // c
            padded_rows = plan.padded_lens[level - 1] // c
            is_last = level == num_levels - 1
            if is_last:
                rows = padded_rows
                vals = upper_ref[pl.ds(off_rows, rows), :]
                idx = (
                    jax.lax.broadcasted_iota(jnp.int32, (rows, c), 0) * c
                    + jax.lax.broadcasted_iota(jnp.int32, (rows, c), 1)
                )
                pos = (
                    upper_pos_ref[pl.ds(off_rows, rows), :]
                    if track_pos
                    else None
                )
                m2, p2 = _masked_min_2d(vals, idx, l_k, r_k, pos)
                m, p = _merge(m, p, m2, p2)
            else:
                a_row = jnp.clip(l_k // c, 0, padded_rows - 1)
                b_row = jnp.clip(r_k // c, 0, padded_rows - 1)
                nl = ((l_k + c - 1) // c) * c
                pr = (r_k // c) * c
                va = upper_ref[pl.ds(off_rows + a_row, 1), :]
                vb = upper_ref[pl.ds(off_rows + b_row, 1), :]
                ia = a_row * c + lane
                ib = b_row * c + lane
                pa = (
                    upper_pos_ref[pl.ds(off_rows + a_row, 1), :]
                    if track_pos
                    else None
                )
                pb = (
                    upper_pos_ref[pl.ds(off_rows + b_row, 1), :]
                    if track_pos
                    else None
                )
                m2, p2 = _masked_min_2d(va, ia, l_k, jnp.minimum(nl, r_k), pa)
                m, p = _merge(m, p, m2, p2)
                m2, p2 = _masked_min_2d(vb, ib, jnp.maximum(pr, l_k), r_k, pb)
                m, p = _merge(m, p, m2, p2)
                l_k = (l_k + c - 1) // c
                r_k = r_k // c

        out_ref[i] = m
        if track_pos:
            out_pos_ref[i] = p
        return a_start, b_start

    # anchors start at -1 so iteration 0 always copies both windows
    jax.lax.fori_loop(
        0, qb, body, (jnp.int32(-1), jnp.int32(-1))
    )


def rmq_bulk_pallas(
    base: jax.Array,
    upper2d: jax.Array,
    upper_pos2d: Optional[jax.Array],
    offsets: jax.Array,
    ls: jax.Array,
    rs: jax.Array,
    plan: HierarchyPlan,
    qb: int = DEFAULT_QUERY_BLOCK,
    track_pos: bool = False,
    interpret: bool = False,
):
    """Launch the bulk query kernel.  ``ls.shape[0]`` must divide by qb.

    Same operand layout as ``rmq_fused_pallas`` (contiguous ``(rows, c)``
    upper buffer, int32 offset table via scalar prefetch).  Returns
    ``(values, positions)``; positions are ``INT32_MAX`` unless
    ``track_pos``.  Callers are expected to pass a batch sorted by
    ``(chunk(l), chunk(r))`` — correctness does not depend on it, the
    chunk-reuse DMA savings do.
    """
    m = ls.shape[0]
    assert m % qb == 0, (m, qb)
    rows = upper2d.shape[0]
    c = plan.c

    kernel = functools.partial(
        _rmq_bulk_kernel, plan=plan, qb=qb, track_pos=track_pos
    )

    in_specs = [
        pl.BlockSpec((qb,), lambda i, offs: (i,), memory_space=pltpu.SMEM),
        pl.BlockSpec((qb,), lambda i, offs: (i,), memory_space=pltpu.SMEM),
        pl.BlockSpec(memory_space=pl.ANY),              # base stays in HBM
        pl.BlockSpec((rows, c), lambda i, offs: (0, 0)),  # upper: resident
    ]
    out_specs = [
        pl.BlockSpec((qb,), lambda i, offs: (i,), memory_space=pltpu.SMEM),
    ]
    out_shape = [jax.ShapeDtypeStruct((m,), base.dtype)]

    if track_pos:
        in_specs.append(pl.BlockSpec((rows, c), lambda i, offs: (0, 0)))
        out_specs.append(
            pl.BlockSpec((qb,), lambda i, offs: (i,),
                         memory_space=pltpu.SMEM)
        )
        out_shape.append(jax.ShapeDtypeStruct((m,), jnp.int32))
        args = (ls, rs, base, upper2d, upper_pos2d)

        def kern(offs_ref, l_ref, r_ref, base_h, up_ref, upos_ref, o_ref,
                 opos_ref, win, sems):
            kernel(offs_ref, l_ref, r_ref, base_h, up_ref, upos_ref,
                   o_ref, opos_ref, win, sems)
    else:
        args = (ls, rs, base, upper2d)

        def kern(offs_ref, l_ref, r_ref, base_h, up_ref, o_ref, win, sems):
            kernel(offs_ref, l_ref, r_ref, base_h, up_ref, None, o_ref,
                   None, win, sems)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m // qb,),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((2, c), base.dtype),   # [side][c] resident windows
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(offsets.astype(jnp.int32), *args)
    if track_pos:
        return out[0], out[1]
    return out[0], None
