"""Pure-jnp oracle for the bulk query pass.

The bulk pass changes the *execution shape* — one shared per-chunk
sparse ladder amortized over an endpoint-sorted batch instead of two
fresh chunk scans per query — not the algebra: every query still
computes the exact lexicographic (value, leftmost-position) minimum
over its range.  So the oracle delegates to the shared branch-free
reference (same policy as ``rmq_fused/ref.py``): any divergence between
``rmq_bulk`` and this oracle localizes to the ladder/interior
decomposition, not to drift in a private reference copy.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.plan import HierarchyPlan
from repro.kernels.rmq_scan.ref import rmq_branchfree_batch


def rmq_bulk_batch_ref(
    plan: HierarchyPlan,
    base: jax.Array,
    upper: jax.Array,
    upper_pos: Optional[jax.Array],
    ls: jax.Array,
    rs: jax.Array,
    track_pos: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """(values, leftmost-tie positions) for the whole batch, one pass."""
    ls = jnp.asarray(ls, jnp.int32)
    rs = jnp.asarray(rs, jnp.int32)
    return rmq_branchfree_batch(
        plan, base, upper, upper_pos, ls, rs, track_pos=track_pos
    )
