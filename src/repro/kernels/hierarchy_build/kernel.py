"""Pallas TPU kernel: one hierarchy-build level (chunked min-reduce).

Paper §4.1/§5.6: "a group of g adjacent threads reduces a chunk of c
adjacent entries via warp reductions to a single summary".  The TPU
realization tiles the level through VMEM: each program DMAs a
``(TILE_OUT * c,)`` contiguous slice HBM→VMEM, reshapes it to
``(TILE_OUT, c)`` (sublane × lane when c is a multiple of 128), and
reduces along the chunk axis on the VPU — ``TILE_OUT`` chunk reductions
per program instead of the GPU's one-warp-per-chunk.

Layout notes:
* ``c`` ≥ 128 keeps the reduction axis on lanes; the reshape is free
  because the slice is contiguous.
* ``TILE_OUT * c * 4`` bytes is the VMEM working set per program
  (default 512 * 128 * 4 = 256 KiB, well under the ~16 MiB budget, big
  enough to amortize DMA setup).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_OUT = 512


def _min_kernel(x_ref, o_ref, *, c: int, tile_out: int):
    x = x_ref[...].reshape(tile_out, c)
    o_ref[...] = jnp.min(x, axis=1)


def _argmin_kernel(x_ref, p_ref, o_ref, po_ref, *, c: int, tile_out: int):
    x = x_ref[...].reshape(tile_out, c)
    p = p_ref[...].reshape(tile_out, c)
    idx = jnp.argmin(x, axis=1)  # first occurrence == leftmost tie-break
    o_ref[...] = jnp.take_along_axis(x, idx[:, None], axis=1)[:, 0]
    po_ref[...] = jnp.take_along_axis(p, idx[:, None], axis=1)[:, 0]


@functools.partial(
    jax.jit, static_argnames=("c", "tile_out", "interpret")
)
def build_level(
    values: jax.Array,
    c: int,
    tile_out: int = DEFAULT_TILE_OUT,
    interpret: bool = False,
) -> jax.Array:
    """Reduce a (padded) level to its chunk minima: ``(m*c,) -> (m,)``.

    ``values`` must already be padded to a multiple of ``tile_out * c``
    by the caller (ops.py handles padding with +inf).
    """
    total = values.shape[0]
    assert total % (tile_out * c) == 0, (total, tile_out, c)
    grid = (total // (tile_out * c),)
    return pl.pallas_call(
        functools.partial(_min_kernel, c=c, tile_out=tile_out),
        grid=grid,
        in_specs=[pl.BlockSpec((tile_out * c,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tile_out,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((total // c,), values.dtype),
        interpret=interpret,
    )(values)


@functools.partial(
    jax.jit, static_argnames=("c", "tile_out", "interpret")
)
def build_level_with_positions(
    values: jax.Array,
    positions: jax.Array,
    c: int,
    tile_out: int = DEFAULT_TILE_OUT,
    interpret: bool = False,
):
    """Chunk-min with carried original-array positions (for RMQ_index)."""
    total = values.shape[0]
    assert total % (tile_out * c) == 0, (total, tile_out, c)
    grid = (total // (tile_out * c),)
    return pl.pallas_call(
        functools.partial(_argmin_kernel, c=c, tile_out=tile_out),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_out * c,), lambda i: (i,)),
            pl.BlockSpec((tile_out * c,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tile_out,), lambda i: (i,)),
            pl.BlockSpec((tile_out,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((total // c,), values.dtype),
            jax.ShapeDtypeStruct((total // c,), positions.dtype),
        ],
        interpret=interpret,
    )(values, positions)
