"""Jitted wrapper: build a full Hierarchy with the per-level Pallas kernel.

Produces a ``Hierarchy`` pytree bit-identical to
``repro.core.hierarchy.build_hierarchy`` (the oracle); tests assert this
across shape/dtype sweeps.

This is the historical one-launch-per-level path (L-1 launches; the glue
between levels — tile padding, slicing, the final assembly into the
contiguous ``upper`` buffer — is compiled into one XLA program around the
launches, so nothing bounces through the host).  The fused single-launch
pipeline lives in ``repro.kernels.hierarchy_fused``; keep this one for
geometries whose upper buffer exceeds the fused kernel's VMEM budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.constants import PAD_POS as _PAD_POS
from repro.core.hierarchy import Hierarchy, _pad_to, pos_dtype_for
from repro.core.plan import HierarchyPlan
from repro.kernels import profiling
from repro.kernels.hierarchy_build import kernel as K


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick_tile_out(padded_len: int, c: int) -> int:
    """Largest power-of-two tile (<= default) dividing the level."""
    m = padded_len // c
    tile = K.DEFAULT_TILE_OUT
    while tile > 1 and m % tile != 0:
        tile //= 2
    return tile


@functools.partial(
    jax.jit, static_argnames=("plan", "with_positions", "interpret")
)
def _build_jit(x, plan, with_positions, interpret):
    c = plan.c
    cap = plan.capacity
    pos_dtype = pos_dtype_for(cap) if with_positions else None
    inf = jnp.array(jnp.inf, dtype=x.dtype)

    levels_v, levels_p = [], []
    base = _pad_to(x, cap, inf)
    cur_v = base
    cur_p = jnp.arange(cap, dtype=pos_dtype) if with_positions else None

    for k in range(1, plan.num_levels):
        # consume ceil(len/c)*c entries, then tile-align for the kernel
        want = plan.level_lens[k] * c
        tile = _pick_tile_out(want, c)
        want_aligned = -(-want // (tile * c)) * (tile * c)
        v_in = _pad_to(cur_v, want_aligned, inf)
        profiling.record_launch(
            "hierarchy_build",
            lowering="pallas",
            level=k,
            grid=int(want_aligned // (tile * c)),
            with_positions=bool(with_positions),
            operand_bytes=profiling.operand_bytes(v_in),
        )
        if with_positions:
            p_in = _pad_to(cur_p, want_aligned, jnp.array(_PAD_POS, pos_dtype))
            nxt_v, nxt_p = K.build_level_with_positions(
                v_in, p_in, c=c, tile_out=tile, interpret=interpret
            )
            nxt_v = nxt_v[: plan.level_lens[k]]
            nxt_p = nxt_p[: plan.level_lens[k]]
        else:
            nxt_v = K.build_level(
                v_in, c=c, tile_out=tile, interpret=interpret
            )[: plan.level_lens[k]]
            nxt_p = None

        padded_len = plan.padded_lens[k - 1]
        levels_v.append(_pad_to(nxt_v, padded_len, inf))
        if with_positions:
            levels_p.append(
                _pad_to(nxt_p, padded_len, jnp.array(_PAD_POS, pos_dtype))
            )
        cur_v = nxt_v
        cur_p = nxt_p

    if levels_v:
        upper = jnp.concatenate(levels_v)
        upper_pos = jnp.concatenate(levels_p) if with_positions else None
    else:
        upper = jnp.zeros((0,), dtype=x.dtype)
        upper_pos = (
            jnp.zeros((0,), dtype=pos_dtype) if with_positions else None
        )
    return Hierarchy(base=base, upper=upper, upper_pos=upper_pos, plan=plan)


def build_hierarchy_pallas(
    x: jax.Array,
    plan: HierarchyPlan,
    with_positions: bool = False,
    interpret: bool | None = None,
) -> Hierarchy:
    """Level-by-level Pallas build (paper §4.1, bottom-up)."""
    if interpret is None:
        interpret = not _on_tpu()
    return _build_jit(jnp.asarray(x), plan, with_positions, interpret)
