"""Pure-jnp oracle for the hierarchy-build kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def build_level_ref(values: jax.Array, c: int) -> jax.Array:
    """Chunk minima of a level already padded to a multiple of c."""
    assert values.shape[0] % c == 0
    return values.reshape(-1, c).min(axis=1)


def build_level_with_positions_ref(values, positions, c: int):
    assert values.shape[0] % c == 0
    v = values.reshape(-1, c)
    p = positions.reshape(-1, c)
    idx = jnp.argmin(v, axis=1)
    return (
        jnp.take_along_axis(v, idx[:, None], axis=1)[:, 0],
        jnp.take_along_axis(p, idx[:, None], axis=1)[:, 0],
    )
