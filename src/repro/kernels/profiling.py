"""Trace-time kernel-launch accounting.

The fused construction pipeline's contract is *one* Pallas launch per
build (vs. one per level on the historical path).  That claim is easy to
bit-rot silently — a refactor that quietly adds a second ``pallas_call``
still produces correct values.  This module makes it assertable: each
kernel wrapper calls :func:`record_launch` from *inside its traced body*,
so tracing a build records exactly as many launches as the compiled
program will issue per call.

Because jitted functions trace once per (shape, static-args)
specialization, launches are only recorded the first time a given
geometry is traced — wrap the *first* build of a fresh geometry in
:func:`count_launches`:

    with count_launches() as counts:
        build_hierarchy_fused(x, plan)          # first call for this plan
    assert counts == {"hierarchy_fused": 1}

Outside a :func:`count_launches` scope, :func:`record_launch` is a no-op,
so production builds pay nothing.
"""

from __future__ import annotations

import contextlib
from typing import Dict, Iterator, Optional

__all__ = ["count_launches", "record_launch"]

_counts: Optional[Dict[str, int]] = None


def record_launch(name: str) -> None:
    """Record one kernel launch under ``name`` (no-op when not counting)."""
    if _counts is not None:
        _counts[name] = _counts.get(name, 0) + 1


@contextlib.contextmanager
def count_launches() -> Iterator[Dict[str, int]]:
    """Collect ``{kernel name: launches}`` recorded while tracing inside."""
    global _counts
    prev = _counts
    _counts = {}
    try:
        yield _counts
    finally:
        _counts = prev
