"""Trace-time kernel-launch accounting and the launch/cost registry.

The fused construction pipeline's contract is *one* Pallas launch per
build (vs. one per level on the historical path).  That claim is easy to
bit-rot silently — a refactor that quietly adds a second ``pallas_call``
still produces correct values.  This module makes it assertable: each
kernel wrapper calls :func:`record_launch` from *inside its traced body*,
so tracing a build records exactly as many launches as the compiled
program will issue per call.

Because jitted functions trace once per (shape, static-args)
specialization, launches are only recorded the first time a given
geometry is traced — wrap the *first* build of a fresh geometry in
:func:`count_launches`:

    with count_launches() as counts:
        build_hierarchy_fused(x, plan)          # first call for this plan
    assert counts == {"hierarchy_fused": 1}

Outside a :func:`count_launches` scope, :func:`record_launch` is a no-op,
so production builds pay nothing.

Two richer layers stack on the same recording sites without changing the
:func:`count_launches` contract:

* :func:`launch_registry` collects :class:`LaunchRecord`\\ s — kernel
  name plus whatever static metadata the wrapper knows at trace time
  (grid/level count, operand bytes, query count).  Wrappers pass these
  as keyword arguments to :func:`record_launch`; when only the plain
  counter is active the kwargs are ignored.
* ``launch_registry(timing=True)`` additionally makes
  :func:`timed_dispatch` time dispatch sites wall-clock (with a
  ``jax.block_until_ready`` barrier, imported lazily so this module
  stays jax-free when idle).  Timing records are *per call*, unlike
  trace-time launch records which are per specialization — the registry
  keeps them in separate tables.

FLOP/byte *estimates* from the compiler are a property of a compiled
artifact, not of a traced body, so they attach separately:
:meth:`LaunchRegistry.attach_cost` accepts any object with an AOT
``cost_analysis`` (normalized via ``repro.compat.cost_analysis_dict``)
and files the estimate under the kernel name.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
import time
from typing import Any, Dict, Iterator, List, Optional

__all__ = [
    "LaunchRecord",
    "LaunchRegistry",
    "count_launches",
    "launch_registry",
    "operand_bytes",
    "record_config",
    "record_launch",
    "timed_dispatch",
]


def operand_bytes(*arrays) -> int:
    """Total byte footprint of the given operands, from static shape/dtype.

    Safe to call on tracers inside a jitted body — only ``.shape`` and
    ``.dtype`` are touched, both static.  ``None`` operands (optional
    position planes) are skipped.
    """
    total = 0
    for a in arrays:
        if a is None:
            continue
        total += math.prod(a.shape) * a.dtype.itemsize
    return int(total)

_counts: Optional[Dict[str, int]] = None
_registry: Optional["LaunchRegistry"] = None


@dataclasses.dataclass
class LaunchRecord:
    """One recorded kernel launch (trace-time) with static metadata."""

    name: str
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        return {"name": self.name, **self.meta}


class LaunchRegistry:
    """Thread-safe collection of launch records, timings, and cost
    estimates, keyed by kernel name."""

    def __init__(self, timing: bool = False):
        self._lock = threading.Lock()
        self.timing = bool(timing)
        self.records: List[LaunchRecord] = []
        self.timings: Dict[str, List[float]] = {}
        self.costs: Dict[str, Dict[str, float]] = {}
        self.configs: List[LaunchRecord] = []

    # -- recording ---------------------------------------------------------
    def add(self, name: str, meta: Dict[str, Any]) -> None:
        with self._lock:
            self.records.append(LaunchRecord(name, dict(meta)))

    def add_config(self, name: str, meta: Dict[str, Any]) -> None:
        """File a configuration decision (e.g. an engine adopting a tuned
        geometry).  Configs live in their own table: they are *not*
        launches and never reach :func:`count_launches` counts or the
        per-kernel launch views."""
        with self._lock:
            self.configs.append(LaunchRecord(name, dict(meta)))

    def add_timing(self, name: str, seconds: float) -> None:
        with self._lock:
            self.timings.setdefault(name, []).append(float(seconds))

    def attach_cost(self, name: str, compiled: Any) -> Dict[str, float]:
        """File the compiler's FLOP/byte estimate for ``name``.

        ``compiled`` is anything exposing AOT ``cost_analysis()`` (a
        ``jax.stages.Compiled``); the result is normalized through
        ``repro.compat.cost_analysis_dict`` and reduced to the scalar
        entries (``flops``, ``bytes accessed``, ...).
        """
        from repro.compat import cost_analysis_dict

        raw = cost_analysis_dict(compiled) or {}
        cost = {k: float(v) for k, v in raw.items()
                if isinstance(v, (int, float))}
        with self._lock:
            self.costs[name] = cost
        return cost

    # -- views -------------------------------------------------------------
    @property
    def counts(self) -> Dict[str, int]:
        """``{kernel name: launch count}`` over the recorded launches."""
        out: Dict[str, int] = {}
        with self._lock:
            for rec in self.records:
                out[rec.name] = out.get(rec.name, 0) + 1
        return out

    def operand_bytes(self) -> Dict[str, int]:
        """Total trace-time ``operand_bytes`` attributed per kernel."""
        out: Dict[str, int] = {}
        with self._lock:
            for rec in self.records:
                b = rec.meta.get("operand_bytes")
                if b is not None:
                    out[rec.name] = out.get(rec.name, 0) + int(b)
        return out

    def as_dict(self) -> dict:
        with self._lock:
            records = [r.as_dict() for r in self.records]
            timings = {k: list(v) for k, v in self.timings.items()}
            costs = {k: dict(v) for k, v in self.costs.items()}
            configs = [r.as_dict() for r in self.configs]
        counts: Dict[str, int] = {}
        for r in records:
            counts[r["name"]] = counts.get(r["name"], 0) + 1
        out: dict = {"counts": counts, "launches": records}
        if configs:
            out["configs"] = configs
        if timings:
            out["timings_s"] = {
                k: {"calls": len(v), "total": sum(v),
                    "mean": sum(v) / len(v), "max": max(v)}
                for k, v in timings.items()
            }
        if costs:
            out["cost_estimates"] = costs
        return out


def record_launch(name: str, **meta: Any) -> None:
    """Record one kernel launch under ``name`` (no-op when not counting).

    Called from inside jitted traced bodies; ``meta`` carries static,
    trace-time facts only (level counts, operand bytes computed from
    ``.shape``/``.dtype`` — never traced values).  The plain counter
    contract is unchanged: under :func:`count_launches`, ``meta`` is
    ignored and only the count increments.
    """
    if _counts is not None:
        _counts[name] = _counts.get(name, 0) + 1
    if _registry is not None:
        _registry.add(name, meta)


def record_config(name: str, **meta: Any) -> None:
    """Record a configuration decision (no-op when no registry is active).

    Unlike :func:`record_launch` this NEVER touches the plain launch
    counter — :func:`count_launches` results stay byte-identical whether
    or not engines record their tuned configs — and only feeds an active
    :func:`launch_registry`'s ``configs`` table.
    """
    if _registry is not None:
        _registry.add_config(name, meta)


@contextlib.contextmanager
def count_launches() -> Iterator[Dict[str, int]]:
    """Collect ``{kernel name: launches}`` recorded while tracing inside."""
    global _counts
    prev = _counts
    _counts = {}
    try:
        yield _counts
    finally:
        _counts = prev


@contextlib.contextmanager
def launch_registry(timing: bool = False) -> Iterator[LaunchRegistry]:
    """Collect full :class:`LaunchRecord`\\ s (and, with ``timing=True``,
    wall-clock dispatch timings via :func:`timed_dispatch`) for the
    duration of the block."""
    global _registry
    prev = _registry
    reg = LaunchRegistry(timing=timing)
    _registry = reg
    try:
        yield reg
    finally:
        _registry = prev


def current_registry() -> Optional["LaunchRegistry"]:
    return _registry


def timed_dispatch(name: str, fn, *args, **kwargs):
    """Call ``fn(*args, **kwargs)``; when a timing-enabled registry is
    active, record wall time to completion (``jax.block_until_ready`` on
    the result, so device work is included, not just dispatch).

    When no registry is active — the production default — this is one
    global load and a tail call: no timers, no barriers.  The barrier is
    the point *and* the cost: enabling timing serializes dispatch sites,
    so it is strictly an offline profiling mode.
    """
    reg = _registry
    if reg is None or not reg.timing:
        return fn(*args, **kwargs)
    import jax

    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(*args, **kwargs))
    reg.add_timing(name, time.perf_counter() - t0)
    return out
