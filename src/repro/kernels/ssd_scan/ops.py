"""Dispatching wrapper for the SSD chunk scan."""

from __future__ import annotations

import jax

from repro.kernels.ssd_scan import kernel as K
from repro.kernels.ssd_scan.ref import ssd_chunked_ref, ssd_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssd(dtx, log_a, Bm, Cm, chunk: int = 128, impl: str = "auto",
        interpret: bool = False, init_state=None):
    """Returns y (and discards final state on the kernel path).

    impl: 'auto' | 'ref' | 'chunked_ref' | 'pallas'.
    Use ``ssd_with_state`` when the final state is needed (serving).
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "chunked_ref"
    if impl == "pallas" and dtx.shape[1] % chunk == 0 and init_state is None:
        return K.ssd_scan(dtx, log_a, Bm, Cm, chunk=chunk,
                          interpret=interpret)
    if impl == "ref":
        return ssd_ref(dtx, log_a, Bm, Cm, init_state=init_state)[0]
    return ssd_chunked_ref(dtx, log_a, Bm, Cm, chunk=min(chunk, dtx.shape[1]),
                           init_state=init_state)[0]


def ssd_with_state(dtx, log_a, Bm, Cm, chunk: int = 128, init_state=None):
    """Chunked-ref path returning (y, final_state) — used by serving."""
    return ssd_chunked_ref(
        dtx, log_a, Bm, Cm, chunk=min(chunk, dtx.shape[1]),
        init_state=init_state,
    )
