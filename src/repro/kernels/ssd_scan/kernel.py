"""Pallas TPU kernel: Mamba-2 SSD chunk scan.

Framework hot-spot kernel for the ``mamba2-1.3b`` / ``hymba-1.5b`` archs.
Grid ``(B, H, num_chunks)`` with the chunk dimension innermost: TPU grids
execute sequentially, so a VMEM scratch carries the (P, N) state across
chunk steps — the inter-chunk recurrence — while the intra-chunk part is
two MXU matmuls on (Q, N)/(Q, P) tiles.  Q = N = 128 keeps every matmul
MXU-aligned; P = head_dim (64) rides the sublane dim.

All math in f32; see ref.ssd_chunked_ref for the einsum form this kernel
tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssd_kernel(dtx_ref, la_ref, b_ref, c_ref, y_ref, state_scr, *,
                q: int, p: int, n: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _reset():
        state_scr[...] = jnp.zeros_like(state_scr)

    dtx = dtx_ref[...].reshape(q, p).astype(jnp.float32)
    la = la_ref[...].reshape(q, 1).astype(jnp.float32)
    bm = b_ref[...].reshape(q, n).astype(jnp.float32)
    cm = c_ref[...].reshape(q, n).astype(jnp.float32)

    cum = jnp.cumsum(la, axis=0)            # (Q, 1)
    total = cum[q - 1, 0]

    # intra-chunk: M[i, j] = exp(cum_i - cum_j) * (C_i · B_j), j <= i
    g = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                        # (Q, Q)
    diff = cum - cum.reshape(1, q)           # (Q, Q) broadcast
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tril = col <= row
    decay = jnp.exp(jnp.where(tril, diff, -jnp.inf))
    m = g * decay
    y = jax.lax.dot_general(
        m, dtx, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                        # (Q, P)

    # inter-chunk: y += exp(cum_i) * C_i @ state^T      state: (P, N)
    state = state_scr[...]
    y += jnp.exp(cum) * jax.lax.dot_general(
        cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                        # (Q, N) @ (P, N)^T -> (Q, P)

    # state update: S = exp(total) * S + (w * dtx)^T @ B
    w = jnp.exp(total - cum)                 # (Q, 1)
    state_scr[...] = jnp.exp(total) * state + jax.lax.dot_general(
        w * dtx, bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                        # (P, N)

    y_ref[...] = y.reshape(y_ref.shape).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    dtx: jax.Array,    # (B, L, H, P)
    log_a: jax.Array,  # (B, L, H)
    Bm: jax.Array,     # (B, L, N)
    Cm: jax.Array,     # (B, L, N)
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> jax.Array:
    b, l, h, p = dtx.shape
    n = Bm.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    q = chunk

    kernel = functools.partial(_ssd_kernel, q=q, p=p, n=n)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, q, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, q, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, q, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, q, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(dtx.shape, dtx.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(dtx, log_a, Bm, Cm)
