"""Pure-jnp oracles for the SSD (state-space duality) chunk scan.

Two references:

* ``ssd_ref`` — the literal per-timestep recurrence (slow, unambiguous):
      S_t = exp(log_a_t) * S_{t-1} + dtx_t ⊗ B_t
      y_t = S_t @ C_t
* ``ssd_chunked_ref`` — the chunked SSD algorithm in plain jnp (einsum
  form).  This is the CPU / dry-run production path for Mamba-2 style
  layers and the direct oracle for the Pallas kernel, which computes the
  same chunk algebra tile-by-tile in VMEM.

Shapes (ngroups = 1, B/C shared across heads — Mamba-2 default):
  dtx:   (B, L, H, P)   dt-scaled inputs  (dt * x)
  log_a: (B, L, H)      per-step log decay (<= 0), already dt-scaled
  Bm:    (B, L, N)      input projection onto state
  Cm:    (B, L, N)      output projection from state
  y:     (B, L, H, P)
  state: (B, H, P, N)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(dtx, log_a, Bm, Cm, init_state=None):
    """Naive recurrence via lax.scan. Returns (y, final_state)."""
    b, l, h, p = dtx.shape
    n = Bm.shape[-1]
    f32 = jnp.float32
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), f32)

    def step(s, inputs):
        dtx_t, la_t, b_t, c_t = inputs  # (B,H,P), (B,H), (B,N), (B,N)
        a = jnp.exp(la_t)[:, :, None, None]            # (B,H,1,1)
        s = a * s + dtx_t[..., None] * b_t[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", s, c_t)
        return s, y

    xs = (
        dtx.astype(f32).transpose(1, 0, 2, 3),
        log_a.astype(f32).transpose(1, 0, 2),
        Bm.astype(f32).transpose(1, 0, 2),
        Cm.astype(f32).transpose(1, 0, 2),
    )
    final, ys = jax.lax.scan(step, init_state, xs)
    return ys.transpose(1, 0, 2, 3).astype(dtx.dtype), final


def ssd_chunked_ref(dtx, log_a, Bm, Cm, chunk: int = 128, init_state=None):
    """Chunked SSD: intra-chunk quadratic part + inter-chunk state pass.

    Identical math to ``ssd_ref``; O(L/Q) sequential steps instead of O(L).
    """
    b, l, h, p = dtx.shape
    n = Bm.shape[-1]
    assert l % chunk == 0, (l, chunk)
    q = chunk
    nc = l // q
    f32 = jnp.float32

    dtx_c = dtx.astype(f32).reshape(b, nc, q, h, p)
    la_c = log_a.astype(f32).reshape(b, nc, q, h)
    B_c = Bm.astype(f32).reshape(b, nc, q, n)
    C_c = Cm.astype(f32).reshape(b, nc, q, n)

    cum = jnp.cumsum(la_c, axis=2)                    # (B,NC,Q,H)
    total = cum[:, :, -1, :]                          # (B,NC,H)

    # ---- intra-chunk (the "duality" matmul form) ------------------------
    g = jnp.einsum("bcin,bcjn->bcij", C_c, B_c)       # (B,NC,Q,Q)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,NC,Q,Q,H)
    tril = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.exp(jnp.where(tril[None, None, :, :, None], diff, -jnp.inf))
    m = g[..., None] * decay                          # (B,NC,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m, dtx_c)

    # ---- inter-chunk: carry states sequentially -------------------------
    # state contribution of chunk c: Z_c = sum_j exp(total - cum_j) dtx_j ⊗ B_j
    w = jnp.exp(total[:, :, None, :] - cum)           # (B,NC,Q,H)
    z = jnp.einsum("bcjh,bcjhp,bcjn->bchpn", w, dtx_c, B_c)

    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), f32)

    def carry(s, inputs):
        z_c, tot_c = inputs                           # (B,H,P,N), (B,H)
        s_in = s
        s = jnp.exp(tot_c)[:, :, None, None] * s + z_c
        return s, s_in

    final, s_prev = jax.lax.scan(
        carry,
        init_state,
        (z.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
    )
    s_prev = s_prev.transpose(1, 0, 2, 3, 4)          # (B,NC,H,P,N)

    y_inter = jnp.einsum(
        "bcih,bcin,bchpn->bcihp",
        jnp.exp(cum), C_c, s_prev,
    )

    y = (y_intra + y_inter).reshape(b, l, h, p).astype(dtx.dtype)
    return y, final
