"""Jitted wrappers for the short-span RMQ kernel.

Handles query-batch padding to the query block and backend fallbacks:
degenerate geometries (``capacity < 2c``) use the pure-jnp ref, which is
also the production path on non-TPU backends.

Contract (both backends): every query must satisfy the engine planner's
SHORT predicate ``r // c - l // c <= 1`` — the answer for wider queries
would silently miss entries, so the engine owns the routing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.hierarchy import Hierarchy
from repro.kernels import profiling
from repro.kernels.rmq_short import kernel as K
from repro.kernels.rmq_short.ref import rmq_short_batch_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _kernel_applicable(h: Hierarchy) -> bool:
    return h.plan.capacity >= 2 * h.plan.c


@functools.partial(
    jax.jit, static_argnames=("plan", "qb", "track_pos", "interpret")
)
def _run(base, ls, rs, plan, qb, track_pos, interpret):
    m = ls.shape[0]
    m_pad = -(-m // qb) * qb
    profiling.record_launch(
        "rmq_short",
        lowering="pallas",
        queries=int(m),
        grid=int(m_pad // qb),
        track_pos=bool(track_pos),
        operand_bytes=profiling.operand_bytes(base, ls, rs),
    )
    if m_pad != m:
        ls = jnp.pad(ls, (0, m_pad - m))
        rs = jnp.pad(rs, (0, m_pad - m))
    vals, pos = K.rmq_short_pallas(
        base,
        ls.astype(jnp.int32),
        rs.astype(jnp.int32),
        plan,
        qb=qb,
        track_pos=track_pos,
        interpret=interpret,
    )
    if track_pos:
        return vals[:m], pos[:m]
    return vals[:m], None


def rmq_short_value_batch(h: Hierarchy, ls, rs) -> jax.Array:
    """Pure-JAX short-span values (the non-TPU production path)."""
    vals, _ = rmq_short_batch_ref(
        h.base, ls, rs, h.plan.c, h.plan.capacity, track_pos=False
    )
    return vals


def rmq_short_index_batch(h: Hierarchy, ls, rs) -> jax.Array:
    """Pure-JAX short-span leftmost-minimum positions.

    Works on value-only builds: level 0 positions are the indices
    themselves.
    """
    _, pos = rmq_short_batch_ref(
        h.base, ls, rs, h.plan.c, h.plan.capacity, track_pos=True
    )
    return pos


def rmq_short_value_batch_pallas(
    h: Hierarchy,
    ls: jax.Array,
    rs: jax.Array,
    qb: int = K.DEFAULT_QUERY_BLOCK,
    interpret: bool | None = None,
) -> jax.Array:
    if not _kernel_applicable(h):
        return rmq_short_value_batch(h, ls, rs)
    if interpret is None:
        interpret = not _on_tpu()
    vals, _ = _run(
        h.base, jnp.asarray(ls), jnp.asarray(rs), h.plan, qb, False,
        interpret,
    )
    return vals


def rmq_short_index_batch_pallas(
    h: Hierarchy,
    ls: jax.Array,
    rs: jax.Array,
    qb: int = K.DEFAULT_QUERY_BLOCK,
    interpret: bool | None = None,
) -> jax.Array:
    if not _kernel_applicable(h):
        return rmq_short_index_batch(h, ls, rs)
    if interpret is None:
        interpret = not _on_tpu()
    _, pos = _run(
        h.base, jnp.asarray(ls), jnp.asarray(rs), h.plan, qb, True,
        interpret,
    )
    return pos
