"""Pure-jnp oracle for the short-span RMQ kernel.

Short-span contract (enforced by the query-engine planner, checked here
only in the docstring): the query's level-0 footprint spans at most two
aligned ``c``-chunks, i.e. ``r // c - l // c <= 1``.  Such a query is
fully covered by the ``2c`` window starting at ``floor(l / c) * c``, so
it never needs the hierarchy at all: one masked scan of (at most) two
chunks answers it, and — because level 0 *is* the original array — the
leftmost-minimum position is just the window index, no ``upper_pos``
required.

This is the engine's fast path for the paper's "small" range class
(§5.1, Fig. 16): the full walk costs ``2c(L-1) + ct`` scanned entries on
every query regardless of span; a two-chunk query pays ``2c``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.constants import POS_INF_I32 as _POS_INF_I32


@functools.partial(jax.jit, static_argnames=("c", "capacity", "track_pos"))
def rmq_short_batch_ref(base, ls, rs, c: int, capacity: int,
                        track_pos: bool = False):
    """(values, positions) for a batch of two-chunk queries.

    ``base`` is the level-0 array stored at ``capacity`` length (+inf
    padded past the live region).  Positions are INT32_MAX when
    ``track_pos=False``.
    """
    w = min(2 * c, capacity)

    def one(l, r):
        l = l.astype(jnp.int32)
        r = r.astype(jnp.int32)
        anchor = jnp.clip((l // c) * c, 0, max(capacity - w, 0))
        vals = jax.lax.dynamic_slice(base, (anchor,), (w,))
        idx = anchor + jnp.arange(w, dtype=jnp.int32)
        mask = (idx >= l) & (idx <= r)
        masked = jnp.where(mask, vals, jnp.inf)
        m = jnp.min(masked)
        if not track_pos:
            return m, jnp.int32(_POS_INF_I32)
        cand = jnp.where(mask & (masked == m), idx, _POS_INF_I32)
        return m, jnp.min(cand)

    return jax.vmap(one)(jnp.asarray(ls), jnp.asarray(rs))
